"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

  PYTHONPATH=src python scripts/roofline_report.py [--mesh single|multi|all]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_s(s: float) -> str:
    if s == 0:
        return "0"
    if s < 0.1:
        return f"{s * 1e3:.1f}ms"
    return f"{s:.2f}s"


def load(out_dir: str) -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows: list[dict], mesh: str) -> str:
    out = ["| cell | mesh | kind | compute | memory | collective | dominant "
           "| bound | frac | useful | HBM/chip | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if not r.get("ok"):
            out.append(f"| {r['cell']} | {r['mesh']} | {r.get('kind','?')} "
                       f"| FAIL: {r.get('error','')[:60]} ||||||||||")
            continue
        if mesh != "all" and r["mesh"] != mesh:
            continue
        bound = max(r["t_compute"], r["t_memory"], r["t_collective"])
        frac = r["t_compute"] / bound if bound else 0.0
        out.append(
            f"| {r['cell']} | {r['mesh']} | {r['kind']} "
            f"| {fmt_s(r['t_compute'])} | {fmt_s(r['t_memory'])} "
            f"| {fmt_s(r['t_collective'])} | {r['dominant']} "
            f"| {fmt_s(bound)} | {frac:.3f} | {r['useful_ratio']:.2f} "
            f"| {r['bytes_per_device'] / 1e9:.1f}GB "
            f"| {'yes' if r.get('fits_hbm') else 'NO'} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--dir", default="artifacts/dryrun")
    args = ap.parse_args()
    print(table(load(args.dir), args.mesh))


if __name__ == "__main__":
    main()
