#!/usr/bin/env bash
# Tier-1 gate + streaming/flat equivalence smoke build.
#
# Usage: scripts/check.sh            (from the repo root)
#
# 0. runs the static contract checker (python -m repro.analysis.lint):
#    kernel VMEM/tiling/coverage/oracle contracts, jaxpr hot-path +
#    donation + recompilation audits, AST jit hygiene — fail-fast with a
#    per-finding file:line report before any test spins up
# 0b. runs the SPMD sharding auditor (lint --pass spmd) in its own
#    process under 8 forced host devices: collective whitelist,
#    replication audit, halo/HBM footprint pricing, host-transfer
#    budget, mesh-shape stability (PIPS001-005)
# 0c. runs the memory-bound auditor (lint --pass memory) in its own
#    process under 8 forced host devices: AOT-compiled byte ledgers over
#    a shape lattice per registered program — scaling-exponent bounds,
#    donation crediting, workspace models, BigANN-1B envelope pricing
#    against PIPNN_DEVICE_HBM_BUDGET, and the memory_envelope.json
#    regression gate (PIPM001-006)
# 1. runs the tier-1 test command (PYTHONPATH=src python -m pytest -x -q)
# 2. re-runs the partition-invariant + degenerate-data regression suite
#    standalone (fast; it is also part of tier-1)
# 3. runs a ~30 s smoke build (n=2000, d=32) through the streaming
#    device-resident path (segmented + flat-merge folds) and the O(E) flat
#    oracle path and asserts the produced graphs are bit-identical, with
#    streaming peak candidate-edge memory bounded by the chunk size; also
#    smokes the streaming robust_prune leaf method against its flat oracle
# 4. smokes the fully-static Stage-1 (ball_carve_device) end to end: its
#    build's recall must be at parity with the recursive RBC baseline
#    (device-vs-host ball_carve bit-identity is covered by the partition
#    suite in step 2)
# 5. QPS smoke: the device-resident multi-expansion serving path must have
#    a recall>=0.9 operating point, reach >= 2x the legacy single-expansion
#    engine's QPS there, stay at recall parity with the beam_search_np
#    pointer-chasing oracle, and the run is appended to BENCH_qps.json;
#    the int8 scalar-quantized serving path (pipnn.search(dtype="int8"))
#    must stay within 0.02 recall of f32 serving at the same operating
#    point (serve_i8 row appended too), and on a BigANN-shaped packing
#    (d=128, R=16) the int8 ServingIndex footprint must be <= ~1/3 of f32
# 6. sharded-serving recall-parity gate: on 8 forced host devices
#    (XLA_FLAGS=--xla_force_host_platform_device_count=8) the mesh-sharded
#    serving path (halo shards + shard_map search + cross-shard merge)
#    must stay within 0.01 recall of single-device serving, f32 AND int8,
#    and the S=1 mesh must match single-device ids exactly
# 7. resilient serving-loop gate (8 forced host devices): Poisson smoke
#    load must finish with zero timeouts and p99 under the deadline; the
#    straggler drain must return bit-identical ids for converged queries
#    AND beat single-phase latency for them; the Issue-9 fault drill
#    (1 of 8 shards killed mid-run, 5% NaN queries, one straggling shard)
#    must complete every request with zero unhandled errors, structured
#    errors on exactly the poisoned rows, degraded recall >= 0.85x
#    healthy, and the dead shard re-admitted by the probe loop
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== static contract checker (repro.analysis.lint) =="
# kernel VMEM/tiling/coverage/oracle contracts + jaxpr hot-path, donation
# and recompilation audits + AST jit hygiene; findings print as
# "file:line: RULE [symbol] message" (see README "Static analysis").
# Fails fast BEFORE the test suite: a contract violation here would
# otherwise surface as a slow test failure or a TPU-only OOM.
if ! python -m repro.analysis.lint --pass ast --pass kernels --pass jaxpr; then
  echo ""
  echo "lint FAILED: fix the findings above (rule catalog:"
  echo "  python -m repro.analysis.lint --list-rules)."
  echo "The baseline (src/repro/analysis/baseline.txt) stays empty —"
  echo "baselining is only for genuinely unfixable findings."
  exit 1
fi

echo "== SPMD sharding auditor (lint --pass spmd, 8 simulated devices) =="
# separate process: the forced-device flag must land before jax
# initializes so the auditor gets its full S in {1,2,4,8} mesh sweep
if ! XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
     python -m repro.analysis.lint --pass spmd; then
  echo ""
  echo "SPMD audit FAILED: a shard_map program broke its declared"
  echo "sharding contract (PIPS001-005; see README 'Static analysis')."
  echo "Contracts are registered in src/repro/analysis/spmd_audit.py."
  exit 1
fi

echo "== memory-bound auditor (lint --pass memory, 8 simulated devices) =="
# separate process: forced devices give the sharded-search program a
# real mesh for its compiled byte ledger; everything else is per-device
if ! XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
     python -m repro.analysis.lint --pass memory; then
  echo ""
  echo "memory audit FAILED: a hot-path program broke its bounded-memory"
  echo "contract (PIPM001-006; see README 'Static analysis'). After an"
  echo "INTENTIONAL memory change, regenerate the envelope with:"
  echo "  python -m repro.analysis.memory_audit --write-envelope"
  exit 1
fi

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== partition invariants + degenerate-data regressions =="
python -m pytest -q tests/test_partitioners.py

echo "== smoke: streaming vs flat build (n=2000, d=32) =="
python - <<'EOF'
import numpy as np

from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

rng = np.random.default_rng(0)
x = rng.standard_normal((2000, 32)).astype(np.float32)
for metric in ("l2", "mips"):
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2, leaf_chunk=8, stream_chunk=8),
                    l_max=32, max_deg=16, metric=metric, seed=1)
    i_s = pipnn.build(x, p, streaming=True)                  # segmented fold
    i_m = pipnn.build(x, p.with_(merge="flat"), streaming=True)
    i_f = pipnn.build(x, p, streaming=False)                 # O(E) oracle
    np.testing.assert_array_equal(i_s.graph, i_f.graph)
    np.testing.assert_array_equal(i_s.dists, i_f.dists)
    np.testing.assert_array_equal(i_m.graph, i_f.graph)
    bound = 2 * 8 * p.rbc.c_max * p.leaf.k * 16
    assert i_s.stats["peak_edge_bytes"] == bound, i_s.stats
    assert i_s.stats["peak_edge_bytes"] < i_f.stats["peak_edge_bytes"]
    print(f"  {metric}: identical graphs (segmented + flat-merge folds); "
          f"peak bytes streaming={i_s.stats['peak_edge_bytes']} "
          f"flat={i_f.stats['peak_edge_bytes']}")

# streaming robust_prune leaf method vs its flat oracle
p = PiPNNParams(rbc=RBCParams(c_max=64, c_min=8, fanout=(3,)),
                leaf=LeafParams(method="robust_prune", leaf_chunk=4,
                                alpha=1.2, max_deg=8),
                l_max=32, max_deg=16, seed=1)
i_s = pipnn.build(x[:800], p, streaming=True)
i_f = pipnn.build(x[:800], p, streaming=False)
assert i_s.stats["streaming"] and not i_f.stats["streaming"]
np.testing.assert_array_equal(i_s.graph, i_f.graph)
print("  robust_prune leaf: streaming identical to flat oracle")
print("smoke OK")
EOF

echo "== smoke: Stage-1 static partitioner recall parity =="
# (device-vs-host ball_carve bit-identity runs in tests/test_partitioners.py)
python - <<'EOF'
import numpy as np

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

rng = np.random.default_rng(0)
x = rng.standard_normal((2000, 32)).astype(np.float32)
q = x[:64] + 0.01 * rng.standard_normal((64, 32)).astype(np.float32)
truth = brute_force_knn(x, q, 10)
recalls = {}
for execution in ("host", "static"):
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2),
                                  execution=execution),
                    leaf=LeafParams(k=2), l_max=32, max_deg=16, seed=1)
    idx = pipnn.build(x, p, streaming=True)
    found = pipnn.search(idx, x, q, k=10, beam=64)
    recalls[execution] = recall_at_k(found, truth, 10)
print(f"  recall: rbc={recalls['host']:.3f} static={recalls['static']:.3f}")
assert recalls["static"] >= recalls["host"] - 0.03, recalls
print("stage-1 smoke OK")
EOF

echo "== smoke: serving QPS (multi-expansion vs legacy single-expansion) =="
python - <<'EOF'
import numpy as np
import jax.numpy as jnp

from benchmarks.common import BENCH_QPS_JSON, append_bench_json, timed
from repro.core import pipnn
from repro.core import beam_search as bs
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex

rng = np.random.default_rng(0)
x = rng.standard_normal((2000, 32)).astype(np.float32)
q = x[:128] + 0.01 * rng.standard_normal((128, 32)).astype(np.float32)
truth = brute_force_knn(x, q, 10)
p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2)),
                leaf=LeafParams(k=2), l_max=32, max_deg=16, seed=1)
idx = pipnn.build(x, p)
sv = pipnn.serving_index(idx, x)
gj, xj, qj = sv.graph, sv.points, jnp.asarray(q)

def sweep(fn):
    """First (beam, recall, qps) with recall >= 0.9."""
    for beam in (8, 16, 24, 32, 48, 64):
        ids, _ = timed(fn, beam)                 # warm-up/compile
        ids, secs = timed(fn, beam, repeat=3)
        r = recall_at_k(np.asarray(ids)[:, :10], truth, 10)
        if r >= 0.9:
            return beam, r, q.shape[0] / secs
    raise AssertionError("no recall>=0.9 operating point found")

b_m, r_m, qps_m = sweep(lambda beam: sv.search(q, k=10, beam=beam))
b_s, r_s, qps_s = sweep(lambda beam: np.asarray(bs.beam_search_single(
    gj, xj, qj, start=idx.start, beam=beam,
    iters=bs.default_iters(beam))[0]))
# np pointer-chasing oracle: recall parity at the serving operating point
ids_np = pipnn.search(idx, x, q[:32], k=10, beam=b_m, batch=False)
r_np = recall_at_k(ids_np, truth[:32], 10)
speedup = qps_m / max(qps_s, 1e-9)
print(f"  serving  beam={b_m} recall={r_m:.3f} qps={qps_m:.0f}")
print(f"  single   beam={b_s} recall={r_s:.3f} qps={qps_s:.0f}")
print(f"  np-oracle recall={r_np:.3f} (beam={b_m});  speedup={speedup:.2f}x")
assert r_m >= r_np - 0.05, (r_m, r_np)
assert speedup >= 2.0, f"serving only {speedup:.2f}x the legacy engine"

# int8 scalar-quantized serving, end to end through pipnn.search: recall
# must stay within 0.02 of f32 serving at the same operating point
i8 = lambda: pipnn.search(idx, x, q, k=10, beam=b_m, dtype="int8")
ids8, _ = timed(i8)                      # warm-up/compile (+ packs sv8)
ids8, secs8 = timed(i8, repeat=3)
r_i8 = recall_at_k(np.asarray(ids8)[:, :10], truth, 10)
qps_i8 = q.shape[0] / secs8
sv8 = pipnn.serving_index(idx, x, dtype="int8")
print(f"  int8     beam={b_m} recall={r_i8:.3f} qps={qps_i8:.0f} "
      f"bytes={sv8.device_bytes()} (f32 {sv.device_bytes()})")
assert r_i8 >= r_m - 0.02, f"int8 recall {r_i8:.3f} vs f32 {r_m:.3f}"
assert sv8.device_bytes() < sv.device_bytes(), "int8 packing not smaller"

# footprint on a serving-shaped packing (BigANN-like d=128, R=16): the
# smoke index above is graph-dominated (d=32), so gate the ~1/3 claim
# where the points block dominates, as it does at scale
from repro.core.serving import ServingIndex
xw = rng.standard_normal((1024, 128)).astype(np.float32)
gw = np.zeros((1024, 16), np.int32)
svw32 = ServingIndex.from_graph(gw, xw, 0)
svw8 = ServingIndex.from_graph(gw, xw, 0, dtype="int8")
ratio = svw8.device_bytes() / svw32.device_bytes()
print(f"  footprint d=128 R=16: int8/f32 = {ratio:.3f}")
assert ratio <= 0.35, f"int8 packing ratio {ratio:.3f} > ~1/3"

append_bench_json(
    [{"engine": "serve_E4", "beam": b_m, "recall": round(r_m, 4),
      "qps": round(qps_m, 1)},
     {"engine": "serve_i8", "beam": b_m, "recall": round(r_i8, 4),
      "qps": round(qps_i8, 1), "device_bytes": sv8.device_bytes(),
      "device_bytes_f32": sv.device_bytes()},
     {"engine": "single", "beam": b_s, "recall": round(r_s, 4),
      "qps": round(qps_s, 1)},
     {"engine": "np_oracle", "beam": b_m, "recall": round(r_np, 4)},
     {"metric_name": "serve_vs_single_at0.9", "speedup": round(speedup, 2)}],
    path=BENCH_QPS_JSON, bench="qps_smoke", n=2000, d=32, n_queries=128)
print("serving QPS smoke OK")
EOF

echo "== smoke: sharded SPMD serving recall parity (8 simulated devices) =="
# the forced-host-device flag must be set before jax initializes, so this
# step runs in its own python process with its own XLA_FLAGS
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
python - <<'EOF'
import numpy as np
import jax
from jax.sharding import Mesh

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex

assert len(jax.devices()) == 8, jax.devices()
rng = np.random.default_rng(0)
x = rng.standard_normal((2000, 32)).astype(np.float32)
q = x[:128] + 0.01 * rng.standard_normal((128, 32)).astype(np.float32)
truth = brute_force_knn(x, q, 10)
p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2)),
                leaf=LeafParams(k=2), l_max=32, max_deg=16, seed=1)
idx = pipnn.build(x, p)

sv = ServingIndex.from_index(idx, x)
ids1 = sv.search(q, k=10, beam=32)
r1 = recall_at_k(ids1, truth, 10)

# S=1 mesh is the single-device search wearing the shard_map plumbing
m1 = Mesh(np.array(jax.devices()[:1]), ("shards",))
np.testing.assert_array_equal(
    ids1, ServingIndex.from_index(idx, x, mesh=m1).search(q, k=10, beam=32))

mesh = Mesh(np.array(jax.devices()), ("shards",))
ssv = ServingIndex.from_index(idx, x, mesh=mesh)
ids8, stats = ssv.search(q, k=10, beam=32, with_stats=True)
r8 = recall_at_k(ids8, truth, 10)
print(f"  f32: single={r1:.3f} sharded(S=8)={r8:.3f} "
      f"delta={r1 - r8:+.4f} per_shard_bytes="
      f"{ssv.device_bytes(per_shard=True)} router={stats['router']}")
assert r8 >= r1 - 0.01, f"sharded recall {r8:.3f} vs single {r1:.3f}"

# int8 packing through the same mesh
r1_8 = recall_at_k(ServingIndex.from_index(idx, x, dtype="int8")
                   .search(q, k=10, beam=32), truth, 10)
r8_8 = recall_at_k(ServingIndex.from_index(idx, x, mesh=mesh, dtype="int8")
                   .search(q, k=10, beam=32), truth, 10)
print(f"  int8: single={r1_8:.3f} sharded(S=8)={r8_8:.3f} "
      f"delta={r1_8 - r8_8:+.4f}")
assert r8_8 >= r1_8 - 0.01, f"int8 sharded {r8_8:.3f} vs single {r1_8:.3f}"
print("sharded serving smoke OK")
EOF

echo "== gate: resilient serving loop (faults, drain, SLO; 8 devices) =="
# own process: the fault drill shards over 8 forced host devices
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
python - <<'EOF'
import numpy as np

from benchmarks.bench_serving_loop import (_build, fault_drill, poisson_load,
                                           straggler_drain_ab)
from repro.core.serving import ServingIndex

# Poisson smoke: open-loop arrivals, per-request deadline. Zero timeouts
# and p99 under the deadline — the continuous-batching loop keeps up.
rng = np.random.default_rng(0)
x = rng.standard_normal((2000, 32)).astype(np.float32)
q = x[rng.integers(0, 2000, 96)] + 0.01 * rng.standard_normal(
    (96, 32)).astype(np.float32)
sv = ServingIndex.from_index(_build(x), x)
rec = poisson_load(sv, q.astype(np.float32), rate=300.0, seed=0,
                   deadline_s=2.0, chunk=32)
print(f"  poisson: served={rec['served']}/{rec['requests']} "
      f"p99={rec['p99_ms']}ms timeouts={rec['timeout_rate']}")
assert rec["served"] == rec["requests"], rec
assert rec["timeout_rate"] == 0.0, rec
assert rec["p99_ms"] <= 2000.0, rec

# Straggler drain: converged queries must come back bit-identical to the
# single-phase run AND measurably faster (the drain is real, not a
# quality trade).
rec = straggler_drain_ab()
print(f"  drain: rerun={rec['stragglers_rerun']}/{rec['batch']} "
      f"speedup={rec['drain_speedup']}x "
      f"bit_identical={rec['drained_bit_identical']}")
assert rec["drained_bit_identical"], rec
assert rec["drain_speedup"] > 1.0, rec
assert rec["stragglers_rerun"] < rec["batch"], rec

# Issue-9 fault drill: 1/8 shards down mid-run + 5% NaN queries + one
# straggling shard. Every request completes, poisoned rows (and only
# those) get structured errors, degraded recall >= 0.85x healthy, and
# the tombstoned shard is re-admitted once its probe succeeds.
rec = fault_drill()
print(f"  drill: completed={rec['completed']}/{rec['requests']} "
      f"unhandled={rec['unhandled_errors']} "
      f"degraded_ratio={rec['degraded_ratio']} "
      f"readmitted={rec['shard_readmitted']}")
assert rec["unhandled_errors"] == 0, rec
assert rec["completed"] == rec["requests"], rec
assert rec["errors_match_poisoned"], rec
assert rec["degraded_ratio"] >= 0.85, rec
assert rec["shard_readmitted"] == 1, rec
print("serving loop gate OK")
EOF

echo "ALL CHECKS PASSED"
