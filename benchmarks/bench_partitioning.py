"""Table 2 / Supplemental Fig. 7: the four partitioning strategies
(randomized ball carving, binary partitioning, hierarchical k-means,
sorting-LSH) — partition time + resulting index quality, leaf method
fixed to bidirected 2-NN (as in the paper's ablation)."""
from __future__ import annotations

from benchmarks.common import Row, dataset, graph_recall, ground_truth
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    # the three rbc execution strategies ride along the four-method
    # ablation: rbc == rbc_device bit-identically (same leaves), rbc_static
    # is the fully-static two-level carve (spill-routed capacities)
    variants = [("rbc", "auto"), ("rbc_device", "device"),
                ("rbc_static", "static"), ("binary", "auto"),
                ("kmeans", "auto"), ("sorting_lsh", "auto")]
    for label, execution in variants:
        method = "rbc" if label.startswith("rbc") else label
        # binary/sorting_lsh have no fanout analog (paper A.1) -> replicas
        rbc = RBCParams(c_max=256, c_min=32, fanout=(4, 2), replicas=1,
                        execution=execution) \
            if method in ("rbc", "kmeans") else \
            RBCParams(c_max=256, c_min=32, fanout=(1,), replicas=4)
        p = PiPNNParams(rbc=rbc, partitioner=method, leaf=LeafParams(k=2),
                        max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        r = graph_recall(idx.graph, idx.start, x, q, truth, beam=64)
        rows.append((f"partitioning/{label}",
                     idx.timings["partition"] * 1e6,
                     f"recall={r:.3f} leaves={idx.stats['n_leaves']} "
                     f"repeat={idx.stats['point_repeat']:.2f} "
                     f"exec={idx.stats['partition_execution']}"))
    return rows
