"""Fig. 5: QPS-vs-recall curves for PiPNN (1 and 2 replicas) vs Vamana,
plus the serving-engine comparison the multi-expansion PR is about:

  * ``serve_E{1,4}`` — the device-resident multi-expansion serving path
    (``ServingIndex``: prepacked graph/points/norms, sort-free rank
    merges, early exit) at expansion widths 1 and 4,
  * ``serve_i8``    — the same engine over the scalar-quantized int8
    packing (int8 points + per-point f32 scales, exact norm terms); its
    summary row records the recall delta vs f32 serving and the device
    footprint of both packings,
  * ``single``      — the legacy one-expansion-per-step double-sort scan
    (``beam_search_single``), the pre-ServingIndex baseline,
  * ``np_oracle``   — the pointer-chasing numpy reference, timed on a
    query subset (it is per-query host code by design).

Emits one row per (index, engine, beam) point so the full trade-off curve
is in the CSV; the summary rows report QPS at the 0.9-recall operating
point, and everything is appended to BENCH_qps.json
(``common.append_bench_json``) so the serving trajectory is tracked
across PRs — including the multi-expansion-vs-single-expansion speedup
and the int8-vs-f32 serving deltas.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_QPS_JSON, Row, append_bench_json,
                               dataset, ground_truth, qps_at_recall, timed)
from repro.core import pipnn
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.beam_search import beam_search_np, pad_ids, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex

N, D = 4096, 32
NP_QUERIES = 32   # subset for timing the per-query host oracle


def run() -> list[Row]:
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    records: list[dict] = []

    indexes = {}
    for reps in (1, 2):
        p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2),
                                      replicas=reps),
                        leaf=LeafParams(k=2), max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        indexes[f"pipnn_{reps}rep"] = (idx.graph, idx.start)
    g, start, _ = build_vamana(x, VamanaParams(max_deg=32, beam=48, passes=1))
    indexes["vamana_1pass"] = (g, start)

    xj, qj = jnp.asarray(x), jnp.asarray(q)
    for name, (graph, start) in indexes.items():
        gj = jnp.asarray(graph)
        sv = ServingIndex.from_graph(graph, x, start)
        sv8 = ServingIndex.from_graph(graph, x, start, dtype="int8")
        engines = {
            "serve_E1": lambda beam: sv.search(q, k=10, beam=beam,
                                               expansions=1),
            "serve_E4": lambda beam: sv.search(q, k=10, beam=beam,
                                               expansions=4),
            "serve_i8": lambda beam: sv8.search(q, k=10, beam=beam,
                                                expansions=4),
            "single": lambda beam: np.asarray(bs.beam_search_single(
                gj, xj, qj, start=start, beam=beam,
                iters=bs.default_iters(beam))[0]),
        }
        at09 = {}
        for ename, efn in engines.items():
            for beam in (8, 16, 32, 64):
                ids, _ = timed(efn, beam)
                ids, secs = timed(efn, beam, repeat=3)
                # -1 padding keeps beam<10 an honest 10@10 number
                r = recall_at_k(pad_ids(ids, 10), truth[:, :10], 10)
                qps = q.shape[0] / secs
                rows.append((f"qps_recall/{name}/{ename}/beam{beam}",
                             secs / q.shape[0] * 1e6,
                             f"recall={r:.3f} qps={qps:.0f}"))
                records.append({"index": name, "engine": ename, "beam": beam,
                                "recall": round(r, 4), "qps": round(qps, 1)})
            qps, r, beam = qps_at_recall(
                graph, start, x, q, truth, target=0.9, search_ids_fn=efn)
            at09[ename] = (qps, r, beam)
            rows.append((f"qps_recall/{name}/{ename}/at0.9",
                         1e6 / max(qps, 1e-9),
                         f"qps={qps:.0f} recall={r:.3f} beam={beam}"))
            records.append({"index": name, "engine": ename, "at": 0.9,
                            "beam": beam, "recall": round(r, 4),
                            "qps": round(qps, 1)})
        # the acceptance delta: multi-expansion serving vs the legacy scan
        speedup = at09["serve_E4"][0] / max(at09["single"][0], 1e-9)
        rows.append((f"qps_recall/{name}/serve_vs_single_at0.9", 0.0,
                     f"speedup={speedup:.2f}x"))
        records.append({"index": name, "metric_name": "serve_vs_single_at0.9",
                        "speedup": round(speedup, 2)})
        # int8 serving deltas vs f32: recall at the operating points +
        # device footprint of both packings
        r_delta = at09["serve_E4"][1] - at09["serve_i8"][1]
        qps_ratio = at09["serve_i8"][0] / max(at09["serve_E4"][0], 1e-9)
        rows.append((f"qps_recall/{name}/int8_vs_f32_at0.9", 0.0,
                     f"recall_delta={r_delta:.4f} qps_ratio={qps_ratio:.2f} "
                     f"bytes={sv8.device_bytes()}/{sv.device_bytes()}"))
        records.append({"index": name, "metric_name": "int8_vs_f32_at0.9",
                        "recall_delta": round(r_delta, 4),
                        "qps_ratio": round(qps_ratio, 2),
                        "device_bytes_i8": sv8.device_bytes(),
                        "device_bytes_f32": sv.device_bytes()})
        # np pointer-chasing oracle on a subset (recall parity + QPS scale)
        op_beam = at09["serve_E4"][2]
        qs = q[:NP_QUERIES]

        def run_np():
            out = np.full((NP_QUERIES, 10), -1, dtype=np.int64)
            for i, qq in enumerate(qs):
                ids, _, _ = beam_search_np(graph, x, qq, start=start,
                                           beam=op_beam)
                out[i, : min(10, len(ids))] = ids[:10]
            return out

        ids_np, secs = timed(run_np)
        r_np = recall_at_k(ids_np, truth[:NP_QUERIES, :10], 10)
        qps_np = NP_QUERIES / secs
        rows.append((f"qps_recall/{name}/np_oracle/beam{op_beam}",
                     secs / NP_QUERIES * 1e6,
                     f"recall={r_np:.3f} qps={qps_np:.0f}"))
        records.append({"index": name, "engine": "np_oracle", "beam": op_beam,
                        "recall": round(r_np, 4), "qps": round(qps_np, 1),
                        "n_queries": NP_QUERIES})
    append_bench_json(records, path=BENCH_QPS_JSON, bench="qps_recall",
                      n=N, d=D, n_queries=q.shape[0])
    return rows
