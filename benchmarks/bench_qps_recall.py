"""Fig. 5: QPS-vs-recall curves for PiPNN (1 and 2 replicas) vs Vamana.

Emits one row per (index, beam) point so the full trade-off curve is in
the CSV; the summary row reports QPS at the 0.9-recall operating point.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Row, dataset, ground_truth, qps_at_recall,
                               timed)
from repro.core import pipnn
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.beam_search import recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 4096, 32


def run() -> list[Row]:
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []

    indexes = {}
    for reps in (1, 2):
        p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2),
                                      replicas=reps),
                        leaf=LeafParams(k=2), max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        indexes[f"pipnn_{reps}rep"] = (idx.graph, idx.start)
    g, start, _ = build_vamana(x, VamanaParams(max_deg=32, beam=48, passes=1))
    indexes["vamana_1pass"] = (g, start)

    xj, qj = jnp.asarray(x), jnp.asarray(q)
    for name, (graph, start) in indexes.items():
        gj = jnp.asarray(graph)
        for beam in (8, 16, 32, 64):
            fn = lambda: bs.beam_search_batch(gj, xj, qj, start=start,
                                              beam=beam, iters=beam + 4)
            (ids, _), _ = timed(fn)
            (ids, _), secs = timed(fn, repeat=3)
            # beam < 10 returns [Q, beam]: pad to [Q, 10] with -1 so this
            # stays an honest 10@10 number (missing neighbors count as misses)
            ids = np.asarray(ids)[:, :10]
            if ids.shape[1] < 10:
                ids = np.pad(ids, ((0, 0), (0, 10 - ids.shape[1])),
                             constant_values=-1)
            r = recall_at_k(ids, truth[:, :10], 10)
            rows.append((f"qps_recall/{name}/beam{beam}",
                         secs / q.shape[0] * 1e6,
                         f"recall={r:.3f} qps={q.shape[0] / secs:.0f}"))
        qps, r, beam = qps_at_recall(graph, start, x, q, truth, target=0.9)
        rows.append((f"qps_recall/{name}/at0.9", 1e6 / max(qps, 1e-9),
                     f"qps={qps:.0f} recall={r:.3f} beam={beam}"))
    return rows
