"""Fig. 5: QPS-vs-recall curves for PiPNN (1 and 2 replicas) vs Vamana,
plus the serving-engine comparison the multi-expansion PR is about:

  * ``serve_E{1,4}`` — the device-resident multi-expansion serving path
    (``ServingIndex``: prepacked graph/points/norms, sort-free rank
    merges, early exit) at expansion widths 1 and 4,
  * ``serve_i8``    — the same engine over the scalar-quantized int8
    packing (int8 points + per-point f32 scales, exact norm terms); its
    summary row records the recall delta vs f32 serving and the device
    footprint of both packings,
  * ``single``      — the legacy one-expansion-per-step double-sort scan
    (``beam_search_single``), the pre-ServingIndex baseline,
  * ``np_oracle``   — the pointer-chasing numpy reference, timed on a
    query subset (it is per-query host code by design).

Two sweeps ride along for the sharded-serving PR:

  * an expansion-width (E) sweep at the serving beam, isolating the
    multi-expansion knob from the beam knob,
  * a sharded SPMD sweep over 1/2/4/8 simulated devices — each point
    runs in a SUBPROCESS with ``--xla_force_host_platform_device_count``
    (the flag must be set before jax initializes), builds the same
    deterministic index, and serves through the mesh-sharded
    ``ShardedServingIndex`` (replicate-to-all router, halo shards,
    cross-shard merge); rows record recall parity vs the parent's
    single-device serving and per-shard footprints.

Emits one row per (index, engine, beam) point so the full trade-off curve
is in the CSV; the summary rows report QPS at the 0.9-recall operating
point, and everything is appended to BENCH_qps.json
(``common.append_bench_json``) so the serving trajectory is tracked
across PRs — including the multi-expansion-vs-single-expansion speedup
and the int8-vs-f32 serving deltas.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import (BENCH_QPS_JSON, Row, append_bench_json,
                               dataset, ground_truth, qps_at_recall, timed)
from repro.core import pipnn
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.beam_search import beam_search_np, pad_ids, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex

N, D = 4096, 32
NP_QUERIES = 32   # subset for timing the per-query host oracle
E_SWEEP = (1, 2, 4, 8)      # expansion widths at the serving beam
SHARD_DEVICES = (1, 2, 4, 8)
SHARD_BEAM = 32


def _shard_params() -> PiPNNParams:
    """The pipnn_1rep build, shared between parent and sharded children so
    every sweep point serves the SAME graph."""
    return PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                       leaf=LeafParams(k=2), max_deg=32, seed=0)


def _sharded_child(ndev: int) -> dict:
    """One sharded sweep point: runs inside a subprocess whose XLA_FLAGS
    forced ``ndev`` host devices.  Prints nothing; returns the record."""
    import jax

    from benchmarks.common import dataset, ground_truth, timed
    from jax.sharding import Mesh
    from repro.core.serving import ServingIndex

    assert len(jax.devices()) == ndev, (len(jax.devices()), ndev)
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    idx = pipnn.build(x, _shard_params())
    mesh = Mesh(np.array(jax.devices()), ("shards",))
    ssv = ServingIndex.from_index(idx, x, mesh=mesh)
    fn = lambda: ssv.search(q, k=10, beam=SHARD_BEAM, expansions=4)
    ids, _ = timed(fn)                        # warm-up/compile
    ids, secs = timed(fn, repeat=3)
    r = recall_at_k(pad_ids(ids, 10), truth[:, :10], 10)
    return {
        "engine": "serve_sharded", "ndev": ndev, "beam": SHARD_BEAM,
        "recall": round(float(r), 4),
        "qps": round(q.shape[0] / max(secs, 1e-9), 1),
        "per_shard_bytes": ssv.device_bytes(per_shard=True),
        "shard_capacity": ssv.shard_capacity,
        "kernel_path": ssv.kernel_path,
    }


def _run_sharded_sweep() -> list[dict]:
    """Spawn one subprocess per device count (the forced-host-device flag
    must precede jax init) and collect the records; a failed point is
    recorded with its error rather than sinking the bench."""
    out = []
    for ndev in SHARD_DEVICES:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count={ndev}"
                            ).strip()
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_qps_recall",
             "--sharded-child", str(ndev)],
            capture_output=True, text=True, env=env, timeout=1200)
        if proc.returncode != 0:
            out.append({"engine": "serve_sharded", "ndev": ndev,
                        "error": proc.stderr.strip()[-300:]})
            continue
        out.append(json.loads(proc.stdout.strip().splitlines()[-1]))
    return out


def run() -> list[Row]:
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    records: list[dict] = []

    indexes = {}
    for reps in (1, 2):
        p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2),
                                      replicas=reps),
                        leaf=LeafParams(k=2), max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        indexes[f"pipnn_{reps}rep"] = (idx.graph, idx.start)
    g, start, _ = build_vamana(x, VamanaParams(max_deg=32, beam=48, passes=1))
    indexes["vamana_1pass"] = (g, start)

    xj, qj = jnp.asarray(x), jnp.asarray(q)
    for name, (graph, start) in indexes.items():
        gj = jnp.asarray(graph)
        sv = ServingIndex.from_graph(graph, x, start)
        sv8 = ServingIndex.from_graph(graph, x, start, dtype="int8")
        engines = {
            "serve_E1": lambda beam: sv.search(q, k=10, beam=beam,
                                               expansions=1),
            "serve_E4": lambda beam: sv.search(q, k=10, beam=beam,
                                               expansions=4),
            "serve_i8": lambda beam: sv8.search(q, k=10, beam=beam,
                                                expansions=4),
            "single": lambda beam: np.asarray(bs.beam_search_single(
                gj, xj, qj, start=start, beam=beam,
                iters=bs.default_iters(beam))[0]),
        }
        at09 = {}
        for ename, efn in engines.items():
            for beam in (8, 16, 32, 64):
                ids, _ = timed(efn, beam)
                ids, secs = timed(efn, beam, repeat=3)
                # -1 padding keeps beam<10 an honest 10@10 number
                r = recall_at_k(pad_ids(ids, 10), truth[:, :10], 10)
                qps = q.shape[0] / secs
                rows.append((f"qps_recall/{name}/{ename}/beam{beam}",
                             secs / q.shape[0] * 1e6,
                             f"recall={r:.3f} qps={qps:.0f}"))
                records.append({"index": name, "engine": ename, "beam": beam,
                                "recall": round(r, 4), "qps": round(qps, 1)})
            qps, r, beam = qps_at_recall(
                graph, start, x, q, truth, target=0.9, search_ids_fn=efn)
            at09[ename] = (qps, r, beam)
            rows.append((f"qps_recall/{name}/{ename}/at0.9",
                         1e6 / max(qps, 1e-9),
                         f"qps={qps:.0f} recall={r:.3f} beam={beam}"))
            records.append({"index": name, "engine": ename, "at": 0.9,
                            "beam": beam, "recall": round(r, 4),
                            "qps": round(qps, 1)})
        # the acceptance delta: multi-expansion serving vs the legacy scan
        speedup = at09["serve_E4"][0] / max(at09["single"][0], 1e-9)
        rows.append((f"qps_recall/{name}/serve_vs_single_at0.9", 0.0,
                     f"speedup={speedup:.2f}x"))
        records.append({"index": name, "metric_name": "serve_vs_single_at0.9",
                        "speedup": round(speedup, 2)})
        # int8 serving deltas vs f32: recall at the operating points +
        # device footprint of both packings
        r_delta = at09["serve_E4"][1] - at09["serve_i8"][1]
        qps_ratio = at09["serve_i8"][0] / max(at09["serve_E4"][0], 1e-9)
        rows.append((f"qps_recall/{name}/int8_vs_f32_at0.9", 0.0,
                     f"recall_delta={r_delta:.4f} qps_ratio={qps_ratio:.2f} "
                     f"bytes={sv8.device_bytes()}/{sv.device_bytes()}"))
        records.append({"index": name, "metric_name": "int8_vs_f32_at0.9",
                        "recall_delta": round(r_delta, 4),
                        "qps_ratio": round(qps_ratio, 2),
                        "device_bytes_i8": sv8.device_bytes(),
                        "device_bytes_f32": sv.device_bytes()})
        # np pointer-chasing oracle on a subset (recall parity + QPS scale)
        op_beam = at09["serve_E4"][2]
        qs = q[:NP_QUERIES]

        def run_np():
            out = np.full((NP_QUERIES, 10), -1, dtype=np.int64)
            for i, qq in enumerate(qs):
                ids, _, _ = beam_search_np(graph, x, qq, start=start,
                                           beam=op_beam)
                out[i, : min(10, len(ids))] = ids[:10]
            return out

        ids_np, secs = timed(run_np)
        r_np = recall_at_k(ids_np, truth[:NP_QUERIES, :10], 10)
        qps_np = NP_QUERIES / secs
        rows.append((f"qps_recall/{name}/np_oracle/beam{op_beam}",
                     secs / NP_QUERIES * 1e6,
                     f"recall={r_np:.3f} qps={qps_np:.0f}"))
        records.append({"index": name, "engine": "np_oracle", "beam": op_beam,
                        "recall": round(r_np, 4), "qps": round(qps_np, 1),
                        "n_queries": NP_QUERIES})
    # ---- expansion-width sweep at the serving beam (pipnn_1rep) --------
    graph, start = indexes["pipnn_1rep"]
    sv = ServingIndex.from_graph(graph, x, start)
    r_single = 0.0
    for e in E_SWEEP:
        fn = lambda: sv.search(q, k=10, beam=SHARD_BEAM, expansions=e)
        ids, _ = timed(fn)                       # warm-up/compile
        ids, secs = timed(fn, repeat=3)
        r = recall_at_k(pad_ids(ids, 10), truth[:, :10], 10)
        qps = q.shape[0] / max(secs, 1e-9)
        rows.append((f"qps_recall/pipnn_1rep/E{e}/beam{SHARD_BEAM}",
                     secs / q.shape[0] * 1e6,
                     f"recall={r:.3f} qps={qps:.0f}"))
        records.append({"index": "pipnn_1rep", "engine": "serve",
                        "expansions": e, "beam": SHARD_BEAM,
                        "recall": round(r, 4), "qps": round(qps, 1)})
        if e == 4:
            r_single = r                         # sharded-parity reference
    # ---- sharded SPMD sweep (subprocess per simulated device count) ----
    for rec in _run_sharded_sweep():
        if "error" in rec:
            rows.append((f"qps_recall/pipnn_1rep/sharded_ndev{rec['ndev']}",
                         0.0, f"ERROR {rec['error'][:80]}"))
            records.append({"index": "pipnn_1rep", **rec})
            continue
        rec["recall_delta_vs_single"] = round(r_single - rec["recall"], 4)
        rows.append((
            f"qps_recall/pipnn_1rep/sharded_ndev{rec['ndev']}"
            f"/beam{SHARD_BEAM}",
            q.shape[0] / max(rec["qps"], 1e-9) / q.shape[0] * 1e6,
            f"recall={rec['recall']:.3f} qps={rec['qps']:.0f} "
            f"delta={rec['recall_delta_vs_single']:+.4f} "
            f"per_shard_bytes={rec['per_shard_bytes']}"))
        records.append({"index": "pipnn_1rep", **rec})
    append_bench_json(records, path=BENCH_QPS_JSON, bench="qps_recall",
                      n=N, d=D, n_queries=q.shape[0])
    return rows


if __name__ == "__main__":
    # sharded-sweep child entry: the parent spawns
    #   python -m benchmarks.bench_qps_recall --sharded-child NDEV
    # with XLA_FLAGS forcing NDEV host devices (set before jax init).
    if "--sharded-child" in sys.argv:
        n = int(sys.argv[sys.argv.index("--sharded-child") + 1])
        print(json.dumps(_sharded_child(n)))
        sys.exit(0)
    for row in run():
        print(",".join(str(c) for c in row))
