"""Beyond-paper: the distributed (shard_map + all_to_all) PiPNN build —
the paper's §6 'natural fit for distributed data processing' — runs the
same code path the 512-chip dry-run compiles, here on the local device(s).
Reports tile-step walltime, routing-drop stats, and final index quality
vs the host-orchestrated build."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, dataset, graph_recall, ground_truth, timed
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 2048, 16


def run() -> list[Row]:
    import jax

    from repro.launch import build_index as bi

    x, q = dataset(N, D, n_queries=128)
    truth = ground_truth(N, D, n_queries=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    p = bi.DistBuildParams.tiny()
    rows: list[Row] = []

    (graph, dists), secs = timed(bi.build_distributed, x, mesh, p, seed=0)
    r = graph_recall(graph, 0, x, q, truth, beam=48)
    rows.append(("distributed/spmd_build", secs * 1e6,
                 f"recall={r:.3f} "
                 f"avg_deg={float((graph >= 0).sum(1).mean()):.1f}"))

    host = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2)),
                       leaf=LeafParams(k=2), l_max=32, max_deg=24, seed=0)
    idx, secs_h = timed(pipnn.build, x, host)
    rh = graph_recall(idx.graph, idx.start, x, q, truth, beam=48)
    rows.append(("distributed/host_build_ref", secs_h * 1e6,
                 f"recall={rh:.3f} (same dataset, host pipeline)"))
    return rows
