"""Beyond-paper: the distributed (shard_map + all_to_all) PiPNN build —
the paper's §6 'natural fit for distributed data processing' — runs the
same code path the 512-chip dry-run compiles, here on the local device(s).
Reports tile-step walltime, routing-drop stats, and final index quality
vs the host-orchestrated build; then sweeps the sharded SERVING packing
over S in {1, 2, 4, 8} shards, recording the halo replication cost
(member/ghost/pad rows, halo fraction, per-shard bytes) and the serving
QPS per shard count into BENCH_qps.json."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (BENCH_QPS_JSON, Row, append_bench_json,
                               dataset, graph_recall, ground_truth, timed)
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 2048, 16


def run() -> list[Row]:
    import jax

    from repro.launch import build_index as bi

    x, q = dataset(N, D, n_queries=128)
    truth = ground_truth(N, D, n_queries=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    p = bi.DistBuildParams.tiny()
    rows: list[Row] = []

    (graph, dists), secs = timed(bi.build_distributed, x, mesh, p, seed=0)
    r = graph_recall(graph, 0, x, q, truth, beam=48)
    rows.append(("distributed/spmd_build", secs * 1e6,
                 f"recall={r:.3f} "
                 f"avg_deg={float((graph >= 0).sum(1).mean()):.1f}"))

    host = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2)),
                       leaf=LeafParams(k=2), l_max=32, max_deg=24, seed=0)
    idx, secs_h = timed(pipnn.build, x, host)
    rh = graph_recall(idx.graph, idx.start, x, q, truth, beam=48)
    rows.append(("distributed/host_build_ref", secs_h * 1e6,
                 f"recall={rh:.3f} (same dataset, host pipeline)"))
    rows += halo_sweep(idx, x, q)
    return rows


def halo_sweep(idx, x, q) -> list[Row]:
    """Sharded serving over every meshable S: the halo fraction
    (ghost-row share of live rows — the ROADMAP's replication-cost-vs-
    scale measurement), per-shard footprint and serving QPS, appended to
    BENCH_qps.json so the scaling curve accumulates across runs."""
    import jax
    from jax.sharding import Mesh

    from repro.core.serving import ServingIndex

    ndev = len(jax.devices())
    rows: list[Row] = []
    records = []
    for s in (1, 2, 4, 8):
        if s > ndev:
            break
        mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
        ssv = ServingIndex.from_index(idx, x, mesh=mesh)
        hs = ssv.halo_stats()
        _, secs = timed(ssv.search, q, k=10, beam=32)          # compile
        _, secs = timed(ssv.search, q, k=10, beam=32, repeat=3)
        qps = q.shape[0] / secs
        per_shard = ssv.device_bytes(per_shard=True)
        rows.append((f"distributed/serve_S{s}", secs * 1e6 / q.shape[0],
                     f"halo={hs['halo_fraction']:.3f} "
                     f"ghosts={int(hs['ghosts'].sum())} "
                     f"bytes/shard={per_shard}"))
        records.append({
            "engine": f"sharded_S{s}", "n_shards": s,
            "halo_fraction": round(hs["halo_fraction"], 4),
            "members": int(hs["members"].sum()),
            "ghosts": int(hs["ghosts"].sum()),
            "pads": int(hs["pads"].sum()),
            "row_bytes": hs["row_bytes"],
            "device_bytes_per_shard": per_shard,
            "qps": round(qps, 1),
        })
    if records:
        append_bench_json(records, path=BENCH_QPS_JSON,
                          bench="halo_sweep", n=x.shape[0], d=x.shape[1],
                          n_queries=q.shape[0])
    return rows
