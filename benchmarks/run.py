"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only build,phases] [--list]

Prints ``name,us_per_call,derived`` CSV (one row per measured
configuration).  Module -> paper-artifact map lives in DESIGN.md §6.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

BENCHES = [
    "build",            # Fig. 1 / Fig. 5 build times
    "qps_recall",       # Fig. 5 QPS-recall curves
    "fanout",           # Fig. 3 / Supp. Figs. 8-9
    "phases",           # Fig. 4 phase breakdown
    "partitioning",     # Table 2 / Supp. Fig. 7
    "leaf_methods",     # Fig. 10 / Table 3
    "leaf_k",           # Fig. 11
    "leaf_opts",        # Fig. 12 / Supp. A.4
    "hashprune_params",  # Fig. 13 / Table 5
    "knn_graph",        # Fig. 6 downstream task
    "kernels",          # Pallas kernels vs ref oracles
    "distributed",      # beyond-paper: SPMD build path
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated subset of benches")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)
    if args.list:
        print("\n".join(BENCHES))
        return 0
    selected = [b for b in args.only.split(",") if b] or BENCHES

    print("name,us_per_call,derived")
    n_fail = 0
    for bench in selected:
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(f"benchmarks.bench_{bench}")
            rows = mod.run()
        except Exception as e:
            n_fail += 1
            print(f"{bench},ERROR,\"{type(e).__name__}: {e}\"")
            traceback.print_exc(file=sys.stderr)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},\"{derived}\"")
        print(f"# {bench} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
