"""Pallas kernel micro-benchmarks (interpret mode on CPU): latency per
call + agreement with the pure-jnp oracle.  TPU performance claims come
from the roofline (EXPERIMENTS.md), not these numbers — interpret mode
measures correctness-path overhead only."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.kernels import ops, ref

B, C, D_ = 4, 128, 32


def run() -> list[Row]:
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.standard_normal((B, C, D_)).astype(np.float32))
    valid = jnp.asarray(rng.random((B, C)) > 0.1)
    rows: list[Row] = []

    fn = lambda: jax.block_until_ready(
        ops.pairwise_distance(pts, pts, interpret=True))
    _, _ = timed(fn)
    ref_d = np.asarray(ref.pairwise_distance_ref(pts, pts))
    out, secs = timed(fn, repeat=3)
    err = float(np.max(np.abs(np.asarray(out) - ref_d)))
    rows.append(("kernels/pairwise_distance", secs * 1e6,
                 f"max_err_vs_ref={err:.2e}"))

    fn = lambda: jax.block_until_ready(
        ops.leaf_topk(pts, valid, k=2, interpret=True))
    _, _ = timed(fn)
    _, secs = timed(fn, repeat=3)
    rows.append(("kernels/leaf_topk_flash", secs * 1e6, "k=2"))

    x = rng.standard_normal((256, 16)).astype(np.float32)
    h = rng.standard_normal((12, 16)).astype(np.float32)
    sk = jnp.asarray(x @ h.T)
    fn = lambda: jax.block_until_ready(ops.edge_hashes(sk, sk,
                                                       interpret=True))
    _, _ = timed(fn)
    _, secs = timed(fn, repeat=3)
    rows.append(("kernels/edge_hashes", secs * 1e6, "m=12"))
    return rows
