"""Fig. 3 / Supplemental Fig. 8-9: replication vs fanout vs multi-level
fanout at an equal point-repeat budget (r = 4): partitioning time falls,
index quality holds.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import Row, dataset, graph_recall, ground_truth
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32

# p_samp chosen so level-0 buckets exceed c_max and the recursion actually
# runs (at 8k points the paper's default 0.01 hits the base case in one
# level, which would silently disable multi-level fanout).
_P = dict(c_max=256, c_min=32, p_samp=0.002)

STRATEGIES = {
    # equal point-repeat budget r=6 (Supp. Fig. 8's comparison)
    "replication_r6": RBCParams(**_P, fanout=(1,), replicas=6),
    "fanout_6": RBCParams(**_P, fanout=(6,), replicas=1),
    "multilevel_3x2": RBCParams(**_P, fanout=(3, 2), replicas=1),
}


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    base = None
    for name, rbc in STRATEGIES.items():
        p = PiPNNParams(rbc=rbc, leaf=LeafParams(k=2), max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        t_part = idx.timings["partition"]
        if base is None:
            base = t_part
        r = graph_recall(idx.graph, idx.start, x, q, truth, beam=64)
        rows.append((f"fanout/{name}", t_part * 1e6,
                     f"partition_speedup={base / t_part:.2f}x recall={r:.3f} "
                     f"repeat={idx.stats['point_repeat']:.2f} "
                     f"total_s={idx.timings['total']:.2f}"))
    return rows
