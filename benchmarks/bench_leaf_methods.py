"""Fig. 10 / Table 3: the five leaf candidate-picking methods (bidirected /
directed / inverted k-NN, degree-capped MST, all-to-all RobustPrune) —
quality + average degree, partitioning fixed to RBC."""
from __future__ import annotations

from benchmarks.common import Row, dataset, graph_recall, ground_truth, timed
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    for method in ("bidirected", "directed", "inverted", "mst",
                   "robust_prune"):
        p = PiPNNParams(
            rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
            leaf=LeafParams(method=method, k=2, max_deg=32), max_deg=32,
            seed=0)
        idx, secs = timed(pipnn.build, x, p)
        r = graph_recall(idx.graph, idx.start, x, q, truth, beam=64)
        rows.append((f"leaf_methods/{method}", secs * 1e6,
                     f"recall={r:.3f} avg_deg={idx.average_degree():.2f} "
                     f"leaf_s={idx.timings['build_leaves']:.2f}"))
    return rows
