"""Fig. 12 / Supplement A.4: leaf-building optimization ladder.

(naive)  per-point python distance loops (what partitioning methods do
         without the paper's insight);
(D)      precomputed distance matrix, numpy;
(D,E)    batched GEMM distance matrix, one launch for a whole leaf batch
         (jax == our Eigen analog);
(F)      fused FlashKNN Pallas kernel — distances + top-k in one pass,
         never materializing the C^2 matrix (our TPU-native beyond-paper
         step; validated in interpret mode here).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, dataset, timed
from repro.core.leaf import leaf_knn_jax
from repro.core.rbc import RBCParams, leaves_to_padded, partition
from repro.kernels import ops

N, D = 8192, 32
K = 2
N_LEAVES = 16   # naive path is O(C^2 d) per leaf in python — keep it small


def _naive_knn(pts: np.ndarray, valid: np.ndarray):
    out = []
    for b in range(pts.shape[0]):
        ids = np.where(valid[b])[0]
        for i in ids:
            d = np.sum((pts[b, ids] - pts[b, i]) ** 2, axis=1)
            d[ids == i] = np.inf
            out.append(ids[np.argsort(d)[:K]])
    return out


def _numpy_matrix_knn(pts: np.ndarray, valid: np.ndarray):
    out = []
    for b in range(pts.shape[0]):
        p = pts[b]
        n2 = (p * p).sum(1)
        dm = n2[:, None] + n2[None] - 2 * p @ p.T
        dm[~valid[b]] = np.inf
        dm[:, ~valid[b]] = np.inf
        np.fill_diagonal(dm, np.inf)
        out.append(np.argsort(dm, axis=1)[:, :K])
    return out


def run() -> list[Row]:
    import jax
    import jax.numpy as jnp

    x, _ = dataset(N, D)
    leaves = partition(x, RBCParams(c_max=256, c_min=32, fanout=(2,)))
    padded = leaves_to_padded(leaves, 256)[:N_LEAVES]
    pts = x[np.maximum(padded, 0)]
    valid = padded >= 0

    rows: list[Row] = []
    _, t_naive = timed(_naive_knn, pts, valid)
    rows.append(("leaf_opts/naive_loop", t_naive / N_LEAVES * 1e6,
                 "speedup=1.00x"))
    _, t_np = timed(_numpy_matrix_knn, pts, valid, repeat=3)
    rows.append(("leaf_opts/dist_matrix_numpy(D)", t_np / N_LEAVES * 1e6,
                 f"speedup={t_naive / t_np:.2f}x"))

    ptsj, validj = jnp.asarray(pts), jnp.asarray(valid)
    fn = jax.jit(lambda: leaf_knn_jax(ptsj, validj, k=K))
    _, _ = timed(lambda: jax.block_until_ready(fn()))
    _, t_gemm = timed(lambda: jax.block_until_ready(fn()), repeat=5)
    rows.append(("leaf_opts/batched_gemm(D,E)", t_gemm / N_LEAVES * 1e6,
                 f"speedup={t_naive / t_gemm:.2f}x"))

    flash = lambda: jax.block_until_ready(
        ops.leaf_topk(ptsj, validj, k=K, interpret=True))
    _, _ = timed(flash)
    _, t_flash = timed(flash, repeat=3)
    rows.append(("leaf_opts/flashknn_pallas(F,interp)",
                 t_flash / N_LEAVES * 1e6,
                 f"speedup={t_naive / t_flash:.2f}x "
                 "(interpret mode; wins on TPU come from VMEM fusion, "
                 "see EXPERIMENTS.md roofline)"))
    return rows
