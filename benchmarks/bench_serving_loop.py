"""Resilient serving-loop benchmark: Poisson load, straggler drain A/B,
and the deterministic fault drill's degraded-mode recall.

Three measurements, appended to BENCH_serving.json
(``common.append_bench_json``) so the loop's latency trajectory is
tracked across PRs:

  * **Poisson smoke load** — open-loop arrivals at a configurable rate
    against the continuous-batching loop; reports p50/p99 request
    latency, timeout rate and throughput, plus downshift counts under a
    burst (the SLO-degradation path exercised end to end).
  * **Straggler drain A/B** — the deterministic chain-graph straggler
    (one query that cannot converge inside any reasonable cap) batched
    with fast queries, served two-phase vs single-phase over identical
    requests.  Records the drain speedup for the CONVERGED majority and
    verifies their ids are bit-identical between modes — the acceptance
    bar for the drain being real, not a quality trade.
  * **Fault drill (8 forced devices, subprocess)** — the Issue-9
    schedule (1 of 8 shards killed mid-run, 5% NaN queries, one injected
    straggler) against the sharded loop; records healthy vs degraded
    recall, unhandled-error count (must be 0) and shard re-admission.
    Runs in a subprocess because ``--xla_force_host_platform_device_count``
    must precede jax init; skipped with ``--no-sharded``.

  PYTHONPATH=src python benchmarks/bench_serving_loop.py --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

from benchmarks.common import append_bench_json, dataset
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex
from repro.launch.serve_loop import OperatingPoint, ServeLoop

BENCH_SERVING_JSON = (pathlib.Path(__file__).resolve().parent.parent
                      / "BENCH_serving.json")


def _build(x: np.ndarray):
    p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=2), max_deg=32, seed=0)
    return pipnn.build(x, p)


def _percentiles(lat: list[float]) -> dict:
    a = np.asarray(lat, float)
    return {"p50_ms": round(float(np.percentile(a, 50)) * 1e3, 3),
            "p99_ms": round(float(np.percentile(a, 99)) * 1e3, 3)}


def poisson_load(sv, q: np.ndarray, *, rate: float, seed: int,
                 deadline_s: float, chunk: int) -> dict:
    """Open-loop Poisson arrivals against the serving loop: requests are
    submitted when their arrival time comes due (sleeping while idle),
    the loop steps whenever work is queued."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(q)))
    loop = ServeLoop(sv, k=10, query_chunk=chunk, max_queue=4 * chunk,
                     slo_p99=deadline_s)
    # warm the compile caches so the first arrivals don't eat XLA time
    loop.submit(q[0])
    loop.run_until_drained()
    results, nexti = [], 0
    t0 = time.perf_counter()
    while nexti < len(q) or loop.queue_depth:
        now = time.perf_counter() - t0
        while nexti < len(q) and arrivals[nexti] <= now:
            try:
                loop.submit(q[nexti], deadline_s=deadline_s)
            except Exception:
                loop.counters["load_rejected"] += 1
            nexti += 1
        if loop.queue_depth:
            results.extend(loop.step())
        elif nexti < len(q):
            time.sleep(min(0.001, arrivals[nexti] - now))
    wall = time.perf_counter() - t0
    ok = [r for r in results if r.ok]
    lat = [r.latency for r in ok]
    return {
        "bench": "poisson_load",
        "rate_qps": rate,
        "requests": len(q),
        "served": len(ok),
        "timeout_rate": round(
            sum(r.error == "timeout" for r in results) / max(len(q), 1), 4),
        "rejected": int(loop.counters["load_rejected"]),
        "downshifts": int(loop.counters["downshift"]),
        "throughput_qps": round(len(ok) / wall, 1),
        **_percentiles(lat),
    }


def straggler_drain_ab(*, n: int = 2048, fast: int = 14, seed: int = 5
                       ) -> dict:
    """Two-phase vs single-phase over an identical batch holding one
    deterministic never-converging straggler (path graph, far-end
    query): the drain must beat single-phase wall-clock for the batch
    AND return bit-identical ids for every converged query."""
    d = 8
    rng = np.random.default_rng(seed)
    x = np.zeros((n, d), np.float32)
    x[:, 0] = np.arange(n)
    x[:, 1:] = 0.01 * rng.standard_normal((n, d - 1))
    graph = np.full((n, 2), -1, np.int32)
    graph[:, 0] = np.arange(n) - 1
    graph[: n - 1, 1] = np.arange(1, n)
    sv = ServingIndex.from_graph(graph, x, start=0)
    # fast queries sit within a few hops of the entry (they converge well
    # inside drain_iters); the single far-end query is the straggler
    q = np.concatenate([x[rng.integers(0, 5, size=fast)] + 0.001,
                        x[n - 1 :] + 0.001])
    kw = dict(k=4, query_chunk=fast + 1, straggler_chunk=2,
              ladder=(OperatingPoint("b8", beam=8, expansions=4),),
              drain_iters=12, backstop_iters=64)

    def run(two_phase: bool):
        loop = ServeLoop(sv, two_phase=two_phase, **kw)
        rids = [loop.submit(qi) for qi in q]
        loop.run_until_drained()          # warm both compiled variants
        loop = ServeLoop(sv, two_phase=two_phase, **kw)
        rids = [loop.submit(qi) for qi in q]
        t0 = time.perf_counter()
        res = {r.rid: r for r in loop.run_until_drained()}
        wall = time.perf_counter() - t0
        drained = [res[r].latency for r in rids
                   if res[r].ok and res[r].phase == 1]
        return loop, rids, res, wall, drained

    loop2, rids2, res2, wall2, drained2 = run(True)
    loop1, rids1, res1, wall1, drained1 = run(False)
    mismatches = 0
    for i in range(len(q)):
        a, b = res2[rids2[i]], res1[rids1[i]]
        if a.phase == 1 and not np.array_equal(a.ids, b.ids):
            mismatches += 1
    return {
        "bench": "straggler_drain_ab",
        "batch": len(q),
        "stragglers_rerun": int(loop2.counters["rerun_phase2"]),
        "drained_p99_ms": round(
            float(np.percentile(drained2, 99)) * 1e3, 3),
        "single_phase_p99_ms": round(
            float(np.percentile(drained1, 99)) * 1e3, 3),
        "drain_speedup": round(
            float(np.percentile(drained1, 99))
            / max(float(np.percentile(drained2, 99)), 1e-9), 2),
        "wall_two_phase_ms": round(wall2 * 1e3, 2),
        "wall_single_phase_ms": round(wall1 * 1e3, 2),
        "drained_bit_identical": mismatches == 0,
    }


def fault_drill(*, n: int = 4096, d: int = 32, n_queries: int = 128,
                seed: int = 0) -> dict:
    """The Issue-9 deterministic fault schedule against the sharded loop:
    1 of 8 shards killed for search calls [1, 6), one straggling shard,
    5% NaN queries.  Must run in a process where jax already sees 8
    devices (``fault_drill_subprocess`` arranges that from a plain run).
    """
    import jax
    from jax.sharding import Mesh

    from repro.core.beam_search import brute_force_knn, recall_at_k
    from repro.testing.faults import FaultPlan, inject_faults, poison_queries

    S = 8
    assert len(jax.devices()) == S, len(jax.devices())
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal((n_queries, d)).astype(np.float32)
    idx = _build(x)
    mesh = Mesh(np.array(jax.devices()), ("shards",))
    ssv = ServingIndex.from_index(idx, x, mesh=mesh)
    truth = brute_force_knn(x, q, 10)
    r_healthy = recall_at_k(np.asarray(ssv.search(q, k=10, beam=32)),
                            truth, 10)
    qp, rows = poison_queries(q, 0.05, seed=7)
    plan = FaultPlan(shard_down={S - 1: (1, 6)}, straggle={2: 0.02})
    unhandled = 0
    with inject_faults(ssv, plan):
        loop = ServeLoop(ssv, k=10, query_chunk=16, straggler_chunk=8,
                         max_queue=256, probe_every=1)
        rid_to_row = {loop.submit(qp[i]): i for i in range(len(qp))}
        try:
            res = loop.run_until_drained()
            for _ in range(16):       # idle steps: probe readmits the shard
                loop.step()
                if not loop.index.down_shards:
                    break
        except Exception:
            unhandled += 1
            res = []
    ids = np.full((len(qp), 10), -1, np.int64)
    for r in res:
        if r.ok:
            ids[rid_to_row[r.rid]] = r.ids
    ok_rows = np.setdiff1d(np.arange(len(qp)), rows)
    r_deg = recall_at_k(ids[ok_rows], truth[ok_rows], 10)
    bad = sorted(rid_to_row[r.rid] for r in res if r.error)
    return {
        "bench": "fault_drill",
        "n_shards": S,
        "requests": len(qp),
        "completed": len(res),
        "unhandled_errors": unhandled,
        "poisoned": int(rows.size),
        "structured_errors": sum(1 for r in res if r.error),
        "errors_match_poisoned": bad == sorted(rows.tolist()),
        "recall_healthy": round(float(r_healthy), 4),
        "recall_degraded": round(float(r_deg), 4),
        "degraded_ratio": round(float(r_deg / max(r_healthy, 1e-9)), 4),
        "shard_readmitted": int(loop.counters["shards_readmitted"]),
    }


_FAULT_DRILL_CHILD = r"""
import json
from benchmarks.bench_serving_loop import fault_drill
print(json.dumps(fault_drill()))
"""


def fault_drill_subprocess() -> dict | None:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = f"{root / 'src'}:{root}:{env.get('PYTHONPATH', '')}"
    proc = subprocess.run([sys.executable, "-c", _FAULT_DRILL_CHILD],
                          env=env, capture_output=True, text=True,
                          timeout=1200)
    if proc.returncode != 0:
        print(proc.stderr[-2000:], file=sys.stderr)
        return {"bench": "fault_drill", "unhandled_errors": 1,
                "error": "child failed"}
    return json.loads(proc.stdout.strip().splitlines()[-1])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=400.0)
    ap.add_argument("--deadline", type=float, default=2.0)
    ap.add_argument("--chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the 8-device fault-drill subprocess")
    args = ap.parse_args(argv)

    x, q = dataset(args.n, args.d, seed=args.seed)
    rng = np.random.default_rng(args.seed + 1)
    qq = q[rng.integers(0, len(q), args.requests)]
    idx = _build(x)
    sv = ServingIndex.from_index(idx, x)

    records = []
    rec = poisson_load(sv, qq, rate=args.rate, seed=args.seed,
                       deadline_s=args.deadline, chunk=args.chunk)
    records.append(rec)
    print(json.dumps(rec))
    rec = straggler_drain_ab()
    records.append(rec)
    print(json.dumps(rec))
    if not args.no_sharded:
        rec = fault_drill_subprocess()
        if rec is not None:
            records.append(rec)
            print(json.dumps(rec))
    append_bench_json(records, path=BENCH_SERVING_JSON,
                      bench="serving_loop_smoke", n=args.n, d=args.d,
                      requests=args.requests)
    print(f"appended {len(records)} records to {BENCH_SERVING_JSON.name}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
