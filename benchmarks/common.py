"""Shared benchmark infrastructure.

Every bench module exposes ``run() -> list[Row]``; a Row is
``(name, us_per_call, derived)`` and ``benchmarks.run`` prints them as the
CSV the deliverables require.  Datasets are Gaussian-mixture vectors with
planted neighbor structure (data/pipeline.py), sized for the 1-core CPU
container — the billion-scale regime is exercised structurally by the
dry-run, not here.
"""
from __future__ import annotations

import functools
import json
import pathlib
import time
from typing import Callable

import numpy as np

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, pad_ids, recall_at_k
from repro.data.pipeline import VectorPipelineConfig, make_queries, make_vectors

Row = tuple[str, float, str]


@functools.lru_cache(maxsize=8)
def dataset(n: int = 8192, d: int = 32, seed: int = 0,
            n_queries: int = 256) -> tuple[np.ndarray, np.ndarray]:
    cfg = VectorPipelineConfig(n=n, dim=d, n_clusters=32, seed=seed)
    return make_vectors(cfg), make_queries(cfg, n_queries)


@functools.lru_cache(maxsize=8)
def ground_truth(n: int, d: int, seed: int = 0, k: int = 10,
                 n_queries: int = 256) -> np.ndarray:
    x, q = dataset(n, d, seed, n_queries)
    return brute_force_knn(x, q, k)


BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_build.json"
BENCH_QPS_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_qps.json"


def append_bench_json(records: list[dict], path: pathlib.Path | None = None,
                      **meta) -> None:
    """Append one run's records to a bench-history JSON (list of run dicts)
    so the perf trajectory is tracked across PRs.  ``path`` defaults to
    BENCH_build.json; the serving benches write BENCH_qps.json.  ``meta``
    (n, d, bench, ...) is stored alongside the records."""
    path = BENCH_JSON if path is None else path
    history = []
    if path.exists():
        try:
            history = json.loads(path.read_text())
        except (json.JSONDecodeError, OSError):
            history = []
        if not isinstance(history, list):
            history = []
    history.append({**meta, "records": records})
    path.write_text(json.dumps(history, indent=1))


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    """Returns (result, seconds) — median over ``repeat`` runs.

    Blocks on jax async results so dispatch-only times never leak in."""
    import jax

    ts = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        try:
            jax.block_until_ready(out)
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    return out, float(np.median(ts))


def graph_recall(graph: np.ndarray, start: int, x: np.ndarray,
                 q: np.ndarray, truth: np.ndarray, *, beam: int = 64,
                 k: int = 10, metric: str = "l2") -> float:
    """10@10 recall of beam search over an adjacency matrix."""
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    ids, _ = bs.beam_search_batch(
        jnp.asarray(graph), jnp.asarray(x), jnp.asarray(q),
        start=start, beam=beam, iters=beam + 4, metric=metric)
    return recall_at_k(np.asarray(ids)[:, :k], truth[:, :k], k)


def qps_at_recall(graph: np.ndarray, start: int, x: np.ndarray,
                  q: np.ndarray, truth: np.ndarray, *,
                  target: float = 0.9, metric: str = "l2",
                  beams=(8, 16, 24, 32, 48, 64, 96, 128),
                  search_ids_fn=None) -> tuple[float, float, int]:
    """Sweep beam widths; return (QPS, recall, beam) at the first beam
    reaching ``target`` recall (or the best seen).

    ``search_ids_fn(beam) -> ids [Q, >=10]`` overrides the engine; the
    default packs a ``ServingIndex`` once and runs the multi-expansion
    serving path (what ``pipnn.search`` uses)."""
    if search_ids_fn is None:
        from repro.core.serving import ServingIndex

        sv = ServingIndex.from_graph(graph, x, start, metric=metric)
        search_ids_fn = lambda beam: sv.search(q, k=10, beam=beam)
    best = (0.0, 0.0, beams[-1])
    for beam in beams:
        fn = lambda: search_ids_fn(beam)
        ids, _ = timed(fn)                           # warm-up/compile
        ids, secs = timed(fn, repeat=3)
        r = recall_at_k(pad_ids(ids, 10), truth[:, :10], 10)
        qps = q.shape[0] / max(secs, 1e-9)
        best = (qps, r, beam)
        if r >= target:
            return best
    return best


def fmt(x: float, nd: int = 3) -> str:
    return f"{x:.{nd}f}"
