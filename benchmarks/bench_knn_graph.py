"""Fig. 6: downstream k-NN graph construction (k=10, >=95% recall target):
index build + all-points query, end-to-end, PiPNN vs Vamana vs HNSW."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, dataset, timed
from repro.core import pipnn
from repro.core.baselines.hnsw import HNSWParams, build_hnsw
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.knn_graph import knn_graph_pipnn, knn_graph_recall
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D, K = 4096, 32, 10


def _query_all(graph, start, x, k):
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    found, _ = bs.beam_search_batch(
        jnp.asarray(graph), jnp.asarray(x), jnp.asarray(x), start=start,
        beam=48, iters=52)
    out = np.empty((x.shape[0], k), dtype=np.int64)
    f = np.asarray(found)
    for i in range(x.shape[0]):
        row = f[i][f[i] != i][:k]
        out[i] = np.pad(row, (0, k - len(row)), constant_values=-1)[:k]
    return out


def run() -> list[Row]:
    x, _ = dataset(N, D)
    rows: list[Row] = []

    p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=3), l_max=64, max_deg=32, seed=0)
    (knn, timings), t_pipnn = timed(knn_graph_pipnn, x, k=K, beam=48,
                                    params=p)
    r = knn_graph_recall(x, knn, k=K, sample=400)
    rows.append(("knn_graph/pipnn", t_pipnn * 1e6,
                 f"recall={r:.3f} build_s={timings['build']:.2f} "
                 f"query_s={timings['query']:.2f} slowdown=1.00x"))

    def vam():
        g, start, _ = build_vamana(x, VamanaParams(max_deg=32, beam=48))
        return _query_all(g, start, x, K)

    knn_v, t_vam = timed(vam)
    rv = knn_graph_recall(x, knn_v, k=K, sample=400)
    rows.append(("knn_graph/vamana", t_vam * 1e6,
                 f"recall={rv:.3f} slowdown={t_vam / t_pipnn:.2f}x"))

    def hnsw():
        g, start, _ = build_hnsw(x, HNSWParams(m=16, ef_construction=48))
        return _query_all(g, start, x, K)

    knn_h, t_hnsw = timed(hnsw)
    rh = knn_graph_recall(x, knn_h, k=K, sample=400)
    rows.append(("knn_graph/hnsw", t_hnsw * 1e6,
                 f"recall={rh:.3f} slowdown={t_hnsw / t_pipnn:.2f}x"))
    return rows
