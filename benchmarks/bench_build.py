"""Fig. 1 / Fig. 5 (build-time columns): PiPNN vs Vamana (1- and 2-pass),
HNSW, HCNNG — equal max degree, same dataset, build time + index quality.

The paper's headline: PiPNN builds 6-12x faster than Vamana/HNSW at equal
quality.  Our incremental baselines are faithful numpy implementations of
the same algorithms (beam-search construction), so the *ratio* reproduces
the search-bottleneck argument even though absolute times are CPU-scale.
"""
from __future__ import annotations

from benchmarks.common import Row, dataset, graph_recall, ground_truth, timed
from repro.core import pipnn
from repro.core.baselines.hcnng import HCNNGParams, build_hcnng
from repro.core.baselines.hnsw import HNSWParams, build_hnsw
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 4096, 32
MAX_DEG = 32


def _pipnn_params(replicas: int = 1) -> PiPNNParams:
    return PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2), replicas=replicas),
        leaf=LeafParams(k=2), hash_bits=12, l_max=64, max_deg=MAX_DEG,
        seed=0)


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    results = {}

    idx, t_pipnn = timed(pipnn.build, x, _pipnn_params())
    results["pipnn_1rep"] = (idx.graph, idx.start, t_pipnn)
    idx2, t_pipnn2 = timed(pipnn.build, x, _pipnn_params(replicas=2))
    results["pipnn_2rep"] = (idx2.graph, idx2.start, t_pipnn2)

    (g, start, stats), t_vam = timed(
        build_vamana, x, VamanaParams(max_deg=MAX_DEG, beam=48, passes=1))
    results["vamana_1pass"] = (g, start, t_vam)
    (g2, start2, _), t_vam2 = timed(
        build_vamana, x, VamanaParams(max_deg=MAX_DEG, beam=48, passes=2))
    results["vamana_2pass"] = (g2, start2, t_vam2)

    (gh, starth, _), t_hnsw = timed(
        build_hnsw, x, HNSWParams(m=MAX_DEG // 2, ef_construction=48))
    results["hnsw"] = (gh, starth, t_hnsw)

    (gc, startc, _), t_hcnng = timed(
        build_hcnng, x, HCNNGParams(c_max=256, replicas=6, max_deg=90))
    results["hcnng"] = (gc, startc, t_hcnng)

    for name, (graph, start, secs) in results.items():
        r = graph_recall(graph, start, x, q, truth, beam=64)
        speedup = results["vamana_1pass"][2] / secs
        rows.append((f"build/{name}", secs * 1e6,
                     f"recall={r:.3f} speedup_vs_vamana={speedup:.2f}x "
                     f"deg={float((graph >= 0).sum(1).mean()):.1f}"))
    return rows
