"""Fig. 1 / Fig. 5 (build-time columns): PiPNN vs Vamana (1- and 2-pass),
HNSW, HCNNG — equal max degree, same dataset, build time + index quality.

The paper's headline: PiPNN builds 6-12x faster than Vamana/HNSW at equal
quality.  Our incremental baselines are faithful numpy implementations of
the same algorithms (beam-search construction), so the *ratio* reproduces
the search-bottleneck argument even though absolute times are CPU-scale.

Also measures the streaming device-resident build vs the O(E) flat oracle
(wall time + peak candidate-edge bytes), records each registered build
program's AOT-compiled peak device bytes next to the memory auditor's
model-priced prediction (the PIPM004 contract, made visible as a bench
series), and appends the rows to ``BENCH_build.json`` at the repo root so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import (Row, append_bench_json, dataset, graph_recall,
                               ground_truth, timed)
from repro.core import pipnn
from repro.core.baselines.hcnng import HCNNGParams, build_hcnng
from repro.core.baselines.hnsw import HNSWParams, build_hnsw
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 4096, 32
MAX_DEG = 32


def _pipnn_params(replicas: int = 1) -> PiPNNParams:
    return PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2), replicas=replicas),
        leaf=LeafParams(k=2), hash_bits=12, l_max=64, max_deg=MAX_DEG,
        seed=0)


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    results = {}
    records: list[dict] = []

    # streaming (device-resident, bounded memory; segmented merge default)
    # vs the flat-merge fold (global re-sort per chunk) vs flat (O(E)
    # oracle); all three graphs are bit-identical (asserted by tests /
    # check.sh) so only the first gets a recall pass.  The segmented-vs-
    # flat-merge wall delta is the regression signal for ROADMAP's
    # "streaming 2-3x slower on CPU (reservoir re-sort)" item.
    idx, t_pipnn = timed(pipnn.build, x, _pipnn_params())
    results["pipnn_1rep"] = (idx.graph, idx.start, t_pipnn)
    idx_m, t_flatmerge = timed(
        pipnn.build, x, _pipnn_params().with_(merge="flat"))
    idx_f, t_flat = timed(pipnn.build, x, _pipnn_params(), streaming=False)
    for name, i, t in (("streaming", idx, t_pipnn),
                       ("streaming_flatmerge", idx_m, t_flatmerge),
                       ("flat", idx_f, t_flat)):
        rows.append((
            f"build/pipnn_memory_{name}",
            i.stats["peak_edge_bytes"],
            f"peak_candidate_edge_bytes={i.stats['peak_edge_bytes']} "
            f"merge_workspace_bytes={i.stats['merge_workspace_bytes']} "
            f"n_candidate_edges={i.stats['n_candidate_edges']} "
            f"wall_s={t:.3f} final_prune_s={i.timings['final_prune']:.3f}",
        ))
        records.append({
            "variant": name, "wall_s": t,
            "peak_edge_bytes": int(i.stats["peak_edge_bytes"]),
            "edge_bytes_build_leaves": int(i.stats["edge_bytes_build_leaves"]),
            "merge_workspace_bytes": int(i.stats["merge_workspace_bytes"]),
            "n_candidate_edges": int(i.stats["n_candidate_edges"]),
            "timings": {k: float(v) for k, v in i.timings.items()},
        })
    records.append({
        "variant": "merge_delta",
        "segmented_vs_flatmerge_wall_s": t_pipnn - t_flatmerge,
        "streaming_vs_flat_wall_s": t_pipnn - t_flat,
    })

    idx2, t_pipnn2 = timed(pipnn.build, x, _pipnn_params(replicas=2))
    results["pipnn_2rep"] = (idx2.graph, idx2.start, t_pipnn2)

    (g, start, stats), t_vam = timed(
        build_vamana, x, VamanaParams(max_deg=MAX_DEG, beam=48, passes=1))
    results["vamana_1pass"] = (g, start, t_vam)
    (g2, start2, _), t_vam2 = timed(
        build_vamana, x, VamanaParams(max_deg=MAX_DEG, beam=48, passes=2))
    results["vamana_2pass"] = (g2, start2, t_vam2)

    (gh, starth, _), t_hnsw = timed(
        build_hnsw, x, HNSWParams(m=MAX_DEG // 2, ef_construction=48))
    results["hnsw"] = (gh, starth, t_hnsw)

    (gc, startc, _), t_hcnng = timed(
        build_hcnng, x, HCNNGParams(c_max=256, replicas=6, max_deg=90))
    results["hcnng"] = (gc, startc, t_hcnng)

    for name, (graph, start, secs) in results.items():
        r = graph_recall(graph, start, x, q, truth, beam=64)
        speedup = results["vamana_1pass"][2] / secs
        rows.append((f"build/{name}", secs * 1e6,
                     f"recall={r:.3f} speedup_vs_vamana={speedup:.2f}x "
                     f"deg={float((graph >= 0).sum(1).mean()):.1f}"))
        records.append({"variant": name, "wall_s": secs, "recall": r})
    rows += _aot_peak_rows(records)
    append_bench_json(records, bench="build", n=N, d=D, max_deg=MAX_DEG)
    return rows


def _aot_peak_rows(records: list[dict]) -> list[Row]:
    """Measured AOT peak device bytes per registered build program at its
    canonical lattice point, next to the auditor's model-priced prediction
    (exact avals + workspace model — what PIPM003 extrapolates from)."""
    from repro.analysis import memory_audit as ma

    if not ma.ledger_available():
        return [("build/aot_peak", 0.0, "skipped: no compiled byte ledger")]
    rows: list[Row] = []
    for spec in ma.default_specs():
        if spec.kind != "build":
            continue
        ledger, _ = ma.measure(spec, spec.base)
        pred = ma.price_envelope(dataclasses.replace(spec,
                                                     envelope=dict(spec.base),
                                                     envelope_pricer=None))
        ratio = ledger["peak"] / max(pred["total"], 1)
        rows.append((
            f"build/aot_peak_{spec.name}", ledger["peak"],
            f"measured_peak_bytes={int(ledger['peak'])} "
            f"predicted_bytes={pred['total']} ratio={ratio:.2f} "
            f"temp_bytes={int(ledger['temp_size_in_bytes'])} "
            f"workspace_model_bytes={pred['workspace_bytes']}"))
        records.append({
            "variant": f"aot_{spec.name}", "point": dict(spec.base),
            "measured_peak_bytes": int(ledger["peak"]),
            "measured_temp_bytes": int(ledger["temp_size_in_bytes"]),
            "predicted_peak_bytes": int(pred["total"]),
            "workspace_model_bytes": int(pred["workspace_bytes"]),
            "measured_over_predicted": round(ratio, 3),
        })
    return rows
