"""Fig. 13 / Table 5: HashPrune parameter grid — hash bits m x reservoir
size l_max (plus the unbounded-reservoir control), quality at fixed beam.
The paper's finding: broad insensitivity for m >= 8; m = 6 degrades."""
from __future__ import annotations

from benchmarks.common import Row, dataset, graph_recall, ground_truth, timed
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    for bits in (6, 8, 12, 16):
        for l_max in (32, 64, 128):
            p = PiPNNParams(
                rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                leaf=LeafParams(k=2), hash_bits=bits, l_max=l_max,
                max_deg=32, seed=0)
            idx, secs = timed(pipnn.build, x, p)
            r = graph_recall(idx.graph, idx.start, x, q, truth, beam=64)
            rows.append((f"hashprune/m{bits}_l{l_max}", secs * 1e6,
                         f"recall={r:.3f} "
                         f"avg_deg={idx.average_degree():.2f}"))
    return rows
