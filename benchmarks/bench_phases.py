"""Fig. 4: fraction of build time in Partition / Build-Leaves / HashPrune /
Final-Prune, from the orchestrator's own timers — for the streaming
device-resident pipeline (segmented merge default), the flat-merge fold
variant, and the O(E) flat oracle, plus each path's actual allocated
candidate-edge / merge-workspace bytes (peak, per stage)."""
from __future__ import annotations

from benchmarks.common import Row, dataset
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32

PHASES = ("partition", "build_leaves", "hashprune", "final_prune")
BYTE_STATS = ("peak_edge_bytes", "edge_bytes_build_leaves",
              "merge_workspace_bytes")


def run() -> list[Row]:
    x, _ = dataset(N, D)
    p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=2), max_deg=32, seed=0)
    rows: list[Row] = []
    variants = (("streaming", p, True),
                ("streaming_flatmerge", p.with_(merge="flat"), True),
                ("flat", p, False))
    for label, params, streaming in variants:
        idx = pipnn.build(x, params, streaming=streaming)
        total = idx.timings["total"]
        for phase in PHASES:
            t = idx.timings[phase]
            rows.append((f"phases/{label}/{phase}", t * 1e6,
                         f"share={t / total:.3f}"))
        for stat in BYTE_STATS:
            rows.append((f"phases/{label}/{stat}", idx.stats[stat], "bytes"))
        rows.append((f"phases/{label}/total", total * 1e6,
                     f"peak_edge_bytes={idx.stats['peak_edge_bytes']}"))
    return rows
