"""Fig. 4: fraction of build time in Partition / Build-Leaves / HashPrune /
Final-Prune, from the orchestrator's own timers — for the streaming
device-resident pipeline (segmented merge default), the flat-merge fold
variant, and the O(E) flat oracle, plus each path's actual allocated
candidate-edge / merge-workspace bytes (peak, per stage).

Also sweeps the Stage-1 execution strategies (host numpy oracle,
host-orchestrated device carve, fully-static two-level device carve) and
records the device-vs-host partition wall-time deltas as a
``partition_delta`` record in BENCH_build.json — the regression signal
for the ROADMAP's "Stage 1 is the last host bottleneck" item."""
from __future__ import annotations

from benchmarks.common import Row, append_bench_json, dataset
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32

PHASES = ("partition", "build_leaves", "hashprune", "final_prune")
BYTE_STATS = ("peak_edge_bytes", "edge_bytes_build_leaves",
              "merge_workspace_bytes")


def _params(execution: str = "auto") -> PiPNNParams:
    return PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2), execution=execution),
        leaf=LeafParams(k=2), max_deg=32, seed=0)


def run() -> list[Row]:
    x, _ = dataset(N, D)
    rows: list[Row] = []
    records: list[dict] = []
    p = _params()
    variants = (("streaming", p, True),
                ("streaming_flatmerge", p.with_(merge="flat"), True),
                ("flat", p, False),
                # Stage-1 execution sweep (streaming Stage 2-4 throughout):
                # host oracle vs host-orchestrated device carve vs the
                # fully-static two-level device carve
                ("part_host", _params("host"), True),
                ("part_device", _params("device"), True),
                ("part_static", _params("static"), True))
    part_wall: dict[str, float] = {}
    for label, params, streaming in variants:
        if label in ("part_device", "part_static"):
            # warm run: these Stage-1 paths jit-compile per padded shape
            # on first use; partition_delta should record the steady-state
            # wall time, not tracing overhead (part_host is pure numpy and
            # its Stage 2-4 shapes were already compiled by "streaming")
            pipnn.build(x, params, streaming=streaming)
        idx = pipnn.build(x, params, streaming=streaming)
        total = idx.timings["total"]
        for phase in PHASES:
            t = idx.timings[phase]
            rows.append((f"phases/{label}/{phase}", t * 1e6,
                         f"share={t / total:.3f}"))
        for stat in BYTE_STATS:
            rows.append((f"phases/{label}/{stat}", idx.stats[stat], "bytes"))
        rows.append((f"phases/{label}/total", total * 1e6,
                     f"peak_edge_bytes={idx.stats['peak_edge_bytes']}"))
        records.append({
            "variant": label,
            "partition_execution": idx.stats["partition_execution"],
            "timings": {k: float(v) for k, v in idx.timings.items()},
            "n_leaves": int(idx.stats["n_leaves"]),
            "partition_uncovered": int(idx.stats["partition_uncovered"]),
        })
        if label.startswith("part_"):
            part_wall[label] = idx.timings["partition"]
    records.append({
        "variant": "partition_delta",
        "device_vs_host_partition_s":
            part_wall["part_device"] - part_wall["part_host"],
        "static_vs_host_partition_s":
            part_wall["part_static"] - part_wall["part_host"],
    })
    rows.append(("phases/partition_delta/device_vs_host",
                 (part_wall["part_device"] - part_wall["part_host"]) * 1e6,
                 f"host_s={part_wall['part_host']:.3f} "
                 f"device_s={part_wall['part_device']:.3f} "
                 f"static_s={part_wall['part_static']:.3f}"))
    append_bench_json(records, bench="phases", n=N, d=D)
    return rows
