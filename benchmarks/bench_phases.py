"""Fig. 4: fraction of build time in Partition / Build-Leaves / HashPrune /
Final-Prune, from the orchestrator's own timers."""
from __future__ import annotations

from benchmarks.common import Row, dataset
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32


def run() -> list[Row]:
    x, _ = dataset(N, D)
    p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=2), max_deg=32, seed=0)
    idx = pipnn.build(x, p)
    total = idx.timings["total"]
    rows: list[Row] = []
    for phase in ("partition", "build_leaves", "hashprune", "final_prune"):
        t = idx.timings[phase]
        rows.append((f"phases/{phase}", t * 1e6,
                     f"share={t / total:.3f}"))
    return rows
