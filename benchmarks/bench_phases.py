"""Fig. 4: fraction of build time in Partition / Build-Leaves / HashPrune /
Final-Prune, from the orchestrator's own timers — for BOTH Stage-2+3
strategies (streaming device-resident pipeline vs the O(E) flat oracle),
plus the peak candidate-edge bytes each one holds."""
from __future__ import annotations

from benchmarks.common import Row, dataset
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32

PHASES = ("partition", "build_leaves", "hashprune", "final_prune")


def run() -> list[Row]:
    x, _ = dataset(N, D)
    p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=2), max_deg=32, seed=0)
    rows: list[Row] = []
    for label, streaming in (("streaming", True), ("flat", False)):
        idx = pipnn.build(x, p, streaming=streaming)
        total = idx.timings["total"]
        for phase in PHASES:
            t = idx.timings[phase]
            rows.append((f"phases/{label}/{phase}", t * 1e6,
                         f"share={t / total:.3f}"))
        rows.append((f"phases/{label}/total", total * 1e6,
                     f"peak_edge_bytes={idx.stats['peak_edge_bytes']}"))
    return rows
