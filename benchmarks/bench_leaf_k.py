"""Fig. 11: sweep the leaf k-NN parameter k in [1..8] — degree grows,
visited-nodes falls, QPS peaks at k in {2,3,4} (the paper's sweet spot)."""
from __future__ import annotations

from benchmarks.common import Row, dataset, ground_truth, qps_at_recall
from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams

N, D = 8192, 32


def run() -> list[Row]:
    x, q = dataset(N, D)
    truth = ground_truth(N, D)
    rows: list[Row] = []
    for k in (1, 2, 3, 4, 6, 8):
        p = PiPNNParams(rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                        leaf=LeafParams(k=k), max_deg=32, seed=0)
        idx = pipnn.build(x, p)
        qps, r, beam = qps_at_recall(idx.graph, idx.start, x, q, truth,
                                     target=0.9)
        rows.append((f"leaf_k/k{k}", 1e6 / max(qps, 1e-9),
                     f"qps@0.9={qps:.0f} recall={r:.3f} "
                     f"avg_deg={idx.average_degree():.2f}"))
    return rows
