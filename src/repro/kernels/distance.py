"""Pallas TPU kernel: batched pairwise distance matrices.

The paper's hot loop (Sec. 4.2): all-pairs distances inside each leaf via
GEMM.  On TPU this is an MXU kernel: grid over (leaf, row-tile, col-tile);
each step loads a [bm, d] row tile and [bn, d] col tile into VMEM, computes
the inner-product tile on the MXU, and fuses the norm expansion
``||a-b||^2 = |a|^2 + |b|^2 - 2ab`` so the distance tile is produced in one
pass without materializing intermediate products in HBM.

Also here: the int8 variant (paper Sec. 6 future work — "quantized GEMM
operations on scalar-quantized points").  int8 x int8 -> int32 runs on the
MXU at 2x bf16 throughput on v5e; BigANN (uint8) and MS-SPACEV (int8) are
natively quantized datasets.

Tiling notes (v5e): MXU is 128x128; bm = bn = 128 default, full-d K panels
(d <= 2048 after padding => a 128x2048 f32 tile is 1 MB; two tiles + the
f32 accumulator tile (64 KB) sit comfortably in ~128 MB VMEM even with
double buffering).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_kernel(a_ref, b_ref, o_ref, *, metric: str):
    a = a_ref[0].astype(jnp.float32)           # [bm, d]
    b = b_ref[0].astype(jnp.float32)           # [bn, d]
    ip = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # [bm, bn] on the MXU
    if metric == "mips":
        o_ref[0] = -ip
        return
    if metric == "cosine":
        an = jnp.sqrt(jnp.sum(a * a, axis=-1))[:, None]
        bn_ = jnp.sqrt(jnp.sum(b * b, axis=-1))[None, :]
        o_ref[0] = 1.0 - ip / jnp.maximum(an * bn_, 1e-30)
        return
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    o_ref[0] = jnp.maximum(a2 + b2 - 2.0 * ip, 0.0)


def _dist_kernel_int8(a_ref, b_ref, o_ref):
    a = a_ref[0].astype(jnp.int32)
    b = b_ref[0].astype(jnp.int32)
    # int8 dot with int32 accumulation on the MXU
    ip = jax.lax.dot_general(
        a_ref[0], b_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    o_ref[0] = a2 + b2 - 2 * ip


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("metric", "bm", "bn", "interpret")
)
def pairwise_distance(
    a: jax.Array,   # [B, M, D]
    b: jax.Array,   # [B, N, D]
    *,
    metric: str = "l2",
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Batched pairwise dissimilarity via the Pallas kernel. [B, M, N] f32."""
    bsz, m, d = a.shape
    n = b.shape[1]
    a = _pad_to(_pad_to(a, 1, bm), 2, 128)
    b = _pad_to(_pad_to(b, 1, bn), 2, 128)
    mp, np_ = a.shape[1], b.shape[1]
    dp = a.shape[2]
    grid = (bsz, mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_dist_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((bsz, mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, dp), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bn, dp), lambda bb, i, j: (bb, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j: (bb, i, j)),
        interpret=interpret,
    )(a, b)
    return out[:, :m, :n]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def pairwise_distance_int8(
    a: jax.Array,   # [B, M, D] int8
    b: jax.Array,   # [B, N, D] int8
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Quantized squared-L2 on int8 inputs -> int32 distances."""
    if a.dtype != jnp.int8 or b.dtype != jnp.int8:
        raise TypeError("pairwise_distance_int8 expects int8 inputs")
    bsz, m, d = a.shape
    n = b.shape[1]
    a = _pad_to(_pad_to(a, 1, bm, 0), 2, 128, 0)
    b = _pad_to(_pad_to(b, 1, bn, 0), 2, 128, 0)
    mp, np_, dp = a.shape[1], b.shape[1], a.shape[2]
    grid = (bsz, mp // bm, np_ // bn)
    out = pl.pallas_call(
        _dist_kernel_int8,
        out_shape=jax.ShapeDtypeStruct((bsz, mp, np_), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, dp), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bn, dp), lambda bb, i, j: (bb, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bb, i, j: (bb, i, j)),
        interpret=interpret,
    )(a, b)
    return out[:, :m, :n]
