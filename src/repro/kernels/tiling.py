"""TPU tiling arithmetic — single-sourced for kernels and the analyzer.

Mosaic lays VMEM arrays out in (sublane, lane) tiles over the trailing two
dimensions; the minimum tile depends on the element width:

    f32/int32 -> (8, 128)      bf16/f16 -> (16, 128)      int8/fp8 -> (32, 128)

A block whose trailing dims are not tile multiples still *occupies* the
rounded-up tile in VMEM (a [1, n] f32 row costs 8 sublanes, a [n, 32] f32
block costs n x 128 lanes), so any byte accounting that ignores the
rounding under-counts — sometimes by 4x and more for narrow-d points
blocks.  ``fits_vmem`` (kernels/gather_distance.py) and the static
contract checker (``repro.analysis``) both price shapes through
``padded_bytes`` so the admission predicate and the analyzer can never
disagree about what a block really costs on TPU.
"""
from __future__ import annotations

import os

import numpy as np

LANE = 128

# ---------------------------------------------------------------------------
# Device-HBM budget — the ONE resolver every byte gate prices against.
#
# Three consumers used to carry their own copy of "how much HBM does a
# device have" (the SPMD auditor's PIPS003 gate, the roofline model's
# fits-HBM bit, and the memory auditor's PIPM003 envelope gate); a drift
# between them would let a packing pass one gate and fail another.  They
# all call ``hbm_budget()`` now: v5e-class 16 GiB by default, overridable
# per run via the ``PIPNN_DEVICE_HBM_BUDGET`` env var (bytes).
# ---------------------------------------------------------------------------

DEFAULT_HBM_BUDGET = 16 * 1024**3
HBM_BUDGET_ENV = "PIPNN_DEVICE_HBM_BUDGET"


def hbm_budget() -> int:
    """Per-device HBM byte budget: ``PIPNN_DEVICE_HBM_BUDGET`` env
    override, v5e-class 16 GiB default.  Read at call time so a test or
    CI job can re-point every gate with one env var."""
    return int(os.environ.get(HBM_BUDGET_ENV, DEFAULT_HBM_BUDGET))

# minimum sublane rows per element width (bytes)
_SUBLANE_BY_ITEMSIZE = {1: 32, 2: 16, 4: 8, 8: 8}


def sublane(dtype) -> int:
    """Minimum sublane-tile rows for ``dtype`` (f32 -> 8, bf16 -> 16,
    int8 -> 32)."""
    return _SUBLANE_BY_ITEMSIZE.get(np.dtype(dtype).itemsize, 8)


def round_up(x: int, mult: int) -> int:
    return -(-int(x) // int(mult)) * int(mult)


def padded_shape(shape: tuple, dtype) -> tuple:
    """``shape`` with the trailing two dims rounded up to the dtype's
    minimum (sublane, lane) tile — the extents the block actually occupies
    in VMEM.  0-d and 1-d shapes pad the lane dim only (a 1-d array is one
    sublane-padded row; ``padded_bytes`` accounts for that)."""
    shape = tuple(int(s) for s in shape)
    if not shape:
        return shape
    out = list(shape)
    out[-1] = round_up(out[-1], LANE)
    if len(out) >= 2:
        out[-2] = round_up(out[-2], sublane(dtype))
    return tuple(out)


def padded_bytes(shape: tuple, dtype) -> int:
    """VMEM bytes a block of ``shape``/``dtype`` occupies after tile
    rounding.  1-d shapes are priced as a single sublane-padded row."""
    dtype = np.dtype(dtype)
    if len(shape) == 1:
        shape = (1, shape[0])
    p = padded_shape(shape, dtype)
    total = dtype.itemsize
    for s in p:
        total *= max(int(s), 1)
    return total
