"""jit'd public wrappers for the Pallas kernels.

On this container (CPU) kernels run in ``interpret=True`` mode — the kernel
body executes in Python/XLA-CPU for correctness validation; on TPU the same
calls lower to Mosaic.  ``default_interpret()`` picks automatically.

``make_knn_fn`` adapts FlashKNN to the ``build_leaf_edges`` hook so the
whole PiPNN build can run on the fused kernel end-to-end
(``PiPNNParams`` users pass ``knn_fn=ops.make_knn_fn(k, metric)``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.distance import pairwise_distance, pairwise_distance_int8
from repro.kernels.edge_hash import edge_hashes
from repro.kernels.leaf_knn import leaf_topk
from repro.kernels.topk import rowwise_topk

__all__ = [
    "pairwise_distance",
    "pairwise_distance_int8",
    "edge_hashes",
    "leaf_topk",
    "rowwise_topk",
    "default_interpret",
    "make_knn_fn",
]


@functools.cache
def default_interpret() -> bool:
    """True when no TPU is present (kernels validate in interpret mode)."""
    return jax.default_backend() != "tpu"


@functools.cache
def make_knn_fn(k: int, metric: str = "l2", interpret: bool | None = None):
    """FlashKNN as a drop-in for leaf.build_leaf_edges(knn_fn=...).

    Cached on the arguments so repeated calls return the SAME callable:
    the streaming build keys its compiled fused step on knn_fn identity,
    so a stable callable means one compile per configuration instead of
    one per build.
    """
    interp = default_interpret() if interpret is None else interpret

    def knn(pts: jax.Array, valid: jax.Array):
        return leaf_topk(pts, valid, k=k, metric=metric, interpret=interp)

    return knn
