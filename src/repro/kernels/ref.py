"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; kernels must match them (tests sweep shapes and
dtypes with assert_allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_distance_ref(
    a: jax.Array, b: jax.Array, metric: str = "l2"
) -> jax.Array:
    """Batched pairwise dissimilarity. a: [B, M, D], b: [B, N, D] -> [B, M, N].

    Accumulation in f32 regardless of input dtype (bf16/f32 inputs).
    """
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    ip = jnp.einsum("bmd,bnd->bmn", a32, b32)
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = jnp.linalg.norm(a32, axis=-1)[:, :, None]
        bn = jnp.linalg.norm(b32, axis=-1)[:, None, :]
        return 1.0 - ip / jnp.maximum(an * bn, 1e-30)
    a2 = jnp.sum(a32 * a32, axis=-1)[:, :, None]
    b2 = jnp.sum(b32 * b32, axis=-1)[:, None, :]
    return jnp.maximum(a2 + b2 - 2.0 * ip, 0.0)


def pairwise_distance_int8_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Quantized squared-L2 (paper Sec. 6 future work). int8 in, int32 out.

    ||a-b||^2 = a.a + b.b - 2 a.b, exact in int32 for d <= 2^15.
    """
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    ip = jnp.einsum("bmd,bnd->bmn", a32, b32)
    a2 = jnp.sum(a32 * a32, axis=-1)[:, :, None]
    b2 = jnp.sum(b32 * b32, axis=-1)[:, None, :]
    return a2 + b2 - 2 * ip


def leaf_topk_ref(
    pts: jax.Array,    # [B, C, D]
    valid: jax.Array,  # [B, C] bool
    *,
    k: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """FlashKNN oracle: per-row k nearest co-leaf points (self/pad excluded).

    Returns (idx [B, C, k] in-leaf positions, -1 pad; dist [B, C, k], +inf pad).
    Ties broken toward the smaller in-leaf index (matches kernel).
    """
    d = pairwise_distance_ref(pts, pts, metric)
    c = pts.shape[1]
    eye = jnp.eye(c, dtype=bool)
    mask = valid[:, None, :] & valid[:, :, None] & ~eye[None]
    d = jnp.where(mask, d, jnp.inf)
    # stable top-k with index tie-breaking: sort (dist, idx)
    iota = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), d.shape)
    sd, si = jax.lax.sort((d, iota), dimension=-1, num_keys=2)
    sd, si = sd[..., :k], si[..., :k]
    ok = jnp.isfinite(sd)
    return jnp.where(ok, si, -1), jnp.where(ok, sd, jnp.inf)


def rowwise_topk_ref(
    d: jax.Array,      # [B, M, N] dissimilarities (+inf = masked)
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Vectorized partial-sort oracle (the paper's VQPartialSort analogue).

    Returns (idx [B, M, k], vals [B, M, k]); ties toward smaller index.
    """
    iota = jnp.broadcast_to(
        jnp.arange(d.shape[-1], dtype=jnp.int32), d.shape
    )
    sd, si = jax.lax.sort((d, iota), dimension=-1, num_keys=2)
    sd, si = sd[..., :k], si[..., :k]
    ok = jnp.isfinite(sd)
    return jnp.where(ok, si, -1), jnp.where(ok, sd, jnp.inf)


def gather_distance_ref(
    points: jax.Array,   # [n, d] (f32 or downcast)
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
) -> jax.Array:
    """Fused gather + distance oracle for the serving path: [Q, C] f32.

    ``out[q, c]`` is the dissimilarity between ``queries[q]`` and
    ``points[nbr_ids[q, c]]`` (+inf where ``nbr_ids < 0``).  The point-side
    norm term comes from the precomputed ``norms`` (f32, computed before
    any dtype downcast of ``points``); the inner product is accumulated in
    f32 regardless of the points dtype.
    """
    q32 = queries.astype(jnp.float32)
    safe = jnp.maximum(nbr_ids, 0)
    g = points[safe].astype(jnp.float32)                 # [Q, C, d]
    # broadcast-multiply + reduce: XLA CPU lowers this far better than a
    # batched-matvec einsum (the TPU path is the Pallas kernel's MXU
    # dot_general; both accumulate in f32)
    ip = jnp.sum(q32[:, None, :] * g, axis=-1)
    if metric == "mips":
        d = -ip
    elif metric == "cosine":
        qn = jnp.linalg.norm(q32, axis=-1)
        d = 1.0 - ip / jnp.maximum(qn[:, None] * norms[safe], 1e-30)
    else:
        q2 = jnp.sum(q32 * q32, axis=-1)
        d = jnp.maximum(q2[:, None] + norms[safe] - 2.0 * ip, 0.0)
    return jnp.where(nbr_ids >= 0, d, jnp.inf)


def sketch_hash_ref(
    x: jax.Array,           # [N, D] points
    hyperplanes: jax.Array,  # [M_BITS, D]
) -> jax.Array:
    """Fused sketch+nothing oracle: sketches [N, M_BITS] f32.

    (Bit packing happens per-edge; the kernel fuses the GEMM + padding.)
    """
    return (x.astype(jnp.float32) @ hyperplanes.astype(jnp.float32).T)
