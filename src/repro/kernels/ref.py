"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics; kernels must match them (tests sweep shapes and
dtypes with assert_allclose against these).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_distance_ref(
    a: jax.Array, b: jax.Array, metric: str = "l2"
) -> jax.Array:
    """Batched pairwise dissimilarity. a: [B, M, D], b: [B, N, D] -> [B, M, N].

    Accumulation in f32 regardless of input dtype (bf16/f32 inputs).
    """
    a32 = a.astype(jnp.float32)
    b32 = b.astype(jnp.float32)
    ip = jnp.einsum("bmd,bnd->bmn", a32, b32)
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = jnp.linalg.norm(a32, axis=-1)[:, :, None]
        bn = jnp.linalg.norm(b32, axis=-1)[:, None, :]
        return 1.0 - ip / jnp.maximum(an * bn, 1e-30)
    a2 = jnp.sum(a32 * a32, axis=-1)[:, :, None]
    b2 = jnp.sum(b32 * b32, axis=-1)[:, None, :]
    return jnp.maximum(a2 + b2 - 2.0 * ip, 0.0)


def pairwise_distance_int8_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Quantized squared-L2 (paper Sec. 6 future work). int8 in, int32 out.

    ||a-b||^2 = a.a + b.b - 2 a.b, exact in int32 for d <= 2^15.
    """
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    ip = jnp.einsum("bmd,bnd->bmn", a32, b32)
    a2 = jnp.sum(a32 * a32, axis=-1)[:, :, None]
    b2 = jnp.sum(b32 * b32, axis=-1)[:, None, :]
    return a2 + b2 - 2 * ip


def leaf_topk_ref(
    pts: jax.Array,    # [B, C, D]
    valid: jax.Array,  # [B, C] bool
    *,
    k: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """FlashKNN oracle: per-row k nearest co-leaf points (self/pad excluded).

    Returns (idx [B, C, k] in-leaf positions, -1 pad; dist [B, C, k], +inf pad).
    Ties broken toward the smaller in-leaf index (matches kernel).
    """
    d = pairwise_distance_ref(pts, pts, metric)
    c = pts.shape[1]
    eye = jnp.eye(c, dtype=bool)
    mask = valid[:, None, :] & valid[:, :, None] & ~eye[None]
    d = jnp.where(mask, d, jnp.inf)
    # stable top-k with index tie-breaking: sort (dist, idx)
    iota = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), d.shape)
    sd, si = jax.lax.sort((d, iota), dimension=-1, num_keys=2)
    sd, si = sd[..., :k], si[..., :k]
    ok = jnp.isfinite(sd)
    return jnp.where(ok, si, -1), jnp.where(ok, sd, jnp.inf)


def rowwise_topk_ref(
    d: jax.Array,      # [B, M, N] dissimilarities (+inf = masked)
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Vectorized partial-sort oracle (the paper's VQPartialSort analogue).

    Returns (idx [B, M, k], vals [B, M, k]); ties toward smaller index.
    """
    iota = jnp.broadcast_to(
        jnp.arange(d.shape[-1], dtype=jnp.int32), d.shape
    )
    sd, si = jax.lax.sort((d, iota), dimension=-1, num_keys=2)
    sd, si = sd[..., :k], si[..., :k]
    ok = jnp.isfinite(sd)
    return jnp.where(ok, si, -1), jnp.where(ok, sd, jnp.inf)


def quantize_symmetric(
    v: jax.Array, eps: float = 1e-12
) -> tuple[jax.Array, jax.Array]:
    """Per-row symmetric int8 scalar quantization over the last axis.

    ``scale = max(|v|) / 127`` (clamped to ``eps`` so zero rows quantize to
    zeros instead of dividing by zero), ``q = clip(round(v / scale))``.
    Returns ``(q int8 [..., d], scale f32 [...])``.  This is THE
    quantization scheme of the repo — the SPMD build's int8 routing, the
    int8 ``ServingIndex`` packing, and the gather-distance kernel's
    query-side quantization all use it, so kernel and oracle quantize
    bit-identically (max is order-independent, round/clip elementwise).

    ``scale`` is formed as ``max * (1/127)`` — an explicit f32 reciprocal
    multiply, NOT a division: XLA strength-reduces constant-divisor
    divisions to reciprocal multiplies under jit but not eagerly, which
    would put jitted (kernel) and eager (oracle) scales one ulp apart and
    break the bit-for-bit interpret tests.
    """
    v32 = v.astype(jnp.float32)
    inv127 = jnp.float32(1.0 / 127.0)
    scale = jnp.maximum(jnp.max(jnp.abs(v32), axis=-1), eps) * inv127
    q = jnp.clip(jnp.round(v32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def gather_distance_int8_core(
    points: jax.Array,   # [n, d] int8 (quantize_symmetric packing)
    scales: jax.Array,   # [n] f32 per-point dequantization scales
    norms: jax.Array,    # [n] f32 EXACT norms (computed pre-quantization)
    q8: jax.Array,       # [Q, d] int8 pre-quantized queries
    sq: jax.Array,       # [Q] f32 query dequantization scales
    q_norms: jax.Array,  # [Q] f32 query norm terms (metrics.point_norms)
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
) -> jax.Array:
    """Quantized gather + distance on PRE-quantized queries: [Q, C] f32.

    The serving engine's XLA path quantizes the (loop-invariant) query
    batch ONCE and calls this per beam-search step, skipping the
    per-step requantize that the self-contained oracle wrapper pays.
    """
    safe = jnp.maximum(nbr_ids, 0)
    g = points[safe].astype(jnp.int32)                   # [Q, C, d]
    sg = scales[safe]                                    # [Q, C] f32
    ip = jnp.einsum("qd,qcd->qc", q8.astype(jnp.int32), g)
    ipf = ip.astype(jnp.float32) * (sq[:, None] * sg)
    if metric == "mips":
        d = -ipf
    elif metric == "cosine":
        d = 1.0 - ipf / jnp.maximum(q_norms[:, None] * norms[safe], 1e-30)
    else:
        d = jnp.maximum(q_norms[:, None] + norms[safe] - 2.0 * ipf, 0.0)
    return jnp.where(nbr_ids >= 0, d, jnp.inf)


def gather_distance_int8_ref(
    points: jax.Array,   # [n, d] int8 (quantize_symmetric packing)
    scales: jax.Array,   # [n] f32 per-point dequantization scales
    norms: jax.Array,    # [n] f32 EXACT norms (computed pre-quantization)
    queries: jax.Array,  # [Q, d] f32
    q_norms: jax.Array,  # [Q] f32 query norm terms (metrics.point_norms)
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
) -> jax.Array:
    """Quantized gather + distance oracle for int8 serving: [Q, C] f32.

    The query is quantized per-row with the SAME symmetric scheme as the
    packed points (``quantize_symmetric``), the inner product accumulates
    exactly in int32, and only that term is rescaled:
    ``ip ~= s_q * s_p * <q8, p8>``.  Both norm halves of the expansion
    stay EXACT — ``norms`` are f32 norms of the original points and
    ``q_norms`` of the f32 queries (``metrics.point_norms`` — a query
    is just a point on the norm side) — so
    quantization error enters through the inner product alone.  The
    Pallas kernel (``kernels.gather_distance.gather_distance_int8``)
    matches this bit-for-bit in interpret mode: integer ops are exact,
    every f32 op is written in the same order on both sides, and the
    quantization itself is row-local and order-independent, so WHERE it
    runs (per kernel tile, hoisted once per batch in the engine, or here
    per call) cannot change the bits.
    """
    q8, sq = quantize_symmetric(queries)
    return gather_distance_int8_core(points, scales, norms, q8, sq,
                                     q_norms, nbr_ids, metric=metric)


def gather_distance_ref(
    points: jax.Array,   # [n, d] (f32 or downcast)
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
) -> jax.Array:
    """Fused gather + distance oracle for the serving path: [Q, C] f32.

    ``out[q, c]`` is the dissimilarity between ``queries[q]`` and
    ``points[nbr_ids[q, c]]`` (+inf where ``nbr_ids < 0``).  The point-side
    norm term comes from the precomputed ``norms`` (f32, computed before
    any dtype downcast of ``points``); the inner product is accumulated in
    f32 regardless of the points dtype.
    """
    q32 = queries.astype(jnp.float32)
    safe = jnp.maximum(nbr_ids, 0)
    g = points[safe].astype(jnp.float32)                 # [Q, C, d]
    # broadcast-multiply + reduce: XLA CPU lowers this far better than a
    # batched-matvec einsum (the TPU path is the Pallas kernel's MXU
    # dot_general; both accumulate in f32)
    ip = jnp.sum(q32[:, None, :] * g, axis=-1)
    if metric == "mips":
        d = -ip
    elif metric == "cosine":
        qn = jnp.linalg.norm(q32, axis=-1)
        d = 1.0 - ip / jnp.maximum(qn[:, None] * norms[safe], 1e-30)
    else:
        q2 = jnp.sum(q32 * q32, axis=-1)
        d = jnp.maximum(q2[:, None] + norms[safe] - 2.0 * ip, 0.0)
    return jnp.where(nbr_ids >= 0, d, jnp.inf)


def gather_distance_hbm_ref(
    points: jax.Array,   # [n, d] (f32 or downcast)
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
) -> jax.Array:
    """Bit-identity oracle for the HBM-streaming f32 kernel: [Q, C] f32.

    Same SEMANTICS as ``gather_distance_ref`` (allclose-tested), but the
    f32 arithmetic mirrors the streaming kernel's shapes exactly so the
    match is bit-for-bit in interpret mode: ``d`` is zero-padded to the
    lane width (the kernel's VMEM scratch rows) and the inner product is
    the elementwise-multiply + last-axis ``jnp.sum`` the kernel performs
    per gathered row — f32 sum reductions are only bit-stable when both
    sides reduce the same padded extent in the same order.  The int8
    streaming kernel needs no separate oracle: its accumulation is int32
    (order-free) so ``gather_distance_int8_ref`` already matches it
    bit-for-bit.
    """
    lane = 128
    pad = (-queries.shape[1]) % lane
    q32 = jnp.pad(queries.astype(jnp.float32), ((0, 0), (0, pad)))
    pts = jnp.pad(points, ((0, 0), (0, pad)))
    safe = jnp.maximum(nbr_ids, 0)
    g = pts[safe].astype(jnp.float32)                    # [Q, C, dp]
    ip = jnp.sum(g * q32[:, None, :], axis=-1)
    if metric == "mips":
        d = -ip
    elif metric == "cosine":
        qn = jnp.sqrt(jnp.sum(q32 * q32, axis=-1))
        d = 1.0 - ip / jnp.maximum(qn[:, None] * norms[safe], 1e-30)
    else:
        q2 = jnp.sum(q32 * q32, axis=-1)
        d = jnp.maximum(q2[:, None] + norms[safe] - 2.0 * ip, 0.0)
    return jnp.where(nbr_ids >= 0, d, jnp.inf)


def sketch_hash_ref(
    x: jax.Array,           # [N, D] points
    hyperplanes: jax.Array,  # [M_BITS, D]
) -> jax.Array:
    """Fused sketch+nothing oracle: sketches [N, M_BITS] f32.

    (Bit packing happens per-edge; the kernel fuses the GEMM + padding.)
    """
    return (x.astype(jnp.float32) @ hyperplanes.astype(jnp.float32).T)


def edge_hashes_ref(src_sketch: jax.Array, dst_sketch: jax.Array) -> jax.Array:
    """Packed residual hashes [E] int32 — oracle for ``edge_hashes``.

    Eq. 1: the concatenated sign bits of Sketch(dst) - Sketch(src),
    weighted by powers of two (bit i of the hash is sketch column i).
    """
    bits = ((dst_sketch - src_sketch) >= 0.0).astype(jnp.int32)
    m = bits.shape[-1]
    weights = 2 ** jnp.arange(m, dtype=jnp.int32)
    return jnp.sum(bits * weights, axis=-1)


def merge_sorted_reservoirs_ref(
    a_ids: jax.Array, a_hashes: jax.Array, a_dists: jax.Array,
    b_ids: jax.Array, b_hashes: jax.Array, b_dists: jax.Array,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """R(A ∪ B) oracle for ``merge_sorted_reservoirs`` — sort-based, so it
    shares no code with the kernel's rank-based one-hot merge.

    Per row: drop the loser of every cross-side hash collision (smaller
    (dist, id) key wins, exact ties keep A), sort the survivors of the
    concatenated row by (dist, id), truncate to l_max, pad with
    (id -1, hash 0, dist +inf).  Returns ``(ids, hashes, dists)``.
    """
    ad = a_dists.astype(jnp.float32)
    bd = b_dists.astype(jnp.float32)
    l = a_ids.shape[1]
    va, vb = a_ids != -1, b_ids != -1

    def lt(d1, i1, d2, i2):
        return (d1 < d2) | ((d1 == d2) & (i1 < i2))

    b_lt_a = lt(bd[:, None, :], b_ids[:, None, :],
                ad[:, :, None], a_ids[:, :, None])        # [n, lA, lB]
    pair_ok = va[:, :, None] & vb[:, None, :]
    collide = (a_hashes[:, :, None] == b_hashes[:, None, :]) & pair_ok
    keep_a = va & ~jnp.any(collide & b_lt_a, axis=2)
    keep_b = vb & ~jnp.any(collide & ~b_lt_a, axis=1)

    keep = jnp.concatenate([keep_a, keep_b], axis=1)
    ids = jnp.where(keep, jnp.concatenate([a_ids, b_ids], axis=1), -1)
    hs = jnp.where(keep, jnp.concatenate([a_hashes, b_hashes], axis=1), 0)
    ds = jnp.where(keep, jnp.concatenate([ad, bd], axis=1), jnp.inf)

    order = jnp.lexsort((ids, ds), axis=1)
    take = jnp.take_along_axis
    return (take(ids, order, 1)[:, :l], take(hs, order, 1)[:, :l],
            take(ds, order, 1)[:, :l])
