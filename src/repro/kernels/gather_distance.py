"""Pallas TPU kernel: fused neighbor gather + distance block for serving.

The multi-expansion beam search's per-step hot loop: given the ``E*R``
neighbor ids each query just expanded, gather their vectors and compute the
``[Q_tile, E*R]`` dissimilarity block in one pass.  Grid over query tiles;
per step the kernel

  * loads a ``[TQ, d]`` query tile and its ``[TQ, C]`` neighbor-id tile
    into VMEM,
  * gathers the ``TQ*C`` neighbor rows from the VMEM-resident points block,
  * contracts queries against their gathered neighbors as a batched
    matvec on the MXU (``dot_general`` with a batch dim, f32 accumulation),
  * fuses the norm expansion using the PRECOMPUTED f32 point norms
    (``metrics.point_norms`` — computed before any points-dtype downcast,
    so a bf16 serving copy only rounds the inner-product term),
  * writes +inf for ``-1``-padded ids.

The points block is replicated to every grid step, so the compiler keeps
one VMEM-resident copy: this kernel targets serving shards whose points
fit VMEM (``fits_vmem``); larger shards use the XLA fallback
(``kernels.ref.gather_distance_ref``), which streams the gather from HBM.
``beam_search_batch(use_pallas=...)`` auto-enables it on TPU exactly like
``edge_hash`` / ``segmented_merge``, and it is interpret-mode tested
against the oracle on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
_TQ = 8  # query rows per grid step (f32 sublane tile)

# points bytes budget for auto-enabling the VMEM-resident kernel (leave
# headroom out of ~16 MB/core for the query/id/output tiles)
_VMEM_POINTS_BUDGET = 8 * 1024 * 1024


def fits_vmem(points: jax.Array, budget: int = _VMEM_POINTS_BUDGET) -> bool:
    """True when the points block is small enough to keep VMEM-resident."""
    return points.size * points.dtype.itemsize <= budget


def _gather_distance_kernel(q_ref, ids_ref, pts_ref, n2_ref, o_ref, *,
                            metric: str):
    q = q_ref[...].astype(jnp.float32)          # [TQ, d]
    ids = ids_ref[...]                          # [TQ, C]
    tq, c = ids.shape
    flat = jnp.maximum(ids.reshape(-1), 0)      # [TQ*C]
    g = jnp.take(pts_ref[...], flat, axis=0).astype(jnp.float32)
    g = g.reshape(tq, c, -1)                    # [TQ, C, d]
    # batched matvec on the MXU: contract d, batch over the query row
    ip = jax.lax.dot_general(
        q, g, (((1,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )                                           # [TQ, C]
    if metric == "mips":
        d = -ip
    else:
        n2 = jnp.take(n2_ref[...].reshape(-1), flat).reshape(tq, c)
        if metric == "cosine":
            qn = jnp.sqrt(jnp.sum(q * q, axis=-1))
            d = 1.0 - ip / jnp.maximum(qn[:, None] * n2, 1e-30)
        else:
            q2 = jnp.sum(q * q, axis=-1)
            d = jnp.maximum(q2[:, None] + n2 - 2.0 * ip, 0.0)
    o_ref[...] = jnp.where(ids >= 0, d, jnp.inf)


def _pad(x, axis, mult, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w, constant_values=value)


@functools.partial(jax.jit, static_argnames=("metric", "tq", "interpret"))
def gather_distance(
    points: jax.Array,   # [n, d] (f32 or downcast serving copy)
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
    tq: int = _TQ,
    interpret: bool = False,
) -> jax.Array:
    """Fused gather-distance block [Q, C] f32; +inf where ``nbr_ids < 0``.

    Semantics identical to ``kernels.ref.gather_distance_ref`` (tested in
    interpret mode on CPU).
    """
    nq, c = nbr_ids.shape
    if nq == 0 or c == 0:
        return jnp.full((nq, c), jnp.inf, jnp.float32)
    points = _pad(_pad(points, 0, 8, 0), 1, LANE, 0)
    norms = _pad(norms.astype(jnp.float32), 0, 8, 0.0).reshape(1, -1)
    queries = _pad(_pad(queries, 0, tq, 0), 1, LANE, 0)
    nbr_ids = _pad(_pad(nbr_ids, 0, tq, -1), 1, LANE, -1)
    qp, dp = queries.shape
    cp = nbr_ids.shape[1]
    np_ = points.shape[0]
    out = pl.pallas_call(
        functools.partial(_gather_distance_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.float32),
        grid=(qp // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((np_, dp), lambda r: (0, 0)),
            pl.BlockSpec((1, norms.shape[1]), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tq, cp), lambda r: (r, 0)),
        interpret=interpret,
    )(queries, nbr_ids, points, norms)
    return out[:nq, :c]
