"""Pallas TPU kernel: fused neighbor gather + distance block for serving.

The multi-expansion beam search's per-step hot loop: given the ``E*R``
neighbor ids each query just expanded, gather their vectors and compute the
``[Q_tile, E*R]`` dissimilarity block in one pass.  Grid over query tiles;
per step the kernel

  * loads a ``[TQ, d]`` query tile and its ``[TQ, C]`` neighbor-id tile
    into VMEM,
  * gathers the ``TQ*C`` neighbor rows from the VMEM-resident points block,
  * contracts queries against their gathered neighbors as a batched
    matvec on the MXU (``dot_general`` with a batch dim, f32 accumulation),
  * fuses the norm expansion using the PRECOMPUTED f32 point norms
    (``metrics.point_norms`` — computed before any points-dtype downcast,
    so a bf16 serving copy only rounds the inner-product term),
  * writes +inf for ``-1``-padded ids.

The points block is replicated to every grid step, so the compiler keeps
one VMEM-resident copy: this kernel targets serving shards whose points
fit VMEM (``fits_vmem``).  Larger shards use the HBM-streaming twins
(``gather_distance_hbm`` / ``gather_distance_int8_hbm``): points stay in
HBM (``TPUMemorySpace.ANY``) and each query row's neighbor rows arrive in
VMEM scratch via double-buffered ``pltpu.make_async_copy`` DMAs — while
row ``t`` computes its distances the row ``t+1`` copies are already in
flight.  The serving engine's kernel-path resolution
(``beam_search.resolve_kernel_path``) selects vmem vs hbm per shard size
instead of silently dropping to the XLA gather
(``kernels.ref.gather_distance_ref``), which remains the CPU path.  All
four kernels are interpret-mode tested against their oracles on CPU.

``gather_distance_int8`` is the scalar-quantized twin (paper Sec. 6:
"quantized GEMM operations on scalar-quantized points"): int8 points +
per-point f32 scales packed by ``ServingIndex(dtype="int8")``, int8 x int8
-> int32 batched matvec on the MXU, fused rescale + exact-norm expansion.
The 4x-smaller points block means ``fits_vmem`` admits shards 4x larger
before HBM streaming is needed — and once it is, the int8 packing also
cuts the streamed DMA bytes 4x per row.

The VMEM points budget is configurable: ``fits_vmem(budget=...)`` per
call (``ServingIndex(vmem_budget=...)`` threads it through), or the
``PIPNN_VMEM_POINTS_BUDGET`` environment variable to override the
default globally.
"""
from __future__ import annotations

import functools
import logging
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ref as _ref
from repro.kernels.tiling import LANE, padded_bytes

logger = logging.getLogger(__name__)
_TQ = 8  # query rows per grid step (f32 sublane tile)
_SUBLANE_I8 = 32  # int8 sublane tile: the packed points block pads rows to 32

# points bytes budget for auto-enabling the VMEM-resident kernel (leave
# headroom out of ~16 MB/core for the query/id/output tiles)
_VMEM_POINTS_BUDGET = 8 * 1024 * 1024


def vmem_points_budget() -> int:
    """The effective VMEM points budget in bytes: the
    ``PIPNN_VMEM_POINTS_BUDGET`` environment variable when set, else the
    8 MiB default.  Read per call so tests (and deployments sizing for a
    different accelerator generation) can adjust it without reimports.

    A malformed or negative override is IGNORED with a warning (a serving
    process must not crash at dispatch time over an env typo); ``0`` is a
    valid budget meaning "nothing fits" — it forces the HBM-streaming
    path wherever Pallas is requested."""
    env = os.environ.get("PIPNN_VMEM_POINTS_BUDGET", "")
    if not env:
        return _VMEM_POINTS_BUDGET
    try:
        value = int(env)
    except ValueError:
        logger.warning(
            "ignoring malformed PIPNN_VMEM_POINTS_BUDGET=%r "
            "(not an int); using the %d-byte default",
            env, _VMEM_POINTS_BUDGET)
        return _VMEM_POINTS_BUDGET
    if value < 0:
        logger.warning(
            "ignoring negative PIPNN_VMEM_POINTS_BUDGET=%d; "
            "using the %d-byte default", value, _VMEM_POINTS_BUDGET)
        return _VMEM_POINTS_BUDGET
    return value


def fits_vmem(points: jax.Array, *extras: jax.Array,
              budget: int | None = None) -> bool:
    """True when the points block (plus any ``extras`` that must ride along
    VMEM-resident, e.g. the int8 packing's per-point scales) fits the
    budget (``None``: ``vmem_points_budget()``).  The check is
    itemsize-aware, so an int8 serving copy gets 4x the f32 headroom: a
    shard that needed HBM streaming at f32 may serve fully VMEM-resident
    once scalar-quantized.

    Bytes are priced at the TPU-tile-padded footprint
    (``tiling.padded_bytes``): the kernels lane-pad d to 128 and
    sublane-pad n to the dtype tile before ``pallas_call``, so a narrow-d
    block occupies far more VMEM than ``size * itemsize`` suggests — a
    [262144, 8] f32 block is 8 MiB of payload but 128 MiB once lane-padded.
    Pricing the unpadded size here would admit shards that cannot compile
    on real hardware (the static contract checker in ``repro.analysis``
    verifies this predicate against total VMEM for exactly that reason)."""
    if budget is None:
        budget = vmem_points_budget()
    total = sum(padded_bytes(a.shape, a.dtype) for a in (points,) + extras)
    return total <= int(budget)


def _gather_distance_kernel(q_ref, ids_ref, pts_ref, n2_ref, o_ref, *,
                            metric: str):
    q = q_ref[...].astype(jnp.float32)          # [TQ, d]
    ids = ids_ref[...]                          # [TQ, C]
    tq, c = ids.shape
    flat = jnp.maximum(ids.reshape(-1), 0)      # [TQ*C]
    g = jnp.take(pts_ref[...], flat, axis=0).astype(jnp.float32)
    g = g.reshape(tq, c, -1)                    # [TQ, C, d]
    # batched matvec on the MXU: contract d, batch over the query row
    ip = jax.lax.dot_general(
        q, g, (((1,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32
    )                                           # [TQ, C]
    if metric == "mips":
        d = -ip
    else:
        n2 = jnp.take(n2_ref[...].reshape(-1), flat).reshape(tq, c)
        if metric == "cosine":
            qn = jnp.sqrt(jnp.sum(q * q, axis=-1))
            d = 1.0 - ip / jnp.maximum(qn[:, None] * n2, 1e-30)
        else:
            q2 = jnp.sum(q * q, axis=-1)
            d = jnp.maximum(q2[:, None] + n2 - 2.0 * ip, 0.0)
    o_ref[...] = jnp.where(ids >= 0, d, jnp.inf)


def _pad(x, axis, mult, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w, constant_values=value)


@functools.partial(jax.jit, static_argnames=("metric", "tq", "interpret"))
def gather_distance(
    points: jax.Array,   # [n, d] (f32 or downcast serving copy)
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
    tq: int = _TQ,
    interpret: bool = False,
) -> jax.Array:
    """Fused gather-distance block [Q, C] f32; +inf where ``nbr_ids < 0``.

    Semantics identical to ``kernels.ref.gather_distance_ref`` (tested in
    interpret mode on CPU).
    """
    nq, c = nbr_ids.shape
    if nq == 0 or c == 0:
        return jnp.full((nq, c), jnp.inf, jnp.float32)
    points = _pad(_pad(points, 0, 8, 0), 1, LANE, 0)
    norms = _pad(norms.astype(jnp.float32), 0, 8, 0.0).reshape(1, -1)
    queries = _pad(_pad(queries, 0, tq, 0), 1, LANE, 0)
    nbr_ids = _pad(_pad(nbr_ids, 0, tq, -1), 1, LANE, -1)
    qp, dp = queries.shape
    cp = nbr_ids.shape[1]
    np_ = points.shape[0]
    out = pl.pallas_call(
        functools.partial(_gather_distance_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.float32),
        grid=(qp // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((np_, dp), lambda r: (0, 0)),
            pl.BlockSpec((1, norms.shape[1]), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tq, cp), lambda r: (r, 0)),
        interpret=interpret,
    )(queries, nbr_ids, points, norms)
    return out[:nq, :c]


def _gather_distance_int8_kernel(q_ref, ids_ref, pts_ref, scl_ref, n2_ref,
                                 qa_ref, o_ref, *, metric: str):
    q = q_ref[...].astype(jnp.float32)          # [TQ, d]
    ids = ids_ref[...]                          # [TQ, C]
    tq, c = ids.shape
    flat = jnp.maximum(ids.reshape(-1), 0)      # [TQ*C]
    g = jnp.take(pts_ref[...], flat, axis=0)    # [TQ*C, d] int8 gather
    sg = jnp.take(scl_ref[...].reshape(-1), flat).reshape(tq, c)
    # query quantized with the SAME symmetric scheme as the packed points
    # (max reduction is padding-safe, round/clip elementwise => the oracle
    # quantizes bit-identically on the unpadded array)
    q8, sq = _ref.quantize_symmetric(q)
    # int8 x int8 -> int32 batched matvec on the MXU: the accumulation is
    # EXACT; only the single rescale below carries quantization error
    ip = jax.lax.dot_general(
        q8, g.reshape(tq, c, -1), (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )                                           # [TQ, C] int32
    ipf = ip.astype(jnp.float32) * (sq[:, None] * sg)
    rows = pl.program_id(0) * tq + \
        jax.lax.broadcasted_iota(jnp.int32, (tq, 1), 0)[:, 0]
    qa = jnp.take(qa_ref[...].reshape(-1), rows)          # [TQ]
    if metric == "mips":
        d = -ipf
    elif metric == "cosine":
        n2 = jnp.take(n2_ref[...].reshape(-1), flat).reshape(tq, c)
        d = 1.0 - ipf / jnp.maximum(qa[:, None] * n2, 1e-30)
    else:
        n2 = jnp.take(n2_ref[...].reshape(-1), flat).reshape(tq, c)
        d = jnp.maximum(qa[:, None] + n2 - 2.0 * ipf, 0.0)
    o_ref[...] = jnp.where(ids >= 0, d, jnp.inf)


@functools.partial(jax.jit, static_argnames=("metric", "tq", "interpret"))
def gather_distance_int8(
    points: jax.Array,   # [n, d] int8 (quantize_symmetric packing)
    scales: jax.Array,   # [n] f32 per-point dequantization scales
    norms: jax.Array,    # [n] f32 EXACT norms (computed pre-quantization)
    queries: jax.Array,  # [Q, d] f32
    q_norms: jax.Array,  # [Q] f32 query norm terms (metrics.point_norms)
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
    tq: int = _TQ,
    interpret: bool = False,
) -> jax.Array:
    """Quantized fused gather-distance block [Q, C] f32 (+inf at pads).

    The int8 serving twin of ``gather_distance``: the points block lives
    VMEM-resident at 1/4 the f32 footprint (``fits_vmem`` sees the
    itemsize, so shards 4x larger auto-enable the kernel), the gathered
    rows hit the MXU as an int8 x int8 -> int32 batched matvec, and the
    per-point scale + norm expansion are fused into the same pass.  The
    query side is quantized per-row IN the kernel (symmetric, the
    packing's own scheme — reused each grid step from the f32 query
    tile); the query norm terms arrive precomputed (``q_norms``, from
    ``metrics.point_norms`` on the queries, once per batch) so both
    norm halves of
    the distance expansion stay full-precision.  Semantics identical to
    ``kernels.ref.gather_distance_int8_ref`` — bit-for-bit in interpret
    mode (integer ops exact, f32 ops in matching order).
    """
    if points.dtype != jnp.int8:
        raise TypeError("gather_distance_int8 expects int8 points")
    nq, c = nbr_ids.shape
    if nq == 0 or c == 0:
        return jnp.full((nq, c), jnp.inf, jnp.float32)
    q32 = queries.astype(jnp.float32)
    qa = q_norms.astype(jnp.float32)
    points = _pad(_pad(points, 0, _SUBLANE_I8, 0), 1, LANE, 0)
    scales = _pad(scales.astype(jnp.float32), 0, _SUBLANE_I8, 0.0)
    norms = _pad(norms.astype(jnp.float32), 0, _SUBLANE_I8, 0.0)
    queries = _pad(_pad(q32, 0, tq, 0), 1, LANE, 0)
    nbr_ids = _pad(_pad(nbr_ids, 0, tq, -1), 1, LANE, -1)
    qa = _pad(qa, 0, tq, 0.0).reshape(1, -1)
    qp, dp = queries.shape
    cp = nbr_ids.shape[1]
    np_ = points.shape[0]
    scales = scales.reshape(1, np_)
    norms = norms.reshape(1, np_)
    out = pl.pallas_call(
        functools.partial(_gather_distance_int8_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.float32),
        grid=(qp // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((np_, dp), lambda r: (0, 0)),
            pl.BlockSpec((1, np_), lambda r: (0, 0)),
            pl.BlockSpec((1, np_), lambda r: (0, 0)),
            pl.BlockSpec((1, qp), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tq, cp), lambda r: (r, 0)),
        interpret=interpret,
    )(queries, nbr_ids, points, scales, norms, qa)
    return out[:nq, :c]


# ---------------------------------------------------------------------------
# HBM-streaming kernels: points stay in HBM, neighbor rows are DMA'd
# ---------------------------------------------------------------------------

def _row_copies(pts_hbm, ids_ref, scratch, sem, slot, t, cp):
    """The ``cp`` single-row HBM->VMEM async copies for query row ``t``
    into scratch buffer ``slot``.  ``.start()`` and ``.wait()`` must see
    the SAME copy descriptors, so both phases rebuild them through here;
    -1 ids fetch row 0 (their output is masked to +inf afterwards)."""
    def one(c, _):
        sid = jnp.maximum(ids_ref[t, c], 0)
        copy = pltpu.make_async_copy(
            pts_hbm.at[pl.ds(sid, 1), :],
            scratch.at[slot, pl.ds(c, 1), :],
            sem.at[slot],
        )
        return copy

    return one


def _stream_rows(pts_hbm, ids_ref, scratch, sem, tq, cp, compute_row):
    """Double-buffered row loop shared by both HBM kernels: issue row 0's
    copies, then per row prefetch row ``t+1`` into the other buffer,
    drain row ``t``, and hand its gathered block to ``compute_row``."""
    def issue(slot, t):
        def one(c, carry):
            _row_copies(pts_hbm, ids_ref, scratch, sem, slot, t, cp)(
                c, None).start()
            return carry
        jax.lax.fori_loop(0, cp, one, 0)

    def drain(slot, t):
        def one(c, carry):
            _row_copies(pts_hbm, ids_ref, scratch, sem, slot, t, cp)(
                c, None).wait()
            return carry
        jax.lax.fori_loop(0, cp, one, 0)

    issue(0, 0)

    def body(t, carry):
        slot = jax.lax.rem(t, 2)

        @pl.when(t + 1 < tq)
        def _prefetch_next():
            issue(jax.lax.rem(t + 1, 2), t + 1)

        drain(slot, t)
        compute_row(t, scratch[slot])
        return carry

    jax.lax.fori_loop(0, tq, body, 0)


def _gather_distance_hbm_kernel(q_ref, ids_ref, n2g_ref, pts_hbm, o_ref,
                                scratch, sem, *, metric: str):
    tq, cp = ids_ref.shape

    def compute_row(t, g):                       # g: [Cp, dp] gathered rows
        q = q_ref[t, :].astype(jnp.float32)      # [dp]
        ids = ids_ref[t, :]
        ip = jnp.sum(g.astype(jnp.float32) * q[None, :], axis=-1)   # [Cp]
        n2 = n2g_ref[t, :]                       # pre-gathered norms
        if metric == "mips":
            d = -ip
        elif metric == "cosine":
            qn = jnp.sqrt(jnp.sum(q * q))
            d = 1.0 - ip / jnp.maximum(qn * n2, 1e-30)
        else:
            q2 = jnp.sum(q * q)
            d = jnp.maximum(q2 + n2 - 2.0 * ip, 0.0)
        o_ref[pl.ds(t, 1), :] = jnp.where(ids >= 0, d, jnp.inf)[None]

    _stream_rows(pts_hbm, ids_ref, scratch, sem, tq, cp, compute_row)


@functools.partial(jax.jit, static_argnames=("metric", "tq", "interpret"))
def gather_distance_hbm(
    points: jax.Array,   # [n, d] (f32 or downcast serving copy) — stays in HBM
    norms: jax.Array,    # [n] f32 metric-dependent norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
    tq: int = _TQ,
    interpret: bool = False,
) -> jax.Array:
    """HBM-streaming gather-distance block [Q, C] f32; +inf at ``-1`` ids.

    The over-VMEM-budget twin of ``gather_distance``: the points block is
    placed in ``TPUMemorySpace.ANY`` (HBM) and never copied wholesale;
    per query row the C neighbor rows arrive in a double-buffered VMEM
    scratch via per-row ``make_async_copy`` DMAs, overlapped with the
    previous row's distance compute.  The point-side norms are gathered
    OUTSIDE the kernel into a [Q, C] block (a gather has no arithmetic,
    so it cannot move bits) and ride in as a regular VMEM input.

    Bit-identical in interpret mode to ``kernels.ref.
    gather_distance_hbm_ref`` — the oracle mirrors the kernel's reduction
    shape (d padded to the lane width, elementwise-multiply + sum) so the
    f32 accumulation order matches exactly.
    """
    nq, c = nbr_ids.shape
    if nq == 0 or c == 0:
        return jnp.full((nq, c), jnp.inf, jnp.float32)
    # pre-gather the per-candidate norms (bit-free) before any padding
    n2g = norms.astype(jnp.float32)[jnp.maximum(nbr_ids, 0)]       # [Q, C]
    points = _pad(points, 1, LANE, 0)
    queries = _pad(_pad(queries, 0, tq, 0), 1, LANE, 0)
    nbr_ids = _pad(_pad(nbr_ids, 0, tq, -1), 1, LANE, -1)
    n2g = _pad(_pad(n2g, 0, tq, 0.0), 1, LANE, 0.0)
    qp, dp = queries.shape
    cp = nbr_ids.shape[1]
    out = pl.pallas_call(
        functools.partial(_gather_distance_hbm_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.float32),
        grid=(qp // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((tq, cp), lambda r: (r, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, cp, dp), points.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(queries, nbr_ids, n2g, points)
    return out[:nq, :c]


def _gather_distance_int8_hbm_kernel(q_ref, ids_ref, sg_ref, n2g_ref, qa_ref,
                                     pts_hbm, o_ref, scratch, sem, *,
                                     metric: str):
    tq, cp = ids_ref.shape
    # quantize the query tile once per grid step — row-local and
    # order-independent, so the bits match the oracle's per-batch pass
    q8, sq = _ref.quantize_symmetric(q_ref[...].astype(jnp.float32))

    def compute_row(t, g):                       # g: [Cp, dp] int8 rows
        ids = ids_ref[t, :]
        # int8 x int8 -> int32 accumulation is EXACT (order-free), so the
        # streamed per-row reduction cannot differ from the oracle einsum
        ip = jnp.sum(g.astype(jnp.int32) * q8[t, :].astype(jnp.int32)[None, :],
                     axis=-1)                    # [Cp] int32
        ipf = ip.astype(jnp.float32) * (sq[t] * sg_ref[t, :])
        qa = qa_ref[t, 0]
        if metric == "mips":
            d = -ipf
        elif metric == "cosine":
            d = 1.0 - ipf / jnp.maximum(qa * n2g_ref[t, :], 1e-30)
        else:
            d = jnp.maximum(qa + n2g_ref[t, :] - 2.0 * ipf, 0.0)
        o_ref[pl.ds(t, 1), :] = jnp.where(ids >= 0, d, jnp.inf)[None]

    _stream_rows(pts_hbm, ids_ref, scratch, sem, tq, cp, compute_row)


@functools.partial(jax.jit, static_argnames=("metric", "tq", "interpret"))
def gather_distance_int8_hbm(
    points: jax.Array,   # [n, d] int8 (quantize_symmetric packing) — in HBM
    scales: jax.Array,   # [n] f32 per-point dequantization scales
    norms: jax.Array,    # [n] f32 EXACT norms (computed pre-quantization)
    queries: jax.Array,  # [Q, d] f32
    q_norms: jax.Array,  # [Q] f32 query norm terms (metrics.point_norms)
    nbr_ids: jax.Array,  # [Q, C] int32, -1 = padding
    *,
    metric: str = "l2",
    tq: int = _TQ,
    interpret: bool = False,
) -> jax.Array:
    """HBM-streaming quantized gather-distance block [Q, C] f32.

    The int8-first streaming kernel (the DMA traffic is 1/4 of the f32
    twin's per row): int8 points stay in HBM, neighbor rows stream into a
    double-buffered int8 VMEM scratch, and the per-point scales + exact
    norms are pre-gathered outside the kernel into [Q, C] f32 blocks.
    Query quantization happens in-kernel per tile exactly as in
    ``gather_distance_int8``.

    Bit-identical in interpret mode to ``kernels.ref.
    gather_distance_int8_ref`` — the SAME oracle as the VMEM-resident
    int8 kernel, because the int32 inner-product accumulation is
    order-free and every f32 op is elementwise in matching order, so the
    streaming row-at-a-time schedule cannot move bits.
    """
    if points.dtype != jnp.int8:
        raise TypeError("gather_distance_int8_hbm expects int8 points")
    nq, c = nbr_ids.shape
    if nq == 0 or c == 0:
        return jnp.full((nq, c), jnp.inf, jnp.float32)
    safe = jnp.maximum(nbr_ids, 0)
    sg = scales.astype(jnp.float32)[safe]                          # [Q, C]
    n2g = norms.astype(jnp.float32)[safe]                          # [Q, C]
    points = _pad(points, 1, LANE, 0)
    queries = _pad(_pad(queries.astype(jnp.float32), 0, tq, 0), 1, LANE, 0)
    nbr_ids = _pad(_pad(nbr_ids, 0, tq, -1), 1, LANE, -1)
    sg = _pad(_pad(sg, 0, tq, 0.0), 1, LANE, 0.0)
    n2g = _pad(_pad(n2g, 0, tq, 0.0), 1, LANE, 0.0)
    qa = _pad(q_norms.astype(jnp.float32), 0, tq, 0.0)[:, None]    # [Qp, 1]
    qa = _pad(qa, 1, LANE, 0.0)
    qp, dp = queries.shape
    cp = nbr_ids.shape[1]
    out = pl.pallas_call(
        functools.partial(_gather_distance_int8_hbm_kernel, metric=metric),
        out_shape=jax.ShapeDtypeStruct((qp, cp), jnp.float32),
        grid=(qp // tq,),
        in_specs=[
            pl.BlockSpec((tq, dp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((tq, cp), lambda r: (r, 0)),
            pl.BlockSpec((tq, qa.shape[1]), lambda r: (r, 0)),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
        ],
        out_specs=pl.BlockSpec((tq, cp), lambda r: (r, 0)),
        scratch_shapes=[
            pltpu.VMEM((2, cp, dp), jnp.int8),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(queries, nbr_ids, sg, n2g, qa, points)
    return out[:nq, :c]
