"""Pallas TPU kernel: bounded per-row merge of two sorted HashPrune
reservoirs (the segmented-merge hot loop, hashprune.py).

Inputs are two [n, l_max] reservoirs whose rows satisfy the HashPrune
invariants: sorted ascending by (dist, id), at most one slot per residual
hash bucket, padding (id == -1, dist == +inf) at the tail.  The kernel
produces R(A ∪ B) per row without any sort:

  * cross-reservoir bucket dedup — within a row each side already holds its
    bucket minima, so a collision can only pair an A slot with a B slot:
    one [l, l] hash-equality compare per side decides the losers
    (lexicographic (dist, id); ties keep A);
  * rank-based merge — each surviving slot's output position is its own
    survivor rank plus the count of survivors on the other side with a
    smaller key (two more [l, l] compares), so the merged row materializes
    through one-hot selects instead of a sort network;
  * truncate to l_max, pad with (id -1, hash 0, dist +inf).

Everything is elementwise compares + small-axis reductions on [rows, l, l]
tiles — pure VPU work, no MXU, no gather/scatter.  Bit-identical to the
``hashprune_batch``-based fallback in ``merge_segmented_edges`` (asserted
by tests in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashprune import Reservoir

_ROWS = 8  # row block per grid step (f32 sublane tile)


def _lt(d1, i1, d2, i2):
    """(dist, id) lexicographic strict less-than, broadcasting."""
    return (d1 < d2) | ((d1 == d2) & (i1 < i2))


def _select(onehot, x, fill):
    """Per output slot, pick the single input slot flagged in ``onehot``
    [R, l_out, l_in]; ``fill`` where no slot is flagged (avoids 0 * inf)."""
    picked = jnp.sum(jnp.where(onehot, x[:, None, :], 0), axis=2)
    return jnp.where(jnp.any(onehot, axis=2), picked, fill)


def _merge_rows_kernel(a_i_ref, a_h_ref, a_d_ref,
                       b_i_ref, b_h_ref, b_d_ref,
                       o_i_ref, o_h_ref, o_d_ref, *, l: int):
    ai, ah, ad = a_i_ref[...], a_h_ref[...], a_d_ref[...]   # [R, l]
    bi, bh, bd = b_i_ref[...], b_h_ref[...], b_d_ref[...]
    va, vb = ai != -1, bi != -1

    # pair [r, i, j] = (A slot i, B slot j)
    b_lt_a = _lt(bd[:, None, :], bi[:, None, :], ad[:, :, None], ai[:, :, None])
    a_le_b = ~b_lt_a
    pair_ok = va[:, :, None] & vb[:, None, :]
    collide = (ah[:, :, None] == bh[:, None, :]) & pair_ok

    # bucket dedup: the strictly-smaller key wins; exact key ties keep A
    keep_a = va & ~jnp.any(collide & b_lt_a, axis=2)
    keep_b = vb & ~jnp.any(collide & a_le_b, axis=1)

    # survivor rank = own-side survivors before me + other-side survivors
    # with a smaller key (A wins (dist, id) ties, so B counts a_le_b)
    excl = lambda k: jnp.cumsum(k.astype(jnp.int32), axis=1) - k.astype(jnp.int32)
    pos_a = excl(keep_a) + jnp.sum(
        (keep_b[:, None, :] & b_lt_a).astype(jnp.int32), axis=2)
    pos_b = excl(keep_b) + jnp.sum(
        (keep_a[:, :, None] & a_le_b).astype(jnp.int32), axis=1)

    slot = jax.lax.broadcasted_iota(jnp.int32, (ai.shape[0], l, l), 1)
    oh_a = keep_a[:, None, :] & (pos_a[:, None, :] == slot)
    oh_b = keep_b[:, None, :] & (pos_b[:, None, :] == slot)
    o_i_ref[...] = _select(oh_a, ai, 0) + _select(oh_b, bi, 0) - jnp.where(
        jnp.any(oh_a | oh_b, axis=2), 0, 1)
    o_h_ref[...] = _select(oh_a, ah, 0) + _select(oh_b, bh, 0)
    o_d_ref[...] = jnp.minimum(_select(oh_a, ad, jnp.inf),
                               _select(oh_b, bd, jnp.inf))


@functools.partial(jax.jit, static_argnames=("interpret",))
def merge_sorted_reservoirs(
    a_ids: jax.Array, a_hashes: jax.Array, a_dists: jax.Array,
    b_ids: jax.Array, b_hashes: jax.Array, b_dists: jax.Array,
    *,
    interpret: bool = False,
) -> Reservoir:
    """R(A ∪ B) for two per-row-sorted [n, l_max] reservoirs.

    Output rows sorted by (dist, id), padded with (-1, 0, +inf) — the same
    representation ``hashprune_batch`` produces.
    """
    n, l = a_ids.shape
    pad = (-n) % _ROWS
    if pad:
        pr = lambda x, v: jnp.pad(x, ((0, pad), (0, 0)), constant_values=v)
        a_ids, a_hashes, a_dists = pr(a_ids, -1), pr(a_hashes, 0), pr(a_dists, jnp.inf)
        b_ids, b_hashes, b_dists = pr(b_ids, -1), pr(b_hashes, 0), pr(b_dists, jnp.inf)
    rows = a_ids.shape[0]
    spec = pl.BlockSpec((_ROWS, l), lambda r: (r, 0))
    out = pl.pallas_call(
        functools.partial(_merge_rows_kernel, l=l),
        out_shape=(
            jax.ShapeDtypeStruct((rows, l), jnp.int32),
            jax.ShapeDtypeStruct((rows, l), jnp.int32),
            jax.ShapeDtypeStruct((rows, l), jnp.float32),
        ),
        grid=(rows // _ROWS,),
        in_specs=[spec] * 6,
        out_specs=(spec, spec, spec),
        interpret=interpret,
    )(a_ids, a_hashes, a_dists, b_ids, b_hashes, b_dists)
    ids, hs, ds = (x[:n] for x in out)
    return Reservoir(ids=ids, hashes=hs, dists=ds)
