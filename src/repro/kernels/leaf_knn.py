"""FlashKNN: fused per-leaf (distances + k-nearest) Pallas kernel.

The beyond-paper kernel (DESIGN.md §3): the paper materializes each leaf's
C_max x C_max distance matrix, then partial-sorts rows (Eigen + Highway
VQPartialSort, Supplement A.4).  At C_max = 2048 that is a 16 MB f32
round-trip to HBM per leaf.  This kernel never materializes the matrix:
like flash attention, the distance tile lives only in VMEM and a running
top-k (k <= 8) per row is folded in tile-by-tile.

Arithmetic-intensity math (v5e, C=2048, d=128, f32):
  materialized: 2*C^2*d FLOPs vs (C*d read + C^2 write + C^2 read) * 4 B
                => ~ 2d / 12 ≈ 21 FLOP/B  -> memory-bound at d=128.
  fused:        2*C^2*d FLOPs vs C*d*4 B read (dominant)
                => ~ 2*C FLOP/B ≈ 4096 FLOP/B -> compute-bound.  That is
  the whole optimization; the roofline section quantifies it per shape.

Grid: (leaf, row-tile i, col-tile j), j innermost.  Outputs are revisited
across j (the TPU grid is sequential over the trailing dim), acting as the
running top-k accumulator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BIG_IDX = 2**30  # python literal: jnp constants would be captured consts


def _merge_topk(comb_v, comb_i, k: int):
    """k-step (min, argmin-with-tie-toward-smaller-index) extraction."""
    outs_v, outs_i = [], []
    for _ in range(k):
        mv = jnp.min(comb_v, axis=1)                        # [bm]
        is_min = comb_v == mv[:, None]
        mi = jnp.min(jnp.where(is_min, comb_i, _BIG_IDX), axis=1)
        outs_v.append(mv)
        outs_i.append(jnp.where(jnp.isfinite(mv), mi, -1))
        chosen = is_min & (comb_i == mi[:, None])
        comb_v = jnp.where(chosen, jnp.inf, comb_v)
    return jnp.stack(outs_v, axis=1), jnp.stack(outs_i, axis=1)


def _flash_knn_kernel(
    a_ref, b_ref, vcol_ref, ov_ref, oi_ref, *, k: int, bm: int, bn: int,
    metric: str,
):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        ov_ref[0] = jnp.full((bm, k), jnp.inf, dtype=jnp.float32)
        oi_ref[0] = jnp.full((bm, k), -1, dtype=jnp.int32)

    a = a_ref[0].astype(jnp.float32)            # [bm, d]
    b = b_ref[0].astype(jnp.float32)            # [bn, d]
    ip = jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    if metric == "mips":
        d = -ip
    elif metric == "cosine":
        an = jnp.sqrt(jnp.sum(a * a, axis=-1))[:, None]
        bn_n = jnp.sqrt(jnp.sum(b * b, axis=-1))[None, :]
        d = 1.0 - ip / jnp.maximum(an * bn_n, 1e-30)
    else:
        a2 = jnp.sum(a * a, axis=-1)[:, None]
        b2 = jnp.sum(b * b, axis=-1)[None, :]
        d = jnp.maximum(a2 + b2 - 2.0 * ip, 0.0)

    col_pos = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1)
    row_pos = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0)
    col_ok = (vcol_ref[0] != 0)[None, :]        # [1, bn]
    d = jnp.where(col_ok & (row_pos != col_pos), d, jnp.inf)

    comb_v = jnp.concatenate([ov_ref[0], d], axis=1)          # [bm, k+bn]
    comb_i = jnp.concatenate([oi_ref[0], col_pos], axis=1)
    nv, ni = _merge_topk(comb_v, comb_i, k)
    ov_ref[0] = nv
    oi_ref[0] = ni


def _pad(x, axis, mult, value):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w, constant_values=value)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "bm", "bn", "interpret")
)
def leaf_topk(
    pts: jax.Array,    # [B, C, D]
    valid: jax.Array,  # [B, C] bool
    *,
    k: int,
    metric: str = "l2",
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused all-pairs + top-k per leaf.  Returns (idx, dist) [B, C, k]."""
    bsz, c, d = pts.shape
    pts_p = _pad(_pad(pts, 1, max(bm, bn), 0.0), 2, 128, 0.0)
    valid_p = _pad(valid.astype(jnp.int32), 1, max(bm, bn), 0)
    cp, dp = pts_p.shape[1], pts_p.shape[2]
    grid = (bsz, cp // bm, cp // bn)
    ov, oi = pl.pallas_call(
        functools.partial(
            _flash_knn_kernel, k=k, bm=bm, bn=bn, metric=metric
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, cp, k), jnp.float32),
            jax.ShapeDtypeStruct((bsz, cp, k), jnp.int32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, dp), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bn, dp), lambda bb, i, j: (bb, j, 0)),
            pl.BlockSpec((1, bn), lambda bb, i, j: (bb, j)),
        ],
        out_specs=(
            pl.BlockSpec((1, bm, k), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bm, k), lambda bb, i, j: (bb, i, 0)),
        ),
        interpret=interpret,
    )(pts_p, pts_p, valid_p)
    ov, oi = ov[:, :c], oi[:, :c]
    # invalid rows -> (-1, inf)
    rv = valid[:, :, None]
    return jnp.where(rv, oi, -1), jnp.where(rv, ov, jnp.inf)
