"""Pallas TPU kernels for PiPNN's compute hot spots.

distance.py  — batched pairwise distance matrices (MXU GEMM + fused norms),
               f32/bf16 and int8 (paper Sec. 6 future work) variants.
leaf_knn.py  — FlashKNN: fused distances + running top-k, never materializes
               the C_max^2 leaf matrix in HBM (beyond-paper optimization).
topk.py      — batched row-wise partial top-k (VQPartialSort analogue).
edge_hash.py — fused residual-hash bit packing (paper Eq. 1).
segmented_merge.py — rank-based per-row merge of two sorted HashPrune
               reservoirs (the segmented fold's bounded merge, no sort).
gather_distance.py — fused neighbor gather + [Q_tile, E*R] distance block
               (the multi-expansion beam search's per-step hot loop),
               f32/bf16 and int8 scalar-quantized serving variants.
ops.py       — jit'd wrappers; ref.py — pure-jnp oracles.
"""
from repro.kernels import ops, ref
from repro.kernels.gather_distance import (
    fits_vmem,
    gather_distance,
    gather_distance_int8,
)
from repro.kernels.ops import (
    edge_hashes,
    leaf_topk,
    make_knn_fn,
    pairwise_distance,
    pairwise_distance_int8,
    rowwise_topk,
)
