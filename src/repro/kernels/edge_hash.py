"""Pallas TPU kernel: fused residual-hash bit packing for candidate edges.

Given pre-gathered sketch rows for each candidate edge (src = owning point
p, dst = candidate c), computes the paper's Eq. 1 hash

    h_p(c) = pack_bits( sign(Sketch(c) - Sketch(p)) )

in one VPU pass: subtract, threshold, weighted-sum with powers of two.
Edges are viewed as [rows, 128] so tiles are lane-aligned; m <= 16 bits pack
into an int32 (stored alongside the 8-byte reservoir slot layout the paper
describes).

Wired into both PiPNN build paths via ``sketch.edge_hashes_from_ids``: the
streaming build fuses it into the per-chunk jitted step, the flat path uses
it when ``PiPNNParams.use_pallas_hash`` is set (auto-on on TPU, with the
pure-jnp ``hash_from_sketches`` as the interpret-mode fallback).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _edge_hash_kernel(src_ref, dst_ref, o_ref, *, m: int):
    s = src_ref[0]                              # [LANE, m]
    t = dst_ref[0]                              # [LANE, m]
    bits = ((t - s) >= 0.0).astype(jnp.int32)   # [LANE, m]
    weights = (2 ** jax.lax.broadcasted_iota(jnp.int32, (LANE, m), 1))
    o_ref[0] = jnp.sum(bits * weights, axis=1)  # [LANE]


@functools.partial(jax.jit, static_argnames=("interpret",))
def edge_hashes(
    src_sketch: jax.Array,   # [E, m] sketches of edge sources (owning points)
    dst_sketch: jax.Array,   # [E, m] sketches of edge destinations
    *,
    interpret: bool = False,
) -> jax.Array:
    """Packed residual hashes [E] int32."""
    e, m = src_sketch.shape
    if m > 16:
        raise ValueError(f"m={m} hash bits do not pack into the paper's "
                         "16-bit reservoir slot")
    if e == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-e) % LANE
    if pad:
        src_sketch = jnp.pad(src_sketch, ((0, pad), (0, 0)))
        dst_sketch = jnp.pad(dst_sketch, ((0, pad), (0, 0)))
    rows = src_sketch.shape[0] // LANE
    s3 = src_sketch.reshape(rows, LANE, m)
    t3 = dst_sketch.reshape(rows, LANE, m)
    out = pl.pallas_call(
        functools.partial(_edge_hash_kernel, m=m),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.int32),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, LANE, m), lambda r: (r, 0, 0)),
            pl.BlockSpec((1, LANE, m), lambda r: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANE), lambda r: (r, 0)),
        interpret=interpret,
    )(s3, t3)
    return out.reshape(-1)[:e]
