"""Pallas TPU kernel: batched row-wise top-k partial selection.

The TPU analogue of the paper's Highway VQPartialSort optimization
(Supplement A.4): given a (possibly masked, +inf) dissimilarity matrix,
select each row's k smallest entries with indices, reading each tile of the
matrix exactly once.  Used standalone (e.g. point->leader fanout selection
in the distributed RBC build) where the distance matrix already exists;
where it doesn't, prefer the fused FlashKNN kernel (leaf_knn.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.leaf_knn import _merge_topk


def topf(dists: jax.Array, f: int) -> jax.Array:
    """Indices [..., f] of the f smallest entries along the last axis,
    ordered ascending; equal values tie-break to the lower index
    (``lax.top_k`` semantics — the same order a stable argsort produces).

    This is the selection half of the shared Stage-1 leader-assignment
    step (``core/leader_assign.py``) and of the SPMD build's bucket /
    leaf fanout selection (``launch/build_index.py``).  It is the XLA
    top-k; ``rowwise_topk`` below is the Pallas single-pass variant for
    matrices that already live in HBM on TPU.
    """
    _, idx = jax.lax.top_k(-dists, f)
    return idx.astype(jnp.int32)


def _topk_kernel(d_ref, ov_ref, oi_ref, *, k: int, bm: int, bn: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        ov_ref[0] = jnp.full((bm, k), jnp.inf, dtype=jnp.float32)
        oi_ref[0] = jnp.full((bm, k), -1, dtype=jnp.int32)

    d = d_ref[0].astype(jnp.float32)                        # [bm, bn]
    col_pos = j * bn + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    comb_v = jnp.concatenate([ov_ref[0], d], axis=1)
    comb_i = jnp.concatenate([oi_ref[0], col_pos], axis=1)
    nv, ni = _merge_topk(comb_v, comb_i, k)
    ov_ref[0] = nv
    oi_ref[0] = ni


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "interpret"))
def rowwise_topk(
    d: jax.Array,   # [B, M, N] dissimilarities, +inf = masked
    *,
    k: int,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Row-wise k smallest (with original column indices). [B, M, k]."""
    bsz, m, n = d.shape
    padm = (-m) % bm
    padn = (-n) % bn
    if padm or padn:
        d = jnp.pad(d, ((0, 0), (0, padm), (0, padn)), constant_values=jnp.inf)
    mp, np_ = d.shape[1], d.shape[2]
    grid = (bsz, mp // bm, np_ // bn)
    ov, oi = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, bm=bm, bn=bn),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, mp, k), jnp.float32),
            jax.ShapeDtypeStruct((bsz, mp, k), jnp.int32),
        ),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bm, bn), lambda bb, i, j: (bb, i, j))],
        out_specs=(
            pl.BlockSpec((1, bm, k), lambda bb, i, j: (bb, i, 0)),
            pl.BlockSpec((1, bm, k), lambda bb, i, j: (bb, i, 0)),
        ),
        interpret=interpret,
    )(d)
    return oi[:, :m], ov[:, :m]
