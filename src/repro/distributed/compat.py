"""Version shims for jax APIs that moved between 0.4.x and 0.6+.

``shard_map``: promoted from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``).  Import
``shard_map_norep`` from here instead of duplicating the probe; drop this
module when the floor is jax >= 0.6.
"""
from __future__ import annotations

import functools

import jax

if hasattr(jax, "shard_map"):                      # jax >= 0.6
    shard_map_norep = functools.partial(jax.shard_map, check_vma=False)
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _sm

    shard_map_norep = functools.partial(_sm, check_rep=False)
