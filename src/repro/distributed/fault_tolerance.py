"""Fault tolerance + elasticity for long multi-pod runs.

What actually breaks at 1000+ nodes and what this module does about it:

  * **Preemption / node loss** -> checkpoint/restart.  ``RunGuard``
    installs SIGTERM/SIGINT handlers that request a final blocking
    checkpoint at the next step boundary; the training loop polls
    ``should_stop``.  On startup ``resume_or_init`` restores the newest
    committed checkpoint (data-pipeline counters included, so the token
    stream continues exactly where it left off — the pipeline is
    counter-based, Sec. data/pipeline.py).
  * **Corrupted / partial writes** -> the Checkpointer's atomic COMMIT
    protocol; restore only ever sees committed snapshots.
  * **Stragglers** -> ``StepWatchdog`` tracks a rolling step-time
    distribution and flags steps slower than ``k`` sigma (logging + a
    callback hook, e.g. to evict a node via the cluster scheduler).  At
    the JAX level, per-step work is fully synchronous SPMD, so detection +
    eviction + elastic restart is the mitigation path (same policy as
    Borg/MaxText production runs).
  * **Elastic re-scale** -> checkpoints store logical arrays;
    ``elastic.restore_to_mesh`` reshards them onto the live mesh, and the
    counter-based pipeline re-splits the batch across the new data ranks.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import time
from typing import Any, Callable

import numpy as np


class RunGuard:
    """Cooperative preemption: flips ``should_stop`` on SIGTERM/SIGINT."""

    def __init__(self, install_handlers: bool = True):
        self.should_stop = False
        self._prev = {}
        if install_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.should_stop = True

    def restore_handlers(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class StepWatchdog:
    """Rolling straggler detector over synchronous step times."""

    window: int = 50
    sigma: float = 4.0
    min_samples: int = 10
    on_straggler: Callable[[int, float, float], None] | None = None
    _times: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=50))
    flagged: list[tuple[int, float]] = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step is flagged as a straggler."""
        is_straggler = False
        if len(self._times) >= self.min_samples:
            mu = float(np.mean(self._times))
            sd = float(np.std(self._times)) + 1e-9
            if seconds > mu + self.sigma * sd and seconds > 1.5 * mu:
                is_straggler = True
                self.flagged.append((step, seconds))
                if self.on_straggler:
                    self.on_straggler(step, seconds, mu)
        self._times.append(seconds)
        return is_straggler


@dataclasses.dataclass
class RollingPercentile:
    """Rolling percentile over a bounded sample window.

    The SLO signal of the serving loop's degradation controller
    (``launch.serve_loop``): request latencies stream in through
    ``record`` and the controller reads ``percentile(99)`` — same
    bounded-window philosophy as ``StepWatchdog``, but measuring the
    tail rather than flagging individual outliers."""

    window: int = 256
    _values: collections.deque = dataclasses.field(
        default_factory=collections.deque)

    def __post_init__(self):
        self._values = collections.deque(self._values,
                                         maxlen=int(self.window))

    def __len__(self) -> int:
        return len(self._values)

    def record(self, seconds: float) -> None:
        self._values.append(float(seconds))

    def percentile(self, pct: float = 99.0) -> float:
        """Percentile over the current window (0.0 while empty — callers
        gate on ``len() >= min_samples`` before acting on it)."""
        if not self._values:
            return 0.0
        return float(np.percentile(np.fromiter(self._values, dtype=float),
                                   pct))


def resume_or_init(
    checkpointer, init_fn: Callable[[], Any], like_fn: Callable[[], Any]
) -> tuple[Any, int, dict]:
    """Restore the newest committed checkpoint or initialize fresh.

    Returns (state, start_step, extra)."""
    latest = checkpointer.latest_step()
    if latest is None:
        return init_fn(), 0, {}
    state, extra = checkpointer.restore(latest, like_fn())
    return state, latest, extra
