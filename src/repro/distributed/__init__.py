"""Distribution: sharding rules, gradient compression, fault tolerance,
elastic scaling."""
from repro.distributed import compression, elastic, fault_tolerance, sharding
