"""Sharded SPMD serving: one partition-aligned index shard per device.

The single-device ``core.serving.ServingIndex`` tops out at whatever one
device can hold; the paper's billion-scale regime (and GGNN / the
multi-GPU indexing line in PAPERS.md) shard the index across devices and
merge per-shard results.  ``ShardedServingIndex`` is that serving shape,
built from the primitives the repo already has:

  * **Partition-aligned shards with a 1-hop halo.**  Every point joins
    the shard of its nearest shard leader (``core.leader_assign`` — the
    same Stage-1 RBC assignment primitive the build uses), so ownership
    is a DISJOINT partition and locality-preserving: most graph edges
    stay intra-shard.  Each shard then also carries GHOST rows — the
    out-of-shard endpoints of its members' edges — so NO graph edge is
    dropped (the GGNN-style halo): member rows keep their full neighbor
    lists under LOCAL renumbering, ghost rows keep whichever of their
    own edges happen to land in-shard.  Each shard has its own entry
    point (the owned member nearest the global entry) and a ``gids`` map
    back to global ids; shards pad to the largest row count so the
    stacked ``[S, m, ...]`` arrays are fixed-shape.
  * **Per-shard search under ``shard_map``.**  Each device runs the
    UNCHANGED multi-expansion beam search (``_beam_search_multi``) over
    its shard — same kernels (VMEM-resident or HBM-streaming per the
    shard's size, see ``beam_search.resolve_kernel_path``), same early
    exit — then maps beam ids local -> global through ``gids``.
  * **Query routing.**  ``router="all"`` (default) replicates every query
    to every shard — the recall-parity configuration: the merged result
    can only see MORE of the graph than a single-device search.
    ``router="leaders"`` probes only each query's ``n_probes`` nearest
    shard leaders (``leader_assign`` again, now as the query router) and
    masks the other shards' results out of the merge — the
    throughput-over-recall trade.
  * **Cross-shard top-k merge.**  A global id reaching two shards' beams
    (a halo replica) carries BIT-IDENTICAL distances on both — same row
    values, same query, same padded reduction extent — which is exactly
    the dedup contract of the engine's rank-based bounded merge
    (``beam_search.merge_block``): ``cross_shard_topk`` folds the ``S``
    beams into one sorted [Q, k] block with no sort anywhere.

``ServingIndex.from_index(..., mesh=...)``, ``pipnn.search(mesh=...)``
and ``launch.serve.Retriever(mesh=...)`` all route here.  On this
container the mesh is simulated CPU devices
(``--xla_force_host_platform_device_count``); the shard_map program is
identical on a real TPU pod slice.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import metrics as _metrics
from repro.distributed.compat import shard_map_norep

ROUTERS = ("all", "leaders")


class AllShardsDown(RuntimeError):
    """Every shard is marked unhealthy — no result could be served.

    The serving loop treats this as fail-stop (nothing left to degrade
    to) rather than returning an all ``-1`` result that looks like an
    empty index."""


def _dist_to_point(x: np.ndarray, p: np.ndarray, metric: str) -> np.ndarray:
    """Host-side dissimilarity of every row of ``x`` to the single point
    ``p`` (entry-point selection; mirrors ``beam_search._dist_np``)."""
    ip = x @ p
    if metric == "mips":
        return -ip
    if metric == "cosine":
        return 1.0 - ip / np.maximum(
            np.linalg.norm(x, axis=1) * np.linalg.norm(p), 1e-30)
    return np.sum(x * x, axis=1) + p @ p - 2.0 * ip


@functools.partial(jax.jit, static_argnames=("k",))
def cross_shard_topk(ids_s: jax.Array, ds_s: jax.Array, *, k: int
                     ) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard result blocks into the global top-k.

    ``ids_s`` [S, Q, B] global ids (-1 = pad / masked), ``ds_s`` [S, Q, B]
    f32 (+inf at pads) -> (ids [Q, k], dists [Q, k]) sorted ascending by
    (dist, id) — ties break toward the smaller global id, padded with
    (-1, +inf) when fewer than ``k`` valid entries exist in the union.

    Built from the engine's own sort-free rank-based bounded merge
    (``beam_search.merge_block``): shard ownership partitions the
    dataset, and a halo replica reaching two shards' beams carries
    bit-identical distances on both (same row values, same query, same
    padded reduction extent) — exactly the merge's dedup contract, so
    folding one block at a time into a k-bounded beam is exact.  ``k``
    may exceed the per-shard beam width B — the union supplies up to
    ``S * B`` entries.

    The fold is a ``lax.scan`` over the shard axis (same left-to-right
    block order as the old Python loop, so bit-identical results): the
    traced program is one merge body regardless of S, which is what the
    mesh-shape stability rule (PIPS005) requires — a Python loop here
    would bake the shard count into the jaxpr and recompile per mesh
    size.
    """
    from repro.core.beam_search import merge_block

    _, nq, _ = ids_s.shape
    init = (jnp.full((nq, k), -1, jnp.int32),
            jnp.full((nq, k), jnp.inf, jnp.float32),
            jnp.zeros((nq, k), dtype=bool))

    def fold(carry, block):
        bids, bds = block
        return merge_block(*carry, bids.astype(jnp.int32), bds), None

    (ids, ds, _), _ = jax.lax.scan(fold, init, (ids_s, ds_s))
    return ids, ds


def cross_shard_topk_workspace_bytes(n_shards: int, nq: int, b: int,
                                     k: int) -> int:
    """Modeled XLA temp bytes of one ``cross_shard_topk`` merge: the
    [nq, k] carry triplet (ids + dists + visited) double-buffered through
    the scan plus one [nq, b] block's rank-merge scratch.  Independent of
    ``n_shards`` beyond the stacked INPUT blocks (arguments, not temp) —
    the scan body is one merge regardless of S.  Validated by the memory
    auditor (PIPM004); prices the S=256 envelope (PIPM003)."""
    carry = 2 * nq * k * 12
    block = nq * (b + k) * 32
    return carry + block


def sharded_search_workspace_bytes(nq: int, m: int, d: int, r: int,
                                   beam: int, expansions: int,
                                   n_shards: int) -> int:
    """Modeled per-device XLA temp bytes of one sharded search dispatch:
    the unchanged per-shard engine workspace over the [m, ...] local
    shard (``core.serving.engine_workspace_bytes``) plus the all-gathered
    [S, nq, beam] result blocks feeding the cross-shard merge.  Validated
    by the memory auditor when a multi-device mesh exists (PIPM004);
    prices the BigANN-1B S=256 envelope together with the packing model
    (``spmd_audit.price_shard_packing``) in PIPM003."""
    from repro.core.serving import engine_workspace_bytes

    engine = engine_workspace_bytes(nq, m, d, r, beam, expansions)
    gathered = 2 * n_shards * nq * beam * 8
    return engine + gathered


@dataclasses.dataclass
class ShardedServingIndex:
    """A PiPNN index packed as one partition-aligned shard per device.

    All shard arrays are stacked on a leading shard axis ``[S, ...]`` and
    consumed through ``shard_map`` over the single-axis ``mesh``; ``-1``
    pads everywhere (gids, local graph ids).
    """

    gids: jax.Array           # [S, m] int32 global ids, -1 pad
    graph: jax.Array          # [S, m, R] int32 LOCAL neighbor ids, -1 pad
    points: jax.Array         # [S, m, d] (f32 / downcast / int8)
    norms: jax.Array          # [S, m] f32 point norms (pre-downcast)
    starts: jax.Array         # [S] int32 per-shard local entry point
    leaders: jax.Array        # [S, d] f32 shard leader vectors (router)
    mesh: Mesh
    metric: str = "l2"
    scales: jax.Array | None = None   # [S, m] f32 dequant scales (int8)
    router: str = "all"
    n_probes: int = 2
    vmem_budget: int | None = None
    n_points: int = 0         # dataset size (each point OWNED by 1 shard)
    owned: np.ndarray | None = None   # [S] owned (member) row counts
    health: np.ndarray | None = None  # [S] bool shard health mask (None=all)
    _search_cache: dict = dataclasses.field(default_factory=dict,
                                            repr=False, compare=False)
    _dummy_scales: Any = dataclasses.field(default=None, repr=False,
                                           compare=False)
    _health_dev: Any = dataclasses.field(default=None, repr=False,
                                         compare=False)

    # Declared per-chunk host<->device transfer budget of ``search``:
    # queries in, merged ids out — everything between the shard search and
    # the cross-shard merge stays on device.  ``with_stats=True`` adds
    # three d2h crossings (hops, dist_comps, converged), and the first
    # search after a health-mask change adds one h2d (the re-committed
    # mask operand, cached until the next change).  The SPMD auditor
    # (PIPS004) replays a steady-state search under
    # ``core.transfers.ledger`` and gates against this.
    TRANSFER_BUDGET = {"h2d": 1, "d2h": 1}

    # ------------------------------------------------------------- sizing --
    @property
    def n_shards(self) -> int:
        return self.gids.shape[0]

    @property
    def shard_capacity(self) -> int:
        return self.gids.shape[1]

    @property
    def n(self) -> int:
        """Dataset size.  Live rows across shards exceed this by the halo
        replicas — count ``gids >= 0`` for the physical total."""
        return self.n_points

    @property
    def axis(self) -> str:
        return self.mesh.axis_names[0]

    def _shard_avals(self):
        """ShapeDtypeStructs of ONE shard's points/scales slice — all the
        kernel-path pricing reads.  ``self.points[0]`` would work too, but
        an eager getitem on a mesh-sharded array dispatches a gather (with
        an implicit scalar h2d for the index) on every search call."""
        pts = jax.ShapeDtypeStruct(self.points.shape[1:], self.points.dtype)
        scl = (None if self.scales is None else
               jax.ShapeDtypeStruct(self.scales.shape[1:],
                                    self.scales.dtype))
        return pts, scl

    @property
    def kernel_path(self) -> str:
        """The distance-kernel path each shard auto-selects, judged on the
        PER-SHARD [m, d] points block — the whole reason to shard is that
        the budget applies per device, not to the global index."""
        from repro.core import beam_search as _bs

        return _bs.resolve_kernel_path(*self._shard_avals(),
                                       vmem_budget=self.vmem_budget)

    def device_bytes(self, per_shard: bool = False,
                     breakdown: bool = False):
        """Device-resident footprint: the full stacked packing, or (with
        ``per_shard=True``) ONE shard's slice — what a single device
        actually holds under the mesh.  ``breakdown=True`` additionally
        splits the row-indexed bytes into member / ghost / pad shares
        (``halo_stats``) — the replication cost of the halo packing."""
        parts = (self.gids, self.graph, self.points, self.norms,
                 self.starts, self.leaders) + (
            () if self.scales is None else (self.scales,))
        total = sum(int(a.size) * a.dtype.itemsize for a in parts)
        total = total // self.n_shards if per_shard else total
        if not breakdown:
            return total
        hs = self.halo_stats()
        scale = 1.0 / self.n_shards if per_shard else 1.0
        return {
            "total": total,
            "member_bytes": int(hs["member_bytes"].sum() * scale),
            "ghost_bytes": int(hs["ghost_bytes"].sum() * scale),
            "pad_bytes": int(hs["pad_bytes"].sum() * scale),
            "halo_fraction": hs["halo_fraction"],
        }

    def halo_stats(self) -> dict[str, Any]:
        """Member / ghost / pad row accounting per shard — the replication
        cost of the GGNN-style 1-hop halo, and the measured data the SPMD
        auditor's footprint model (PIPS003) prices against.

        Returns per-shard int arrays ``members`` / ``ghosts`` / ``pads``
        (rows: owned partition members, halo replicas, -1 padding up to
        the stacked capacity ``m``), the matching ``*_bytes`` (at
        ``row_bytes`` — the per-row cost across gids+graph+points+norms
        [+scales]), and the scalar ``halo_fraction``: ghost rows' share
        of all LIVE rows across the packing — 0.0 means no replication,
        0.5 would mean every owned row is matched by a ghost copy."""
        if self.owned is None:
            raise ValueError(
                "halo_stats needs the owned-row counts recorded by "
                "from_graph; this packing was constructed without them")
        gids = np.asarray(self.gids)
        m = self.shard_capacity
        members = np.asarray(self.owned, np.int64)
        live = (gids >= 0).sum(axis=1).astype(np.int64)
        ghosts = live - members
        pads = m - live
        r, d = self.graph.shape[2], self.points.shape[2]
        row_bytes = (self.gids.dtype.itemsize
                     + r * self.graph.dtype.itemsize
                     + d * self.points.dtype.itemsize
                     + self.norms.dtype.itemsize
                     + (0 if self.scales is None
                        else self.scales.dtype.itemsize))
        total_live = max(int(live.sum()), 1)
        return {
            "members": members,
            "ghosts": ghosts,
            "pads": pads,
            "row_bytes": int(row_bytes),
            "member_bytes": members * row_bytes,
            "ghost_bytes": ghosts * row_bytes,
            "pad_bytes": pads * row_bytes,
            "halo_fraction": float(ghosts.sum() / total_live),
        }

    # ------------------------------------------------------------ packing --
    @classmethod
    def from_graph(
        cls,
        graph: np.ndarray,
        x: np.ndarray,
        start: int,
        *,
        mesh: Mesh,
        metric: str = "l2",
        dtype=None,
        vmem_budget: int | None = None,
        router: str = "all",
        n_probes: int = 2,
        seed: int = 0,
        halo: bool = True,
    ) -> "ShardedServingIndex":
        """Shard an adjacency matrix + dataset across ``mesh``'s devices.

        ``mesh`` must have a single axis; one shard per device.  Leaders
        are a deterministic sample of ``S`` dataset points (``seed``);
        every point joins its top-1 nearest leader (``leader_assign`` —
        ties toward the smaller leader index).  With ``halo`` (default)
        each shard also carries its members' out-of-shard neighbors as
        ghost rows so no graph edge is dropped; ``halo=False`` keeps the
        bare induced subgraph (smaller, lower recall).  Each shard's
        entry point is its OWNED member nearest the global entry
        ``x[start]``.  ``dtype`` follows the single-device packing:
        ``None``/f32, a downcast dtype (e.g. bf16), or ``"int8"`` for
        the scalar-quantized copy (quantization is per-point/row-local,
        so sharding cannot change the bits — a ghost row quantizes
        identically in every shard that holds it).
        """
        from repro.core.leader_assign import leader_assign
        from repro.core.serving import _is_int8

        if len(mesh.axis_names) != 1:
            raise ValueError(f"serving mesh must have exactly one axis, "
                             f"got {mesh.axis_names}")
        if router not in ROUTERS:
            raise ValueError(f"router must be one of {ROUTERS}, "
                             f"got {router!r}")
        if router == "leaders" and int(n_probes) <= 0:
            # an empty probe set would mask EVERY shard out of the merge
            # and return all -1 ids — fail loudly at packing time instead
            raise ValueError(f"router='leaders' needs n_probes >= 1, "
                             f"got {n_probes}")
        s = int(np.prod(mesh.devices.shape))
        x = np.ascontiguousarray(x, dtype=np.float32)
        graph = np.ascontiguousarray(graph, dtype=np.int32)
        n, d = x.shape
        r = graph.shape[1]
        if n < s:
            raise ValueError(f"cannot shard {n} points over {s} devices")
        rng = np.random.default_rng(seed)
        leader_ids = np.sort(rng.choice(n, size=s, replace=False))
        leaders = x[leader_ids]
        assign = np.asarray(leader_assign(
            jnp.asarray(x), jnp.asarray(leaders), 1, metric=metric))[:, 0]
        # per-shard row lists: owned members (ascending global id) first,
        # then the 1-hop halo — every out-of-shard endpoint of a member's
        # edge rides along as a ghost row, so no edge is dropped
        rows, owned = [], np.zeros(s, np.int64)
        for i in range(s):
            mem = np.where(assign == i)[0]
            owned[i] = len(mem)
            if halo and len(mem):
                flat = graph[mem]
                flat = flat[flat >= 0]
                ghosts = np.unique(flat[assign[flat] != i])
            else:
                ghosts = np.empty(0, np.int64)
            rows.append(np.concatenate([mem, ghosts]))
        m = max(1, max(len(ridx) for ridx in rows))
        gids = np.full((s, m), -1, np.int32)
        graph_s = np.full((s, m, r), -1, np.int32)
        norms_s = np.zeros((s, m), np.float32)
        # norms in f32 BEFORE any downcast/quantization (the exact-norm
        # trick carries over shard by shard)
        norms = np.asarray(_metrics.point_norms(jnp.asarray(x), metric))
        int8 = _is_int8(dtype)
        if int8:
            from repro.kernels.ref import quantize_symmetric

            x8, scl = quantize_symmetric(jnp.asarray(x))
            xp, scl = np.asarray(x8), np.asarray(scl)
            pts_s = np.zeros((s, m, d), np.int8)
            # pad scales with 1.0, not 0.0: pad rows are all-zero int8
            # vectors, and a zero scale would be the only 0.0 the kernels'
            # rescale path ever sees
            scales_np = np.ones((s, m), np.float32)
        else:
            xp = x
            pts_s = np.zeros((s, m, d), np.float32)
        lookup = np.full(n, -1, np.int64)
        for i, ridx in enumerate(rows):
            c = len(ridx)
            gids[i, :c] = ridx
            lookup[:] = -1
            lookup[ridx] = np.arange(c)
            ga = graph[ridx]
            # member rows: every edge endpoint is in-shard by halo
            # construction; ghost rows keep whichever of their own edges
            # happen to land in-shard
            graph_s[i, :c] = np.where(ga >= 0, lookup[np.maximum(ga, 0)], -1)
            norms_s[i, :c] = norms[ridx]
            pts_s[i, :c] = xp[ridx]
            if int8:
                scales_np[i, :c] = scl[ridx]
        pts_j = jnp.asarray(pts_s)
        if dtype is not None and not int8:
            pts_j = pts_j.astype(dtype)
        # per-shard entry: the OWNED member nearest the global entry point
        # (owned rows come first, so the argmin's position IS its local id)
        dstart = _dist_to_point(x, x[start], metric)
        starts_local = np.zeros(s, np.int32)
        for i in range(s):
            mem = rows[i][: owned[i]]
            if len(mem):
                starts_local[i] = np.argmin(dstart[mem])
        # commit every stacked array to its mesh placement NOW: shard-axis
        # arrays split over the devices, router leaders replicated.  A
        # plain jnp.asarray would land everything on device 0 and the jit
        # dispatch of the shard_map program would reshard the ENTIRE
        # packing device->devices on every single search call (an implicit
        # transfer jax performs silently — PIPS004's reason to exist).
        from jax.sharding import NamedSharding

        shard = NamedSharding(mesh, P(mesh.axis_names[0]))
        rep = NamedSharding(mesh, P())
        return cls(
            gids=jax.device_put(gids, shard),
            graph=jax.device_put(graph_s, shard),
            points=jax.device_put(pts_j, shard),
            norms=jax.device_put(norms_s, shard),
            starts=jax.device_put(starts_local, shard),
            leaders=jax.device_put(np.ascontiguousarray(leaders), rep),
            mesh=mesh, metric=metric,
            scales=(jax.device_put(scales_np, shard) if int8 else None),
            router=router, n_probes=int(n_probes), vmem_budget=vmem_budget,
            n_points=n, owned=owned.astype(np.int64),
        )

    @classmethod
    def from_index(cls, index, x: np.ndarray, *, mesh: Mesh, dtype=None,
                   **kw) -> "ShardedServingIndex":
        return cls.from_graph(index.graph, x, index.start, mesh=mesh,
                              metric=index.params.metric, dtype=dtype, **kw)

    # ------------------------------------------------------------- search --
    def _sharded_search_fn(self, *, beam, iters, expansions, early_exit,
                           kernel_path, interpret):
        """Compile (and cache) the shard_map'd per-shard search: every
        device runs the unchanged multi-expansion engine over its own
        shard and maps beam ids local -> global through its gids slice."""
        key = (beam, iters, expansions, early_exit, kernel_path, interpret,
               self.scales is not None)
        fn = self._search_cache.get(key)
        if fn is not None:
            return fn
        from repro.core.beam_search import _beam_search_multi

        int8 = self.scales is not None

        def body(gids, graph, points, norms, starts, scales, queries):
            ids, ds, hops, comps, conv = _beam_search_multi(
                graph[0], points[0], norms[0], queries, starts[0],
                scales[0] if int8 else None,
                beam=beam, iters=iters, metric=self.metric,
                expansions=expansions, early_exit=early_exit,
                kernel_path=kernel_path, interpret=interpret)
            g = gids[0]
            gid = jnp.where(ids >= 0, g[jnp.maximum(ids, 0)], -1)
            # a pad entry point (empty shard) carries gid -1: push its
            # distance to +inf so the cross-shard merge drops it
            ds = jnp.where(gid >= 0, ds, jnp.inf)
            return gid[None], ds[None], hops[None], comps[None], conv[None]

        p, rep = P(self.axis), P()
        sm = shard_map_norep(
            body, mesh=self.mesh,
            in_specs=(p, p, p, p, p, p, rep),
            out_specs=(p, p, p, p, p))
        fn = jax.jit(sm)
        self._search_cache[key] = fn
        return fn

    # ------------------------------------------------------------- health --
    def _health_np(self) -> np.ndarray:
        """Host-side [S] bool shard health mask (lazily all-healthy)."""
        if self.health is None:
            self.health = np.ones(self.n_shards, dtype=bool)
        return self.health

    @property
    def healthy_shards(self) -> int:
        return int(self._health_np().sum())

    @property
    def down_shards(self) -> tuple[int, ...]:
        """Indices of tombstoned shards (empty when fully healthy)."""
        return tuple(int(i) for i in np.nonzero(~self._health_np())[0])

    def mark_shard_down(self, shard: int) -> None:
        """Tombstone a shard: its beams are masked out of every merge
        (router="all") / its leader is never probed (router="leaders")
        until :meth:`probe_shard` re-admits it.  The device mask operand
        is rebuilt ONCE here, not per search call."""
        h = self._health_np()
        h[int(shard)] = False
        self._health_dev = None

    def mark_shard_up(self, shard: int) -> None:
        h = self._health_np()
        h[int(shard)] = True
        self._health_dev = None

    def probe_shard(self, shard: int, probe=None) -> bool:
        """Attempt to re-admit a tombstoned shard.

        The shard is optimistically marked up, then ``probe(shard)`` must
        return truthy without raising; on failure the tombstone is
        restored.  The default probe serves the shard's own leader vector
        through ``search`` and checks a valid id comes back — under fault
        injection (``repro.testing.faults``) that call raises while the
        shard's outage is still scheduled, so probing naturally fails
        until the fault clears.  Returns True iff the shard is healthy
        after the call (idempotent on already-healthy shards)."""
        i = int(shard)
        if self._health_np()[i]:
            return True
        if probe is None:
            probe = self._default_probe
        self.mark_shard_up(i)
        try:
            ok = bool(probe(i))
        except Exception:
            ok = False
        if not ok:
            self.mark_shard_down(i)
        return ok

    def _default_probe(self, shard: int) -> bool:
        q = np.asarray(self.leaders)[int(shard)][None, :]
        ids = self.search(np.ascontiguousarray(q, np.float32), k=1, beam=4)
        return bool(ids[0, 0] >= 0)

    def _health_operand(self) -> jax.Array:
        """Replicated device copy of the health mask, rebuilt only when
        the mask changes (``mark_shard_down`` / ``mark_shard_up``) — built
        per call it would be a fresh h2d transfer on every search, blowing
        the PIPS004 budget."""
        if self._health_dev is None:
            from jax.sharding import NamedSharding

            from repro.core.transfers import to_device

            self._health_dev = to_device(
                np.ascontiguousarray(self._health_np()),
                NamedSharding(self.mesh, P()))
        return self._health_dev

    def _active_mask(self, queries: jax.Array) -> jax.Array | None:
        """Bool mask ([S, Q] or a broadcastable [S, 1]) of which shards'
        beams enter the merge for which query: the router's probe set
        AND'd with the shard health mask.  ``None`` — the steady state:
        router="all" with every shard healthy — skips masking entirely,
        so healthy serving stays bit-identical to (and as transfer-lean
        as) the pre-health code path."""
        health = self._health_np()
        if not health.any():
            raise AllShardsDown(
                f"all {self.n_shards} shards are marked down")
        healthy = bool(health.all())
        hdev = None if healthy else self._health_operand()
        if self.router == "all":
            return None if healthy else hdev[:, None]
        if int(self.n_probes) <= 0:
            # guard direct construction too: from_graph already rejects
            # this, but an empty probe set silently masking every shard
            # (all -1 results) must never reach the merge
            raise ValueError(f"router='leaders' needs n_probes >= 1, "
                             f"got {self.n_probes}")
        from repro.core.leader_assign import leader_assign

        # a dead shard's leader is masked out of the probe distance
        # matrix, so each query re-probes its next-best HEALTHY leaders
        # instead of silently losing a probe slot
        probes = min(int(self.n_probes), int(health.sum()))
        probe = leader_assign(queries, self.leaders, probes,
                              metric=self.metric,
                              leader_valid=hdev)           # [Q, probes]
        sids = jnp.arange(self.n_shards, dtype=probe.dtype)
        mask = jnp.any(probe[None, :, :] == sids[:, None, None], axis=2)
        return mask if healthy else mask & hdev[:, None]

    def _scales_operand(self) -> jax.Array:
        """The scales argument of the shard_map program: the real [S, m]
        scales (int8 packing) or a cached mesh-committed [S, 1] dummy the
        f32 body ignores — rebuilt per call it would be a fresh implicit
        h2d transfer on every search."""
        if self.scales is not None:
            return self.scales
        if self._dummy_scales is None:
            from jax.sharding import NamedSharding

            self._dummy_scales = jax.device_put(
                np.zeros((self.n_shards, 1), np.float32),
                NamedSharding(self.mesh, P(self.axis)))
        return self._dummy_scales

    def search(
        self,
        queries: np.ndarray,
        *,
        k: int = 10,
        beam: int = 32,
        expansions: int = 4,
        iters: int | None = None,
        early_exit: bool = True,
        kernel_path: str | None = None,
        interpret: bool | None = None,
        query_chunk: int | None = None,
        with_stats: bool = False,
    ):
        """Serve a query batch over the mesh; [Q, k] global ids (int64,
        -1-padded).  Semantics mirror ``ServingIndex.search``: per shard
        the multi-expansion beam search runs unchanged (``beam`` is the
        PER-SHARD beam width), then the ``router`` decides which shards'
        beams enter the cross-shard top-k merge.  ``query_chunk`` bounds
        the per-dispatch batch exactly like the single-device path: small
        batches pad UP to the chunk so every dispatch reuses one compiled
        shard_map program instead of compiling per distinct nq.
        ``with_stats=True`` adds per-query telemetry summed over the
        shards that served the query, plus the resolved kernel path,
        routing settings and the packing's halo fraction.

        The boundary is hardened exactly like the single-device path:
        ``k``/``beam`` must be >= 1 and NaN/Inf query rows raise a
        structured ``InvalidQueryError`` (``core.validation``).  Shards
        tombstoned by :meth:`mark_shard_down` are masked out of the merge
        (router="all") or re-probed around (router="leaders"); when all
        shards are down the call raises :class:`AllShardsDown`.

        Host traffic per chunk is exactly the declared
        ``TRANSFER_BUDGET``: queries in (``core.transfers.to_device``,
        committed replicated to the mesh), merged ids out
        (``to_host``) — the per-shard beams and the cross-shard merge
        never leave the devices.  ``with_stats`` adds the three telemetry
        d2h crossings (hops, dist_comps, converged).
        """
        from jax.sharding import NamedSharding

        from repro.core import beam_search as _bs
        from repro.core.transfers import to_device, to_host
        from repro.core.validation import (validate_queries,
                                           validate_search_params)

        if query_chunk is not None and int(query_chunk) <= 0:
            raise ValueError(f"query_chunk must be >= 1, got {query_chunk}")
        validate_search_params(k=k, beam=beam)
        q = validate_queries(queries, dim=int(self.points.shape[-1]))
        nq = q.shape[0]
        iters_cap = int(iters if iters is not None
                        else _bs.default_iters(beam))
        path = _bs.resolve_kernel_path(
            *self._shard_avals(),
            kernel_path=kernel_path, vmem_budget=self.vmem_budget)
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        if nq == 0:
            out = np.full((0, k), -1, dtype=np.int64)
            if with_stats:
                return out, self._stats(np.empty((0,), np.int32),
                                        np.empty((0,), np.int32),
                                        np.empty((0,), bool),
                                        expansions, iters_cap, path)
            return out
        fn = self._sharded_search_fn(
            beam=beam, iters=iters_cap, expansions=int(expansions),
            early_exit=bool(early_exit), kernel_path=path,
            interpret=bool(interpret))
        scales = self._scales_operand()
        replicated = NamedSharding(self.mesh, P())
        chunk = int(query_chunk) if query_chunk else nq
        ids_parts, hops_parts, comps_parts, conv_parts = [], [], [], []
        for c0 in range(0, nq, chunk):
            qc = q[c0 : c0 + chunk]
            pad = chunk - qc.shape[0]
            if pad:
                qc = np.pad(qc, ((0, pad), (0, 0)))
            qj = to_device(qc, replicated)
            ids_s, ds_s, hops_s, comps_s, conv_s = fn(
                self.gids, self.graph, self.points, self.norms,
                self.starts, scales, qj)               # [S, Q, B] / [S, Q]
            active = self._active_mask(qj)
            if active is not None:
                ids_s = jnp.where(active[:, :, None], ids_s, -1)
                ds_s = jnp.where(active[:, :, None], ds_s, jnp.inf)
                hops_s = jnp.where(active, hops_s, 0)
                comps_s = jnp.where(active, comps_s, 0)
                # a shard that did not serve the query cannot be its
                # straggler: converged is the AND over ACTIVE shards only
                conv_s = jnp.where(active, conv_s, True)
            ids, _ = cross_shard_topk(ids_s, ds_s, k=k)
            take = chunk - pad
            ids_parts.append(to_host(ids)[:take])
            if with_stats:
                hops_parts.append(to_host(
                    jnp.sum(hops_s, axis=0, dtype=jnp.int32))[:take])
                comps_parts.append(to_host(
                    jnp.sum(comps_s, axis=0, dtype=jnp.int32))[:take])
                conv_parts.append(to_host(
                    jnp.all(conv_s, axis=0))[:take])
        out = _bs.pad_ids(np.concatenate(ids_parts, axis=0),
                          k).astype(np.int64)
        if with_stats:
            return out, self._stats(
                np.concatenate(hops_parts), np.concatenate(comps_parts),
                np.concatenate(conv_parts).astype(bool),
                expansions, iters_cap, path)
        return out

    def _stats(self, hops, comps, converged, expansions, iters_cap, path
               ) -> dict[str, Any]:
        stats = {
            "hops": hops,
            "dist_comps": comps,
            "converged": converged,
            "expansions": int(expansions),
            "iters_cap": int(iters_cap),
            "kernel_path": path,
            "n_shards": self.n_shards,
            "healthy_shards": self.healthy_shards,
            "router": self.router,
        }
        if self.router == "leaders":
            stats["n_probes"] = min(int(self.n_probes), self.healthy_shards)
        if self.owned is not None:
            stats["halo_fraction"] = self.halo_stats()["halo_fraction"]
        return stats
