"""Sharding rules: parameter / activation / cache PartitionSpecs per
architecture family on the production mesh axes ("pod", "data", "model").

Strategy (DESIGN.md §4):
  * params: FSDP over the data axes (+pod), TP over `model`:
      - attention projections: shard the flattened head dim over `model`,
        d_model over (`pod`,`data`)  (ZeRO-3-style weight gathering is
        XLA SPMD's job);
      - MLP: d_ff over `model`;
      - embedding/unembedding: vocab over `model`, d_model over data;
      - MoE EP (experts % model == 0): experts over `model`;
        MoE TP (otherwise): d_ff-within-expert over `model`;
      - Mamba2: d_inner-derived projection columns over `model`;
      - norms / small vectors: replicated.
  * activations: batch over (`pod`,`data`); residual d_model unsharded
    (GSPMD inserts the TP collectives at the projections).
  * KV caches: batch over data where divisible, SEQUENCE over `model`
    (flash-decode style) so 500k-token caches fit per-chip HBM.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def all_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


# Parallelism policies (per-arch, ArchConfig.parallelism):
#   fsdp_tp — params FSDP over (pod,data) + TP over `model` (attention
#             heads / d_ff / vocab).  Right for >=70B dense where TP is
#             needed to fit and activation all-reduces amortize.
#   fsdp    — pure ZeRO-3: params sharded over ALL axes, batch over all
#             axes when divisible.  No activation all-reduces at all; the
#             only collectives are per-layer weight all-gathers (+ grad
#             reduce-scatters).  Right for <=20B dense: the §Perf pass
#             measured TP-16 costing 100x more wire than FSDP here.
#   ep_dp   — MoE: expert stacks over `model` (EP), everything else FSDP
#             over (pod,data), batch over all axes when divisible (the
#             token->expert all_to_all is the dominant collective, as it
#             should be).
POLICIES = ("fsdp_tp", "fsdp", "ep_dp")


def _dim_ok(dim: int, mesh: Mesh, axes) -> bool:
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """Shard dim over axes when divisible, else replicate that dim."""
    return axes if _dim_ok(dim, mesh, axes) else None


def param_spec(name: str, leaf: Any, mesh: Mesh, family: str,
               policy: str = "fsdp_tp") -> P:
    """Map a flattened param name + abstract leaf to a PartitionSpec."""
    da = data_axes(mesh)
    shape = leaf.shape
    if len(shape) <= 1:
        return P()

    if policy in ("fsdp", "ep_dp"):
        # MoE expert stacks keep EP over `model` under ep_dp
        if policy == "ep_dp" and re.search(r"(w_gate|w_up|w_down)$", name) \
                and len(shape) == 4:
            return P(None, _maybe(shape[1], mesh, "model"),
                     _maybe(shape[2], mesh, da), None)
        # ZeRO-3: shard the largest dim over every available axis
        axes = all_axes(mesh) if policy == "fsdp" else da
        stacked = len(shape) >= 3
        lead = 1 if stacked else 0
        dims = shape[lead:]
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        spec = [None] * len(dims)
        for i in order:
            if _dim_ok(dims[i], mesh, axes):
                spec[i] = axes
                break
        else:
            if _dim_ok(dims[order[0]], mesh, da):
                spec[order[0]] = da
        return P(*(None,) * lead, *spec)

    def spec2(rows_axes, cols_axes, extra_lead=0):
        """Spec for a (maybe layer-stacked) 2D matrix."""
        lead = (None,) * extra_lead
        return P(*lead, rows_axes, cols_axes)

    stacked = len(shape) >= 3  # leading layer dim from vmap-init
    lead = 1 if stacked else 0
    r, c = shape[-2], shape[-1]

    # embedding table [vocab, d]
    if "embed" in name and "table" in name:
        return P(_maybe(r, mesh, "model"), _maybe(c, mesh, da))
    # MoE expert stacks [L, E, d, ff] / [L, E, ff, d]
    if re.search(r"(w_gate|w_up|w_down)$", name) and len(shape) == 4:
        e = shape[1]
        if _dim_ok(e, mesh, "model"):      # EP
            return P(None, "model", _maybe(shape[2], mesh, da), None)
        # TP inside experts: shard the ff dim
        if "w_down" in name:
            return P(None, None, _maybe(shape[2], mesh, "model"),
                     _maybe(shape[3], mesh, da))
        return P(None, None, _maybe(shape[2], mesh, da),
                 _maybe(shape[3], mesh, "model"))
    # router [d, E]
    if "router" in name:
        return P(*(None,) * lead, _maybe(r, mesh, da), None)
    # attention projections: wq/wk/wv [.., d, H*hd]; wo [.., H*hd, d]
    if re.search(r"w[qkv]_w$|w[qkv]$", name) or "_wq" in name or \
            re.search(r"attn.*w[qkv]", name) or re.search(r"cross.*w[qkv]", name):
        return spec2(_maybe(r, mesh, da), _maybe(c, mesh, "model"), lead)
    if "wo" in name:
        return spec2(_maybe(r, mesh, "model"), _maybe(c, mesh, da), lead)
    # MLP [.., d, ff] up/gate ; [.., ff, d] down
    if "w_up" in name or "w_gate" in name:
        return spec2(_maybe(r, mesh, da), _maybe(c, mesh, "model"), lead)
    if "w_down" in name:
        return spec2(_maybe(r, mesh, "model"), _maybe(c, mesh, da), lead)
    # mamba in_proj [.., d, d_proj] / out_proj [.., d_inner, d]
    if "in_proj" in name:
        return spec2(_maybe(r, mesh, da), _maybe(c, mesh, "model"), lead)
    if "out_proj" in name:
        return spec2(_maybe(r, mesh, "model"), _maybe(c, mesh, da), lead)
    if "conv_w" in name:
        return P(*(None,) * lead, None, _maybe(c, mesh, "model"))
    # fallback: replicate
    return P(*(None,) * len(shape))


def params_shardings(params: Any, mesh: Mesh, family: str,
                     policy: str = "fsdp_tp") -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append(NamedSharding(
            mesh, param_spec(name, leaf, mesh, family, policy)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_spec(name: str, leaf: Any, mesh: Mesh,
               policy: str = "fsdp_tp") -> P:
    da = data_axes(mesh)
    shape = leaf.shape
    # fsdp / ep_dp: the model axis carries batch too (when divisible) —
    # there is no tensor parallelism to feed, so idle replicas would
    # otherwise duplicate all compute.
    axes = all_axes(mesh) if policy in ("fsdp", "ep_dp") else da
    if name == "positions":                       # [3, B, T]
        b_ax = axes if _dim_ok(shape[1], mesh, axes) else             (da if _dim_ok(shape[1], mesh, da) else None)
        return P(None, b_ax, None)
    if len(shape) >= 1:
        if _dim_ok(shape[0], mesh, axes):
            return P(axes, *(None,) * (len(shape) - 1))
        if _dim_ok(shape[0], mesh, da):
            return P(da, *(None,) * (len(shape) - 1))
    return P(*(None,) * len(shape))


def batch_shardings(batch: Any, mesh: Mesh,
                    policy: str = "fsdp_tp") -> Any:
    return {
        k: NamedSharding(mesh, batch_spec(k, v, mesh, policy))
        for k, v in batch.items()
    }


def cache_spec(name: str, leaf: Any, mesh: Mesh,
               policy: str = "fsdp_tp") -> P:
    """KV / SSM cache shardings for serving.

    Batch over as many axes as divide it (all axes under the fsdp
    policies); whatever axis is left UNUSED by the batch dim shards the
    sequence / head / channel dim — never both (a single spec may not
    repeat a mesh axis).
    """
    shape = leaf.shape
    if name == "index" or len(shape) == 0:
        return P()
    da = data_axes(mesh)
    aa = all_axes(mesh)

    def batch_and_rest(bdim: int):
        if policy in ("fsdp", "ep_dp") and _dim_ok(bdim, mesh, aa):
            return aa, None                 # batch takes everything
        b_ax = da if _dim_ok(bdim, mesh, da) else None
        rest = "model" if "model" in mesh.axis_names else None
        return b_ax, rest

    if name in ("k", "v", "cross_k", "cross_v"):  # [L, B, S, KV, hd]
        b_ax, rest = batch_and_rest(shape[1])
        return P(None, b_ax, _maybe(shape[2], mesh, rest) if rest else None,
                 None, None)
    if name == "conv":                            # [L, B, W-1, conv_dim]
        b_ax, rest = batch_and_rest(shape[1])
        return P(None, b_ax, None,
                 _maybe(shape[3], mesh, rest) if rest else None)
    if name == "ssm":                             # [L, B, H, P, N]
        b_ax, rest = batch_and_rest(shape[1])
        return P(None, b_ax,
                 _maybe(shape[2], mesh, rest) if rest else None, None, None)
    return P(*(None,) * len(shape))


def cache_shardings(cache: Any, mesh: Mesh,
                    policy: str = "fsdp_tp") -> Any:
    return type(cache)(*[
        NamedSharding(mesh, cache_spec(f, getattr(cache, f), mesh, policy))
        for f in cache._fields
    ])
