"""Gradient compression for cross-pod all-reduce.

At 2+ pods the data-parallel gradient all-reduce crosses the (slow) pod
interconnect; compressing the payload trades a little optimizer noise for
halved (bf16) or quartered (int8 + per-tensor scale) wire bytes.  Error
feedback keeps the quantization residual and re-injects it next step, the
standard trick that restores convergence for biased compressors.

These run inside shard_map: gradients are reduced in two stages —
full-precision within a pod (fast ICI), compressed across pods.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: Any  # pytree matching grads (f32)


def ef_init(grads_like: Any) -> ErrorFeedbackState:
    return ErrorFeedbackState(
        residual=jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def compress_bf16(x: jax.Array) -> jax.Array:
    return x.astype(jnp.bfloat16)


def decompress_bf16(x: jax.Array) -> jax.Array:
    return x.astype(jnp.float32)


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(
    grads: Any,
    ef: ErrorFeedbackState,
    *,
    axis_name: str,
    method: str = "bf16",      # "none" | "bf16" | "int8"
) -> tuple[Any, ErrorFeedbackState]:
    """All-reduce `grads` over `axis_name` with compression+error feedback.

    Call INSIDE shard_map over the cross-pod axis.  Returns (mean grads,
    new error-feedback state).
    """
    if method == "none":
        return jax.tree.map(
            lambda g: jax.lax.pmean(g, axis_name), grads), ef

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        if method == "bf16":
            sent = compress_bf16(g32)
            err = g32 - decompress_bf16(sent)
            red = jax.lax.pmean(sent.astype(jnp.float32), axis_name)
        elif method == "int8":
            q, scale = compress_int8(g32)
            deq = decompress_int8(q, scale)
            err = g32 - deq
            red = jax.lax.pmean(deq, axis_name)
        else:
            raise ValueError(f"unknown compression {method!r}")
        return red.astype(g.dtype), err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return new_g, ErrorFeedbackState(residual=new_r)


def wire_bytes(grads: Any, method: str) -> int:
    """Bytes on the cross-pod wire per all-reduce round (reporting)."""
    per = {"none": 4, "bf16": 2, "int8": 1}[method]
    return sum(int(g.size) * per for g in jax.tree.leaves(grads))
