"""Capacity-routed group-by: the MoE-dispatch-shaped primitive shared by
the distributed PiPNN build (point-replica / candidate-edge routing) and
the expert-parallel MoE layer (token routing).

Sort-based (the TPU idiom): stable-sort by key, rank within each key run,
drop rank >= cap (overflow), scatter into [n_groups, cap, ...].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INVALID_ID = jnp.int32(-1)
INF = jnp.float32(jnp.inf)


def group_by_capacity(keys: jax.Array, valid: jax.Array, n_groups: int,
                      cap: int, payloads: list[jax.Array],
                      shuffle: bool = False
                      ) -> tuple[list[jax.Array], jax.Array]:
    """Scatter flat entries into [n_groups, cap, ...] buckets.

    Returns (grouped payloads, valid mask [n_groups, cap]); int payloads
    pad with -1, float payloads with +inf.  ``shuffle=True`` pre-permutes
    entries with a fixed Weyl sequence so overflow drops are unbiased
    instead of systematically hitting the highest-index entries.
    """
    e = keys.shape[0]
    if shuffle:
        perm = jnp.argsort(
            (jnp.arange(e, dtype=jnp.uint32) * jnp.uint32(2654435761)))
        keys, valid = keys[perm], valid[perm]
        payloads = [p[perm] for p in payloads]
    skey = jnp.where(valid, keys, n_groups).astype(jnp.int32)
    order = jnp.argsort(skey, stable=True)
    skey = skey[order]
    idx = jnp.arange(e, dtype=jnp.int32)
    start = skey != jnp.roll(skey, 1)
    start = start.at[0].set(True)
    run_start = jax.lax.cummax(jnp.where(start, idx, 0))
    rank = idx - run_start
    ok = (rank < cap) & (skey < n_groups)
    row = jnp.where(ok, skey, n_groups)
    col = jnp.where(ok, rank, cap)

    out_valid = jnp.zeros((n_groups, cap), bool).at[row, col].set(
        True, mode="drop")
    outs = []
    for pay in payloads:
        pad = INVALID_ID if jnp.issubdtype(pay.dtype, jnp.integer) else INF
        buf = jnp.full((n_groups, cap) + pay.shape[1:], pad, pay.dtype)
        outs.append(buf.at[row, col].set(pay[order], mode="drop"))
    return outs, out_valid
