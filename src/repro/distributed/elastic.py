"""Elastic scaling: restore any checkpoint onto any live mesh.

Checkpoints hold logical (unsharded) arrays; this module provides the
shard_fn that Checkpointer.restore uses to lay each leaf out on the
current mesh according to the family's sharding rules.  Scaling 256 -> 512
chips (pod join) or 512 -> 256 (pod loss) is therefore a restart with a
different ``make_production_mesh`` call — no resharding tool needed.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.distributed import sharding as shd


def restore_to_mesh(
    checkpointer, step: int, like: Any, mesh: Mesh, family: str,
    policy: str = "fsdp_tp",
) -> tuple[Any, dict]:
    """Restore checkpoint ``step`` resharded onto ``mesh``."""
    flat, _ = jax.tree_util.tree_flatten_with_path(like)
    spec_by_name = {}
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        spec_by_name[name] = shd.param_spec(name, leaf, mesh, family, policy)

    def shard_fn(name: str, arr: np.ndarray):
        spec = spec_by_name.get(name)
        if spec is None:
            return jax.device_put(arr)
        return jax.device_put(arr, NamedSharding(mesh, spec))

    return checkpointer.restore(step, like, shard_fn=shard_fn)


def data_shard_slice(global_batch: int, mesh: Mesh) -> int:
    """Per-data-rank batch after a re-scale (pipeline re-split)."""
    ranks = int(np.prod([mesh.shape[a] for a in shd.data_axes(mesh)]))
    assert global_batch % ranks == 0
    return global_batch // ranks
