"""Hybrid SSM + shared-attention LM (zamba2-2.7b).

Zamba2's signature structure: a deep Mamba2 backbone with ONE shared
transformer block (full MHA + MLP) applied at a fixed period.  We apply the
shared block every ``attn_every`` Mamba2 layers (DESIGN.md records the
simplifications vs. the released checkpoints: no per-application LoRA
deltas, no embedding concatenation — the shared block is reused verbatim).

Decode state = per-layer Mamba2 states + one KV cache per shared-block
application (n_apps = n_layers / attn_every), giving near-SSM decode cost
with a few attention reads — the hybrid trade the long_500k cell probes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig, Params
from repro.models.mamba2 import (
    Mamba2Config,
    Mamba2State,
    mamba2_decode_step,
    mamba2_forward,
    mamba2_init,
    mamba2_prefill_state,
)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    name: str
    n_layers: int              # mamba2 layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    attn_every: int = 18       # shared block applied every N mamba layers
    d_state: int = 64
    ssm_head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    head_dim: int | None = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    q_chunk: int = 512
    param_dtype: Any = jnp.float32
    remat: bool = True
    z_loss: float = 1e-4

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_apps(self) -> int:
        assert self.n_layers % self.attn_every == 0
        return self.n_layers // self.attn_every

    def attn_config(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rope_theta=self.rope_theta, q_chunk=self.q_chunk,
            norm_eps=self.norm_eps,
        )

    def mamba_config(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model, d_state=self.d_state,
            head_dim=self.ssm_head_dim, expand=self.expand, chunk=self.chunk,
            norm_eps=self.norm_eps,
        )


class HybridCache(NamedTuple):
    conv: jax.Array     # [L, B, W-1, conv_dim]
    ssm: jax.Array      # [L, B, H, P, N]
    k: jax.Array        # [n_apps, B, S, KV, hd]
    v: jax.Array
    index: jax.Array


def init(key, cfg: HybridConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    mcfg = cfg.mamba_config()
    block_keys = jax.random.split(k2, cfg.n_layers)

    def blk(k):
        return {
            "ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mamba": mamba2_init(k, mcfg, cfg.param_dtype),
        }

    ks = jax.random.split(k3, 2)
    shared = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(ks[0], cfg.attn_config(), cfg.param_dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype=cfg.param_dtype),
    }
    return {
        "embed": L.embedding_init(k1, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "blocks": jax.vmap(blk)(block_keys),
        "shared": shared,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }


def _segments(params: Params, cfg: HybridConfig):
    """Reshape stacked mamba blocks [L, ...] -> [n_apps, per_seg, ...]."""
    per = cfg.attn_every
    return jax.tree.map(
        lambda a: a.reshape((cfg.n_apps, per) + a.shape[1:]), params["blocks"]
    )


def forward(params: Params, cfg: HybridConfig, tokens: jax.Array):
    x = L.embed(params["embed"], tokens)
    b, t = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    mcfg = cfg.mamba_config()
    acfg = cfg.attn_config()
    segs = _segments(params, cfg)

    def mamba_body(x, blk):
        x = x + mamba2_forward(blk["mamba"], mcfg,
                               L.rmsnorm(blk["ln"], x, cfg.norm_eps))
        return x, None

    mamba_body = jax.checkpoint(mamba_body) if cfg.remat else mamba_body

    def seg_body(x, seg_blocks):
        x, _ = jax.lax.scan(mamba_body, x, seg_blocks)
        sh = params["shared"]
        x = x + L.attention(sh["attn"], acfg,
                            L.rmsnorm(sh["ln1"], x, cfg.norm_eps), pos)
        x = x + L.mlp(sh["mlp"], L.rmsnorm(sh["ln2"], x, cfg.norm_eps))
        return x, None

    x, _ = jax.lax.scan(seg_body, x, segs)
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def loss_fn(params: Params, cfg: HybridConfig, batch: dict) -> jax.Array:
    h = forward(params, cfg, batch["tokens"])
    logits = L.unembed(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)


def prefill(params: Params, cfg: HybridConfig, tokens: jax.Array,
            max_len: int, cache_dtype=jnp.bfloat16):
    x = L.embed(params["embed"], tokens)
    b, t = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    mcfg = cfg.mamba_config()
    acfg = cfg.attn_config()
    segs = _segments(params, cfg)

    def mamba_body(x, blk):
        h = L.rmsnorm(blk["ln"], x, cfg.norm_eps)
        y = mamba2_forward(blk["mamba"], mcfg, h)
        st = mamba2_prefill_state(blk["mamba"], mcfg, h)
        return x + y, st

    def seg_body(x, seg_blocks):
        x, states = jax.lax.scan(mamba_body, x, seg_blocks)
        sh = params["shared"]
        h = L.rmsnorm(sh["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_prefill(sh["attn"], acfg, h, pos, max_len)
        x = x + y
        x = x + L.mlp(sh["mlp"], L.rmsnorm(sh["ln2"], x, cfg.norm_eps))
        return x, (states, kc.astype(cache_dtype), vc.astype(cache_dtype))

    x, (states, ks, vs) = jax.lax.scan(seg_body, x, segs)
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, -1:])[:, 0]
    conv = states.conv.reshape((cfg.n_layers,) + states.conv.shape[2:])
    ssm = states.ssm.reshape((cfg.n_layers,) + states.ssm.shape[2:])
    return logits, HybridCache(conv=conv, ssm=ssm, k=ks, v=vs,
                               index=jnp.int32(t))


def decode_step(params: Params, cfg: HybridConfig, token: jax.Array,
                cache: HybridCache):
    x = L.embed(params["embed"], token)
    mcfg = cfg.mamba_config()
    acfg = cfg.attn_config()
    segs = _segments(params, cfg)
    per = cfg.attn_every
    conv = cache.conv.reshape((cfg.n_apps, per) + cache.conv.shape[1:])
    ssm = cache.ssm.reshape((cfg.n_apps, per) + cache.ssm.shape[1:])

    def mamba_body(x, blk_state):
        blk, cv, sm = blk_state
        h = L.rmsnorm(blk["ln"], x, cfg.norm_eps)
        y, st = mamba2_decode_step(blk["mamba"], mcfg, h,
                                   Mamba2State(conv=cv, ssm=sm))
        return x + y, (st.conv, st.ssm)

    def seg_body(x, seg):
        seg_blocks, cv, sm, kc, vc = seg
        x, (ncv, nsm) = jax.lax.scan(mamba_body, x, (seg_blocks, cv, sm))
        sh = params["shared"]
        h = L.rmsnorm(sh["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_decode(sh["attn"], acfg, h, cache.index,
                                         (kc, vc), cache.index)
        x = x + y
        x = x + L.mlp(sh["mlp"], L.rmsnorm(sh["ln2"], x, cfg.norm_eps))
        return x, (ncv, nsm, kc, vc)

    x, (ncv, nsm, ks, vs) = jax.lax.scan(
        seg_body, x, (segs, conv, ssm, cache.k, cache.v)
    )
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)[:, 0]
    return logits, HybridCache(
        conv=ncv.reshape((cfg.n_layers,) + ncv.shape[2:]),
        ssm=nsm.reshape((cfg.n_layers,) + nsm.shape[2:]),
        k=ks, v=vs, index=cache.index + 1,
    )
