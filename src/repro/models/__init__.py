"""Architecture zoo: dense GQA transformers, MoE, encoder-decoder, SSM
(Mamba2/SSD), and hybrid backbones, all scan-over-layers and pure JAX."""
from repro.models.model_zoo import Model, build
