"""Encoder-decoder transformer backbone (whisper-tiny).

Per the assignment, the conv/audio frontend is a STUB: ``input_specs``
supplies precomputed frame embeddings [B, S, D] directly.  Whisper-style
details kept: LayerNorm (not RMS), non-gated GELU MLPs, attention with
biases, sinusoidal absolute positions (no RoPE), causal decoder with
cross-attention into the encoder memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig, Params


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int              # per stack (encoder and decoder)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    norm_eps: float = 1e-5
    q_chunk: int = 512
    k_chunk: int = 1024
    attn_impl: str = "flash"
    param_dtype: Any = jnp.float32
    remat: bool = True
    z_loss: float = 1e-4

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_config(self, causal: bool) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd, qkv_bias=True,
            rope_theta=0.0, causal=causal, q_chunk=self.q_chunk,
            k_chunk=self.k_chunk, attn_impl=self.attn_impl,
            norm_eps=self.norm_eps,
        )


class EncDecCache(NamedTuple):
    k: jax.Array        # [L, B, S, KV, hd] decoder self-attn keys
    v: jax.Array
    cross_k: jax.Array  # [L, B, S_enc, KV, hd] precomputed memory keys
    cross_v: jax.Array
    index: jax.Array


def sinusoidal(t: int, d: int) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]


def _enc_block_init(key, cfg: EncDecConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ln2": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(ks[0], cfg.attn_config(False), cfg.param_dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, gated=False,
                          dtype=cfg.param_dtype),
    }


def _dec_block_init(key, cfg: EncDecConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ln2": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "ln3": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(ks[0], cfg.attn_config(True), cfg.param_dtype),
        "cross": L.attn_init(ks[1], cfg.attn_config(False), cfg.param_dtype),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False,
                          dtype=cfg.param_dtype),
    }


def init(key, cfg: EncDecConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.n_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed": L.embedding_init(k3, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "enc_norm": L.layernorm_init(cfg.d_model, cfg.param_dtype),
        "dec_norm": L.layernorm_init(cfg.d_model, cfg.param_dtype),
    }


def encode(params: Params, cfg: EncDecConfig, frames: jax.Array) -> jax.Array:
    """frames: [B, S, D] precomputed frame embeddings (frontend stub)."""
    b, s, d = frames.shape
    x = frames + sinusoidal(s, d).astype(frames.dtype)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    acfg = cfg.attn_config(False)

    def body(x, blk):
        x = x + L.attention(blk["attn"], acfg,
                            L.layernorm(blk["ln1"], x, cfg.norm_eps), pos)
        x = x + L.mlp(blk["mlp"], L.layernorm(blk["ln2"], x, cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(blk, cfg: EncDecConfig, memory: jax.Array):
    b, s, _ = memory.shape
    k = L.dense(blk["cross"]["wk"], memory).reshape(
        b, s, cfg.n_kv_heads, cfg.hd)
    v = L.dense(blk["cross"]["wv"], memory).reshape(
        b, s, cfg.n_kv_heads, cfg.hd)
    return k, v


def decode_train(params: Params, cfg: EncDecConfig, tokens: jax.Array,
                 memory: jax.Array) -> jax.Array:
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x + sinusoidal(t, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    self_cfg = cfg.attn_config(True)
    cross_cfg = cfg.attn_config(False)

    def body(x, blk):
        x = x + L.attention(blk["attn"], self_cfg,
                            L.layernorm(blk["ln1"], x, cfg.norm_eps), pos)
        kv = _cross_kv(blk, cfg, memory)
        x = x + L.attention(blk["cross"], cross_cfg,
                            L.layernorm(blk["ln2"], x, cfg.norm_eps), pos,
                            kv=kv)
        x = x + L.mlp(blk["mlp"], L.layernorm(blk["ln3"], x, cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_blocks"])
    return L.layernorm(params["dec_norm"], x, cfg.norm_eps)


def loss_fn(params: Params, cfg: EncDecConfig, batch: dict) -> jax.Array:
    """batch: frames [B,S,D], tokens [B,T], labels [B,T]."""
    memory = encode(params, cfg, batch["frames"])
    h = decode_train(params, cfg, batch["tokens"], memory)
    logits = L.unembed(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)


def prefill(params: Params, cfg: EncDecConfig, frames: jax.Array,
            tokens: jax.Array, max_len: int, cache_dtype=jnp.bfloat16):
    """Encode + decoder prefill. Returns (last logits [B, V], cache)."""
    memory = encode(params, cfg, frames)
    b, t = tokens.shape
    x = L.embed(params["embed"], tokens)
    x = x + sinusoidal(t, cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    self_cfg = cfg.attn_config(True)
    cross_cfg = cfg.attn_config(False)

    def body(x, blk):
        h = L.layernorm(blk["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_prefill(blk["attn"], self_cfg, h, pos,
                                          max_len)
        x = x + y
        ck, cv = _cross_kv(blk, cfg, memory)
        x = x + L.attention(blk["cross"], cross_cfg,
                            L.layernorm(blk["ln2"], x, cfg.norm_eps), pos,
                            kv=(ck, cv))
        x = x + L.mlp(blk["mlp"], L.layernorm(blk["ln3"], x, cfg.norm_eps))
        return x, (kc.astype(cache_dtype), vc.astype(cache_dtype),
                   ck.astype(cache_dtype), cv.astype(cache_dtype))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_blocks"])
    h = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, -1:])[:, 0]
    return logits, EncDecCache(k=ks, v=vs, cross_k=cks, cross_v=cvs,
                               index=jnp.int32(t))


def decode_step(params: Params, cfg: EncDecConfig, token: jax.Array,
                cache: EncDecCache):
    x = L.embed(params["embed"], token)
    d = cfg.d_model
    # sinusoidal position for the current index
    posvec = sinusoidal(1, d)[0, 0]  # placeholder; dynamic below
    ang_pos = cache.index.astype(jnp.float32)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    ang = ang_pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
    x = x + pe.astype(x.dtype)
    self_cfg = cfg.attn_config(True)
    cross_cfg = cfg.attn_config(False)

    def body(x, blk_kv):
        blk, kc, vc, ck, cv = blk_kv
        h = L.layernorm(blk["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_decode(blk["attn"], self_cfg, h,
                                         cache.index, (kc, vc), cache.index)
        x = x + y
        pos1 = jnp.broadcast_to(cache.index.reshape(1, 1), (x.shape[0], 1))
        x = x + L.attention(blk["cross"], cross_cfg,
                            L.layernorm(blk["ln2"], x, cfg.norm_eps), pos1,
                            kv=(ck, cv))
        x = x + L.mlp(blk["mlp"], L.layernorm(blk["ln3"], x, cfg.norm_eps))
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache.k, cache.v, cache.cross_k, cache.cross_v),
    )
    h = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)[:, 0]
    return logits, EncDecCache(k=ks, v=vs, cross_k=cache.cross_k,
                               cross_v=cache.cross_v, index=cache.index + 1)
