"""Attention-free SSM language model (mamba2-130m)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import Params
from repro.models.mamba2 import (
    Mamba2Config,
    Mamba2State,
    mamba2_decode_step,
    mamba2_forward,
    mamba2_init,
    mamba2_init_state,
    mamba2_prefill_state,
)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128
    norm_eps: float = 1e-5
    param_dtype: Any = jnp.float32
    remat: bool = True
    z_loss: float = 1e-4

    def mamba_config(self) -> Mamba2Config:
        return Mamba2Config(
            d_model=self.d_model, d_state=self.d_state,
            head_dim=self.head_dim, expand=self.expand, chunk=self.chunk,
            norm_eps=self.norm_eps,
        )


class SSMCache(NamedTuple):
    conv: jax.Array   # [L, B, W-1, conv_dim]
    ssm: jax.Array    # [L, B, H, P, N]
    index: jax.Array


def init(key, cfg: SSMConfig) -> Params:
    k1, k2 = jax.random.split(key)
    mcfg = cfg.mamba_config()
    block_keys = jax.random.split(k2, cfg.n_layers)

    def blk(k):
        return {
            "ln": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
            "mamba": mamba2_init(k, mcfg, cfg.param_dtype),
        }

    return {
        "embed": L.embedding_init(k1, cfg.vocab, cfg.d_model, cfg.param_dtype),
        "blocks": jax.vmap(blk)(block_keys),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }


def forward(params: Params, cfg: SSMConfig, tokens: jax.Array):
    x = L.embed(params["embed"], tokens)
    mcfg = cfg.mamba_config()

    def body(x, blk):
        x = x + mamba2_forward(blk["mamba"], mcfg,
                               L.rmsnorm(blk["ln"], x, cfg.norm_eps))
        return x, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def loss_fn(params: Params, cfg: SSMConfig, batch: dict) -> jax.Array:
    h = forward(params, cfg, batch["tokens"])
    logits = L.unembed(params["embed"], h)
    return L.cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)


def prefill(params: Params, cfg: SSMConfig, tokens: jax.Array, max_len: int):
    """Returns (last-token logits, SSMCache).  max_len unused: the decode
    state is O(1) in context length — the SSM selling point."""
    mcfg = cfg.mamba_config()
    x = L.embed(params["embed"], tokens)

    def body(x, blk):
        h = L.rmsnorm(blk["ln"], x, cfg.norm_eps)
        y = mamba2_forward(blk["mamba"], mcfg, h)
        st = mamba2_prefill_state(blk["mamba"], mcfg, h)
        return x + y, st

    x, states = jax.lax.scan(body, x, params["blocks"])
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, -1:])[:, 0]
    return logits, SSMCache(conv=states.conv, ssm=states.ssm,
                            index=jnp.int32(tokens.shape[1]))


def decode_step(params: Params, cfg: SSMConfig, token: jax.Array,
                cache: SSMCache):
    mcfg = cfg.mamba_config()
    x = L.embed(params["embed"], token)

    def body(x, blk_state):
        blk, conv, ssm = blk_state
        h = L.rmsnorm(blk["ln"], x, cfg.norm_eps)
        y, st = mamba2_decode_step(blk["mamba"], mcfg, h,
                                   Mamba2State(conv=conv, ssm=ssm))
        return x + y, (st.conv, st.ssm)

    x, (convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache.conv, cache.ssm)
    )
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)[:, 0]
    return logits, SSMCache(conv=convs, ssm=ssms, index=cache.index + 1)
