"""Mixture-of-Experts layer (granite-moe, grok-1).

Top-k routing with a sort-based, all-gather-free dispatch that is fully
gather-based (no scatters — friendlier to GSPMD sharding propagation):

  1. replicate each token k times, tag with its routed expert id;
  2. sort the M = N*k rows by expert id;
  3. expert buffers [E, cap, d] are *gathers* from the sorted rows
     (slot (e, c) <- sorted row  offsets[e] + c, zero-masked past counts);
  4. batched expert FFN over the stacked buffers (one einsum on the MXU);
  5. each sorted row gathers its output back from its buffer slot,
     unsorts, and the k copies combine with router weights.

Tokens overflowing an expert's capacity are dropped (standard
capacity-factor semantics); cap = ceil(N * k / E * capacity_factor).

Sharding: two strategies, per config —
  * EP  ("expert"): buffers [E, cap, d] sharded E over the `model` axis
    (requires E % axis == 0; granite's 32 experts / 16).  The
    token->expert reshard is the all_to_all the roofline tracks.
  * TP  ("tensor"): experts replicated, each expert's d_ff sharded over
    `model` (grok's 8 experts on a 16-way axis).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.compat import shard_map_norep as _shard_map
from repro.models.layers import Params, dense_init


def moe_init(key, d: int, d_ff: int, n_experts: int, *,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    sf = d_ff ** -0.5
    return {
        "router": dense_init(ks[0], d, n_experts, dtype=jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (n_experts, d, d_ff)) * s).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (n_experts, d, d_ff)) * s).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (n_experts, d_ff, d)) * sf).astype(dtype),
    }


def moe_apply(
    p: Params,
    x: jax.Array,            # [B, T, D]
    *,
    top_k: int,
    n_experts: int,
    capacity_factor: float = 1.25,
    router_z_coef: float = 1e-3,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B, T, D], aux_loss scalar: load-balance + router-z)."""
    b, t, d = x.shape
    n = b * t
    xf = x.reshape(n, d)
    e = n_experts

    logits = (xf.astype(jnp.float32) @ p["router"]["w"])     # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, sel = jax.lax.top_k(probs, top_k)                # [N, k]
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # aux losses (Switch-style load balance + router z)
    me = jnp.mean(probs, axis=0)
    ce_frac = jnp.mean(
        (jax.nn.one_hot(sel, e).sum(axis=1)).astype(jnp.float32), axis=0
    ) / top_k
    aux = e * jnp.sum(me * ce_frac)
    aux = aux + router_z_coef * jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)

    m = n * top_k
    cap = int(-(-(n * top_k * capacity_factor) // e))        # ceil
    cap = max(8, min(cap, m))

    eid = sel.reshape(m)                                     # [M]
    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), top_k)  # [M]
    order = jnp.argsort(eid, stable=True)
    s_eid = eid[order]
    s_tok = tok[order]
    counts = jnp.bincount(s_eid, length=e)                   # [E]
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                               jnp.cumsum(counts)[:-1]])     # [E]
    pos = jnp.arange(m, dtype=jnp.int32) - offsets[s_eid]    # rank in expert

    # dispatch: buffer slot (e, c) <- sorted row offsets[e] + c
    slot_rows = offsets[:, None] + jnp.arange(cap)[None, :]  # [E, cap]
    slot_valid = jnp.arange(cap)[None, :] < jnp.minimum(counts, cap)[:, None]
    slot_rows = jnp.clip(slot_rows, 0, m - 1)
    buf_tok = s_tok[slot_rows]                               # [E, cap]
    xb = xf[buf_tok] * slot_valid[..., None].astype(xf.dtype)  # [E, cap, D]

    # batched expert FFN (SwiGLU)
    up = jnp.einsum("ecd,edf->ecf", xb, p["w_up"].astype(xb.dtype))
    gate = jnp.einsum("ecd,edf->ecf", xb, p["w_gate"].astype(xb.dtype))
    hidden = jax.nn.silu(gate) * up
    yb = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"].astype(xb.dtype))

    # combine: each sorted row reads back its slot (dropped rows read 0)
    in_cap = pos < cap
    flat_slot = jnp.clip(s_eid * cap + pos, 0, e * cap - 1)
    y_rows = yb.reshape(e * cap, d)[flat_slot]
    y_rows = y_rows * in_cap[:, None].astype(y_rows.dtype)
    # unsort back to [N, k, D] and combine with gate weights
    inv = jnp.argsort(order, stable=True)
    y_nk = y_rows[inv].reshape(n, top_k, d)
    y = jnp.einsum("nkd,nk->nd", y_nk.astype(jnp.float32),
                   gate_w).astype(x.dtype)
    return y.reshape(b, t, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch via shard_map + all_to_all
# ---------------------------------------------------------------------------

def moe_apply_ep(
    p: Params,
    x: jax.Array,            # [B, T, D], sharded as ``act_sharding``
    *,
    top_k: int,
    n_experts: int,
    act_sharding,            # NamedSharding of x (carries the mesh)
    capacity_factor: float = 1.25,
    router_z_coef: float = 1e-3,
) -> tuple[jax.Array, jax.Array]:
    """EP MoE with an EXPLICIT all_to_all token exchange over `model`.

    The gather-based dispatch above is correct under GSPMD but lowers to a
    full-buffer masked-sum all-reduce when tokens are data-sharded and
    buffers expert-sharded (measured: 34 GB/layer wire on granite
    prefill_32k).  This path routes tokens with the same capacity-grouped
    all_to_all the distributed PiPNN build uses for candidate edges —
    wire cost is k * token bytes instead of the full expert buffers.
    """
    from jax.sharding import PartitionSpec as PS

    from repro.distributed.routing import group_by_capacity

    mesh = act_sharding.mesh
    if "model" not in mesh.axis_names or mesh.shape["model"] == 1 \
            or n_experts % mesh.shape["model"] != 0:
        return moe_apply(p, x, top_k=top_k, n_experts=n_experts,
                         capacity_factor=capacity_factor,
                         router_z_coef=router_z_coef)
    sm = mesh.shape["model"]
    e_loc = n_experts // sm
    x_spec = act_sharding.spec
    w_spec = PS("model", None, None)
    d = x.shape[-1]

    # static local shapes for capacity sizing
    def dimsize(size, entry):
        if entry is None:
            return size
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        import numpy as _np
        return size // int(_np.prod([mesh.shape[a] for a in axes]))

    b_loc = dimsize(x.shape[0], x_spec[0] if len(x_spec) > 0 else None)
    t_loc = dimsize(x.shape[1], x_spec[1] if len(x_spec) > 1 else None)
    n_loc = b_loc * t_loc
    cap_send = -(-n_loc * top_k * int(capacity_factor * 4) // (4 * sm))
    cap_send = max(8, -(-cap_send // 8) * 8)
    cap_e = -(-n_loc * sm * top_k * int(capacity_factor * 4) // (4 * n_experts))
    cap_e = max(8, -(-cap_e // 8) * 8)

    def body(xl, router_w, w_gate, w_up, w_down):
        bl, tl, _ = xl.shape
        n = bl * tl
        xf = xl.reshape(n, d)
        logits = xf.astype(jnp.float32) @ router_w          # [n, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, sel = jax.lax.top_k(probs, top_k)           # [n, k]
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        axes = tuple(mesh.axis_names)
        me = jax.lax.pmean(jnp.mean(probs, axis=0), axes)
        ce_frac = jax.lax.pmean(jnp.mean(
            jax.nn.one_hot(sel, n_experts).sum(axis=1), axis=0), axes) / top_k
        aux = n_experts * jnp.sum(me * ce_frac)
        aux = aux + router_z_coef * jax.lax.pmean(
            jnp.mean(jax.nn.logsumexp(logits, -1) ** 2), axes)

        m = n * top_k
        eid = sel.reshape(m)
        owner = eid // e_loc                                 # model shard
        slot = jnp.arange(m, dtype=jnp.int32)
        xrep = jnp.repeat(xf, top_k, axis=0)
        (s_x, s_eid, s_slot), s_ok = group_by_capacity(
            owner, jnp.ones((m,), bool), sm, cap_send,
            [xrep, eid, slot])
        a2a = functools.partial(jax.lax.all_to_all, axis_name="model",
                                split_axis=0, concat_axis=0, tiled=True)
        r_x, r_eid = a2a(s_x), a2a(s_eid)
        r_ok = a2a(s_ok)
        nr = sm * cap_send
        r_x = r_x.reshape(nr, d)
        r_eid = r_eid.reshape(nr)
        r_ok = r_ok.reshape(nr)
        # regroup by LOCAL expert
        lex = jnp.where(r_ok, r_eid % e_loc, e_loc)
        (b_x, b_src), b_ok = group_by_capacity(
            lex, r_ok, e_loc, cap_e, [r_x, jnp.arange(nr, dtype=jnp.int32)])
        b_x = jnp.where(b_ok[..., None], b_x, 0.0)

        up = jnp.einsum("ecd,edf->ecf", b_x, w_up.astype(b_x.dtype))
        gate = jnp.einsum("ecd,edf->ecf", b_x, w_gate.astype(b_x.dtype))
        yb = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                        w_down.astype(b_x.dtype))            # [e_loc,cap_e,d]

        # un-group back to recv-buffer order, then a2a home
        y_r = jnp.zeros((nr, d), jnp.float32)
        y_r = y_r.at[jnp.where(b_ok, b_src, nr).reshape(-1)].set(
            yb.reshape(-1, d), mode="drop")
        y_home = a2a(y_r.reshape(sm, cap_send, d))           # my send layout
        y_flat = jnp.zeros((m, d), jnp.float32)
        y_flat = y_flat.at[jnp.where(s_ok, s_slot, m).reshape(-1)].set(
            y_home.reshape(-1, d), mode="drop")
        y = jnp.einsum("nkd,nk->nd", y_flat.reshape(n, top_k, d), gate_w)
        return y.reshape(bl, tl, d).astype(xl.dtype), aux

    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, PS(), w_spec, w_spec, w_spec),
        out_specs=(x_spec, PS()),
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
