"""Unified model API over the four family implementations.

``build(arch_config)`` returns a ``Model`` with a single interface used by
the trainer, server, smoke tests and the dry-run:

  * ``init(key) -> params``
  * ``loss_fn(params, batch) -> scalar``              (train_step path)
  * ``prefill(params, batch, max_len) -> (logits, cache)``
  * ``decode_step(params, token, cache) -> (logits, cache)``
  * ``train_batch_spec(batch, seq)`` / ``prefill_batch_spec`` /
    ``decode_spec`` -> ShapeDtypeStruct pytrees (dry-run inputs; the
    modality frontends are stubs that appear here as precomputed
    embeddings, per the assignment).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, ssm_lm, transformer


class Model(NamedTuple):
    family: str
    config: Any
    init: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    train_batch_spec: Callable
    prefill_batch_spec: Callable
    decode_spec: Callable
    init_cache_spec: Callable   # (batch, max_len) -> cache ShapeDtypeStructs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_specs(batch, seq, vocab):
    return {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }


def build(cfg: Any, family: str) -> Model:
    act_dtype = jnp.bfloat16

    if family in ("dense", "moe", "vlm"):
        mcfg: transformer.TransformerConfig = cfg

        def loss(params, batch):
            return transformer.loss_fn(params, mcfg, batch)

        def prefill(params, batch, max_len):
            return transformer.prefill(
                params, mcfg, batch["tokens"], max_len,
                positions=batch.get("positions"),
            )

        def decode(params, token, cache):
            return transformer.decode_step(params, mcfg, token, cache)

        def train_spec(b, t):
            s = _token_specs(b, t, mcfg.vocab)
            if family == "vlm":
                s["positions"] = _sds((3, b, t), jnp.int32)
            return s

        def prefill_spec(b, t):
            s = {"tokens": _sds((b, t), jnp.int32)}
            if family == "vlm":
                s["positions"] = _sds((3, b, t), jnp.int32)
            return s

        def cache_spec(b, s):
            shape = (mcfg.n_layers, b, s, mcfg.n_kv_heads, mcfg.hd)
            return transformer.KVCache(
                k=_sds(shape, act_dtype), v=_sds(shape, act_dtype),
                index=_sds((), jnp.int32),
            )

        return Model(
            family=family, config=mcfg,
            init=lambda key: transformer.init(key, mcfg),
            loss_fn=loss, prefill=prefill, decode_step=decode,
            train_batch_spec=train_spec, prefill_batch_spec=prefill_spec,
            decode_spec=lambda b: _sds((b, 1), jnp.int32),
            init_cache_spec=cache_spec,
        )

    if family == "encdec":
        ecfg: encdec.EncDecConfig = cfg

        def loss(params, batch):
            return encdec.loss_fn(params, ecfg, batch)

        def prefill(params, batch, max_len):
            return encdec.prefill(params, ecfg, batch["frames"],
                                  batch["tokens"], max_len)

        def decode(params, token, cache):
            return encdec.decode_step(params, ecfg, token, cache)

        def train_spec(b, t):
            return {
                "frames": _sds((b, t, ecfg.d_model), act_dtype),
                "tokens": _sds((b, t), jnp.int32),
                "labels": _sds((b, t), jnp.int32),
            }

        def prefill_spec(b, t):
            return {
                "frames": _sds((b, t, ecfg.d_model), act_dtype),
                "tokens": _sds((b, t), jnp.int32),
            }

        def cache_spec(b, s):
            shape = (ecfg.n_layers, b, s, ecfg.n_kv_heads, ecfg.hd)
            return encdec.EncDecCache(
                k=_sds(shape, act_dtype), v=_sds(shape, act_dtype),
                cross_k=_sds(shape, act_dtype), cross_v=_sds(shape, act_dtype),
                index=_sds((), jnp.int32),
            )

        return Model(
            family=family, config=ecfg,
            init=lambda key: encdec.init(key, ecfg),
            loss_fn=loss, prefill=prefill, decode_step=decode,
            train_batch_spec=train_spec, prefill_batch_spec=prefill_spec,
            decode_spec=lambda b: _sds((b, 1), jnp.int32),
            init_cache_spec=cache_spec,
        )

    if family == "ssm":
        scfg: ssm_lm.SSMConfig = cfg
        mc = scfg.mamba_config()

        def cache_spec(b, s):
            return ssm_lm.SSMCache(
                conv=_sds((scfg.n_layers, b, mc.conv_width - 1, mc.conv_dim),
                          jnp.float32),
                ssm=_sds((scfg.n_layers, b, mc.n_heads, mc.head_dim,
                          mc.d_state), jnp.float32),
                index=_sds((), jnp.int32),
            )

        return Model(
            family=family, config=scfg,
            init=lambda key: ssm_lm.init(key, scfg),
            loss_fn=lambda p, b: ssm_lm.loss_fn(p, scfg, b),
            prefill=lambda p, b, m: ssm_lm.prefill(p, scfg, b["tokens"], m),
            decode_step=lambda p, t, c: ssm_lm.decode_step(p, scfg, t, c),
            train_batch_spec=lambda b, t: _token_specs(b, t, scfg.vocab),
            prefill_batch_spec=lambda b, t: {"tokens": _sds((b, t), jnp.int32)},
            decode_spec=lambda b: _sds((b, 1), jnp.int32),
            init_cache_spec=cache_spec,
        )

    if family == "hybrid":
        hcfg: hybrid.HybridConfig = cfg
        mc = hcfg.mamba_config()

        def cache_spec(b, s):
            kv_shape = (hcfg.n_apps, b, s, hcfg.n_kv_heads, hcfg.hd)
            return hybrid.HybridCache(
                conv=_sds((hcfg.n_layers, b, mc.conv_width - 1, mc.conv_dim),
                          jnp.float32),
                ssm=_sds((hcfg.n_layers, b, mc.n_heads, mc.head_dim,
                          mc.d_state), jnp.float32),
                k=_sds(kv_shape, act_dtype), v=_sds(kv_shape, act_dtype),
                index=_sds((), jnp.int32),
            )

        return Model(
            family=family, config=hcfg,
            init=lambda key: hybrid.init(key, hcfg),
            loss_fn=lambda p, b: hybrid.loss_fn(p, hcfg, b),
            prefill=lambda p, b, m: hybrid.prefill(p, hcfg, b["tokens"], m),
            decode_step=lambda p, t, c: hybrid.decode_step(p, hcfg, t, c),
            train_batch_spec=lambda b, t: _token_specs(b, t, hcfg.vocab),
            prefill_batch_spec=lambda b, t: {"tokens": _sds((b, t), jnp.int32)},
            decode_spec=lambda b: _sds((b, 1), jnp.int32),
            init_cache_spec=cache_spec,
        )

    raise ValueError(f"unknown family {family!r}")
