"""Mamba2 block with the SSD (state-space duality) chunked algorithm
(Dao & Gu 2024) — mamba2-130m and the zamba2 hybrid's backbone.

Chunked SSD: sequence split into chunks of Q tokens; within a chunk the
recurrence is evaluated as a masked quadratic (attention-like) form — MXU
work — while a short lax.scan carries the [h, n, p] state across chunks.
All decay factors are exp of non-positive sums (A < 0, dt > 0), so the
computation is numerically stable without rescaling.

Decode is the O(1)-state recurrent step — the reason the SSM/hybrid archs
are the only ones assigned the long_500k cell (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, rmsnorm, rmsnorm_init


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 128         # n
    head_dim: int = 64         # p
    expand: int = 2
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128           # Q (SSD chunk length)
    norm_eps: float = 1e-5

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def d_in_proj(self) -> int:
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


class Mamba2State(NamedTuple):
    """Recurrent decode state — constant size, independent of context."""

    conv: jax.Array   # [B, conv_width - 1, conv_dim]
    ssm: jax.Array    # [B, H, P, N] float32


def mamba2_init(key, cfg: Mamba2Config, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    d = cfg.d_model
    return {
        "in_proj": {
            "w": (jax.random.normal(ks[0], (d, cfg.d_in_proj)) * d ** -0.5
                  ).astype(dtype)
        },
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, cfg.conv_dim))
                   * cfg.conv_width ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype=dtype),
        "A_log": jnp.log(
            jax.random.uniform(ks[2], (cfg.n_heads,), minval=1.0, maxval=16.0)
        ).astype(jnp.float32),
        "D": jnp.ones((cfg.n_heads,), dtype=jnp.float32),
        "dt_bias": jnp.log(
            jnp.expm1(
                jnp.exp(jax.random.uniform(
                    ks[3], (cfg.n_heads,),
                    minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
            )
        ).astype(jnp.float32),
        "norm": rmsnorm_init(cfg.d_inner, dtype),
        "out_proj": {
            "w": (jax.random.normal(ks[4], (cfg.d_inner, d))
                  * cfg.d_inner ** -0.5).astype(dtype)
        },
    }


def _split_proj(cfg: Mamba2Config, zxbcdt: jax.Array):
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim :]
    return z, xbc, dt


def _conv1d(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Causal depthwise conv, width K: y_t = sum_k w_k x_{t-K+1+k}."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    y = sum(
        pad[:, i : i + xbc.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(y + b[None, None, :])


def _ssd_chunked(x, b_, c_, dt, a_log, q: int):
    """x: [B,T,H,P]; b_/c_: [B,T,G,N]; dt: [B,T,H] (softplus'ed).

    Returns y [B,T,H,P] (without the D skip term).
    """
    bsz, t, h, p = x.shape
    g, n = b_.shape[2], b_.shape[3]
    rep = h // g
    a = (-jnp.exp(a_log))[None, None, :] * dt                # [B,T,H] <= 0
    pad = (-t) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_ = jnp.pad(b_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_ = jnp.pad(c_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    tp = x.shape[1]
    nc = tp // q
    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    bc = jnp.repeat(b_.reshape(bsz, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    cc = jnp.repeat(c_.reshape(bsz, nc, q, g, n), rep, axis=3).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    ac = a.reshape(bsz, nc, q, h).astype(jnp.float32)
    cs = jnp.cumsum(ac, axis=2)                              # [B,nc,Q,H]

    # intra-chunk quadratic form
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]         # [B,nc,Q(i),Q(j),H]
    mask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc)
    att = cb * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att, xc)

    # chunk-boundary states
    tail = jnp.exp(cs[:, :, -1:, :] - cs)                    # [B,nc,Q,H]
    s = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", tail * dtc, bc, xc)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                   # [B,nc,H]

    def scan_fn(hstate, inp):
        s_c, dec_c = inp
        new = dec_c[:, :, None, None] * hstate + s_c
        return new, hstate                                   # emit PREVIOUS

    init = jnp.zeros((bsz, h, n, p), dtype=jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(s, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )                                                        # [nc,B,H,N,P]
    h_prev = jnp.moveaxis(h_prev, 0, 1)                      # [B,nc,H,N,P]

    y_inter = jnp.einsum(
        "bcihn,bchnp->bcihp", cc * jnp.exp(cs)[..., None], h_prev
    )
    y = (y_intra + y_inter).reshape(bsz, tp, h, p)
    return y[:, :t].astype(x.dtype)


def mamba2_forward(p: Params, cfg: Mamba2Config, u: jax.Array) -> jax.Array:
    """Full-sequence forward (training / prefill). u: [B, T, D]."""
    bsz, t, _ = u.shape
    zxbcdt = u @ p["in_proj"]["w"].astype(u.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _conv1d(xbc, p["conv_w"].astype(u.dtype), p["conv_b"].astype(u.dtype))
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    x = xbc[..., :di].reshape(bsz, t, h, cfg.head_dim)
    b_ = xbc[..., di : di + g * n].reshape(bsz, t, g, n)
    c_ = xbc[..., di + g * n :].reshape(bsz, t, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    y = _ssd_chunked(x, b_, c_, dt, p["A_log"], cfg.chunk)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * x.astype(y.dtype)
    y = y.reshape(bsz, t, di).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)  # gated norm
    return y @ p["out_proj"]["w"].astype(u.dtype)


def mamba2_init_state(cfg: Mamba2Config, batch: int) -> Mamba2State:
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), jnp.float32),
        ssm=jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                      jnp.float32),
    )


def mamba2_prefill_state(
    p: Params, cfg: Mamba2Config, u: jax.Array
) -> Mamba2State:
    """Recompute the decode state after a full-sequence prefill.

    Runs the recurrence chunk-wise to the final state (costs one extra
    state pass; shares all projections with the forward)."""
    bsz, t, _ = u.shape
    zxbcdt = u @ p["in_proj"]["w"].astype(u.dtype)
    _, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _conv1d(xbc_raw, p["conv_w"].astype(u.dtype),
                  p["conv_b"].astype(u.dtype))
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    x = xbc[..., :di].reshape(bsz, t, h, cfg.head_dim).astype(jnp.float32)
    b_ = xbc[..., di : di + g * n].reshape(bsz, t, g, n).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = (-jnp.exp(p["A_log"]))[None, None, :] * dtv          # [B,T,H]
    rep = h // g
    bh = jnp.repeat(b_, rep, axis=2)                          # [B,T,H,N]
    # final state = sum_j exp(sum_{l>j} a_l) dt_j x_j B_j^T
    rev_decay = jnp.exp(jnp.cumsum(a[:, ::-1], axis=1)[:, ::-1] - a)
    ssm = jnp.einsum("bth,bthp,bthn->bhpn", rev_decay * dtv, x, bh)
    conv = xbc_raw[:, t - (cfg.conv_width - 1):].astype(jnp.float32)
    if t < cfg.conv_width - 1:
        conv = jnp.pad(conv, ((0, 0), (cfg.conv_width - 1 - t, 0), (0, 0)))
    return Mamba2State(conv=conv, ssm=ssm)


def mamba2_decode_step(
    p: Params, cfg: Mamba2Config, u: jax.Array, state: Mamba2State
) -> tuple[jax.Array, Mamba2State]:
    """One-token recurrent step. u: [B, 1, D] -> (y [B, 1, D], state)."""
    bsz = u.shape[0]
    zxbcdt = u[:, 0] @ p["in_proj"]["w"].astype(u.dtype)      # [B, dproj]
    di, g, n, h = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc_t = zxbcdt[..., di : di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim :]
    window = jnp.concatenate(
        [state.conv, xbc_t[:, None, :].astype(jnp.float32)], axis=1
    )                                                         # [B, W, convdim]
    w = p["conv_w"].astype(jnp.float32)
    xbc = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    )
    x = xbc[..., :di].reshape(bsz, h, cfg.head_dim)
    b_ = xbc[..., di : di + g * n].reshape(bsz, g, n)
    c_ = xbc[..., di + g * n :].reshape(bsz, g, n)
    rep = h // g
    bh = jnp.repeat(b_, rep, axis=1)                          # [B,H,N]
    ch = jnp.repeat(c_, rep, axis=1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, :])
    decay = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dtv)      # [B,H]
    ssm = (decay[:, :, None, None] * state.ssm
           + jnp.einsum("bh,bhp,bhn->bhpn", dtv, x, bh))
    y = jnp.einsum("bhpn,bhn->bhp", ssm, ch) + p["D"][None, :, None] * x
    y = y.reshape(bsz, 1, di).astype(u.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z[:, None, :]), cfg.norm_eps)
    out = y @ p["out_proj"]["w"].astype(u.dtype)
    return out, Mamba2State(conv=window[:, 1:], ssm=ssm)
