"""Decoder-only transformer LM — covers the dense (llama3, internlm2,
qwen2, qwen3), MoE (granite, grok) and VLM-backbone (qwen2-vl) assigned
architectures.

Blocks are parameter-stacked along a leading [L, ...] axis and executed
with lax.scan (+ optional jax.checkpoint), so a 126-layer 405B model
AOT-compiles in one block's worth of HLO.  Decode carries a stacked KV
cache [L, B, S, KV, hd] scanned in lock-step with the blocks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.layers import AttnConfig, Params
from repro.models.moe import moe_apply, moe_apply_ep, moe_init


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 500000.0
    mrope_sections: tuple[int, int, int] | None = None
    moe: MoESpec | None = None
    norm_eps: float = 1e-6
    q_chunk: int = 512
    k_chunk: int = 1024
    attn_impl: str = "flash"   # "flash" | "chunked" (materialized scores)
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.float32   # residual-stream dtype (bf16 halves
    #                                HBM + wire bytes; f32 kept in norms,
    #                                softmax and CE internals)
    act_sharding: Any = None   # NamedSharding for [B,T,D] activations
    moe_impl: str = "gspmd"    # "gspmd" (gather dispatch) | "ep_a2a"
    remat: bool = True
    remat_group: int = 0       # 0: checkpoint every layer; g>0: checkpoint
    #                            only every g layers (sqrt-remat) — saved
    #                            residuals drop from L*x to (L/g)*x at the
    #                            cost of re-running g-layer groups in bwd
    z_loss: float = 1e-4
    aux_coef: float = 1e-2     # MoE load-balance coefficient

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def attn_config(self) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections,
            q_chunk=self.q_chunk, k_chunk=self.k_chunk,
            attn_impl=self.attn_impl, norm_eps=self.norm_eps,
        )


class KVCache(NamedTuple):
    k: jax.Array       # [L, B, S, KV, hd]
    v: jax.Array       # [L, B, S, KV, hd]
    index: jax.Array   # scalar int32: next write position


def _block_init(key, cfg: TransformerConfig) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": L.attn_init(ks[0], cfg.attn_config(), cfg.param_dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe.n_experts,
                            dtype=cfg.param_dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                              dtype=cfg.param_dtype)
    return p


def init(key, cfg: TransformerConfig) -> Params:
    k_embed, k_blocks, k_final = jax.random.split(key, 3)
    block_keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    return {
        "embed": L.embedding_init(k_embed, cfg.vocab, cfg.d_model,
                                  cfg.param_dtype),
        "blocks": blocks,
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }


def _block_apply(cfg: TransformerConfig, x, positions, blk):
    acfg = cfg.attn_config()
    x = L.pin_activations(x, cfg.act_sharding)
    x = x + L.attention(blk["attn"], acfg, L.rmsnorm(blk["ln1"], x, cfg.norm_eps),
                        positions)
    h = L.rmsnorm(blk["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        if cfg.moe_impl == "ep_a2a" and cfg.act_sharding is not None:
            y, aux = moe_apply_ep(
                blk["moe"], h, top_k=cfg.moe.top_k,
                n_experts=cfg.moe.n_experts,
                act_sharding=cfg.act_sharding,
                capacity_factor=cfg.moe.capacity_factor)
        else:
            y, aux = moe_apply(blk["moe"], h, top_k=cfg.moe.top_k,
                               n_experts=cfg.moe.n_experts,
                               capacity_factor=cfg.moe.capacity_factor)
    else:
        y, aux = L.mlp(blk["mlp"], h), jnp.float32(0.0)
    return x + y, aux


def forward(params: Params, cfg: TransformerConfig, tokens: jax.Array,
            positions: jax.Array | None = None,
            inputs_embeds: jax.Array | None = None):
    """Full forward. Returns (hidden [B, T, D], aux loss)."""
    x = inputs_embeds if inputs_embeds is not None \
        else L.embed(params["embed"], tokens)
    x = x.astype(cfg.act_dtype)
    x = L.pin_activations(x, cfg.act_sharding)
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None], x.shape[:2]
        )

    def body(carry, blk):
        x, aux = carry
        x, a = _block_apply(cfg, x, positions, blk)
        return (x, aux + a), None

    g = cfg.remat_group
    if cfg.remat and g > 1 and cfg.n_layers % g == 0:
        # sqrt-remat: an inner unchckpointed scan over g-layer groups,
        # outer scan checkpoints only group boundaries
        grouped = jax.tree.map(
            lambda a: a.reshape((cfg.n_layers // g, g) + a.shape[1:]),
            params["blocks"])

        def group_body(carry, grp):
            return jax.lax.scan(body, carry, grp)

        (x, aux), _ = jax.lax.scan(
            jax.checkpoint(group_body), (x, jnp.float32(0.0)), grouped)
    else:
        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.float32(0.0)),
                                   params["blocks"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def loss_fn(params: Params, cfg: TransformerConfig, batch: dict) -> jax.Array:
    """Causal LM loss. batch: tokens [B,T], labels [B,T] (+positions)."""
    h, aux = forward(params, cfg, batch["tokens"],
                     positions=batch.get("positions"))
    logits = L.unembed(params["embed"], h)
    ce = L.cross_entropy(logits, batch["labels"], z_loss=cfg.z_loss)
    return ce + cfg.aux_coef * aux


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   index=jnp.zeros((), jnp.int32))


def prefill(params: Params, cfg: TransformerConfig, tokens: jax.Array,
            max_len: int, positions: jax.Array | None = None,
            cache_dtype=jnp.bfloat16):
    """Process the prompt; returns (last-token logits [B, V], KVCache)."""
    x = L.embed(params["embed"], tokens).astype(cfg.act_dtype)
    b, t = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (b, t))
    acfg = cfg.attn_config()

    def body(x, blk):
        x = L.pin_activations(x, cfg.act_sharding)
        h = L.rmsnorm(blk["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_prefill(blk["attn"], acfg, h, positions,
                                          max_len)
        x = x + y
        h2 = L.rmsnorm(blk["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            if cfg.moe_impl == "ep_a2a" and cfg.act_sharding is not None:
                y2, _ = moe_apply_ep(
                    blk["moe"], h2, top_k=cfg.moe.top_k,
                    n_experts=cfg.moe.n_experts,
                    act_sharding=cfg.act_sharding,
                    capacity_factor=cfg.moe.capacity_factor)
            else:
                y2, _ = moe_apply(blk["moe"], h2, top_k=cfg.moe.top_k,
                                  n_experts=cfg.moe.n_experts,
                                  capacity_factor=cfg.moe.capacity_factor)
        else:
            y2 = L.mlp(blk["mlp"], h2)
        return x + y2, (kc.astype(cache_dtype), vc.astype(cache_dtype))

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = jax.lax.scan(body_fn, x, params["blocks"])
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h[:, -1:])[:, 0]
    return logits, KVCache(k=ks, v=vs, index=jnp.int32(t))


def decode_step(params: Params, cfg: TransformerConfig, token: jax.Array,
                cache: KVCache, positions: jax.Array | None = None):
    """One decode step. token: [B, 1]. Returns (logits [B, V], cache)."""
    x = L.embed(params["embed"], token).astype(cfg.act_dtype)
    acfg = cfg.attn_config()
    pos = cache.index if positions is None else positions

    def body(x, blk_kv):
        blk, kc, vc = blk_kv
        h = L.rmsnorm(blk["ln1"], x, cfg.norm_eps)
        y, (kc, vc) = L.attention_decode(
            blk["attn"], acfg, h, pos, (kc, vc), cache.index
        )
        x = x + y
        h2 = L.rmsnorm(blk["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            y2, _ = moe_apply(blk["moe"], h2, top_k=cfg.moe.top_k,
                              n_experts=cfg.moe.n_experts,
                              capacity_factor=cfg.moe.capacity_factor)
        else:
            y2 = L.mlp(blk["mlp"], h2)
        return x + y2, (kc, vc)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache.k, cache.v))
    h = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], h)[:, 0]
    return logits, KVCache(k=ks, v=vs, index=cache.index + 1)
