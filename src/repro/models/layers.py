"""Shared layers for the architecture zoo.

Pure-JAX functional style: params are nested dicts of arrays; every model
stacks its block params along a leading layer axis and lax.scans over them
(essential for AOT-compiling 126-layer models in the dry-run).

Attention covers the whole assigned matrix: GQA with any kv<=q head count,
optional QKV bias (qwen2), optional qk-norm (qwen3), RoPE and M-RoPE
(qwen2-vl), causal + prefix masks, KV-cache decode, and chunked prefill
(online-softmax over query chunks) so 32k-context prefill never
materializes a [T, T] logits buffer.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

Params = dict


# ------------------------------------------------------------------ norms ---

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ----------------------------------------------------------------- linear ---

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None) -> Params:
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ------------------------------------------------------------------- RoPE ---

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, hd]; positions: [B, T] int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float,
    sections: tuple[int, int, int],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: [3, B, T] (t/h/w components).

    The hd/2 frequency slots are split into three contiguous sections, each
    rotated by its own position component (text tokens carry equal
    components, reducing to standard RoPE).
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                        # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [3, B, T, hd/2]
    s_t, s_h, s_w = sections
    assert s_t + s_h + s_w == hd // 2, "M-RoPE sections must cover hd/2"
    sel = jnp.concatenate([
        jnp.zeros((s_t,), jnp.int32),
        jnp.ones((s_h,), jnp.int32),
        jnp.full((s_w,), 2, jnp.int32),
    ])                                                   # [hd/2]
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang, 0, -1),                        # [B, T, hd/2, 3]
        sel[None, None, :, None], axis=-1,
    )[..., 0]                                            # [B, T, hd/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- attention ---

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, int, int] | None = None
    causal: bool = True
    q_chunk: int = 1024        # prefill query-chunk size (memory bound)
    k_chunk: int = 1024        # flash path: key-chunk size
    attn_impl: str = "flash"   # "flash" (online softmax, [qc,kc] tiles) |
    #                            "chunked" (materializes [qc, S] scores)
    norm_eps: float = 1e-6


def attn_init(key, cfg: AttnConfig, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    h, kv, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, h * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], d, kv * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p: Params, cfg: AttnConfig, x: jax.Array,
                 positions: jax.Array):
    b, t, _ = x.shape
    q = dense(p["wq"], x).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta > 0:
        pos2 = positions if positions.ndim == 2 else positions[0]
        q = apply_rope(q, pos2, cfg.rope_theta)
        k = apply_rope(k, pos2, cfg.rope_theta)
    return q, k, v


def _sdpa_chunked(q, k, v, *, causal: bool, q_chunk: int,
                  q_offset: jax.Array | int = 0):
    """Grouped-query attention, online over query chunks.

    q: [B, T, H, hd]; k/v: [B, S, KV, hd].  Each query chunk materializes
    only a [B, H, qc, S] logits tile, so prefill memory is O(T/qc) smaller
    than naive attention.  H % KV == 0 (GQA groups).
    """
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = hd ** -0.5
    qc = min(q_chunk, t)
    pad = (-t) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nchunks = q.shape[1] // qc
    qr = q.reshape(b, nchunks, qc, kv, g, hd)
    k_ = k.astype(jnp.float32)
    v_ = v.astype(jnp.float32)

    def chunk(carry, inputs):
        qi, idx = inputs
        qi = qi.astype(jnp.float32) * scale              # [b, qc, kv, g, hd]
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qi, k_)
        if causal:
            qpos = q_offset + idx * qc + jnp.arange(qc)
            kpos = jnp.arange(s)
            mask = qpos[:, None] >= kpos[None, :]
            logits = jnp.where(mask[None, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", w, v_)
        return carry, o

    _, outs = jax.lax.scan(
        chunk, None,
        (jnp.moveaxis(qr, 1, 0), jnp.arange(nchunks)),
    )                                                    # [n, b, qc, kv, g, hd]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nchunks * qc, h, hd)
    return out[:, :t].astype(q.dtype)


def _sdpa_flash(q, k, v, *, causal: bool, q_chunk: int, k_chunk: int,
                q_offset: jax.Array | int = 0):
    """Flash-style attention: online softmax over [qc, kc] tiles.

    Unlike ``_sdpa_chunked`` (which materializes a [B, H, qc, S] logits
    slab per query chunk), only O(qc x kc) tiles ever exist — HBM traffic
    per layer drops from ~6 full-score round-trips to the q/k/v reads
    plus tile-sized intermediates XLA can fuse.  This is the same
    recurrence the Pallas/TPU flash kernels implement in VMEM; expressed
    in lax.scan so the multi-pod dry-run lowers it on any backend.
    """
    b, t, h, hd = q.shape
    s, kv_ = k.shape[1], k.shape[2]
    g = h // kv_
    scale = hd ** -0.5
    qc = min(q_chunk, t)
    kc = min(k_chunk, s)
    qpad = (-t) % qc
    kpad = (-s) % kc
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // qc, k.shape[1] // kc
    qr = jnp.moveaxis(q.reshape(b, nq, qc, kv_, g, hd), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, kc, kv_, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kc, kv_, hd), 1, 0)
    NEG = jnp.float32(-1e30)

    def q_body(_, q_in):
        qi, qidx = q_in
        qi = qi.astype(jnp.float32) * scale            # [b, qc, kv, g, hd]
        qpos = q_offset + qidx * qc + jnp.arange(qc)

        def k_body(carry, k_in):
            m, l, acc = carry
            ki, vi, kidx = k_in
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qi,
                                ki.astype(jnp.float32))  # [b,kv,g,qc,kc]
            kpos = kidx * kc + jnp.arange(kc)
            ok = kpos[None, :] < s                      # key padding
            if causal:
                ok = ok & (qpos[:, None] >= kpos[None, :])
            logits = jnp.where(ok[None, None, None], logits, NEG)
            m_new = jnp.maximum(m, logits.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, kv_, g, qc), NEG, jnp.float32),
                jnp.zeros((b, kv_, g, qc), jnp.float32),
                jnp.zeros((b, kv_, g, qc, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(k_body, init,
                                      (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]    # [b,kv,g,qc,hd]
        return None, jnp.moveaxis(out, 3, 1)            # [b, qc, kv, g, hd]

    _, outs = jax.lax.scan(q_body, None, (qr, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq * qc, h, hd)
    return out[:, :t].astype(q.dtype)


def _sdpa_flash_sp(q, k, v, *, causal: bool, k_chunk: int,
                   q_offset: jax.Array | int = 0):
    """Sequence-parallel flash attention: online softmax over key tiles,
    NO outer query scan — the query-time axis stays a plain tensor dim, so
    a sequence sharding pinned on the activations propagates through
    (a lax.scan over query chunks forces its xs dim to be unsharded,
    which replicated attention 16x across the model axis under the fsdp
    policies; measured on granite prefill_32k).  Peak memory is one
    [B, KV, G, T_local, kc] tile."""
    b, t, h, hd = q.shape
    s, kv_ = k.shape[1], k.shape[2]
    g = h // kv_
    scale = hd ** -0.5
    kc = min(k_chunk, s)
    kpad = (-s) % kc
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    nk = k.shape[1] // kc
    kr = jnp.moveaxis(k.reshape(b, nk, kc, kv_, hd), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kc, kv_, hd), 1, 0)
    NEG = jnp.float32(-1e30)
    qf = q.reshape(b, t, kv_, g, hd).astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(t)

    def k_body(carry, k_in):
        m, l, acc = carry
        ki, vi, kidx = k_in
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qf,
                            ki.astype(jnp.float32))      # [b,kv,g,t,kc]
        kpos = kidx * kc + jnp.arange(kc)
        ok = kpos[None, :] < s
        if causal:
            ok = ok & (qpos[:, None] >= kpos[None, :])
        logits = jnp.where(ok[None, None, None], logits, NEG)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vi.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kv_, g, t), NEG, jnp.float32),
            jnp.zeros((b, kv_, g, t), jnp.float32),
            jnp.zeros((b, kv_, g, t, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(k_body, init, (kr, vr, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # [b,kv,g,t,hd]
    return jnp.moveaxis(out, 3, 1).reshape(b, t, h, hd).astype(q.dtype)


def _sdpa(q, k, v, cfg: AttnConfig, *, causal: bool,
          q_offset: jax.Array | int = 0):
    if cfg.attn_impl == "flash_sp":
        return _sdpa_flash_sp(q, k, v, causal=causal, k_chunk=cfg.k_chunk,
                              q_offset=q_offset)
    if cfg.attn_impl == "flash":
        return _sdpa_flash(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                           k_chunk=cfg.k_chunk, q_offset=q_offset)
    return _sdpa_chunked(q, k, v, causal=causal, q_chunk=cfg.q_chunk,
                         q_offset=q_offset)


def pin_activations(x: jax.Array, sharding) -> jax.Array:
    """Pin [B, T, D] activation sharding (GSPMD left alone will sometimes
    downgrade a 256-way batch sharding to 32-way after gather/reshape ops;
    observed on qwen2-7b train under the fsdp policy — 8x redundant
    compute per device).  ``sharding`` is a NamedSharding or None."""
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def attention(
    p: Params,
    cfg: AttnConfig,
    x: jax.Array,              # [B, T, D]
    positions: jax.Array,      # [B, T] or [3, B, T] for M-RoPE
    *,
    kv: tuple[jax.Array, jax.Array] | None = None,  # cross-attention memory
) -> jax.Array:
    q, k, v = _project_qkv(p, cfg, x, positions)
    if kv is not None:
        k, v = kv
    out = _sdpa(q, k, v, cfg, causal=cfg.causal and kv is None)
    b, t = x.shape[:2]
    return dense(p["wo"], out.reshape(b, t, cfg.n_heads * cfg.head_dim))


def attention_prefill(
    p: Params, cfg: AttnConfig, x: jax.Array, positions: jax.Array,
    cache_len: int,
):
    """Prefill returning output + a [B, cache_len, KV, hd] padded KV cache."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    out = _sdpa(q, k, v, cfg, causal=cfg.causal)
    b, t = x.shape[:2]
    y = dense(p["wo"], out.reshape(b, t, cfg.n_heads * cfg.head_dim))
    padlen = cache_len - t
    kc = jnp.pad(k, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, padlen), (0, 0), (0, 0)))
    return y, (kc, vc)


def attention_decode(
    p: Params, cfg: AttnConfig, x: jax.Array, position: jax.Array,
    cache: tuple[jax.Array, jax.Array], cache_index: jax.Array,
):
    """One-token decode. x: [B, 1, D]; cache k/v: [B, S, KV, hd].

    Returns (y [B, 1, D], updated cache).  Entries beyond ``cache_index``
    are masked out of the softmax.
    """
    b = x.shape[0]
    pos = jnp.broadcast_to(position.reshape(-1, 1), (b, 1))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(position.reshape(1, -1, 1), (3, b, 1))
    q, k, v = _project_qkv(p, cfg, x, pos)
    kc, vc = cache
    s = kc.shape[1]
    kc = jax.lax.dynamic_update_slice_in_dim(
        kc, k.astype(kc.dtype), cache_index, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(
        vc, v.astype(vc.dtype), cache_index, axis=1)
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kvh
    qf = q.astype(jnp.float32).reshape(b, 1, kvh, g, hd) * hd ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32))
    valid = jnp.arange(s)[None, None, None, None, :] <= cache_index
    logits = jnp.where(valid, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", w, vc.astype(jnp.float32))
    o = o.reshape(b, 1, h * hd).astype(x.dtype)
    return dense(p["wo"], o), (kc, vc)


# -------------------------------------------------------------------- MLP ---

def mlp_init(key, d: int, d_ff: int, *, gated: bool = True,
             dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype=dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype=dtype)}
    if gated:
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp(p: Params, x: jax.Array) -> jax.Array:
    up = dense(p["w_up"], x)
    if "w_gate" in p:
        up = jax.nn.silu(dense(p["w_gate"], x)) * up     # SwiGLU
    else:
        up = jax.nn.gelu(up)
    return dense(p["w_down"], up)


# -------------------------------------------------------------- embedding ---

def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return p["table"][tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits = x @ table.T (f32 accumulation)."""
    return jnp.einsum(
        "btd,vd->btv", x.astype(jnp.float32),
        p["table"].astype(jnp.float32),
    )


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  *, z_loss: float = 0.0) -> jax.Array:
    """Mean token CE; optional z-loss regularizer (stabilizes big-vocab)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss > 0.0:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)
