"""Deterministic fault injection for serving-resilience drills.

Chaos testing with dice is unreproducible; this module injects the fault
classes the resilient serving loop (``launch.serve_loop``) must survive
on an exact, seedable SCHEDULE keyed to the search-call counter — the
same drill replays bit-for-bit on every run, so the regression tests and
``benchmarks/bench_serving_loop.py`` can assert outcomes, not
probabilities:

  * **Shard failure** — while a scheduled outage window is open, any
    ``search`` that still counts the dead shard healthy raises
    :class:`InjectedShardFailure` (the loop's cue to
    ``mark_shard_down`` and retry); once the index has tombstoned the
    shard, serving proceeds in degraded mode.  Probing the shard
    (``probe_shard``) naturally fails until the window closes, then
    succeeds — re-admission needs no extra plumbing.
  * **Stragglers / timeouts** — scheduled calls sleep an injected extra
    latency before running (a slow collective, a paging device), which
    is what deadline propagation and the watchdog must absorb.
  * **Poisoned payloads** — :func:`poison_queries` plants NaN/Inf rows
    at deterministic positions; boundary validation must reject exactly
    those rows without taking down batchmates.
  * **Kernel-path fallback** — scheduled calls are forced DOWN the
    kernel ladder (vmem -> hbm -> xla), the degraded-memory drill.

``inject_faults`` patches the INSTANCE's ``search`` (the class and every
other index stay untouched) and restores it on exit; the yielded
:class:`FaultInjector` records an event log for assertions.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Mapping

import numpy as np


class InjectedShardFailure(RuntimeError):
    """A scheduled-dead shard was reached while still counted healthy."""

    def __init__(self, shard: int, call: int):
        super().__init__(
            f"injected failure: shard {shard} is down (search call "
            f"{call}) and has not been tombstoned")
        self.shard = int(shard)
        self.call = int(call)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic fault schedule, keyed on the patched instance's
    search-call counter (0-based; probes issued through the patched
    ``search`` advance it too, so replays are exact).

    ``shard_down`` maps a shard index to its outage window
    ``(first_call, last_call)`` — half-open, ``None`` = forever.
    ``straggle`` maps a call index to injected extra seconds of latency.
    ``force_kernel_path`` maps a call index to the kernel path forced on
    that call ("hbm" | "xla" — down the ladder only; forcing "vmem" on
    an oversized shard would be a config error, not a fault).
    """

    shard_down: Mapping[int, tuple[int, int | None]] = \
        dataclasses.field(default_factory=dict)
    straggle: Mapping[int, float] = dataclasses.field(default_factory=dict)
    force_kernel_path: Mapping[int, str] = \
        dataclasses.field(default_factory=dict)

    def dead_shards(self, call: int) -> tuple[int, ...]:
        """Shards whose outage window covers ``call``."""
        out = []
        for s, (a, b) in self.shard_down.items():
            if int(a) <= call and (b is None or call < int(b)):
                out.append(int(s))
        return tuple(sorted(out))


def poison_queries(queries: np.ndarray, frac: float = 0.05, *,
                   seed: int = 0, value: float = np.nan
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Plant non-finite entries in a deterministic subset of query rows.

    Returns ``(poisoned_copy, rows)`` — at least one row is poisoned
    whenever ``frac > 0`` and the batch is non-empty, so a "5% NaN
    queries" drill on a small batch cannot silently round to zero
    faults.  ``value`` defaults to NaN; pass ``np.inf`` for the Inf
    variant."""
    q = np.array(queries, dtype=np.float32, copy=True)
    nq = q.shape[0]
    if nq == 0 or frac <= 0:
        return q, np.empty((0,), np.int64)
    n_bad = max(1, int(round(frac * nq)))
    rng = np.random.default_rng(seed)
    rows = np.sort(rng.choice(nq, size=min(n_bad, nq), replace=False))
    q[rows, 0] = value
    return q, rows.astype(np.int64)


class FaultInjector:
    """The live injector yielded by :func:`inject_faults`.

    ``calls`` is the number of ``search`` calls intercepted so far;
    ``events`` logs every injected fault as ``(kind, call, detail)``
    tuples (kinds: "shard_failure", "straggle", "kernel_path") for
    test assertions."""

    def __init__(self, index: Any, plan: FaultPlan):
        self.index = index
        self.plan = plan
        self.calls = 0
        self.events: list[tuple[str, int, Any]] = []
        self._orig_search = index.search

    def _shard_is_trusted(self, shard: int) -> bool:
        health = getattr(self.index, "_health_np", None)
        if health is None:
            return True     # single-device index: no tombstone to honor
        return bool(health()[shard])

    def search(self, queries, **kw):
        call = self.calls
        self.calls += 1
        for s in self.plan.dead_shards(call):
            if self._shard_is_trusted(s):
                self.events.append(("shard_failure", call, s))
                raise InjectedShardFailure(s, call)
        delay = float(self.plan.straggle.get(call, 0.0))
        if delay > 0:
            self.events.append(("straggle", call, delay))
            time.sleep(delay)
        path = self.plan.force_kernel_path.get(call)
        if path is not None:
            self.events.append(("kernel_path", call, path))
            kw["kernel_path"] = path
        return self._orig_search(queries, **kw)


@contextlib.contextmanager
def inject_faults(index, plan: FaultPlan):
    """Run ``index`` under the fault schedule ``plan``.

    Patches the instance's ``search`` attribute (shadowing the class
    method for THIS object only) and always restores it on exit —
    including when the block exits via an injected exception."""
    injector = FaultInjector(index, plan)
    object.__setattr__(index, "search", injector.search)
    try:
        yield injector
    finally:
        try:
            object.__delattr__(index, "search")
        except AttributeError:
            pass
