"""Test/bench support code that ships with the package (deterministic
fault injection for serving-resilience drills) — importable from tests,
benchmarks and the serving loop's examples without reaching into the
test tree."""
