"""Memory-bound auditor over the registered hot-path programs — PIPM001-006.

The paper's central serving/build claim is *bounded memory*: HashPrune
streams an unbounded candidate-edge set through an [n, l_max] reservoir, so
no build program's peak device bytes may scale with the total emitted edge
count E, and every program must fit the per-device HBM budget at the
BigANN-1B deployment envelope.  This pass PROVES that at compile time: every
jitted hot-path program is lowered and compiled AOT across a small
shape-sweep lattice, the compiled byte ledger (``compiled.memory_analysis()``
— argument / output / temp / donation-alias bytes) is pulled per point, and
the measurements are checked against declared scaling bounds, workspace
models and the checked-in envelope.

Registered programs (one ``MemSpec`` each):

  * the streaming build chunk step (``pipnn._make_stream_step``),
  * the reservoir folds (``hashprune._merge_segmented_jit`` / ``_merge_flat_jit``),
  * the final-prune chunk step (``robust_prune._final_prune_step``),
  * the static two-level carve (``rbc._make_static_carve``),
  * the serving engine (``beam_search._beam_search_multi``, f32 and int8),
  * the ServeLoop straggler rerun (same engine, backstop statics),
  * the cross-shard merge (``distributed.serving.cross_shard_topk``),
  * the sharded search body (multi-device hosts only).

Rules:

  PIPM001  peak bytes at a lattice point fit a log-log scaling exponent per
           swept parameter; an exponent over the spec's declared bound means
           the program's memory grows faster than the bounded-memory
           contract allows (for build programs: peak must be a function of
           the chunk and reservoir shapes only — NEVER of the total edge
           count E, whose boundedness follows from the per-parameter
           bounds).
  PIPM002  buffer donation must be credited in the byte ledger: the
           compiled ``alias_size_in_bytes`` must cover the donated argument
           bytes (complements the structural PIPJ003 — this checks the
           LEDGER, not the lowering annotation).
  PIPM003  the program priced at the BigANN-1B per-shard envelope (exact
           aval bytes at the envelope shapes + the validated workspace
           model) must fit ``PIPNN_DEVICE_HBM_BUDGET``
           (``kernels.tiling.hbm_budget`` — single-sourced with PIPS003 and
           the roofline fits-HBM bit).
  PIPM004  measured temp bytes at every lattice point must stay within the
           program's declared workspace model x tolerance — catches hidden
           f32 upcasts, rematerialized gathers and fusion regressions that
           keep peak *scaling* intact but blow the constant.
  PIPM005  the checked-in ``memory_envelope.json`` baselines the canonical-
           point peak per program; >10% regression fails (CI gate).
  PIPM006  every registered program must have a complete envelope record —
           ledger, exponents, envelope price and the three-term v5e
           roofline (``roofline.analyze_compiled``, including collective
           wire bytes for sharded programs).  Regenerate with
           ``python -m repro.analysis.memory_audit --write-envelope``.

Gracefully skips (stderr report, zero findings) when the backend's
``memory_analysis()`` is unavailable or returns an empty ledger.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import math
import pathlib
import sys
from typing import Any, Callable

import numpy as np

from repro.analysis.lint import Finding

ENVELOPE_PATH = pathlib.Path(__file__).resolve().parent / "memory_envelope.json"
ENVELOPE_TOL = 0.10        # PIPM005: allowed canonical-peak growth
WORKSPACE_TOL = 2.0        # PIPM004: model x tol upper bound on temp
WORKSPACE_SLACK = 2 << 20  # PIPM004: absolute slack for tiny-shape constants
DEFAULT_EXPONENT_BOUND = 1.15
SWEEP_FACTORS = (1, 2, 4)

# BigANN-1B deployment envelope (matches spmd_audit.PRODUCTION_ENVELOPE):
# 2^30 points over S=256 shards -> the per-shard/per-device scale every
# single-device program is priced at.  Build programs run f32 (sketches and
# distances are f32 regardless of the serving quantization); serving
# programs price the int8 packing.
ENV_SHARDS = 256
ENV_N = (1 << 30) // ENV_SHARDS          # 4_194_304 owned rows per shard
ENV_D = 128
ENV_R = 64
ENV_L_MAX = 64
ENV_HALO = 0.10                          # measured worst halo (PIPS003 audit)


def _report(msg: str) -> None:
    print(f"  [mem] {msg}", file=sys.stderr)


def _sds(shape, dtype):
    import jax

    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _aval_bytes(a) -> int:
    if a is None:
        return 0
    return int(np.prod(a.shape, dtype=np.int64) * np.dtype(a.dtype).itemsize)


# ---------------------------------------------------------------------------
# registry types
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MemProgram:
    """One concrete lowerable instance of a registered program: the jitted
    entry, its positional avals and static kwargs, and which positional
    args are donated."""

    fn: Any
    args: tuple
    statics: dict = dataclasses.field(default_factory=dict)
    donated: tuple = ()


@dataclasses.dataclass(frozen=True)
class MemSpec:
    """A registered hot-path program and its audit contract."""

    name: str
    path: str                      # repo-relative file for findings
    kind: str                      # "build" | "serve"
    base: dict                     # canonical lattice point {param: value}
    build: Callable                # point dict -> MemProgram
    sweep: dict = dataclasses.field(default_factory=dict)  # param -> bound
    envelope: dict | None = None   # deployment point, or None
    workspace: Callable | None = None      # point dict -> modeled temp bytes
    envelope_pricer: Callable | None = None  # () -> dict(parts, total)
    n_devices: int = 1
    min_devices: int = 1
    note: str = ""


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

_LEDGER_KEYS = ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")

_MEASURE_CACHE: dict = {}


@functools.lru_cache(maxsize=1)
def ledger_available() -> bool:
    """Probe whether this backend exposes a usable compiled byte ledger."""
    try:
        import jax
        import jax.numpy as jnp

        f = jax.jit(lambda a: a + 1.0)
        compiled = f.lower(_sds((128, 128), jnp.float32)).compile()
        ma = compiled.memory_analysis()
        return float(getattr(ma, "argument_size_in_bytes", 0) or 0) > 0
    except Exception as e:          # pragma: no cover - backend dependent
        _report(f"memory_analysis() probe failed ({type(e).__name__}: {e})")
        return False


def _point_key(spec: MemSpec, point: dict) -> tuple:
    return (spec.name, tuple(sorted(point.items())))


def measure(spec: MemSpec, point: dict) -> tuple[dict, Any]:
    """AOT-compile the program at ``point`` and return (byte ledger,
    compiled).  peak = argument + output + temp - alias (the donated /
    aliased bytes are credited once, exactly as the runtime allocates)."""
    key = _point_key(spec, point)
    if key in _MEASURE_CACHE:
        return _MEASURE_CACHE[key]
    prog = spec.build(point)
    compiled = prog.fn.lower(*prog.args, **prog.statics).compile()
    ma = compiled.memory_analysis()
    ledger = {k: float(getattr(ma, k, 0) or 0) for k in _LEDGER_KEYS}
    ledger["peak"] = (ledger["argument_size_in_bytes"]
                      + ledger["output_size_in_bytes"]
                      + ledger["temp_size_in_bytes"]
                      - ledger["alias_size_in_bytes"])
    ledger["donated_arg_bytes"] = float(sum(
        _aval_bytes(prog.args[i]) for i in prog.donated))
    _MEASURE_CACHE[key] = (ledger, compiled)
    return ledger, compiled


def fit_exponent(xs, ys) -> float:
    lx = np.log(np.asarray(xs, dtype=np.float64))
    ly = np.log(np.maximum(np.asarray(ys, dtype=np.float64), 1.0))
    return float(np.polyfit(lx, ly, 1)[0])


def price_envelope(spec: MemSpec) -> dict | None:
    """Exact-shape envelope price: argument + output avals at the envelope
    point (via ``eval_shape`` — no compile) minus the donation credit, plus
    the PIPM004-validated workspace model for temp."""
    if spec.envelope_pricer is not None:
        return spec.envelope_pricer()
    if spec.envelope is None:
        return None
    import jax

    prog = spec.build(spec.envelope)
    target = functools.partial(prog.fn, **prog.statics) if prog.statics \
        else prog.fn
    out = jax.eval_shape(target, *prog.args)
    arg_bytes = sum(_aval_bytes(a) for a in prog.args)
    out_bytes = sum(_aval_bytes(a) for a in jax.tree_util.tree_leaves(out))
    donated = sum(_aval_bytes(prog.args[i]) for i in prog.donated)
    temp = int(spec.workspace(spec.envelope)) if spec.workspace else 0
    return {
        "argument_bytes": int(arg_bytes),
        "output_bytes": int(out_bytes),
        "donated_credit": int(min(donated, out_bytes)),
        "workspace_bytes": temp,
        "total": int(arg_bytes + out_bytes - min(donated, out_bytes) + temp),
    }


def _roofline_record(spec: MemSpec, compiled) -> dict:
    from repro.roofline import analyze_compiled

    r = analyze_compiled(
        compiled, name=spec.name, mesh_name="host",
        n_devices=spec.n_devices, kind=spec.kind)
    return {
        "t_compute": r.t_compute, "t_memory": r.t_memory,
        "t_collective": r.t_collective, "dominant": r.dominant,
        "hlo_flops": r.hlo_flops, "hlo_bytes": r.hlo_bytes,
        "coll_bytes": r.coll_bytes, "bound_seconds": r.bound_seconds(),
    }


# ---------------------------------------------------------------------------
# per-spec audit
# ---------------------------------------------------------------------------

def audit_spec(spec: MemSpec, baseline_record: dict | None,
               budget: int | None = None) -> tuple[list[Finding], dict]:
    """All compile-time checks for one registered program.  Returns
    (findings, envelope record)."""
    from repro.kernels.tiling import hbm_budget

    budget = hbm_budget() if budget is None else int(budget)
    findings: list[Finding] = []

    base_ledger, compiled = measure(spec, spec.base)

    # -- PIPM002: donation credited in the byte ledger ----------------------
    donated = base_ledger["donated_arg_bytes"]
    if donated > 0 and base_ledger["alias_size_in_bytes"] < donated:
        findings.append(Finding(
            "PIPM002", spec.path, 0, spec.name,
            f"{int(donated)} donated argument bytes but only "
            f"{int(base_ledger['alias_size_in_bytes'])} aliased in the "
            f"compiled ledger — the donation is not actually credited "
            f"against allocation and peak memory double-counts the "
            f"reservoir"))

    # -- PIPM004: temp within the declared workspace model ------------------
    def check_workspace(point: dict, ledger: dict) -> None:
        if spec.workspace is None:
            return
        model = float(spec.workspace(point))
        limit = model * WORKSPACE_TOL + WORKSPACE_SLACK
        if ledger["temp_size_in_bytes"] > limit:
            findings.append(Finding(
                "PIPM004", spec.path, 0, spec.name,
                f"temp bytes {int(ledger['temp_size_in_bytes'])} exceed the "
                f"declared workspace model {int(model)} x {WORKSPACE_TOL} "
                f"(+{WORKSPACE_SLACK} slack) at point {point} — hidden "
                f"upcast/remat/gather blowup"))

    check_workspace(spec.base, base_ledger)

    # -- PIPM001: scaling exponents over the sweep lattice ------------------
    exponents: dict[str, float] = {}
    for param, bound in spec.sweep.items():
        xs, ys = [], []
        for f in SWEEP_FACTORS:
            point = dict(spec.base)
            point[param] = spec.base[param] * f
            ledger, _ = measure(spec, point)
            check_workspace(point, ledger)
            xs.append(point[param])
            ys.append(ledger["peak"])
        exp = fit_exponent(xs, ys)
        exponents[param] = exp
        if exp > bound:
            findings.append(Finding(
                "PIPM001", spec.path, 0, spec.name,
                f"peak bytes scale as {param}^{exp:.2f} over {xs} (bound "
                f"{bound:.2f}) — the bounded-memory contract is broken: "
                f"peak must depend on chunk/reservoir shapes only, never "
                f"superlinearly (build programs: never on the emitted edge "
                f"count E)"))

    # -- PIPM003: envelope price fits the HBM budget ------------------------
    env = price_envelope(spec)
    if env is not None and env["total"] > budget:
        findings.append(Finding(
            "PIPM003", spec.path, 0, spec.name,
            f"BigANN-1B per-shard envelope prices at "
            f"{env['total'] / 2**30:.2f} GiB "
            f"(args {env.get('argument_bytes', 0) / 2**30:.2f} + workspace "
            f"{env.get('workspace_bytes', 0) / 2**30:.2f}) over the "
            f"{budget / 2**30:.2f} GiB device budget "
            f"(PIPNN_DEVICE_HBM_BUDGET)"))

    # -- envelope record + PIPM005/PIPM006 ----------------------------------
    record = {
        "path": spec.path,
        "kind": spec.kind,
        "canonical_point": dict(spec.base),
        "canonical_ledger": {k: base_ledger[k]
                             for k in (*_LEDGER_KEYS, "peak")},
        "exponents": exponents,
        "envelope_point": dict(spec.envelope) if spec.envelope else None,
        "envelope_bytes": env,
        "roofline": _roofline_record(spec, compiled),
    }

    if baseline_record is None:
        findings.append(Finding(
            "PIPM006", spec.path, 0, spec.name,
            "program has no record in memory_envelope.json — regenerate "
            "with `python -m repro.analysis.memory_audit --write-envelope`"))
    else:
        missing = [k for k in ("canonical_ledger", "exponents",
                               "envelope_bytes", "roofline")
                   if baseline_record.get(k) is None
                   and record.get(k) is not None]
        if missing:
            findings.append(Finding(
                "PIPM006", spec.path, 0, spec.name,
                f"envelope record incomplete (missing {missing}) — "
                f"regenerate with --write-envelope"))
        stored = (baseline_record.get("canonical_ledger") or {}).get("peak")
        if stored:
            grown = base_ledger["peak"] / float(stored) - 1.0
            if grown > ENVELOPE_TOL:
                findings.append(Finding(
                    "PIPM005", spec.path, 0, spec.name,
                    f"canonical-point peak grew {grown * 100:.1f}% over the "
                    f"checked-in envelope ({int(base_ledger['peak'])} vs "
                    f"{int(stored)}) — memory regression; if intended, "
                    f"regenerate with --write-envelope"))

    exps = " ".join(f"{p}^{e:.2f}" for p, e in exponents.items())
    env_s = (f" env={env['total'] / 2**30:.2f}GiB" if env else "")
    _report(f"{spec.name}: peak={base_ledger['peak'] / 2**20:.1f}MiB "
            f"temp={base_ledger['temp_size_in_bytes'] / 2**20:.1f}MiB "
            f"[{exps}]{env_s} "
            f"roofline={record['roofline']['dominant']}")
    return findings, record


# ---------------------------------------------------------------------------
# program registry
# ---------------------------------------------------------------------------

def _stream_spec() -> MemSpec:
    def build(pt):
        import jax.numpy as jnp

        from repro.core.pipnn import _make_stream_step

        step = _make_stream_step(None, pt["k"], "l2", "bidirected", False,
                                 True, pt["sub"], 1.2, 64, "segmented",
                                 False)
        n, d, l, s, c, m = (pt["n"], pt["d"], pt["l_max"], pt["s"], pt["c"],
                            pt["m"])
        args = (_sds((n, l), jnp.int32), _sds((n, l), jnp.int32),
                _sds((n, l), jnp.float32), _sds((n, d), jnp.float32),
                _sds((n, m), jnp.float32), _sds((s, c), jnp.int32))
        return MemProgram(step, args, donated=(0, 1, 2))

    def ws(pt):
        from repro.core.pipnn import stream_step_workspace_bytes

        return stream_step_workspace_bytes(pt["n"], pt["l_max"], pt["s"],
                                           pt["c"], pt["k"])

    return MemSpec(
        name="stream_step", path="src/repro/core/pipnn.py", kind="build",
        base=dict(n=2048, d=16, l_max=16, s=8, c=16, k=4, m=8, sub=4),
        sweep=dict(n=DEFAULT_EXPONENT_BOUND, s=DEFAULT_EXPONENT_BOUND,
                   l_max=DEFAULT_EXPONENT_BOUND, d=DEFAULT_EXPONENT_BOUND),
        envelope=dict(n=ENV_N, d=ENV_D, l_max=ENV_L_MAX, s=1024, c=256,
                      k=8, m=12, sub=64),
        build=build, workspace=ws)


def _merge_spec(flavor: str) -> MemSpec:
    def build(pt):
        import jax.numpy as jnp

        from repro.core import hashprune as hp

        n, l, e = pt["n"], pt["l_max"], pt["e"]
        args = (_sds((n, l), jnp.int32), _sds((n, l), jnp.int32),
                _sds((n, l), jnp.float32), _sds((e,), jnp.int32),
                _sds((e,), jnp.int32), _sds((e,), jnp.int32),
                _sds((e,), jnp.float32))
        if flavor == "segmented":
            return MemProgram(hp._merge_segmented_jit, args,
                              statics=dict(use_pallas=False,
                                           interpret=False),
                              donated=(0, 1, 2))
        return MemProgram(hp._merge_flat_jit, args, donated=(0, 1, 2))

    def ws(pt):
        from repro.core import hashprune as hp

        f = (hp.merge_segmented_workspace_bytes if flavor == "segmented"
             else hp.merge_flat_workspace_bytes)
        return f(pt["n"], pt["l_max"], pt["e"])

    return MemSpec(
        name=f"merge_{flavor}", path="src/repro/core/hashprune.py",
        kind="build",
        base=dict(n=4096, l_max=16, e=65536),
        sweep=dict(n=DEFAULT_EXPONENT_BOUND, l_max=DEFAULT_EXPONENT_BOUND,
                   e=DEFAULT_EXPONENT_BOUND),
        envelope=dict(n=ENV_N, l_max=ENV_L_MAX, e=4 * (1 << 22)),
        build=build, workspace=ws)


def _final_prune_spec() -> MemSpec:
    def build(pt):
        import jax.numpy as jnp

        from repro.core.robust_prune import _final_prune_step

        n, d, l, chunk, md = (pt["n"], pt["d"], pt["l_max"], pt["chunk"],
                              pt["max_deg"])
        args = (_sds((n, md), jnp.int32), _sds((n, md), jnp.float32),
                _sds((n, d), jnp.float32), _sds((n, l), jnp.int32),
                _sds((n, l), jnp.float32), _sds((), jnp.int32))
        statics = dict(alpha=1.44, max_deg=md, metric="l2", chunk=chunk)
        return MemProgram(_final_prune_step, args, statics=statics,
                          donated=(0, 1))

    def ws(pt):
        from repro.core.robust_prune import final_prune_workspace_bytes

        return final_prune_workspace_bytes(pt["chunk"], pt["l_max"],
                                           pt["d"], pt["max_deg"])

    return MemSpec(
        name="final_prune_step", path="src/repro/core/robust_prune.py",
        kind="build",
        base=dict(n=4096, d=16, l_max=16, chunk=512, max_deg=16),
        sweep=dict(n=DEFAULT_EXPONENT_BOUND, chunk=DEFAULT_EXPONENT_BOUND,
                   l_max=1.6, d=DEFAULT_EXPONENT_BOUND),
        envelope=dict(n=ENV_N, d=ENV_D, l_max=ENV_L_MAX, chunk=2048,
                      max_deg=ENV_R),
        build=build, workspace=ws)


def _carve_spec() -> MemSpec:
    def _shapes(pt):
        from repro.core.rbc import RBCParams, carve_chunks

        return carve_chunks(pt["n"], RBCParams(metric="l2"))

    def build(pt):
        import jax.numpy as jnp

        from repro.core.rbc import RBCParams, _make_static_carve

        sh = _shapes(pt)
        p = RBCParams(metric="l2")
        step = _make_static_carve(
            sh["n_pad"], sh["l0"], sh["f0"], sh["f0r"], sh["cap_b"],
            sh["l1"], sh["f1"], p.c_max, p.metric, sh["sub"],
            sh["bucket_chunk"], sh["cap_chunk"])
        args = (_sds((sh["n_pad"], pt["d"]), jnp.float32),
                _sds((sh["l0"],), jnp.int32), _sds((), jnp.int32))
        return MemProgram(step, args)

    def ws(pt):
        from repro.core.rbc import carve_workspace_bytes

        sh = _shapes(pt)
        return carve_workspace_bytes(
            sh["n_pad"], pt["d"], sh["l0"], sh["f0r"], sh["cap_b"],
            sh["l1"], sh["f1"], sh["bucket_chunk"], sh["cap_chunk"])

    return MemSpec(
        name="carve_static", path="src/repro/core/rbc.py", kind="build",
        base=dict(n=4096, d=16),
        sweep=dict(n=1.35, d=DEFAULT_EXPONENT_BOUND),
        envelope=dict(n=ENV_N, d=ENV_D),
        build=build, workspace=ws,
        note="n exponent bound 1.35: cap_b rounds up in steps of 8, so tiny "
             "lattice points see a discretization bump over the true ~n^1")


def _engine_build(pt) -> MemProgram:
    import jax.numpy as jnp

    from repro.core import beam_search as bs

    n, d, nq = pt["n"], pt["d"], pt["nq"]
    int8 = bool(pt.get("int8"))
    x = _sds((n, d), jnp.int8 if int8 else jnp.float32)
    scales = _sds((n,), jnp.float32) if int8 else None
    args = (_sds((n, pt["r"]), jnp.int32), x, _sds((n,), jnp.float32),
            _sds((nq, d), jnp.float32), _sds((), jnp.int32), scales)
    statics = dict(beam=pt["beam"], iters=pt["iters"], metric="l2",
                   expansions=pt["expansions"], early_exit=True,
                   kernel_path="xla", interpret=False)
    return MemProgram(bs._beam_search_multi, args, statics=statics)


def _engine_ws(pt) -> int:
    from repro.core.serving import engine_workspace_bytes

    return engine_workspace_bytes(pt["nq"], pt["n"], pt["d"], pt["r"],
                                  pt["beam"], pt["expansions"])


def _engine_spec() -> MemSpec:
    return MemSpec(
        name="serving_engine", path="src/repro/core/serving.py",
        kind="serve",
        base=dict(n=4096, d=16, r=8, nq=8, beam=8, expansions=2, iters=12),
        sweep=dict(n=DEFAULT_EXPONENT_BOUND, d=DEFAULT_EXPONENT_BOUND,
                   nq=DEFAULT_EXPONENT_BOUND, beam=DEFAULT_EXPONENT_BOUND),
        envelope=dict(n=_env_shard_rows(), d=ENV_D, r=ENV_R, nq=32, beam=32,
                      expansions=4, iters=36, int8=True),
        build=_engine_build, workspace=_engine_ws)


def _engine_int8_spec() -> MemSpec:
    return MemSpec(
        name="serving_engine_int8", path="src/repro/core/serving.py",
        kind="serve",
        base=dict(n=4096, d=16, r=8, nq=8, beam=8, expansions=2, iters=12,
                  int8=True),
        envelope=dict(n=_env_shard_rows(), d=ENV_D, r=ENV_R, nq=32, beam=32,
                      expansions=4, iters=36, int8=True),
        build=_engine_build, workspace=_engine_ws)


def _straggler_spec() -> MemSpec:
    # the ServeLoop straggler rerun: fixed straggler_chunk batch, the
    # ladder's widest beam, the full backstop_iters cap
    def ws(pt):
        from repro.launch.serve_loop import straggler_workspace_bytes

        return straggler_workspace_bytes(pt["nq"], pt["n"], pt["d"],
                                         pt["r"], pt["beam"],
                                         pt["expansions"])

    return MemSpec(
        name="serve_loop_straggler", path="src/repro/launch/serve_loop.py",
        kind="serve",
        base=dict(n=4096, d=16, r=8, nq=8, beam=32, expansions=4, iters=36),
        envelope=dict(n=_env_shard_rows(), d=ENV_D, r=ENV_R, nq=8, beam=32,
                      expansions=4, iters=36, int8=True),
        build=_engine_build, workspace=ws)


def _topk_spec() -> MemSpec:
    def build(pt):
        import jax.numpy as jnp

        from repro.distributed import serving as dserv

        s, nq, b = pt["s"], pt["nq"], pt["b"]
        args = (_sds((s, nq, b), jnp.int32), _sds((s, nq, b), jnp.float32))
        return MemProgram(dserv.cross_shard_topk, args,
                          statics=dict(k=pt["k"]))

    def ws(pt):
        from repro.distributed.serving import cross_shard_topk_workspace_bytes

        return cross_shard_topk_workspace_bytes(pt["s"], pt["nq"], pt["b"],
                                                pt["k"])

    return MemSpec(
        name="cross_shard_topk", path="src/repro/distributed/serving.py",
        kind="serve",
        base=dict(s=8, nq=8, b=8, k=10),
        sweep=dict(s=DEFAULT_EXPONENT_BOUND, nq=DEFAULT_EXPONENT_BOUND,
                   b=DEFAULT_EXPONENT_BOUND),
        envelope=dict(s=ENV_SHARDS, nq=32, b=32, k=10),
        build=build, workspace=ws)


def _env_shard_rows() -> int:
    """Per-shard rows at the envelope, grown by the halo + pad slack the
    packing model uses (spmd_audit.price_shard_packing)."""
    return math.ceil(ENV_N * (1.0 + ENV_HALO) * 1.10)


def _sharded_spec() -> MemSpec:
    def build(pt):
        from repro.analysis import spmd_audit

        prog = spmd_audit._serving_program(pt["s"])
        return MemProgram(prog.fn, prog.args)

    def pricer():
        from repro.analysis.spmd_audit import price_shard_packing
        from repro.distributed.serving import sharded_search_workspace_bytes

        packing = price_shard_packing(1 << 30, ENV_D, ENV_R, ENV_SHARDS,
                                      int8=True, halo_fraction=ENV_HALO)
        ws = sharded_search_workspace_bytes(32, packing["rows"], ENV_D,
                                            ENV_R, 32, 4, ENV_SHARDS)
        return {
            "argument_bytes": int(packing["total"]),
            "output_bytes": int(ENV_SHARDS * 32 * 32 * 8),
            "donated_credit": 0,
            "workspace_bytes": int(ws),
            "total": int(packing["total"] + ENV_SHARDS * 32 * 32 * 8 + ws),
        }

    import jax

    ndev = len(jax.devices())
    return MemSpec(
        name="sharded_search", path="src/repro/distributed/serving.py",
        kind="serve",
        base=dict(s=min(4, ndev)),
        envelope=dict(s=ENV_SHARDS),
        build=build, envelope_pricer=pricer,
        n_devices=min(4, ndev), min_devices=2,
        note="per-shard body collective-freedom is PIPS001; this spec "
             "audits the ledger and prices the packed envelope")


def default_specs() -> list[MemSpec]:
    return [
        _stream_spec(),
        _merge_spec("segmented"),
        _merge_spec("flat"),
        _final_prune_spec(),
        _carve_spec(),
        _engine_spec(),
        _engine_int8_spec(),
        _straggler_spec(),
        _topk_spec(),
        _sharded_spec(),
    ]


# ---------------------------------------------------------------------------
# top level
# ---------------------------------------------------------------------------

def load_envelope(path: pathlib.Path = ENVELOPE_PATH) -> dict:
    if not path.exists():
        return {}
    try:
        return json.loads(path.read_text()).get("programs", {})
    except (json.JSONDecodeError, AttributeError):
        return {}


def audit_all(specs: list[MemSpec] | None = None, *,
              envelope_path: pathlib.Path = ENVELOPE_PATH,
              write_envelope: bool = False,
              budget: int | None = None) -> list[Finding]:
    """Run every registered spec; returns findings.  With
    ``write_envelope`` the measured records replace ``envelope_path`` and
    PIPM005/PIPM006 are (vacuously) clean."""
    import jax

    if not ledger_available():
        _report("compiled memory_analysis() unavailable on this backend — "
                "memory pass skipped")
        return []
    specs = default_specs() if specs is None else specs
    baseline = {} if write_envelope else load_envelope(envelope_path)
    ndev = len(jax.devices())

    findings: list[Finding] = []
    records: dict[str, dict] = {}
    for spec in specs:
        if ndev < spec.min_devices:
            _report(f"{spec.name}: needs >= {spec.min_devices} devices "
                    f"(have {ndev}) — skipped")
            continue
        try:
            f, record = audit_spec(
                spec, None if write_envelope else baseline.get(spec.name),
                budget=budget)
        except Exception as e:
            findings.append(Finding(
                "PIPM006", spec.path, 0, spec.name,
                f"registered program failed to lower/compile for the "
                f"memory audit: {type(e).__name__}: {e}"))
            continue
        if write_envelope:
            f = [x for x in f if x.rule not in ("PIPM005", "PIPM006")]
        findings += f
        records[spec.name] = record

    if write_envelope:
        from repro.kernels.tiling import hbm_budget

        payload = {
            "_meta": {
                "budget_bytes": hbm_budget() if budget is None else budget,
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "regenerate": "python -m repro.analysis.memory_audit "
                              "--write-envelope",
            },
            "programs": records,
        }
        envelope_path.write_text(json.dumps(payload, indent=1,
                                            sort_keys=True) + "\n")
        _report(f"wrote {len(records)} program record(s) to {envelope_path}")
    return findings


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.memory_audit",
        description="PiPNN memory-bound auditor (PIPM001-006)")
    ap.add_argument("--write-envelope", action="store_true",
                    help="regenerate memory_envelope.json from the current "
                         "measurements")
    ap.add_argument("--envelope", type=pathlib.Path, default=ENVELOPE_PATH)
    args = ap.parse_args(argv)

    findings = audit_all(envelope_path=args.envelope,
                         write_envelope=args.write_envelope)
    for f in findings:
        print(f.render())
    status = "FAIL" if findings else "OK"
    print(f"repro.analysis.memory_audit: {status} — {len(findings)} "
          f"finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
