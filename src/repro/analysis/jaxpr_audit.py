"""Jaxpr/HLO auditor over the serving and build hot paths — PIPJ001-PIPJ004.

The audited entry points are the programs that run per-query or per-chunk
in production:

  * ``core.beam_search._beam_search_multi`` — the serving engine (both the
    pure-XLA and the VMEM-resident Pallas distance path);
  * the streaming build step (``core.pipnn._make_stream_step``);
  * the reservoir folds (``core.hashprune._merge_segmented_jit`` /
    ``_merge_flat_jit``);
  * ``distributed.serving.cross_shard_topk``.

Checks:

  PIPJ001  no host-callback primitive anywhere in the traced jaxpr — a
           callback in a hot path serializes every dispatch on the host.
  PIPJ002  no float64/complex128 value — a stray f64 (e.g. from an
           un-annotated numpy scalar under x64) doubles bandwidth and
           falls off the TPU fast path.
  PIPJ003  buffer donation declared on an entry point must survive
           lowering: each donated argument needs an aliased output in the
           compiled module (``tf.aliasing_output``), otherwise XLA
           silently dropped it and peak memory doubles.
  PIPJ004  a simulated serving session (sweeping nq / beam / expansions /
           serving dtype through ``ServingIndex.search``) must compile at
           most one engine variant per static combination — distinct
           *batch sizes* must all reuse the padded ``query_chunk`` shape.

Tracing only (``jax.make_jaxpr`` / AOT ``.lower()``) for the first three —
nothing executes; the recompilation audit actually runs a tiny index
session, since compile-cache growth is a runtime property.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.analysis.lint import Finding

HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call",
})

_WIDE_DTYPES = ("float64", "complex128")


# ---------------------------------------------------------------------------
# jaxpr traversal
# ---------------------------------------------------------------------------

def _iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` and all nested jaxprs (pjit/scan/while/...)."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        j = getattr(j, "jaxpr", j)      # ClosedJaxpr -> Jaxpr
        for eqn in j.eqns:
            yield eqn
            for v in eqn.params.values():
                for item in (v if isinstance(v, (tuple, list)) else (v,)):
                    if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                        stack.append(item)


def audit_jaxpr(jaxpr, path: str, symbol: str) -> list[Finding]:
    """PIPJ001 + PIPJ002 over one (closed) jaxpr."""
    findings: list[Finding] = []
    seen: set[str] = set()
    for eqn in _iter_eqns(jaxpr):
        prim = eqn.primitive.name
        if prim in HOST_CALLBACK_PRIMS and ("cb", prim) not in seen:
            seen.add(("cb", prim))
            findings.append(Finding(
                "PIPJ001", path, 0, symbol,
                f"host callback '{prim}' in the traced hot path — every "
                f"dispatch round-trips through Python"))
        for var in tuple(eqn.invars) + tuple(eqn.outvars):
            aval = getattr(var, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in _WIDE_DTYPES and ("wide", dt) not in seen:
                seen.add(("wide", dt))
                findings.append(Finding(
                    "PIPJ002", path, 0, symbol,
                    f"{dt} value (op '{prim}') in the traced hot path — "
                    f"double-width types fall off the TPU fast path"))
    return findings


def trace_and_audit(fn, args, path: str, symbol: str,
                    statics: dict | None = None) -> list[Finding]:
    """``jax.make_jaxpr`` the function (bypassing any jit wrapper via
    ``__wrapped__`` so static kwargs stay plain Python) and audit it."""
    import jax

    target = getattr(fn, "__wrapped__", fn)
    if statics:
        target = functools.partial(target, **statics)
    jaxpr = jax.make_jaxpr(target)(*args)
    return audit_jaxpr(jaxpr, path, symbol)


# ---------------------------------------------------------------------------
# PIPJ003: donation survives lowering
# ---------------------------------------------------------------------------

def check_donation(jitted, args, n_donated: int, path: str, symbol: str,
                   statics: dict | None = None) -> list[Finding]:
    """Lower the (already-jitted) entry and require at least ``n_donated``
    aliased outputs in the compiler input — the marker XLA strips when a
    donated buffer has no same-shape/dtype output to reuse."""
    lowered = jitted.lower(*args, **(statics or {}))
    aliased = lowered.as_text().count("tf.aliasing_output")
    if aliased < n_donated:
        return [Finding(
            "PIPJ003", path, 0, symbol,
            f"{n_donated} argument(s) donated but only {aliased} aliased "
            f"output(s) survive lowering — XLA dropped the donation and "
            f"the buffers are double-allocated")]
    return []


# ---------------------------------------------------------------------------
# entry-point registry
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax
    return jax.ShapeDtypeStruct(shape, dtype)


def audit_hot_paths() -> list[Finding]:
    import jax.numpy as jnp

    f32, i32 = jnp.float32, jnp.int32
    findings: list[Finding] = []

    # serving engine — both the XLA and VMEM-resident distance paths
    from repro.core import beam_search as bs
    n, d, nq = 64, 16, 4
    eng_args = (_sds((n, 8), i32), _sds((n, d), f32), _sds((n,), f32),
                _sds((nq, d), f32), _sds((), i32), None)
    for kp in ("xla", "vmem"):
        findings += trace_and_audit(
            bs._beam_search_multi, eng_args,
            "src/repro/core/beam_search.py", f"_beam_search_multi[{kp}]",
            statics=dict(beam=8, iters=12, metric="l2", expansions=2,
                         early_exit=True, kernel_path=kp, interpret=False))

    # streaming build step (fused leaf-kNN -> emit -> hash -> fold)
    from repro.core.pipnn import _make_stream_step
    step = _make_stream_step(None, 4, "l2", "bidirected", False, True,
                             2, 1.2, 64, "segmented", False)
    l_max, m, s, c = 8, 8, 4, 16
    step_args = (_sds((n, l_max), i32), _sds((n, l_max), i32),
                 _sds((n, l_max), f32), _sds((n, d), f32),
                 _sds((n, m), f32), _sds((s, c), i32))
    findings += trace_and_audit(step, step_args,
                                "src/repro/core/pipnn.py", "stream_step")
    findings += check_donation(step, step_args, 3,
                               "src/repro/core/pipnn.py", "stream_step")

    # reservoir folds
    from repro.core import hashprune as hp
    e = 64
    merge_args = (_sds((n, l_max), i32), _sds((n, l_max), i32),
                  _sds((n, l_max), f32), _sds((e,), i32), _sds((e,), i32),
                  _sds((e,), i32), _sds((e,), f32))
    findings += trace_and_audit(
        hp._merge_segmented_jit, merge_args,
        "src/repro/core/hashprune.py", "_merge_segmented_jit",
        statics=dict(use_pallas=False, interpret=False))
    findings += check_donation(
        hp._merge_segmented_jit, merge_args, 3,
        "src/repro/core/hashprune.py", "_merge_segmented_jit",
        statics=dict(use_pallas=False, interpret=False))
    findings += check_donation(
        hp._merge_flat_jit, merge_args, 3,
        "src/repro/core/hashprune.py", "_merge_flat_jit")

    # cross-shard top-k merge
    from repro.distributed import serving as dserv
    topk_args = (_sds((2, nq, 8), i32), _sds((2, nq, 8), f32))
    findings += trace_and_audit(
        dserv.cross_shard_topk, topk_args,
        "src/repro/distributed/serving.py", "cross_shard_topk",
        statics=dict(k=10))
    return findings


# ---------------------------------------------------------------------------
# PIPJ004: bounded jit-cache growth across a serving session
# ---------------------------------------------------------------------------

def _cache_size(jitted) -> int:
    for attr in ("_cache_size", "cache_size"):
        f = getattr(jitted, attr, None)
        if callable(f):
            return int(f())
    return -1


def _clear_cache(jitted) -> None:
    for attr in ("clear_cache", "_clear_cache"):
        f = getattr(jitted, attr, None)
        if callable(f):
            f()
            return


def audit_recompilation(query_chunk: int | None = 4) -> list[Finding]:
    """Replay a serving session over a tiny index: every (beam, expansions,
    serving dtype) combination is a legitimate engine variant; batch size
    is NOT — ``query_chunk`` pads every dispatch to one shape.  Bound:
    exactly |beams| x |expansions| x |dtypes| compiled variants.

    ``query_chunk`` exists so the test suite can prove the rule has teeth:
    passing ``None`` disables chunk padding, batch size leaks into the
    dispatch shape, and the audit must report PIPJ004."""
    from repro.core import beam_search as bs
    from repro.core.serving import ServingIndex

    eng = bs._beam_search_multi
    before = _cache_size(eng)
    if before < 0:
        return []  # cache introspection unavailable on this jax version
    _clear_cache(eng)

    rng = np.random.default_rng(0)
    n, d = 96, 16
    x = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    beams, expansions_sweep, batch_sizes = (4, 8), (1, 2), (1, 3, 7, 12)
    indexes = (ServingIndex.from_graph(graph, x, 0),
               ServingIndex.from_graph(graph, x, 0, dtype="int8"))
    for sv in indexes:
        for beam in beams:
            for e in expansions_sweep:
                for nq in batch_sizes:
                    q = rng.normal(size=(nq, d)).astype(np.float32)
                    sv.search(q, k=4, beam=beam, expansions=e,
                              query_chunk=query_chunk)
    bound = len(indexes) * len(beams) * len(expansions_sweep)
    got = _cache_size(eng)
    if got > bound:
        return [Finding(
            "PIPJ004", "src/repro/core/serving.py", 0, "ServingIndex.search",
            f"serving session compiled {got} engine variants, bound is "
            f"{bound} (|dtypes| x |beams| x |expansions|) — batch size is "
            f"leaking into the dispatch shape despite query_chunk")]
    return []


def audit_recompilation_sharded(query_chunk: int | None = 4) -> list[Finding]:
    """PIPJ004 over the SHARDED serving path: replay small varying batches
    through ``ShardedServingIndex.search`` with chunk padding on and
    check the per-index jit cache (the shard_map'd engine variants plus
    the ``cross_shard_topk`` merge) stays at one variant per (beam,
    expansions) — batch size must never leak into a mesh program's
    dispatch shape, where a recompile also re-lowers every collective.

    No-op on single-device hosts (the sharded path needs a real mesh to
    say anything a plain PIPJ004 run doesn't)."""
    import jax
    from jax.sharding import Mesh

    from repro.distributed import serving as dsv

    if len(jax.devices()) < 2:
        return []
    s = min(4, len(jax.devices()))
    rng = np.random.default_rng(0)
    n, d = 96, 16
    x = rng.normal(size=(n, d)).astype(np.float32)
    graph = rng.integers(0, n, size=(n, 4)).astype(np.int32)
    mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
    ssv = dsv.ShardedServingIndex.from_graph(graph, x, 0, mesh=mesh)
    _clear_cache(dsv.cross_shard_topk)

    beams, expansions_sweep, batch_sizes = (4, 8), (1, 2), (1, 3, 7, 12)
    for beam in beams:
        for e in expansions_sweep:
            for nq in batch_sizes:
                q = rng.normal(size=(nq, d)).astype(np.float32)
                ssv.search(q, k=4, beam=beam, expansions=e,
                           query_chunk=query_chunk)
    bound = len(beams) * len(expansions_sweep)
    engine = sum(_cache_size(fn) for fn in ssv._search_cache.values())
    findings: list[Finding] = []
    if engine > bound:
        findings.append(Finding(
            "PIPJ004", "src/repro/distributed/serving.py", 0,
            "ShardedServingIndex.search",
            f"sharded serving session compiled {engine} engine variants, "
            f"bound is {bound} (|beams| x |expansions|) — batch size is "
            f"leaking into the shard_map dispatch shape despite "
            f"query_chunk"))
    merge = _cache_size(dsv.cross_shard_topk)
    if merge > len(beams):
        findings.append(Finding(
            "PIPJ004", "src/repro/distributed/serving.py", 0,
            "cross_shard_topk",
            f"cross-shard merge compiled {merge} variants, bound is "
            f"{len(beams)} (one per beam width) — batch size is leaking "
            f"into the merge dispatch shape"))
    return findings


def audit_all() -> list[Finding]:
    return (audit_hot_paths() + audit_recompilation()
            + audit_recompilation_sharded())
