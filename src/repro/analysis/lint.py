"""``python -m repro.analysis.lint`` — the static contract checker CLI.

Runs the five analysis passes (AST lint, kernel contracts, jaxpr audit,
SPMD sharding audit, memory-bound audit) and reports findings as
``file:line: RULE [symbol] message``.  Exit code
is 0 iff every finding is covered by the baseline file — which is checked
in EMPTY and expected to stay that way: pre-existing violations get fixed,
not baselined; the file exists so a genuinely unfixable finding (e.g. a
vendored snippet) has an explicit, reviewed escape hatch.

Baseline format: one ``RULE path:symbol`` per line (no line numbers, so
unrelated edits cannot invalidate entries), ``#`` comments allowed.

Usage:
    python -m repro.analysis.lint                 # full run, repo root
    python -m repro.analysis.lint --pass ast      # one pass only
    python -m repro.analysis.lint --list-rules    # rule catalog
    python -m repro.analysis.lint --json          # machine-readable
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

RULES: dict[str, str] = {
    # kernel contract checker (repro.analysis.contracts)
    "PIPK001": "kernel VMEM footprint exceeds the per-core budget at an "
               "admitted swept shape",
    "PIPK002": "BlockSpec tile misaligned to the dtype's minimum TPU "
               "(sublane, lane) tile",
    "PIPK003": "grid x block does not cover the padded operand extents",
    "PIPK004": "kernel has no resolvable paired oracle in kernels/ref.py "
               "(or its declared oracle module)",
    "PIPK005": "pallas_call site not covered by the kernel contract "
               "registry",
    # jaxpr/HLO auditor (repro.analysis.jaxpr_audit)
    "PIPJ001": "host callback primitive inside a device hot path",
    "PIPJ002": "f64/complex128 value inside a device hot path",
    "PIPJ003": "donated buffer not aliased in the lowered output "
               "(donation silently dropped)",
    "PIPJ004": "simulated serving session compiled more jit variants than "
               "the declared bound",
    # AST lint (repro.analysis.ast_lint)
    "PIPA001": "Python if/while on a traced value inside a jitted "
               "function",
    "PIPA002": "host synchronization (.item()/float()/np.*) inside a "
               "jitted function",
    "PIPA003": "mutable default argument",
    "PIPA004": "shape-controlling parameter of a jitted function missing "
               "from static_argnames",
    # SPMD sharding auditor (repro.analysis.spmd_audit)
    "PIPS001": "collective primitive not in the program's declared "
               "(primitive, mesh axis) contract — per-shard search "
               "bodies must be collective-free",
    "PIPS002": "operand declared sharded in in_specs lowered to a "
               "replicated HLO sharding (or replicated without a "
               "whitelist entry)",
    "PIPS003": "per-shard halo packing prices over the per-device HBM "
               "budget (tile-padded bytes, PIPNN_DEVICE_HBM_BUDGET)",
    "PIPS004": "serving call crossed the host boundary outside the "
               "declared to_device/to_host budget",
    "PIPS005": "traced program structure differs across shard counts "
               "(shard count leaked into Python control flow)",
    # memory-bound auditor (repro.analysis.memory_audit)
    "PIPM001": "peak compiled bytes scale past the declared per-parameter "
               "exponent bound (bounded-memory contract: build programs "
               "may never scale with the emitted edge count E)",
    "PIPM002": "donated argument bytes not credited as aliased in the "
               "compiled byte ledger (donation declared but not "
               "realized in allocation)",
    "PIPM003": "program priced at the BigANN-1B per-shard envelope "
               "exceeds the per-device HBM budget "
               "(PIPNN_DEVICE_HBM_BUDGET)",
    "PIPM004": "measured temp bytes exceed the program's declared "
               "workspace model x tolerance (hidden upcast/remat/gather "
               "blowup)",
    "PIPM005": "canonical-point peak bytes regressed >10% over the "
               "checked-in memory_envelope.json",
    "PIPM006": "registered program missing a complete envelope record "
               "(ledger + exponents + envelope price + roofline) — "
               "regenerate with --write-envelope",
}

PASSES = ("ast", "kernels", "jaxpr", "spmd", "memory")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # e.g. "PIPK001"
    path: str       # repo-relative file
    line: int       # 1-indexed; 0 when the finding is not line-anchored
    symbol: str     # function / kernel the finding anchors to
    message: str

    @property
    def key(self) -> str:
        """Baseline key — deliberately line-number-free so unrelated edits
        above a baselined site cannot un-baseline it."""
        return f"{self.rule} {self.path}:{self.symbol}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} [{self.symbol}] " \
               f"{self.message}"


def repo_root() -> pathlib.Path:
    """The repository root (three levels above this file: src/repro/analysis)."""
    return pathlib.Path(__file__).resolve().parents[3]


def default_baseline_path() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parent / "baseline.txt"


def load_baseline(path: pathlib.Path) -> set[str]:
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            keys.add(line)
    return keys


def run_all(root: pathlib.Path | None = None,
            passes: tuple[str, ...] = PASSES) -> list[Finding]:
    """Run the requested passes over the repo; returns raw findings
    (baseline not applied)."""
    root = pathlib.Path(root) if root is not None else repo_root()
    findings: list[Finding] = []
    if "ast" in passes:
        from repro.analysis import ast_lint

        findings += ast_lint.lint_package(root / "src" / "repro", root=root)
    if "kernels" in passes:
        from repro.analysis import contracts

        findings += contracts.check_kernel_contracts(root=root)
    if "jaxpr" in passes:
        from repro.analysis import jaxpr_audit

        findings += jaxpr_audit.audit_all()
    if "spmd" in passes:
        from repro.analysis import spmd_audit

        findings += spmd_audit.audit_all()
    if "memory" in passes:
        from repro.analysis import memory_audit

        findings += memory_audit.audit_all()
    return findings


def _force_host_devices(n: int = 8) -> None:
    """Give the SPMD pass a real mesh sweep on single-accelerator hosts:
    prepend ``--xla_force_host_platform_device_count=N`` to XLA_FLAGS.
    Only effective before jax initializes — a no-op when jax is already
    imported (e.g. lint called from a test process) or the flag is
    already set; the audits then clamp to whatever devices exist."""
    import os

    if "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n} {flags}".strip()


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="PiPNN static contract checker (AST lint, kernel "
                    "contracts, jaxpr audit, SPMD audit, memory audit)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASSES, default=None,
                    help="run only this pass (repeatable; default: all)")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=default_baseline_path(),
                    help="baseline file (default: the checked-in, empty "
                         "src/repro/analysis/baseline.txt)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings to the baseline file "
                         "instead of failing (escape hatch — fix instead "
                         "whenever possible)")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0

    passes = tuple(args.passes) if args.passes else PASSES
    if "spmd" in passes or "memory" in passes:
        # both passes want a real mesh: spmd for the shard-count sweep,
        # memory for the sharded-search program's ledger
        _force_host_devices()
    findings = run_all(passes=passes)

    if args.write_baseline:
        lines = ["# repro.analysis.lint baseline — one 'RULE path:symbol'",
                 "# per line.  Keep this EMPTY: fix findings instead of",
                 "# baselining them whenever possible."]
        lines += sorted({f.key for f in findings})
        args.baseline.write_text("\n".join(lines) + "\n")
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    baseline = load_baseline(args.baseline)
    fresh = [f for f in findings if f.key not in baseline]
    suppressed = len(findings) - len(fresh)

    if args.json:
        print(json.dumps([dataclasses.asdict(f) for f in fresh], indent=2))
    else:
        for f in sorted(fresh, key=lambda f: (f.path, f.line, f.rule)):
            print(f.render())
        tail = f" ({suppressed} baselined)" if suppressed else ""
        status = "FAIL" if fresh else "OK"
        print(f"repro.analysis.lint: {status} — {len(fresh)} finding(s) "
              f"across passes [{', '.join(passes)}]{tail}")
    return 1 if fresh else 0


if __name__ == "__main__":
    sys.exit(main())
