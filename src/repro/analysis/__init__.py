"""Static contract checking for the PiPNN jax_pallas codebase.

Three passes, one CLI (``python -m repro.analysis.lint``), all gated in CI:

  * ``contracts``   — kernel contract checker: captures every
    ``pl.pallas_call`` site through a tracing spy, then verifies VMEM
    footprint, TPU tile alignment, grid coverage and oracle pairing over
    a swept shape grid (rules PIPK001-PIPK005).
  * ``jaxpr_audit`` — jaxpr/HLO auditor over the serving and build hot
    paths: no host callbacks, no f64, donation honored, bounded jit-cache
    growth across a simulated serving session (rules PIPJ001-PIPJ004).
  * ``ast_lint``    — syntactic lint over ``src/repro``: traced-value
    Python branches inside jitted functions, host syncs in jit regions,
    mutable default arguments, missing ``static_argnames`` on
    shape-controlling params (rules PIPA001-PIPA004).

Findings carry ``file:line`` plus a rule id; ``lint.py`` holds the shared
``Finding`` type, the (empty) baseline mechanism and the CLI.  (No eager
submodule imports here — ``python -m repro.analysis.lint`` must own the
first execution of the module.)
"""
