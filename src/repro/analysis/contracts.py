"""Kernel contract checker — rules PIPK001-PIPK005.

Rather than hand-maintaining a shadow copy of every kernel's BlockSpecs
(which would drift), the checker *captures* them: ``pl.pallas_call`` is
replaced with a recording spy while each registered kernel entry is
abstractly evaluated (``jax.eval_shape`` of the entry's ``__wrapped__``,
so the jit wrapper is bypassed and no compilation happens).  The spy sees
the exact grid, in/out BlockSpecs, scratch shapes and call-time operand
avals the real kernel would launch with — including all the padding the
wrapper applied.

Per captured launch, over a swept shape grid per kernel:

  PIPK001  the VMEM working set (tile-padded block bytes, doubled for
           grid-varying blocks to account for double buffering, plus VMEM
           scratch) exceeds the per-core VMEM capacity.  Sweep shapes are
           generated through the kernel's OWN admission predicate
           (``fits_vmem`` under ``vmem_points_budget()``), so this rule
           proves "admitted => fits" — the property serving relies on.
  PIPK002  a BlockSpec's trailing-two block dims are neither multiples of
           the dtype's minimum (sublane, lane) tile nor the full operand
           extent.
  PIPK003  grid x block x index_map fails to cover an operand's padded
           extents (some elements never visited).
  PIPK004  the registry entry's paired oracle does not resolve.
  PIPK005  a ``pl.pallas_call`` site in the source tree is not covered by
           the registry (AST census vs registry claims).
"""
from __future__ import annotations

import ast
import dataclasses
import importlib
import itertools
import pathlib
from typing import Callable

import numpy as np

from repro.analysis.lint import Finding
from repro.kernels.tiling import LANE, padded_bytes, sublane

# Per-core VMEM capacity the working set must fit in (v4/v5 cores carry
# 16 MiB; the points-budget default of 8 MiB deliberately leaves the rest
# as headroom for the other blocks — this rule checks the SUM anyway).
VMEM_CAPACITY = 16 * 1024 * 1024


@dataclasses.dataclass
class PallasCallRecord:
    """One captured ``pl.pallas_call`` launch."""
    grid: tuple
    out_shape: tuple            # ShapeDtypeStructs, flattened
    in_specs: list
    out_specs: tuple
    scratch_shapes: tuple
    arg_avals: tuple            # call-time operand (shape, dtype) pairs


@dataclasses.dataclass
class Case:
    """One swept shape point: entry args as ShapeDtypeStructs + statics."""
    label: str
    args: tuple
    kwargs: dict


@dataclasses.dataclass
class KernelSpec:
    name: str                   # public entry symbol
    module: str                 # e.g. "repro.kernels.gather_distance"
    oracle: str                 # "module:symbol" of the paired reference
    cases: Callable[[], list]   # () -> [Case, ...]

    @property
    def path(self) -> str:
        return "src/" + self.module.replace(".", "/") + ".py"


# ---------------------------------------------------------------------------
# capture harness
# ---------------------------------------------------------------------------

def capture_pallas_calls(fn, *args, **kwargs) -> list[PallasCallRecord]:
    """Abstractly evaluate ``fn(*args, **kwargs)`` (args may be
    ShapeDtypeStructs) with ``pl.pallas_call`` replaced by a spy; returns
    the recorded launches.  No kernel code runs and nothing compiles."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    records: list[PallasCallRecord] = []
    real = pl.pallas_call

    def spy(kernel, *, out_shape, grid=None, in_specs=None, out_specs=None,
            scratch_shapes=(), **_ignored):
        flat_out = jax.tree_util.tree_leaves(
            out_shape, is_leaf=lambda x: hasattr(x, "shape"))
        flat_outspecs = jax.tree_util.tree_leaves(
            out_specs, is_leaf=lambda s: hasattr(s, "block_shape"))

        def runner(*call_args):
            records.append(PallasCallRecord(
                grid=tuple(grid) if grid is not None else (),
                out_shape=tuple(flat_out),
                in_specs=list(in_specs) if in_specs is not None else [],
                out_specs=tuple(flat_outspecs),
                scratch_shapes=tuple(scratch_shapes),
                arg_avals=tuple((tuple(a.shape), np.dtype(a.dtype))
                                for a in call_args),
            ))
            outs = tuple(jnp.zeros(s.shape, s.dtype) for s in flat_out)
            return outs if isinstance(out_shape, (tuple, list)) else outs[0]

        return runner

    pl.pallas_call = spy
    try:
        target = getattr(fn, "__wrapped__", fn)
        import functools
        jax.eval_shape(functools.partial(target, **kwargs), *args)
    finally:
        pl.pallas_call = real
    return records


# ---------------------------------------------------------------------------
# per-record checks
# ---------------------------------------------------------------------------

def _grid_corners(grid: tuple):
    if not grid:
        return [()]
    axes = [(0,) if g <= 1 else (0, g - 1) for g in grid]
    return list(itertools.product(*axes))


def _block_index(spec, corner):
    """index_map output at a grid corner, or None for un-blocked specs."""
    if getattr(spec, "block_shape", None) is None:
        return None
    imap = getattr(spec, "index_map", None)
    if imap is None:
        return tuple(0 for _ in spec.block_shape)
    out = imap(*corner)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(i) for i in out)


def check_record(rec: PallasCallRecord, spec_: KernelSpec, label: str,
                 capacity: int = VMEM_CAPACITY) -> list[Finding]:
    findings: list[Finding] = []
    corners = _grid_corners(rec.grid)

    # pair every blocked spec with the aval it slices
    out_avals = tuple((tuple(s.shape), np.dtype(s.dtype))
                      for s in rec.out_shape)
    pairs = list(zip(rec.in_specs, rec.arg_avals)) + \
        list(zip(rec.out_specs, out_avals))

    total = 0
    for spec, (shape, dtype) in pairs:
        block = getattr(spec, "block_shape", None)
        if block is None:
            continue  # ANY-memory-space operand: lives in HBM, free of VMEM
        block = tuple(int(b) for b in block)

        # --- PIPK002: trailing-two tile alignment --------------------------
        if block:
            lane_dim, lane_ext = block[-1], shape[-1]
            if lane_dim % LANE and lane_dim != lane_ext:
                findings.append(Finding(
                    "PIPK002", spec_.path, 0, spec_.name,
                    f"[{label}] block {block} on operand {shape} "
                    f"{dtype.name}: lane dim {lane_dim} is neither a "
                    f"multiple of {LANE} nor the full extent"))
        if len(block) >= 2:
            sl = sublane(dtype)
            sub_dim, sub_ext = block[-2], shape[-2]
            if sub_dim != 1 and sub_dim % sl and sub_dim != sub_ext:
                findings.append(Finding(
                    "PIPK002", spec_.path, 0, spec_.name,
                    f"[{label}] block {block} on operand {shape} "
                    f"{dtype.name}: sublane dim {sub_dim} is neither a "
                    f"multiple of {sl} nor the full extent"))

        # --- PIPK003: grid coverage ---------------------------------------
        idxs = [_block_index(spec, c) for c in corners]
        for d in range(len(block)):
            max_end = max((i[d] + 1) * block[d] for i in idxs)
            if max_end < shape[d]:
                findings.append(Finding(
                    "PIPK003", spec_.path, 0, spec_.name,
                    f"[{label}] grid {rec.grid} x block {block} covers "
                    f"only {max_end} of {shape[d]} along dim {d} of "
                    f"operand {shape}"))
                break

        # --- VMEM accumulation (for PIPK001) ------------------------------
        varies = len(set(idxs)) > 1
        total += (2 if varies else 1) * padded_bytes(block, dtype)

    for scratch in rec.scratch_shapes:
        try:
            dt = np.dtype(scratch.dtype)
        except TypeError:
            continue  # DMA semaphores etc. — not VMEM tiles
        total += padded_bytes(tuple(int(s) for s in scratch.shape), dt)

    if total > capacity:
        findings.append(Finding(
            "PIPK001", spec_.path, 0, spec_.name,
            f"[{label}] VMEM working set {total / 2**20:.1f} MiB exceeds "
            f"the {capacity / 2**20:.0f} MiB per-core capacity "
            f"(tile-padded blocks x double-buffering + scratch)"))
    return findings


def _resolve(ref: str):
    mod, _, name = ref.partition(":")
    return getattr(importlib.import_module(mod), name)


def check_kernel(spec: KernelSpec,
                 capacity: int = VMEM_CAPACITY) -> list[Finding]:
    findings: list[Finding] = []
    try:
        _resolve(spec.oracle)
    except (ImportError, AttributeError):
        findings.append(Finding(
            "PIPK004", spec.path, 0, spec.name,
            f"declared oracle '{spec.oracle}' does not resolve — every "
            f"kernel needs a pure reference twin"))
    entry = _resolve(f"{spec.module}:{spec.name}")
    for case in spec.cases():
        records = capture_pallas_calls(entry, *case.args, **case.kwargs)
        if not records:
            findings.append(Finding(
                "PIPK005", spec.path, 0, spec.name,
                f"[{case.label}] entry ran without launching any "
                f"pallas_call — registry entry is stale"))
        for rec in records:
            findings += check_record(rec, spec, case.label, capacity)
    return findings


# ---------------------------------------------------------------------------
# the registry + shape sweeps
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    import jax
    return jax.ShapeDtypeStruct(shape, dtype)


def _max_admitted_n(d: int, dtype, with_scales: bool) -> int:
    """Largest point count the kernel's own admission predicate accepts
    for dimensionality ``d`` — binary search over ``fits_vmem`` exactly as
    ``resolve_kernel_path`` calls it."""
    import jax.numpy as jnp
    from repro.kernels.gather_distance import fits_vmem

    def fits(n):
        pts = _sds((n, d), dtype)
        extras = (_sds((n,), jnp.float32),) if with_scales else ()
        return fits_vmem(pts, *extras)

    lo, hi = 1, 1
    while fits(hi):
        hi *= 2
        if hi > 1 << 28:
            break
    while lo + 1 < hi:
        mid = (lo + hi) // 2
        lo, hi = (mid, hi) if fits(mid) else (lo, mid)
    return lo


def _gather_cases(int8: bool) -> list:
    import jax.numpy as jnp
    f32, i32 = jnp.float32, jnp.int32
    pdt = jnp.int8 if int8 else f32
    cases = []
    for d in (8, 32, 128, 512):
        n = _max_admitted_n(d, pdt, with_scales=int8)
        for nq, c in ((7, 100), (64, 512)):
            label = f"n={n} d={d} Q={nq} C={c}"
            if int8:
                args = (_sds((n, d), pdt), _sds((n,), f32), _sds((n,), f32),
                        _sds((nq, d), f32), _sds((nq,), f32),
                        _sds((nq, c), i32))
            else:
                args = (_sds((n, d), pdt), _sds((n,), f32),
                        _sds((nq, d), f32), _sds((nq, c), i32))
            cases.append(Case(label, args, {"metric": "l2"}))
    return cases


def _gather_hbm_cases(int8: bool) -> list:
    """HBM-streaming sweep: points size is irrelevant (ANY memory space);
    what matters is the double-buffered row scratch at the serving
    envelope — C = expansions x beam <= 512 candidates, d <= 2048."""
    import jax.numpy as jnp
    f32, i32 = jnp.float32, jnp.int32
    pdt = jnp.int8 if int8 else f32
    n = 1 << 20
    cases = []
    for d in (128, 2048):
        for nq, c in ((7, 128), (64, 512)):
            label = f"n={n} d={d} Q={nq} C={c}"
            if int8:
                args = (_sds((n, d), pdt), _sds((n,), f32), _sds((n,), f32),
                        _sds((nq, d), f32), _sds((nq,), f32),
                        _sds((nq, c), i32))
            else:
                args = (_sds((n, d), pdt), _sds((n,), f32),
                        _sds((nq, d), f32), _sds((nq, c), i32))
            cases.append(Case(label, args, {"metric": "l2"}))
    return cases


def _merge_cases() -> list:
    import jax.numpy as jnp
    cases = []
    for n, l in ((5, 32), (1000, 64), (64, 128)):
        ids = _sds((n, l), jnp.int32)
        ds = _sds((n, l), jnp.float32)
        cases.append(Case(f"n={n} l={l}",
                          (ids, ids, ds, ids, ids, ds), {}))
    return cases


def _edge_hash_cases() -> list:
    import jax.numpy as jnp
    return [Case(f"E={e} m={m}",
                 (_sds((e, m), jnp.float32), _sds((e, m), jnp.float32)), {})
            for e, m in ((100, 8), (4096, 16))]


def _leaf_cases() -> list:
    import jax.numpy as jnp
    return [Case(f"B={b} C={c} D={d} k={k}",
                 (_sds((b, c, d), jnp.float32), _sds((b, c), jnp.bool_)),
                 {"k": k})
            for b, c, d, k in ((1, 200, 32, 16), (4, 1024, 128, 32))]


def _topk_cases() -> list:
    import jax.numpy as jnp
    return [Case(f"B={b} M={m} N={n} k={k}",
                 (_sds((b, m, n), jnp.float32),), {"k": k})
            for b, m, n, k in ((2, 100, 500, 16), (2, 512, 2048, 64))]


def _pairwise_cases(int8: bool) -> list:
    import jax.numpy as jnp
    dt = jnp.int8 if int8 else jnp.float32
    kw = {} if int8 else {"metric": "l2"}
    return [Case(f"B={b} M={m} N={n} D={d}",
                 (_sds((b, m, d), dt), _sds((b, n, d), dt)), dict(kw))
            for b, m, n, d in ((2, 100, 300, 32), (2, 512, 512, 128))]


REGISTRY: tuple[KernelSpec, ...] = (
    KernelSpec("gather_distance", "repro.kernels.gather_distance",
               "repro.kernels.ref:gather_distance_ref",
               lambda: _gather_cases(int8=False)),
    KernelSpec("gather_distance_int8", "repro.kernels.gather_distance",
               "repro.kernels.ref:gather_distance_int8_ref",
               lambda: _gather_cases(int8=True)),
    KernelSpec("gather_distance_hbm", "repro.kernels.gather_distance",
               "repro.kernels.ref:gather_distance_hbm_ref",
               lambda: _gather_hbm_cases(int8=False)),
    KernelSpec("gather_distance_int8_hbm", "repro.kernels.gather_distance",
               "repro.kernels.ref:gather_distance_int8_ref",
               lambda: _gather_hbm_cases(int8=True)),
    KernelSpec("merge_sorted_reservoirs", "repro.kernels.segmented_merge",
               "repro.kernels.ref:merge_sorted_reservoirs_ref",
               _merge_cases),
    KernelSpec("edge_hashes", "repro.kernels.edge_hash",
               "repro.kernels.ref:edge_hashes_ref",
               _edge_hash_cases),
    KernelSpec("leaf_topk", "repro.kernels.leaf_knn",
               "repro.kernels.ref:leaf_topk_ref",
               _leaf_cases),
    KernelSpec("rowwise_topk", "repro.kernels.topk",
               "repro.kernels.ref:rowwise_topk_ref",
               _topk_cases),
    KernelSpec("pairwise_distance", "repro.kernels.distance",
               "repro.kernels.ref:pairwise_distance_ref",
               lambda: _pairwise_cases(int8=False)),
    KernelSpec("pairwise_distance_int8", "repro.kernels.distance",
               "repro.kernels.ref:pairwise_distance_int8_ref",
               lambda: _pairwise_cases(int8=True)),
)


# ---------------------------------------------------------------------------
# PIPK005: AST census of pallas_call sites vs registry claims
# ---------------------------------------------------------------------------

def _pallas_sites(py: pathlib.Path) -> list[int]:
    tree = ast.parse(py.read_text(), filename=str(py))
    lines = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            f = node.func
            name = f.attr if isinstance(f, ast.Attribute) else \
                (f.id if isinstance(f, ast.Name) else None)
            if name == "pallas_call":
                lines.append(node.lineno)
    return lines


def check_site_census(root: pathlib.Path,
                      registry=REGISTRY) -> list[Finding]:
    findings: list[Finding] = []
    claims: dict[str, int] = {}
    for spec in registry:
        claims[spec.path] = claims.get(spec.path, 0) + 1
    for py in sorted((root / "src" / "repro").rglob("*.py")):
        if "__pycache__" in py.parts or py.name.startswith("test"):
            continue
        rel = py.relative_to(root).as_posix()
        sites = _pallas_sites(py)
        if not sites:
            continue
        claimed = claims.get(rel, 0)
        if len(sites) > claimed:
            for ln in sites[claimed:] if claimed else sites:
                findings.append(Finding(
                    "PIPK005", rel, ln, py.stem,
                    f"pallas_call site not covered by the kernel contract "
                    f"registry ({claimed} registered for this file, "
                    f"{len(sites)} sites found)"))
    return findings


def check_kernel_contracts(root: pathlib.Path | None = None,
                           registry=REGISTRY,
                           capacity: int = VMEM_CAPACITY) -> list[Finding]:
    from repro.analysis.lint import repo_root
    root = pathlib.Path(root) if root is not None else repo_root()
    findings: list[Finding] = []
    for spec in registry:
        findings += check_kernel(spec, capacity)
    findings += check_site_census(root, registry)
    return findings
