"""AST lint over ``src/repro`` — rules PIPA001-PIPA004.

Purely syntactic: nothing here imports jax or executes repo code, so this
pass is fast and safe to run on any checkout.  The rules target the
jit-hygiene bugs that actually bite this codebase:

  PIPA001  Python ``if``/``while`` on a traced value inside a jitted
           function.  Traced values are the function's own parameters
           minus ``static_argnames``/``static_argnums`` (closure
           variables are trace-time constants and never flagged), plus
           any local assigned from a traced expression.
  PIPA002  host synchronization inside a jitted function: ``.item()`` /
           ``.tolist()`` on a traced value, ``float()/int()/bool()`` of a
           traced value, or ``np.*`` called on a traced value.
  PIPA003  mutable default argument (list/dict/set literal or
           constructor) — anywhere in the package.
  PIPA004  a jitted function takes a known shape-controlling parameter
           (``k``, ``beam``, ``bm`` …) that is not declared static, so
           every distinct value silently recompiles.

Shape/dtype introspection is never a traced use: attribute reads in
``SAFE_ATTRS`` and calls to ``len``/``isinstance``/``hasattr``/
``getattr``/``callable`` are excluded, as are ``is None`` tests on
optional array arguments.
"""
from __future__ import annotations

import ast
import pathlib

from repro.analysis.lint import Finding

# Attribute reads that are static under tracing (shape metadata).
SAFE_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "itemsize",
                        "sharding", "aval", "weak_type"})

# Builtins whose result on a traced argument is static / not a sync.
SAFE_CALLS = frozenset({"len", "isinstance", "hasattr", "getattr",
                        "callable", "type", "id"})

# Parameter names that control output shapes / unrolled trip counts in
# this codebase.  A jitted function taking one of these non-statically
# recompiles per value (or mis-traces) — PIPA004.
SHAPE_PARAMS = frozenset({"k", "beam", "iters", "expansions", "bm", "bn",
                          "tq", "l_max", "n_points", "max_deg", "chunk",
                          "sub_chunk", "block", "query_chunk"})

HOST_SYNC_METHODS = frozenset({"item", "tolist", "__array__"})
HOST_CAST_FUNCS = frozenset({"float", "int", "bool", "complex"})
NUMPY_NAMES = frozenset({"np", "numpy"})
MUTABLE_CTORS = frozenset({"list", "dict", "set"})


def _is_jit(node: ast.expr) -> bool:
    """``jit`` / ``jax.jit`` (any attribute path ending in ``.jit``)."""
    if isinstance(node, ast.Name):
        return node.id == "jit"
    if isinstance(node, ast.Attribute):
        return node.attr == "jit"
    return False


def _is_partial(node: ast.expr) -> bool:
    if isinstance(node, ast.Name):
        return node.id == "partial"
    if isinstance(node, ast.Attribute):
        return node.attr == "partial"
    return False


def _literal_names(node: ast.expr | None):
    """Extract a static_argnames literal -> tuple of names, or None if the
    value is not a recognizable literal (caller should then skip the
    traced-param rules rather than guess)."""
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


def _literal_ints(node: ast.expr | None):
    if node is None:
        return ()
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


class _JitSite:
    """A function known to be jitted, with its resolved static params.
    ``known`` is False when static_argnames/nums were not literals — the
    traced set is then unknown and rules 001/002/004 are skipped."""

    def __init__(self, fn: ast.FunctionDef, statics, known: bool):
        self.fn = fn
        self.statics = frozenset(statics)
        self.known = known


def _statics_from_call_kwargs(keywords) -> tuple[frozenset, bool, tuple]:
    names: set[str] = set()
    nums: tuple = ()
    known = True
    for kw in keywords:
        if kw.arg == "static_argnames":
            lit = _literal_names(kw.value)
            if lit is None:
                known = False
            else:
                names.update(lit)
        elif kw.arg == "static_argnums":
            lit = _literal_ints(kw.value)
            if lit is None:
                known = False
            else:
                nums = lit
    return frozenset(names), known, nums


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _positional_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in a.posonlyargs] + [p.arg for p in a.args]


def _collect_jit_sites(tree: ast.Module) -> list[_JitSite]:
    """Find jitted functions two ways: decorator form (``@jax.jit`` /
    ``@functools.partial(jax.jit, ...)``) and call form
    (``jax.jit(step, ...)`` naming a function defined in scope)."""
    defs_by_name: dict[str, list[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            defs_by_name.setdefault(node.name, []).append(node)

    sites: dict[int, _JitSite] = {}

    def add(fn, statics, known, nums=()):
        if nums:
            pos = _positional_names(fn)
            extra = {pos[i] for i in nums if 0 <= i < len(pos)}
            statics = frozenset(statics) | extra
        sites[id(fn)] = _JitSite(fn, statics, known)

    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _is_jit(dec):
                    add(node, frozenset(), True)
                elif isinstance(dec, ast.Call):
                    if _is_partial(dec.func) and dec.args and \
                            _is_jit(dec.args[0]):
                        names, known, nums = _statics_from_call_kwargs(
                            dec.keywords)
                        add(node, names, known, nums)
                    elif _is_jit(dec.func):
                        names, known, nums = _statics_from_call_kwargs(
                            dec.keywords)
                        add(node, names, known, nums)
        elif isinstance(node, ast.Call) and _is_jit(node.func) and \
                node.args and isinstance(node.args[0], ast.Name):
            for fn in defs_by_name.get(node.args[0].id, ()):
                names, known, nums = _statics_from_call_kwargs(node.keywords)
                if id(fn) not in sites:
                    add(fn, names, known, nums)
    return list(sites.values())


class _TracedUse(ast.NodeVisitor):
    """Does this expression read a traced name in a value position?"""

    def __init__(self, traced: frozenset):
        self.traced = traced
        self.hit = False

    def visit_Name(self, node: ast.Name):
        if node.id in self.traced:
            self.hit = True

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr in SAFE_ATTRS:
            return  # shape metadata — static under tracing
        self.visit(node.value)

    def visit_Call(self, node: ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in SAFE_CALLS:
            return
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        # `x is None` / `x is not None` on an optional arg is host logic.
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops) and \
                all(isinstance(c, ast.Constant) and c.value is None
                    for c in node.comparators):
            return
        self.generic_visit(node)


def _uses_traced(node: ast.expr, traced: frozenset) -> bool:
    v = _TracedUse(traced)
    v.visit(node)
    return v.hit


def _lint_jit_body(site: _JitSite, path: str,
                   findings: list[Finding]) -> None:
    fn = site.fn
    traced = {p for p in _param_names(fn)
              if p not in site.statics and p != "self"}

    # PIPA004 — shape-controlling param left non-static.
    if site.known:
        for p in sorted(traced & SHAPE_PARAMS):
            findings.append(Finding(
                "PIPA004", path, fn.lineno, fn.name,
                f"parameter '{p}' controls shapes but is not in "
                f"static_argnames — every distinct value recompiles"))

    if not site.known:
        return

    traced = set(traced)

    def scan(stmts, traced):
        for stmt in stmts:
            # forward-propagate tracedness through simple assignments
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = stmt.value
                targets = (stmt.targets if isinstance(stmt, ast.Assign)
                           else [stmt.target])
                if value is not None:
                    is_traced = _uses_traced(value, frozenset(traced))
                    for t in targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                if is_traced:
                                    traced.add(n.id)
                                else:
                                    traced.discard(n.id)
                continue
            if isinstance(stmt, (ast.If, ast.While)):
                if _uses_traced(stmt.test, frozenset(traced)):
                    kind = "if" if isinstance(stmt, ast.If) else "while"
                    findings.append(Finding(
                        "PIPA001", path, stmt.lineno, fn.name,
                        f"Python '{kind}' on a traced value — use "
                        f"jnp.where/lax.cond/lax.while_loop"))
                scan(stmt.body, traced)
                scan(stmt.orelse, traced)
                continue
            if isinstance(stmt, (ast.For,)):
                scan(stmt.body, traced)
                scan(stmt.orelse, traced)
                continue
            if isinstance(stmt, (ast.With,)):
                scan(stmt.body, traced)
                continue
            if isinstance(stmt, (ast.Try,)):
                scan(stmt.body, traced)
                for h in stmt.handlers:
                    scan(h.body, traced)
                scan(stmt.orelse, traced)
                scan(stmt.finalbody, traced)
                continue
            if isinstance(stmt, ast.FunctionDef):
                # nested def: inherits the enclosing traced set minus any
                # name its own params shadow (the new binding's tracedness
                # is unknown — stay quiet rather than guess).
                inner = set(traced) - set(_param_names(stmt))
                scan(stmt.body, inner)
                continue

    scan(fn.body, traced)

    # PIPA002 — host syncs anywhere in the (possibly nested) body.  Uses
    # the final propagated traced set; nested-def params excluded above
    # don't matter here because the sync patterns name the value directly.
    frozen = frozenset(traced)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr in HOST_SYNC_METHODS \
                and _uses_traced(f.value, frozen):
            findings.append(Finding(
                "PIPA002", path, node.lineno, fn.name,
                f".{f.attr}() on a traced value forces a device->host "
                f"sync inside jit"))
        elif isinstance(f, ast.Name) and f.id in HOST_CAST_FUNCS and \
                node.args and _uses_traced(node.args[0], frozen):
            findings.append(Finding(
                "PIPA002", path, node.lineno, fn.name,
                f"{f.id}() of a traced value forces a device->host sync "
                f"inside jit"))
        elif isinstance(f, ast.Attribute) and \
                isinstance(f.value, ast.Name) and \
                f.value.id in NUMPY_NAMES and \
                any(_uses_traced(a, frozen) for a in node.args):
            findings.append(Finding(
                "PIPA002", path, node.lineno, fn.name,
                f"np.{f.attr}() on a traced value materializes it on "
                f"host inside jit — use jnp.{f.attr}"))


def _lint_mutable_defaults(tree: ast.Module, path: str,
                           findings: list[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        defaults = list(node.args.defaults) + \
            [d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(d, ast.Call) and isinstance(d.func, ast.Name)
                and d.func.id in MUTABLE_CTORS and not d.args
                and not d.keywords)
            if bad:
                findings.append(Finding(
                    "PIPA003", path, d.lineno, node.name,
                    "mutable default argument — use None and create "
                    "inside the function"))


def lint_source(src: str, path: str) -> list[Finding]:
    """Lint one module's source.  ``path`` is used verbatim in findings."""
    findings: list[Finding] = []
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        findings.append(Finding(
            "PIPA001", path, e.lineno or 0, "<module>",
            f"syntax error prevents linting: {e.msg}"))
        return findings
    _lint_mutable_defaults(tree, path, findings)
    for site in _collect_jit_sites(tree):
        _lint_jit_body(site, path, findings)
    return findings


# Template scaffolding retained ONLY because the tier-1 test suite imports
# it (model zoo, arch configs, train/serve launchers); it is not part of
# the audited PiPNN surface, so the analysis walks skip it.  Everything the
# suite does NOT import has been deleted outright — quarantine here is the
# fallback, not the default.
TEMPLATE_QUARANTINE = (
    "repro/models/",
    "repro/configs/",
    "repro/optim/",
    "repro/launch/steps.py",
    "repro/launch/train.py",
    "repro/launch/serve.py",
)


def quarantined(rel_path: str) -> bool:
    """True when ``rel_path`` (posix, relative to src/) is template
    scaffolding excluded from the PiPNN analysis surface."""
    rel = rel_path.split("src/", 1)[-1]
    return any(rel.startswith(q) for q in TEMPLATE_QUARANTINE)


def lint_package(pkg: pathlib.Path,
                 root: pathlib.Path | None = None,
                 exclude_quarantine: bool = True) -> list[Finding]:
    """Lint every ``.py`` under ``pkg``; paths in findings are relative to
    ``root`` (defaults to ``pkg``'s parent).  ``exclude_quarantine``
    skips the retained template subtrees (``TEMPLATE_QUARANTINE``)."""
    pkg = pathlib.Path(pkg)
    base = pathlib.Path(root) if root is not None else pkg.parent
    findings: list[Finding] = []
    for py in sorted(pkg.rglob("*.py")):
        if "__pycache__" in py.parts:
            continue
        rel = py.relative_to(base).as_posix()
        if exclude_quarantine and quarantined(rel):
            continue
        findings += lint_source(py.read_text(), rel)
    return findings
