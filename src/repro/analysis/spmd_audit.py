"""SPMD sharding auditor over the shard_map programs — PIPS001-PIPS005.

The repo has two shard_map program families: the sharded serving path
(``distributed/serving.py`` — per-shard beam search + cross-shard top-k)
and the distributed build supersteps (``launch/build_index.py`` — tile
step, final prune).  GGNN's multi-GPU line (PAPERS.md) makes the scaling
economics explicit: replication and halo cost ARE the knobs at billion
scale, and none of them fail loudly — a per-shard body that sprouts an
accidental collective still returns correct results (slower every step),
an operand that lowers replicated still serves (at S times the HBM), a
shard count baked into Python control flow still works (recompiling per
mesh).  This pass proves the contracts statically, on forced host-device
meshes, before a pod slice ever spins up:

  PIPS001  collective whitelist — every collective primitive anywhere in
           the traced program must appear in the program's DECLARED
           contract ((primitive, mesh axis) pairs, declared at the
           registration site below).  The per-shard search body declares
           the empty contract: it must be collective-free.
  PIPS002  replication audit — operands declared sharded (``P(axis)`` in
           in_specs) must not lower to fully-replicated HLO shardings;
           intentionally replicated operands (queries, hyperplanes) must
           be whitelisted, and their per-device cost is priced and
           reported.
  PIPS003  per-shard footprint pricing — the ``[S, m, ...]`` halo packing
           (member + ghost + pad rows, measured via
           ``ShardedServingIndex.halo_stats``) and a production-scale
           envelope are priced at the TPU-tile-padded byte cost
           (``kernels/tiling.padded_bytes``) and gated against the
           per-device HBM budget (``PIPNN_DEVICE_HBM_BUDGET`` env var,
           default 16 GiB).  The halo fraction is reported per shard
           count.
  PIPS004  host-transfer audit — a ``ShardedServingIndex.search`` call is
           replayed under ``core.transfers.ledger`` with
           ``jax.transfer_guard("disallow")``; any transfer not routed
           through the declared batch-entry/exit boundaries raises, and
           the routed counts are gated at the path's declared
           ``TRANSFER_BUDGET``.
  PIPS005  mesh-shape stability — the traced program must be structurally
           identical (same primitive skeleton, nested jaxprs included)
           across S in {1, 2, 4, 8}: shard count must never leak into
           Python control flow, or every mesh size recompiles its own
           program (the pre-PR-8 ``cross_shard_topk`` Python fold was
           exactly this bug).

Run via ``python -m repro.analysis.lint`` (the ``spmd`` pass); the lint
driver forces ``--xla_force_host_platform_device_count=8`` when jax is
not yet initialized, so the full 1/2/4/8 sweep runs on any host.  On an
already-initialized smaller host the sweeps clamp to the available
device count and the multi-device-only audits degrade to no-ops (the CI
job and check.sh step 0b pin the 8-device configuration).
"""
from __future__ import annotations

import dataclasses
import functools
import math
import sys
from typing import Callable

import numpy as np

from repro.analysis.lint import Finding

# every jax collective primitive name (jaxpr-level) the whitelist knows;
# axis_index is deliberately NOT here — reading your own coordinate is
# free and collective-free
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "pmean", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter", "reduce_scatter",
    "pgather", "pdot", "psum2", "all_gather_invariant",
})

# per-device HBM the footprint model gates against — single-sourced in
# kernels/tiling.py (env override PIPNN_DEVICE_HBM_BUDGET, v5e default)
# so PIPS003, the roofline fits-HBM bit and PIPM003 can never diverge
from repro.kernels.tiling import (  # noqa: F401  (re-exported for tests)
    DEFAULT_HBM_BUDGET, HBM_BUDGET_ENV, hbm_budget)

SWEEP = (1, 2, 4, 8)


def shard_counts(minimum: int = 1) -> list[int]:
    """The S sweep this host can actually mesh: {1, 2, 4, 8} clamped to
    the visible device count."""
    import jax

    ndev = len(jax.devices())
    return [s for s in SWEEP if minimum <= s <= ndev]


def _report(msg: str) -> None:
    """Progress/measurement lines go to stderr so ``lint --json`` stdout
    stays machine-readable."""
    print(f"  [spmd] {msg}", file=sys.stderr)


# ---------------------------------------------------------------------------
# program registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SPMDProgram:
    """One concrete traceable instance of a registered program at a given
    shard count: the entry callable, its positional args (arrays or
    ShapeDtypeStructs), and which arg names the in_specs declare sharded
    (everything else is intentionally replicated)."""

    fn: Callable
    args: tuple
    arg_names: tuple
    sharded: frozenset


@dataclasses.dataclass(frozen=True)
class SPMDSpec:
    """A registered SPMD entry point + its declared contracts.

    ``collectives`` is the collective contract: the exact set of
    (primitive name, mesh axis) pairs the program is allowed to contain —
    declared HERE, at the registration site, so adding a collective to a
    program is a reviewed two-line diff (the code and the contract).
    ``replicated_ok`` whitelists arg names that intentionally lower to
    replicated shardings (every other arg must shard)."""

    name: str
    path: str
    symbol: str
    build: Callable
    collectives: frozenset
    replicated_ok: frozenset


@functools.lru_cache(maxsize=None)
def _tiny_packing(s: int, int8: bool = False):
    """A tiny ShardedServingIndex over ``s`` devices (cached per run) —
    shared by the serving program builder, the footprint audit and the
    transfer audit."""
    import jax
    from jax.sharding import Mesh

    from repro.distributed.serving import ShardedServingIndex

    rng = np.random.default_rng(0)
    n, d, r = 192, 16, 4
    x = rng.standard_normal((n, d)).astype(np.float32)
    graph = rng.integers(0, n, size=(n, r)).astype(np.int32)
    mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
    return ShardedServingIndex.from_graph(
        graph, x, 0, mesh=mesh, dtype="int8" if int8 else None)


_SEARCH_STATICS = dict(beam=8, iters=12, expansions=2, early_exit=True,
                       kernel_path="xla", interpret=False)


def _serving_program(s: int) -> SPMDProgram:
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    ssv = _tiny_packing(s)
    fn = ssv._sharded_search_fn(**_SEARCH_STATICS)
    q = jax.device_put(np.zeros((4, ssv.points.shape[2]), np.float32),
                       NamedSharding(ssv.mesh, P()))
    args = (ssv.gids, ssv.graph, ssv.points, ssv.norms, ssv.starts,
            ssv._scales_operand(), q)
    names = ("gids", "graph", "points", "norms", "starts", "scales",
             "queries")
    return SPMDProgram(fn=fn, args=args, arg_names=names,
                       sharded=frozenset(names) - {"queries"})


def _topk_program(s: int) -> SPMDProgram:
    import jax
    import jax.numpy as jnp

    from repro.distributed.serving import cross_shard_topk

    args = (jax.ShapeDtypeStruct((s, 4, 8), jnp.int32),
            jax.ShapeDtypeStruct((s, 4, 8), jnp.float32))
    # pure jit over already-gathered blocks: no shard_map in_specs, so
    # nothing for the replication audit to check (sharded = empty)
    return SPMDProgram(fn=functools.partial(cross_shard_topk, k=10),
                       args=args, arg_names=("ids_s", "ds_s"),
                       sharded=frozenset())


def _build_avals(p):
    import jax
    import jax.numpy as jnp

    sds = jax.ShapeDtypeStruct
    return {
        "points": sds((p.n_tile, p.dim), jnp.float32),
        "hyperplanes": sds((p.m_bits, p.dim), jnp.float32),
        "res_ids": sds((p.n_tile, p.l_max), jnp.int32),
        "res_hashes": sds((p.n_tile, p.l_max), jnp.int32),
        "res_dists": sds((p.n_tile, p.l_max), jnp.float32),
    }


def _tile_program(s: int) -> SPMDProgram:
    import jax
    from jax.sharding import Mesh

    from repro.launch import build_index as bi

    mesh = Mesh(np.array(jax.devices()[:s]), ("data",))
    p = bi.DistBuildParams.tiny()
    step = bi.make_tile_step(mesh, p).shard_step
    a = _build_avals(p)
    names = ("points", "hyperplanes", "res_ids", "res_hashes", "res_dists")
    return SPMDProgram(fn=step, args=tuple(a[n] for n in names),
                       arg_names=names,
                       sharded=frozenset(names) - {"hyperplanes"})


def _prune_program(s: int) -> SPMDProgram:
    import jax
    from jax.sharding import Mesh

    from repro.launch import build_index as bi

    mesh = Mesh(np.array(jax.devices()[:s]), ("data",))
    p = bi.DistBuildParams.tiny()
    step = bi.make_final_prune_step(mesh, p)
    a = _build_avals(p)
    names = ("points", "res_ids", "res_dists")
    return SPMDProgram(fn=step, args=tuple(a[n] for n in names),
                       arg_names=names, sharded=frozenset(names))


def default_specs() -> tuple:
    """The registry.  Collective contracts are DECLARED here: change a
    program's communication pattern and this tuple is the diff a reviewer
    sees."""
    return (
        SPMDSpec(
            name="sharded_search",
            path="src/repro/distributed/serving.py",
            symbol="ShardedServingIndex._sharded_search_fn",
            build=_serving_program,
            # the whole design: each shard searches ALONE; the only
            # cross-shard step is the separate top-k merge
            collectives=frozenset(),
            replicated_ok=frozenset({"queries"}),
        ),
        SPMDSpec(
            name="cross_shard_topk",
            path="src/repro/distributed/serving.py",
            symbol="cross_shard_topk",
            build=_topk_program,
            collectives=frozenset(),
            replicated_ok=frozenset({"ids_s", "ds_s"}),
        ),
        SPMDSpec(
            name="build_tile_step",
            path="src/repro/launch/build_index.py",
            symbol="make_tile_step",
            build=_tile_program,
            # leaders gather + two capacity-routed exchanges + the stats
            # reduction — the superstep schedule, nothing else
            collectives=frozenset({("all_gather", "data"),
                                   ("all_to_all", "data"),
                                   ("psum", "data")}),
            replicated_ok=frozenset({"hyperplanes"}),
        ),
        SPMDSpec(
            name="build_final_prune",
            path="src/repro/launch/build_index.py",
            symbol="make_final_prune_step",
            build=_prune_program,
            # request/response candidate-vector exchange only
            collectives=frozenset({("all_to_all", "data")}),
            replicated_ok=frozenset(),
        ),
    )


# ---------------------------------------------------------------------------
# jaxpr helpers
# ---------------------------------------------------------------------------

def _sub_jaxprs(params: dict):
    """Nested jaxprs hiding inside an eqn's params (pjit / shard_map /
    scan / while / cond ...), in deterministic key order."""
    for key in sorted(params):
        v = params[key]
        for item in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(item, "eqns") or hasattr(item, "jaxpr"):
                yield item


def _iter_eqns(jaxpr):
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        j = getattr(j, "jaxpr", j)          # ClosedJaxpr -> Jaxpr
        for eqn in j.eqns:
            yield eqn
            stack.extend(_sub_jaxprs(eqn.params))


def _collective_axes(eqn) -> tuple:
    """The mesh axes a collective eqn operates over (from its ``axes`` /
    ``axis_name`` param, whichever spelling the primitive uses)."""
    for key in ("axes", "axis_name"):
        v = eqn.params.get(key)
        if v is not None:
            vs = v if isinstance(v, (tuple, list)) else (v,)
            return tuple(str(a) for a in vs)
    return ()


def collectives_in(fn, args) -> set:
    """All (collective primitive, mesh axis) pairs anywhere in the traced
    program."""
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)
    found = set()
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name in COLLECTIVE_PRIMS:
            for ax in _collective_axes(eqn) or ("<unknown-axis>",):
                found.add((eqn.primitive.name, ax))
    return found


def structural_fingerprint(fn, args) -> tuple:
    """The program's primitive skeleton: nested (primitive name,
    sub-fingerprints) tuples.  Deliberately ignores shapes, dtypes and
    scalar params — a scan whose ``length`` grows with S is the SAME
    program; a loop that UNROLLS with S is not."""
    import jax

    def fp(jaxpr) -> tuple:
        j = getattr(jaxpr, "jaxpr", jaxpr)
        return tuple(
            (eqn.primitive.name,
             tuple(fp(sj) for sj in _sub_jaxprs(eqn.params)))
            for eqn in j.eqns)

    return fp(jax.make_jaxpr(fn)(*args))


# ---------------------------------------------------------------------------
# PIPS001 — collective whitelist
# ---------------------------------------------------------------------------

def audit_collectives(specs: tuple | None = None) -> list:
    specs = default_specs() if specs is None else specs
    findings = []
    for spec in specs:
        for s in shard_counts():
            prog = spec.build(s)
            undeclared = collectives_in(prog.fn, prog.args) - spec.collectives
            if undeclared:
                allowed = (sorted(spec.collectives)
                           or "none (collective-free body)")
                for prim, ax in sorted(undeclared):
                    findings.append(Finding(
                        "PIPS001", spec.path, 0, spec.symbol,
                        f"[S={s}] undeclared collective '{prim}' over "
                        f"mesh axis '{ax}' — the registered contract "
                        f"allows {allowed}; either remove it or extend "
                        f"the contract at the spmd_audit registration "
                        f"site"))
                break       # same program family; don't repeat per S
    return findings


# ---------------------------------------------------------------------------
# PIPS002 — replication audit
# ---------------------------------------------------------------------------

def _input_shardings(fn, args):
    import jax

    jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
    compiled = jitted.lower(*args).compile()
    in_shardings, _ = compiled.input_shardings
    return in_shardings


def audit_replication(specs: tuple | None = None) -> list:
    """Compile each program at the LARGEST available shard count and read
    the actual HLO input shardings back.  S=1 is skipped: on a one-device
    mesh sharded and replicated are the same placement and
    ``is_fully_replicated`` is vacuously true.  Compiling only at max S
    bounds the pass's cost (compile dominates trace ~7:1 here)."""
    from repro.kernels.tiling import padded_bytes

    counts = shard_counts(minimum=2)
    if not counts:
        return []
    s = counts[-1]
    specs = default_specs() if specs is None else specs
    findings = []
    for spec in specs:
        prog = spec.build(s)
        if not prog.sharded:
            continue        # pure-jit program: no in_specs to audit
        shardings = _input_shardings(prog.fn, prog.args)
        for name, arg, sh in zip(prog.arg_names, prog.args, shardings):
            if sh is None:
                # operand unused by this variant (e.g. the dummy scales
                # in the f32 body) — pruned by the compiler, no bytes
                # resident to audit
                continue
            replicated = bool(sh.is_fully_replicated)
            nbytes = padded_bytes(tuple(arg.shape), arg.dtype)
            if name in prog.sharded and replicated:
                findings.append(Finding(
                    "PIPS002", spec.path, 0, spec.symbol,
                    f"[S={s}] operand '{name}' is declared P(axis) in "
                    f"in_specs but lowered to a fully-replicated HLO "
                    f"sharding — every device holds all {nbytes} bytes "
                    f"instead of 1/{s}"))
            elif name not in prog.sharded and replicated:
                if name in spec.replicated_ok:
                    _report(f"{spec.name}: replicated operand '{name}' "
                            f"(whitelisted) costs {nbytes} bytes/device")
                else:
                    findings.append(Finding(
                        "PIPS002", spec.path, 0, spec.symbol,
                        f"[S={s}] operand '{name}' is replicated across "
                        f"the mesh ({nbytes} bytes on every device) but "
                        f"not whitelisted — either shard it or add it to "
                        f"replicated_ok at the registration site"))
    return findings


# ---------------------------------------------------------------------------
# PIPS003 — per-shard footprint pricing
# ---------------------------------------------------------------------------

# the billion-scale envelope the static model prices: BigANN-shaped int8
# serving over a 256-device pod slice
PRODUCTION_ENVELOPE = dict(name="bigann-1B/int8/S=256", n_points=1 << 30,
                           dim=128, degree=64, n_shards=256, int8=True)


def price_shard_packing(n_points: int, dim: int, degree: int,
                        n_shards: int, *, int8: bool = False,
                        halo_fraction: float = 0.0,
                        pad_fraction: float = 0.10) -> dict:
    """Static per-device byte model of the ``[S, m, ...]`` halo packing,
    priced at the TPU-tile-padded footprint (``tiling.padded_bytes`` —
    the same pricing ``fits_vmem`` and the kernel contracts use, so the
    analyzer can never disagree with the admission predicates).

    ``m`` = owned rows, grown by ``halo_fraction`` ghosts and
    ``pad_fraction`` pad-to-max slack across shards."""
    from repro.kernels.tiling import padded_bytes

    owned = math.ceil(n_points / n_shards)
    m = math.ceil(owned * (1.0 + halo_fraction) * (1.0 + pad_fraction))
    parts = {
        "points": padded_bytes((m, dim), np.int8 if int8 else np.float32),
        "graph": padded_bytes((m, degree), np.int32),
        "gids": padded_bytes((m,), np.int32),
        "norms": padded_bytes((m,), np.float32),
    }
    if int8:
        parts["scales"] = padded_bytes((m,), np.float32)
    total = sum(parts.values())
    parts["rows"] = m
    parts["total"] = total
    return parts


def audit_footprint(budget: int | None = None,
                    envelope: dict | None = None) -> list:
    """Measure the tiny packings' halo fraction per shard count (reported
    — the ROADMAP's halo-vs-scale measurement), gate each measured
    per-shard footprint against the HBM budget, then gate the
    production-scale envelope priced with the WORST measured halo
    fraction."""
    from repro.kernels.tiling import padded_bytes

    budget = hbm_budget() if budget is None else int(budget)
    envelope = PRODUCTION_ENVELOPE if envelope is None else envelope
    findings = []
    worst_halo = 0.0
    for s in shard_counts(minimum=2):
        ssv = _tiny_packing(s)
        hs = ssv.halo_stats()
        worst_halo = max(worst_halo, float(hs["halo_fraction"]))
        m, d = ssv.shard_capacity, ssv.points.shape[2]
        r = ssv.graph.shape[2]
        per_shard = (padded_bytes((m, d), ssv.points.dtype)
                     + padded_bytes((m, r), np.int32)
                     + padded_bytes((m,), np.int32)          # gids
                     + padded_bytes((m,), np.float32))       # norms
        _report(f"S={s}: halo_fraction={hs['halo_fraction']:.3f} "
                f"members={int(hs['members'].sum())} "
                f"ghosts={int(hs['ghosts'].sum())} "
                f"pads={int(hs['pads'].sum())} "
                f"per_shard_padded_bytes={per_shard}")
        if per_shard > budget:
            findings.append(Finding(
                "PIPS003", "src/repro/distributed/serving.py", 0,
                "ShardedServingIndex.from_graph",
                f"[S={s}] measured per-shard packing is {per_shard} "
                f"tile-padded bytes, over the {budget}-byte per-device "
                f"HBM budget ({HBM_BUDGET_ENV})"))
    priced = price_shard_packing(
        envelope["n_points"], envelope["dim"], envelope["degree"],
        envelope["n_shards"], int8=envelope.get("int8", False),
        halo_fraction=worst_halo)
    _report(f"envelope {envelope['name']}: rows/shard={priced['rows']} "
            f"(halo_fraction={worst_halo:.3f}) total/shard="
            f"{priced['total']} bytes vs budget {budget}")
    if priced["total"] > budget:
        findings.append(Finding(
            "PIPS003", "src/repro/distributed/serving.py", 0,
            "ShardedServingIndex.from_graph",
            f"production envelope {envelope['name']} prices at "
            f"{priced['total']} tile-padded bytes/device (halo fraction "
            f"{worst_halo:.3f}), over the {budget}-byte HBM budget — "
            f"raise n_shards or shrink the halo before a pod run"))
    return findings


# ---------------------------------------------------------------------------
# PIPS004 — host-transfer audit
# ---------------------------------------------------------------------------

def audit_transfers(budget: dict | None = None,
                    search_call: Callable | None = None) -> list:
    """Replay one sharded search call under the transfer ledger with
    implicit transfers hard-disabled.  ``search_call(ssv, q)`` is
    injectable so the rule's positive fixture can demonstrate a
    host-bouncing serving path."""
    import jax

    from repro.core import transfers
    from repro.distributed.serving import ShardedServingIndex

    counts = shard_counts()
    if not counts:
        return []
    s = counts[-1]
    ssv = _tiny_packing(s)
    budget = dict(ShardedServingIndex.TRANSFER_BUDGET
                  if budget is None else budget)
    q = np.zeros((4, ssv.points.shape[2]), np.float32)
    call = (search_call if search_call is not None
            else lambda sv, qq: sv.search(qq, k=4, beam=8))
    path, symbol = ("src/repro/distributed/serving.py",
                    "ShardedServingIndex.search")
    call(ssv, q)          # warm-up: compile outside the guard
    try:
        with transfers.ledger() as counted, jax.transfer_guard("disallow"):
            call(ssv, q)
    except Exception as e:  # noqa: BLE001 — jax raises XlaRuntimeError
        return [Finding(
            "PIPS004", path, 0, symbol,
            f"[S={s}] search performs an implicit host transfer outside "
            f"the declared to_device/to_host boundaries: "
            f"{str(e).splitlines()[0][:160]}")]
    over = {k: (counted.get(k, 0), v) for k, v in budget.items()
            if counted.get(k, 0) > v}
    _report(f"S={s}: transfer ledger per search call {counted} "
            f"(budget {budget})")
    if over:
        return [Finding(
            "PIPS004", path, 0, symbol,
            f"[S={s}] search call crossed the host boundary more than "
            f"its declared budget: " + ", ".join(
                f"{k}={got} > {bound}"
                for k, (got, bound) in sorted(over.items())))]
    return []


# ---------------------------------------------------------------------------
# PIPS005 — mesh-shape stability
# ---------------------------------------------------------------------------

def audit_mesh_stability(specs: tuple | None = None) -> list:
    specs = default_specs() if specs is None else specs
    counts = shard_counts()
    if len(counts) < 2:
        return []
    findings = []
    for spec in specs:
        fps = {}
        for s in counts:
            prog = spec.build(s)
            fps[s] = structural_fingerprint(prog.fn, prog.args)
        base = fps[counts[0]]
        diverged = [s for s in counts[1:] if fps[s] != base]
        if diverged:
            findings.append(Finding(
                "PIPS005", spec.path, 0, spec.symbol,
                f"traced program structure differs across shard counts "
                f"(S={counts[0]} vs S={diverged}) — the shard count "
                f"leaks into Python control flow, so every mesh size "
                f"compiles its own program; fold the S-dependence into "
                f"lax control flow (scan/vmap) instead"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def audit_all() -> list:
    import jax

    _report(f"device sweep S={shard_counts()} "
            f"(visible devices: {len(jax.devices())})")
    return (audit_collectives()
            + audit_replication()
            + audit_footprint()
            + audit_transfers()
            + audit_mesh_stability())
