"""Deterministic, shardable, resumable data pipeline.

Counter-based generation: batch ``i`` is a pure function of (seed, i), so

  * resume-after-restart needs only the step counter from the checkpoint
    (no iterator state to serialize);
  * every data-parallel shard generates exactly its slice by index
    (host h of H materializes rows [h*B/H, (h+1)*B/H) — no broadcast);
  * skip-ahead after elastic re-scale is O(1).

Token batches follow a Zipfian unigram distribution with a deterministic
"grammar" mixing (shifted self-correlation) so the LM loss actually falls
during the example training runs.  Vector batches (for PiPNN) are Gaussian
mixtures with planted nearest-neighbor structure.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1


def _zipf_probs(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    return (p / p.sum()).astype(np.float64)


class TokenPipeline:
    """``batch(step) -> {tokens, labels}``; pure in (seed, step, shard)."""

    def __init__(self, cfg: TokenPipelineConfig,
                 shard: tuple[int, int] = (0, 1)):
        self.cfg = cfg
        self.shard_idx, self.n_shards = shard
        assert cfg.global_batch % self.n_shards == 0
        self.local_batch = cfg.global_batch // self.n_shards
        self._probs = _zipf_probs(cfg.vocab, cfg.zipf_alpha)
        self._cum = np.cumsum(self._probs)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence(
                entropy=cfg.seed,
                spawn_key=(step, self.shard_idx),
            )
        )
        u = rng.random((self.local_batch, cfg.seq_len + 1))
        toks = np.searchsorted(self._cum, u).astype(np.int32)
        toks = np.minimum(toks, cfg.vocab - 1)
        # plant learnable structure: every 4th token repeats (t-2)'s token
        toks[:, 4::4] = toks[:, 2:-2:4]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }


@dataclasses.dataclass(frozen=True)
class VectorPipelineConfig:
    n: int
    dim: int
    n_clusters: int = 32
    cluster_scale: float = 2.0
    seed: int = 0
    dtype: str = "float32"


def make_vectors(cfg: VectorPipelineConfig) -> np.ndarray:
    """Gaussian-mixture embedding-like vectors (the ANN benchmark data)."""
    rng = np.random.default_rng(cfg.seed)
    centers = rng.standard_normal((cfg.n_clusters, cfg.dim)) * cfg.cluster_scale
    assign = rng.integers(0, cfg.n_clusters, cfg.n)
    x = centers[assign] + rng.standard_normal((cfg.n, cfg.dim))
    if cfg.dtype == "int8":
        x = np.clip(np.round(x * 24), -127, 127).astype(np.int8)
    else:
        x = x.astype(np.float32)
    return x


def make_queries(cfg: VectorPipelineConfig, n_queries: int) -> np.ndarray:
    rng = np.random.default_rng(cfg.seed + 1)
    centers = np.random.default_rng(cfg.seed).standard_normal(
        (cfg.n_clusters, cfg.dim)) * cfg.cluster_scale
    assign = rng.integers(0, cfg.n_clusters, n_queries)
    q = centers[assign] + rng.standard_normal((n_queries, cfg.dim))
    return q.astype(np.float32)
