"""Deterministic, shardable, resumable data pipelines."""
from repro.data.pipeline import (
    TokenPipeline, TokenPipelineConfig, VectorPipelineConfig,
    make_queries, make_vectors,
)
