"""qwen2-7b [dense]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias.  [arXiv:2407.10671; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-7b",
        family="dense",
        model=TransformerConfig(
            name="qwen2-7b", n_layers=28, d_model=3584, n_heads=28,
            n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True,
            rope_theta=1000000.0, q_chunk=512,
            act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="qwen2-7b-smoke", n_layers=2, d_model=56, n_heads=7,
            n_kv_heads=1, d_ff=144, vocab=256, qkv_bias=True, q_chunk=16,
        ),
        microbatches={"train_4k": 2},
        parallelism="fsdp",
        source="arXiv:2407.10671",
        notes="28 q-heads are not divisible by the 16-way model axis; the "
              "dry-run shards the flattened qkv projection dims and lets "
              "GSPMD replicate the per-head einsum grouping (see DESIGN.md).",
    )
