"""Architecture configs for the assigned (arch x shape) dry-run matrix."""
from repro.configs.base import SHAPES, ArchConfig, ShapeCell
from repro.configs.registry import ARCH_IDS, all_configs, get_config
