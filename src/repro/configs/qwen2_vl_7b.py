"""qwen2-vl-7b [vlm]: qwen2-7b backbone + M-RoPE; vision tower STUB
(input_specs provides M-RoPE position ids).  [arXiv:2409.12191; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen2-vl-7b",
        family="vlm",
        model=TransformerConfig(
            name="qwen2-vl-7b", n_layers=28, d_model=3584, n_heads=28,
            n_kv_heads=4, d_ff=18944, vocab=152064, qkv_bias=True,
            mrope_sections=(16, 24, 24),  # t/h/w splits of hd/2 = 64
            rope_theta=1000000.0, q_chunk=512, act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="qwen2-vl-smoke", n_layers=2, d_model=56, n_heads=7,
            n_kv_heads=1, d_ff=144, vocab=256, qkv_bias=True,
            mrope_sections=(2, 1, 1), q_chunk=16,  # hd/2 = 4
        ),
        microbatches={"train_4k": 2},
        parallelism="fsdp",
        source="arXiv:2409.12191",
        notes="M-RoPE exercised with stub 3D position ids; patch tokens flow "
              "through the ordinary embedding table (frontend stubbed).",
    )
