"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="qwen3-14b",
        family="dense",
        model=TransformerConfig(
            name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
            n_kv_heads=8, d_ff=17408, vocab=151936, qk_norm=True,
            rope_theta=1000000.0, q_chunk=512,
            act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="qwen3-14b-smoke", n_layers=2, d_model=40, n_heads=5,
            n_kv_heads=1, d_ff=96, vocab=256, qk_norm=True, q_chunk=16,
        ),
        microbatches={"train_4k": 2},
        parallelism="fsdp",
        source="hf:Qwen/Qwen3-14B",
    )
