"""mamba2-130m [ssm]: 24L d_model=768 attn-free vocab=50280 ssm_state=128 —
SSD (state-space duality).  [arXiv:2405.21060; unverified]"""
from repro.configs.base import ArchConfig
from repro.models.ssm_lm import SSMConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="mamba2-130m",
        family="ssm",
        model=SSMConfig(
            name="mamba2-130m", n_layers=24, d_model=768, vocab=50288,
            d_state=128, head_dim=64, expand=2, chunk=128,  # vocab padded
        ),
        smoke_model=SSMConfig(
            name="mamba2-smoke", n_layers=2, d_model=64, vocab=256,
            d_state=16, head_dim=16, expand=2, chunk=16,
        ),
        sub_quadratic=True,
        parallelism="fsdp_tp",
        source="arXiv:2405.21060",
        notes="vocab padded 50280 -> 50288; decode state is O(1) in context "
              "so decode_32k/long_500k lower with constant-size SSM state.",
    )
