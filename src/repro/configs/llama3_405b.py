"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab.  [arXiv:2407.21783; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="llama3-405b",
        family="dense",
        model=TransformerConfig(
            name="llama3-405b", n_layers=126, d_model=16384, n_heads=128,
            n_kv_heads=8, d_ff=53248, vocab=128256, rope_theta=500000.0,
            q_chunk=512,
            param_dtype=jnp.bfloat16, act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="llama3-405b-smoke", n_layers=2, d_model=64, n_heads=8,
            n_kv_heads=2, d_ff=160, vocab=256, rope_theta=500000.0, q_chunk=16,
        ),
        microbatches={"train_4k": 8, "prefill_32k": 1},
        source="arXiv:2407.21783",
        notes="GQA 16:1; tied unembedding used in-framework (the released "
              "model unties; FLOP-equivalent for the dry-run).",
    )
