"""Architecture registry: --arch <id> resolution for launchers and tests."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig

ARCH_MODULES: dict[str, str] = {
    "llama3-405b": "repro.configs.llama3_405b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
}

ARCH_IDS = list(ARCH_MODULES)


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCH_MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {', '.join(ARCH_IDS)}"
        )
    return importlib.import_module(ARCH_MODULES[arch_id]).get_config()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
