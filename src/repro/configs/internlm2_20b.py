"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 — GQA.  [arXiv:2403.17297; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="internlm2-20b",
        family="dense",
        model=TransformerConfig(
            name="internlm2-20b", n_layers=48, d_model=6144, n_heads=48,
            n_kv_heads=8, d_ff=16384, vocab=92544, rope_theta=1000000.0,
            q_chunk=512,
            act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="internlm2-20b-smoke", n_layers=2, d_model=48, n_heads=6,
            n_kv_heads=2, d_ff=128, vocab=256, rope_theta=1000000.0,
            q_chunk=16,
        ),
        microbatches={"train_4k": 2},
        parallelism="fsdp",
        source="arXiv:2403.17297",
    )
