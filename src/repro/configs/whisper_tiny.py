"""whisper-tiny [audio enc-dec]: 4L d_model=384 6H d_ff=1536 vocab=51865 —
conv frontend STUB (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig
from repro.models.encdec import EncDecConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="whisper-tiny",
        family="encdec",
        model=EncDecConfig(
            name="whisper-tiny", n_layers=4, d_model=384, n_heads=6,
            n_kv_heads=6, d_ff=1536, vocab=51872,  # padded 51865
            q_chunk=512,
        ),
        smoke_model=EncDecConfig(
            name="whisper-smoke", n_layers=2, d_model=48, n_heads=3,
            n_kv_heads=3, d_ff=96, vocab=256, q_chunk=16,
        ),
        parallelism="fsdp",
        source="arXiv:2212.04356",
        notes="enc-dec: encoder runs over seq_len STUB frame embeddings; "
              "decoder is causal w/ cross-attention. vocab padded 51865->51872. "
              "6 heads replicated across TP (tiny model; MLP/vocab sharded).",
    )
