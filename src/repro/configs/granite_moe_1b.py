"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import MoESpec, TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="granite-moe-1b-a400m",
        family="moe",
        model=TransformerConfig(
            name="granite-moe-1b-a400m", n_layers=24, d_model=1024,
            n_heads=16, n_kv_heads=8, d_ff=512, vocab=49168,  # padded 49155
            moe=MoESpec(n_experts=32, top_k=8, capacity_factor=1.25),
            rope_theta=10000.0, q_chunk=512, act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=2, d_ff=32, vocab=256,
            moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.5),
            q_chunk=16,
        ),
        parallelism="ep_dp",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        notes="vocab padded 49155 -> 49168 for 16-way TP divisibility; "
              "32 experts shard EP-16 (2 experts/device) over `model`.",
    )
