"""Config system: architecture configs + the assigned input-shape cells.

Every assigned architecture gets one module in this package exposing
``get_config() -> ArchConfig`` with the EXACT published hyper-parameters,
plus a reduced ``smoke_model`` of the same family for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


# The assigned LM shape set (identical for all 10 archs).
SHAPES: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str                        # dense | moe | encdec | vlm | ssm | hybrid
    model: Any                         # full-size model config
    smoke_model: Any                   # reduced config, same family
    sub_quadratic: bool = False        # eligible for long_500k
    parallelism: str = "fsdp_tp"       # sharding policy (see distributed/sharding.py)
    microbatches: Mapping[str, int] = dataclasses.field(default_factory=dict)
    source: str = ""
    notes: str = ""

    def runnable_cells(self) -> list[ShapeCell]:
        cells = [SHAPES["train_4k"], SHAPES["prefill_32k"],
                 SHAPES["decode_32k"]]
        if self.sub_quadratic:
            cells.append(SHAPES["long_500k"])
        return cells

    def skipped_cells(self) -> list[tuple[str, str]]:
        if self.sub_quadratic:
            return []
        return [("long_500k",
                 "full-attention arch: 500k dense decode is not "
                 "sub-quadratic; skipped per assignment rules")]

    def microbatch(self, shape_name: str) -> int:
        return self.microbatches.get(shape_name, 1)


def pad_to_multiple(x: int, m: int) -> int:
    return -(-x // m) * m
