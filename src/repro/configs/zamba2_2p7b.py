"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 + shared attention blocks.
[arXiv:2411.15242; hf]"""
from repro.configs.base import ArchConfig
from repro.models.hybrid import HybridConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="zamba2-2.7b",
        family="hybrid",
        model=HybridConfig(
            name="zamba2-2.7b", n_layers=54, d_model=2560, n_heads=32,
            n_kv_heads=32, d_ff=10240, vocab=32000, attn_every=18,
            d_state=64, ssm_head_dim=64, expand=2, chunk=128, q_chunk=512,
        ),
        smoke_model=HybridConfig(
            name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4,
            n_kv_heads=4, d_ff=128, vocab=256, attn_every=2, d_state=16,
            ssm_head_dim=16, expand=2, chunk=16, q_chunk=16,
        ),
        sub_quadratic=True,
        microbatches={"train_4k": 2},
        parallelism="fsdp_tp",
        source="arXiv:2411.15242",
        notes="ONE shared MHA+MLP block applied every 18 Mamba2 layers (3 "
              "applications; released ckpt interleaves with LoRA deltas — "
              "simplification recorded in DESIGN.md). long_500k decode cost "
              "= 54 O(1) SSM steps + 3 attention reads over the 500k cache.",
    )
