"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.transformer import MoESpec, TransformerConfig


def get_config() -> ArchConfig:
    return ArchConfig(
        arch_id="grok-1-314b",
        family="moe",
        model=TransformerConfig(
            name="grok-1-314b", n_layers=64, d_model=6144, n_heads=48,
            n_kv_heads=8, d_ff=32768, vocab=131072,
            moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.25),
            rope_theta=10000.0, q_chunk=512,
            param_dtype=jnp.bfloat16, act_dtype=jnp.bfloat16,
        ),
        smoke_model=TransformerConfig(
            name="grok-1-smoke", n_layers=2, d_model=48, n_heads=6,
            n_kv_heads=2, d_ff=96, vocab=256,
            moe=MoESpec(n_experts=4, top_k=2, capacity_factor=1.5),
            q_chunk=16,
        ),
        microbatches={"train_4k": 4},
        source="hf:xai-org/grok-1",
        notes="8 experts < 16-way model axis: experts replicated, each "
              "expert's d_ff TP-sharded (DESIGN.md §4 MoE strategies).",
    )
