"""Device-resident query serving: the PiPNN index packed for heavy traffic.

``pipnn.search`` used to re-upload the graph and the points to the device
on EVERY call (``jnp.asarray(index.graph)`` / ``jnp.asarray(x)``) and then
run the single-expansion beam search.  ``ServingIndex`` is the serving-side
counterpart of the device-resident build: it prepacks everything the query
path touches as device arrays ONCE —

  * ``graph``  [n, R] int32 adjacency (−1 padded),
  * ``points`` [n, d], optionally downcast (e.g. ``jnp.bfloat16``) to halve
    the serving footprint, or scalar-quantized (``dtype="int8"``: per-point
    symmetric int8 vectors at 1/4 the f32 footprint, the paper's Sec. 6
    "quantized GEMM" follow-up) — distances still accumulate exactly
    (f32, or int32 on the quantized inner product),
  * ``scales`` [n] f32 dequantization scales (int8 packing only),
  * ``norms``  [n] f32 metric-dependent point norms
    (``metrics.point_norms``) computed BEFORE the downcast/quantization,
    so the norm half of the distance expansion keeps full precision,
  * ``start``  entry point —

and routes queries through the multi-expansion beam search engine
(``beam_search.beam_search_batch``): per step the ``expansions`` best
unvisited beam entries are expanded at once, their neighbor distances are
computed as one ``[Q, E*R]`` block (the fused Pallas gather-distance
kernel on TPU when the points fit VMEM), and the loop early-exits per
batch as soon as every query's live beam is fully visited (``iters`` is
only a backstop cap).  After construction a ``search`` call transfers
nothing but the queries.

**Kernel selection (VMEM vs HBM vs XLA).**  The distance block has three
implementations, resolved per points block by
``beam_search.resolve_kernel_path`` and surfaced as
``ServingIndex.kernel_path`` (and in ``with_stats`` telemetry):

  * ``"vmem"`` — Pallas kernel with the whole points block VMEM-resident;
    requires ``fits_vmem(points[, scales])`` under the budget
    (``vmem_budget`` here, or the ``PIPNN_VMEM_POINTS_BUDGET`` env
    override, default 8 MiB).  The fastest path when it fits.
  * ``"hbm"``  — Pallas HBM-streaming kernel: points stay in HBM and each
    query row's neighbor rows arrive in VMEM scratch via double-buffered
    async DMAs.  Selected on TPU when the shard exceeds the budget — an
    oversized shard STREAMS instead of silently dropping to XLA.
  * ``"xla"``  — the ``kernels.ref`` gather oracle; the CPU path.

**Shard routing (mesh serving).**  ``from_index(..., mesh=...)`` /
``from_graph(..., mesh=...)`` build a ``distributed.serving.
ShardedServingIndex`` instead: the dataset is split into DISJOINT
partition-aligned shards (each point joins its nearest shard leader —
the Stage-1 ``leader_assign`` primitive), each device holds one shard's
induced subgraph + points and runs the unchanged per-shard beam search
under ``shard_map``, and per-query results merge across shards with the
same rank-based bounded merge the beam uses.  Queries are replicated to
all shards by default (``router="all"`` — the recall-parity
configuration); ``router="leaders"`` probes only each query's
``n_probes`` nearest shards.  See ``distributed/serving.py`` for the
full contract.

``pipnn.search`` caches one ``ServingIndex`` per (index, dataset) behind
the scenes; hold your own instance for long-lived serving processes.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics

logger = logging.getLogger(__name__)


def engine_workspace_bytes(nq: int, n: int, d: int, r: int, beam: int,
                           expansions: int) -> int:
    """Modeled XLA temp bytes of one ``_beam_search_multi`` dispatch at
    the padded ``query_chunk`` shape: the per-step [nq, E*R] candidate
    block (gathered neighbor vectors + distances), the width
    ``beam + E*R`` rank-merge buffers, the [nq, beam] visited/beam state
    threaded through the while carry, and the per-query visited-id
    history.  Chunk-shaped (nq is the padded query chunk) — the index
    arrays themselves are arguments, not temp, so serving workspace
    never scales with the dataset beyond the O(nq * E * R * d) gather.
    Validated by the memory auditor at every lattice point (PIPM004);
    prices the per-shard deployment envelope (PIPM003)."""
    cand = nq * expansions * r
    gather = cand * (4 * d + 48)
    merge = nq * (beam + expansions * r) * 64
    state = nq * beam * (4 * d + 64)
    return gather + merge + state


def _is_int8(dtype) -> bool:
    """True for the scalar-quantized packing request: the string ``"int8"``
    or any spelling of the int8 dtype (``jnp.int8``, ``np.int8``, ...)."""
    if dtype is None:
        return False
    if isinstance(dtype, str):
        return dtype == "int8"
    try:
        return jnp.dtype(dtype) == jnp.int8
    except TypeError:
        return False


@dataclasses.dataclass
class ServingIndex:
    graph: jax.Array          # [n, R] int32, -1 padded, device-resident
    points: jax.Array         # [n, d] device-resident (downcast or int8)
    norms: jax.Array          # [n] f32 point norms (metrics.point_norms)
    start: int                # entry point (medoid)
    metric: str = "l2"
    scales: jax.Array | None = None   # [n] f32 dequant scales (int8 packing)
    vmem_budget: int | None = None    # VMEM points budget override (bytes)
    _start_dev: Any = dataclasses.field(default=None, repr=False,
                                        compare=False)

    def _start_operand(self) -> jax.Array:
        """``start`` as a cached device scalar: passed as a Python int it
        would be a fresh implicit scalar h2d on EVERY dispatch (and a
        hard error under ``jax.transfer_guard("disallow")``)."""
        if self._start_dev is None:
            from repro.core.transfers import to_device

            self._start_dev = to_device(np.int32(self.start))
        return self._start_dev

    @property
    def n(self) -> int:
        return self.graph.shape[0]

    @property
    def degree_bound(self) -> int:
        return self.graph.shape[1]

    @property
    def kernel_path(self) -> str:
        """The distance-kernel path this index auto-selects on the current
        backend: "vmem" (Pallas, points VMEM-resident under
        ``vmem_budget``), "hbm" (Pallas, HBM-streaming DMA), or "xla"
        (the ref gather — the CPU path).  An explicit
        ``search(kernel_path=...)`` / ``use_pallas=...`` overrides it."""
        from repro.core import beam_search as _bs

        return _bs.resolve_kernel_path(self.points, self.scales,
                                       vmem_budget=self.vmem_budget)

    def device_bytes(self) -> int:
        """Actual device-resident footprint of the packed index (graph +
        points + norms, plus the per-point scales on the int8 packing)."""
        parts = (self.graph, self.points, self.norms) + (
            () if self.scales is None else (self.scales,))
        return sum(int(a.size) * a.dtype.itemsize for a in parts)

    @classmethod
    def from_graph(
        cls,
        graph: np.ndarray,
        x: np.ndarray,
        start: int,
        *,
        metric: str = "l2",
        dtype=None,
        vmem_budget: int | None = None,
        mesh=None,
        **shard_kw,
    ):
        """Pack an adjacency matrix + points for serving.  ``dtype`` (e.g.
        ``jnp.bfloat16``) downcasts the device points copy; norms are
        computed in f32 first.  ``dtype="int8"`` (or ``jnp.int8``) packs
        the scalar-quantized serving copy instead: per-point symmetric
        int8 vectors + f32 dequant scales (``kernels.ref.
        quantize_symmetric``), ~1/4 the points footprint, with the norm
        half of every distance kept EXACT from the f32 norms.

        ``vmem_budget`` overrides the VMEM points budget the kernel-path
        auto-selection checks against (bytes; default 8 MiB or the
        ``PIPNN_VMEM_POINTS_BUDGET`` env var).  ``mesh`` (a single-axis
        ``jax.sharding.Mesh``) packs a sharded
        ``distributed.serving.ShardedServingIndex`` instead — one
        partition-aligned shard per device; extra ``shard_kw`` (router,
        n_probes, seed) pass through to it."""
        if mesh is not None:
            from repro.distributed.serving import ShardedServingIndex

            return ShardedServingIndex.from_graph(
                graph, x, start, mesh=mesh, metric=metric, dtype=dtype,
                vmem_budget=vmem_budget, **shard_kw)
        if shard_kw:
            raise TypeError(f"single-device serving does not accept "
                            f"{sorted(shard_kw)} (mesh-only options)")
        gj = jnp.asarray(np.ascontiguousarray(graph), dtype=jnp.int32)
        xj = jnp.asarray(np.ascontiguousarray(x, dtype=np.float32))
        norms = _metrics.point_norms(xj, metric)
        scales = None
        if _is_int8(dtype):
            from repro.kernels.ref import quantize_symmetric

            xj, scales = quantize_symmetric(xj)
        elif dtype is not None:
            xj = xj.astype(dtype)
        sv = cls(graph=gj, points=xj, norms=norms, start=int(start),
                 metric=metric, scales=scales, vmem_budget=vmem_budget)
        # the one-time signal the silent-XLA-fallback era lacked: say which
        # distance path this packing serves through, and why
        from repro.kernels.gather_distance import vmem_points_budget

        logger.info(
            "ServingIndex packed: n=%d d=%d dtype=%s kernel_path=%s "
            "(points %d bytes, vmem budget %d)", sv.n, xj.shape[1],
            xj.dtype, sv.kernel_path, sv.device_bytes(),
            vmem_points_budget() if sv.vmem_budget is None
            else sv.vmem_budget)
        return sv

    @classmethod
    def from_index(cls, index, x: np.ndarray, *, dtype=None,
                   vmem_budget: int | None = None, mesh=None, **shard_kw):
        """Pack a ``PiPNNIndex`` (or any object with ``.graph``, ``.start``
        and ``.params.metric``) over its dataset ``x``.  With ``mesh``
        this returns the sharded packing (``ShardedServingIndex``) — one
        partition-aligned shard per mesh device."""
        return cls.from_graph(index.graph, x, index.start,
                              metric=index.params.metric, dtype=dtype,
                              vmem_budget=vmem_budget, mesh=mesh, **shard_kw)

    def search(
        self,
        queries: np.ndarray,
        *,
        k: int = 10,
        beam: int = 32,
        expansions: int = 4,
        iters: int | None = None,
        early_exit: bool = True,
        use_pallas: bool | None = None,
        kernel_path: str | None = None,
        interpret: bool | None = None,
        query_chunk: int | None = None,
        with_stats: bool = False,
    ):
        """Serve a query batch; returns [Q, k] neighbor ids (int64,
        -1-padded when fewer than ``k`` are found, e.g. ``beam < k``).

        ``expansions`` is the per-step expansion width ``E``; ``iters`` is
        the backstop cap (default ``beam + 4``) — with ``early_exit`` the
        loop stops as soon as every query converged, so raising the cap is
        free.  ``query_chunk`` bounds the per-dispatch batch (chunks are
        zero-padded to a fixed shape so every chunk reuses one compiled
        executable).  ``kernel_path`` forces a distance-kernel path
        ("vmem" | "hbm" | "xla"; default: the index's auto-selection —
        see ``ServingIndex.kernel_path``).  ``with_stats=True`` also
        returns a dict with per-query ``hops`` (vertices expanded),
        ``dist_comps`` (distance evaluations) and ``converged`` (False
        when the ``iters`` backstop cut the query off before its fixed
        point — the straggler signal the serving loop's two-phase drain
        keys on) telemetry, plus the resolved ``kernel_path`` the batch
        actually served through.

        Boundary validation: ``k``/``beam`` must be >= 1 (``ValueError``)
        and queries must be a finite 2-D float batch of the index width —
        NaN/Inf rows raise a structured
        :class:`repro.core.validation.InvalidQueryError` naming the rows
        instead of silently poisoning the batch's beams.
        """
        from repro.core import beam_search as _bs
        from repro.core.validation import (validate_queries,
                                           validate_search_params)

        validate_search_params(k=k, beam=beam)
        if query_chunk is not None and int(query_chunk) <= 0:
            raise ValueError(f"query_chunk must be >= 1, got {query_chunk}")
        q = validate_queries(queries, dim=int(self.points.shape[1]))
        nq = q.shape[0]
        iters_cap = int(iters if iters is not None
                        else _bs.default_iters(beam))
        path = _bs.resolve_kernel_path(self.points, self.scales,
                                       kernel_path=kernel_path,
                                       use_pallas=use_pallas,
                                       vmem_budget=self.vmem_budget)
        if nq == 0:
            # short-circuit: never pad an empty batch up to a 1-row chunk
            # and dispatch a full device search for zero queries
            out = np.full((0, k), -1, dtype=np.int64)
            if with_stats:
                return out, {
                    "hops": np.empty((0,), np.int32),
                    "dist_comps": np.empty((0,), np.int32),
                    "converged": np.empty((0,), bool),
                    "expansions": int(expansions),
                    "iters_cap": iters_cap,
                    "kernel_path": path,
                }
            return out
        from repro.core.transfers import to_device, to_host

        # fixed chunk even when nq < query_chunk: small batches pad UP so
        # every dispatch shares one [chunk, d] dispatch shape — otherwise
        # each distinct small nq compiles its own engine variant
        chunk = int(query_chunk) if query_chunk else nq
        start_dev = self._start_operand()
        ids_parts, hops_parts, comps_parts, conv_parts = [], [], [], []
        for s in range(0, nq, chunk):
            qc = q[s : s + chunk]
            pad = chunk - qc.shape[0]
            if pad:
                qc = np.pad(qc, ((0, pad), (0, 0)))
            ids, _, hops, comps, conv = _bs.beam_search_batch(
                self.graph, self.points, to_device(qc),
                start=start_dev, beam=beam, iters=iters, metric=self.metric,
                expansions=expansions, norms=self.norms, scales=self.scales,
                early_exit=early_exit, kernel_path=path,
                interpret=interpret, with_stats=True,
            )
            take = chunk - pad
            ids_parts.append(to_host(ids)[:take])
            if with_stats:
                hops_parts.append(to_host(hops)[:take])
                comps_parts.append(to_host(comps)[:take])
                conv_parts.append(to_host(conv)[:take])
        ids = np.concatenate(ids_parts, axis=0)
        # beam < k: -1-pad to [Q, k] like the np oracle path
        out = _bs.pad_ids(ids, k).astype(np.int64)
        if with_stats:
            stats: dict[str, Any] = {
                "hops": np.concatenate(hops_parts),
                "dist_comps": np.concatenate(comps_parts),
                "converged": np.concatenate(conv_parts).astype(bool),
                "expansions": int(expansions),
                "iters_cap": iters_cap,
                "kernel_path": path,
            }
            return out, stats
        return out
