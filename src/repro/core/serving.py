"""Device-resident query serving: the PiPNN index packed for heavy traffic.

``pipnn.search`` used to re-upload the graph and the points to the device
on EVERY call (``jnp.asarray(index.graph)`` / ``jnp.asarray(x)``) and then
run the single-expansion beam search.  ``ServingIndex`` is the serving-side
counterpart of the device-resident build: it prepacks everything the query
path touches as device arrays ONCE —

  * ``graph``  [n, R] int32 adjacency (−1 padded),
  * ``points`` [n, d], optionally downcast (e.g. ``jnp.bfloat16``) to halve
    the serving footprint; distances still accumulate in f32,
  * ``norms``  [n] f32 metric-dependent point norms
    (``metrics.point_norms``) computed BEFORE the downcast, so the norm
    half of the distance expansion keeps full precision,
  * ``start``  entry point —

and routes queries through the multi-expansion beam search engine
(``beam_search.beam_search_batch``): per step the ``expansions`` best
unvisited beam entries are expanded at once, their neighbor distances are
computed as one ``[Q, E*R]`` block (the fused Pallas gather-distance
kernel on TPU when the points fit VMEM), and the loop early-exits per
batch as soon as every query's live beam is fully visited (``iters`` is
only a backstop cap).  After construction a ``search`` call transfers
nothing but the queries.

``pipnn.search`` caches one ``ServingIndex`` per (index, dataset) behind
the scenes; hold your own instance for long-lived serving processes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics


@dataclasses.dataclass
class ServingIndex:
    graph: jax.Array          # [n, R] int32, -1 padded, device-resident
    points: jax.Array         # [n, d] device-resident (possibly downcast)
    norms: jax.Array          # [n] f32 point norms (metrics.point_norms)
    start: int                # entry point (medoid)
    metric: str = "l2"

    @property
    def n(self) -> int:
        return self.graph.shape[0]

    @property
    def degree_bound(self) -> int:
        return self.graph.shape[1]

    def device_bytes(self) -> int:
        """Actual device-resident footprint of the packed index."""
        return sum(int(a.size) * a.dtype.itemsize
                   for a in (self.graph, self.points, self.norms))

    @classmethod
    def from_graph(
        cls,
        graph: np.ndarray,
        x: np.ndarray,
        start: int,
        *,
        metric: str = "l2",
        dtype=None,
    ) -> "ServingIndex":
        """Pack an adjacency matrix + points for serving.  ``dtype`` (e.g.
        ``jnp.bfloat16``) downcasts the device points copy; norms are
        computed in f32 first."""
        gj = jnp.asarray(np.ascontiguousarray(graph), dtype=jnp.int32)
        xj = jnp.asarray(np.ascontiguousarray(x, dtype=np.float32))
        norms = _metrics.point_norms(xj, metric)
        if dtype is not None:
            xj = xj.astype(dtype)
        return cls(graph=gj, points=xj, norms=norms, start=int(start),
                   metric=metric)

    @classmethod
    def from_index(cls, index, x: np.ndarray, *, dtype=None) -> "ServingIndex":
        """Pack a ``PiPNNIndex`` (or any object with ``.graph``, ``.start``
        and ``.params.metric``) over its dataset ``x``."""
        return cls.from_graph(index.graph, x, index.start,
                              metric=index.params.metric, dtype=dtype)

    def search(
        self,
        queries: np.ndarray,
        *,
        k: int = 10,
        beam: int = 32,
        expansions: int = 4,
        iters: int | None = None,
        early_exit: bool = True,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
        query_chunk: int | None = None,
        with_stats: bool = False,
    ):
        """Serve a query batch; returns [Q, k] neighbor ids (int64,
        -1-padded when fewer than ``k`` are found, e.g. ``beam < k``).

        ``expansions`` is the per-step expansion width ``E``; ``iters`` is
        the backstop cap (default ``beam + 4``) — with ``early_exit`` the
        loop stops as soon as every query converged, so raising the cap is
        free.  ``query_chunk`` bounds the per-dispatch batch (chunks are
        zero-padded to a fixed shape so every chunk reuses one compiled
        executable).  ``with_stats=True`` also returns a dict with
        per-query ``hops`` (vertices expanded) and ``dist_comps``
        (distance evaluations) telemetry.
        """
        from repro.core import beam_search as _bs

        q = np.ascontiguousarray(queries, dtype=np.float32)
        nq = q.shape[0]
        chunk = nq if not query_chunk else min(int(query_chunk), max(nq, 1))
        ids_parts, hops_parts, comps_parts = [], [], []
        for s in range(0, max(nq, 1), max(chunk, 1)):
            qc = q[s : s + chunk]
            pad = chunk - qc.shape[0]
            if pad:
                qc = np.pad(qc, ((0, pad), (0, 0)))
            ids, _, hops, comps = _bs.beam_search_batch(
                self.graph, self.points, qc,
                start=self.start, beam=beam, iters=iters, metric=self.metric,
                expansions=expansions, norms=self.norms,
                early_exit=early_exit, use_pallas=use_pallas,
                interpret=interpret, with_stats=True,
            )
            take = chunk - pad
            ids_parts.append(np.asarray(ids)[:take])
            hops_parts.append(np.asarray(hops)[:take])
            comps_parts.append(np.asarray(comps)[:take])
        ids = np.concatenate(ids_parts, axis=0) if ids_parts else \
            np.empty((0, beam), np.int32)
        # beam < k: -1-pad to [Q, k] like the np oracle path
        out = _bs.pad_ids(ids, k).astype(np.int64)
        if with_stats:
            stats: dict[str, Any] = {
                "hops": np.concatenate(hops_parts) if hops_parts else
                        np.empty((0,), np.int32),
                "dist_comps": np.concatenate(comps_parts) if comps_parts else
                              np.empty((0,), np.int32),
                "expansions": int(expansions),
                "iters_cap": int(iters if iters is not None else beam + 4),
            }
            return out, stats
        return out
