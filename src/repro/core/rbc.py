"""Overlapping partitioning for PiPNN (Sec. 4.1, Algorithm 5, Appendix A.1).

The production partitioner is Randomized Ball Carving (RBC) with *multi-level
fanout*: in each subproblem sample ``l = min(P_samp * |P|, leader_cap)``
leaders, assign every point to its ``fanout(depth)`` nearest leaders, recurse
on subproblems larger than ``C_max``; merge subproblems smaller than
``C_min``.  Fanout>1 at the top level(s) replaces whole-procedure replication
(Appendix A.2's cost analysis) — the paper observes recursion depth 2–3
suffices in practice because arity is ~1000.

Stage-1 execution strategies, selected by ``RBCParams.execution``:

  * ``"host"`` — the numpy oracle: the original host-side recursion, kept
    as the reference the device paths are bit-compared against.
  * ``"device"`` — host-orchestrated device carving: the host keeps ONLY
    the variable-size worklist (and the leader-sampling RNG stream); all
    per-subproblem math — the leader GEMM, top-f selection, and the
    bucket grouping (stable sort + searchsorted) — runs in fixed-shape
    jitted steps (``core/leader_assign.py``) over power-of-two padded
    row/leader blocks with VMEM-sized sub-batches.  Leader sampling draws
    from the same host ``np.random.Generator`` stream as the oracle, and
    the device assignment mirrors the oracle's arithmetic (same GEMM
    expansion, same stable tie-break), so the produced leaves are
    bit-identical to ``execution="host"`` for a fixed seed whenever the
    backend GEMM matches numpy's bit for bit — exact on this container's
    CPU backend (asserted by tests); on GPU/TPU accumulation order can
    differ and assignments may diverge at near-exact distance ties.
  * ``"static"`` — ``ball_carve_device``: a fully-static two-level carve
    (the ``launch/build_index.py`` tile-step shape, generalized to the
    fanout schedule) compiled as ONE jitted program with capacity-routed
    grouping; zero host compute beyond sampling the level-0 leaders.
    Skew overflow beyond the static capacities is dropped, but each point
    also routes to ``bucket_spill`` next-nearest leaders whose replicas
    only claim capacity primaries left unused — the static substitute for
    the recursion's adaptivity, which keeps index quality at parity with
    the recursive carve.  Points that lose every replica (duplicate-heavy
    clusters) are re-added in appended salvage leaves, so full coverage
    is guaranteed here too.
  * ``"auto"`` (default) — ``"device"`` on an accelerator backend,
    ``"host"`` on CPU (where the jit round-trips don't pay for
    themselves at test scale).

Also implemented (for the Appendix A.1 ablation benchmarks):
  * binary partitioning (HCNNG style) — 2 random leaders, no fanout analog;
  * hierarchical k-means — leaders chosen by Lloyd iterations instead of
    uniformly at random;
  * sorting-LSH — concatenated hyperplane hashes, lexicographic sort,
    consecutive groups of <= C_max (replication, not fanout).

Degenerate-data hardening (duplicate-heavy inputs): the recursive carvers
force-split any oversized bucket that made no progress (bucket == parent)
into permutation halves, ``binary_partition`` splits degenerate 2-leader
ties the same way, and sorting-LSH packs its hash bits into uint64 words
(the old float64 key silently collided past 53 bits).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Literal, Sequence

import numpy as np

from repro.core import metrics as _metrics


@dataclasses.dataclass(frozen=True)
class RBCParams:
    c_max: int = 1024          # max leaf size (paper: 1024-2048)
    c_min: int = 64            # min leaf size before merging
    p_samp: float = 0.01       # leader fraction per subproblem
    leader_cap: int = 1000     # hard cap on leaders per subproblem (paper: 1000)
    fanout: Sequence[int] = (10, 3)  # fanout(depth); 1 past the schedule
    replicas: int = 1          # independent RBC runs (quality knob, Sec. 5.2)
    metric: str = "l2"
    seed: int = 0
    execution: str = "auto"    # "auto" | "host" | "device" | "static"
    assign_rows: int = 4096    # device path: GEMM sub-batch rows (VMEM budget)
    bucket_slack: float = 1.5  # static path: level-0 bucket capacity slack
    bucket_spill: int = 2      # static path: extra next-nearest leaders each
    #                            point routes to, so replicas squeezed out of
    #                            a capacity-full (skewed) bucket survive in
    #                            the point's next-best ball — the static
    #                            substitute for the recursion's adaptivity
    leaf_fill: float = 0.55    # static path: target mean leaf fill (sizes the
    #                            level-1 leader count so skewed leaves stay
    #                            under the hard c_max cap, as in build_index)

    def fanout_at(self, depth: int) -> int:
        return self.fanout[depth] if depth < len(self.fanout) else 1


def resolve_execution(params: RBCParams) -> str:
    """Resolve ``execution="auto"`` against the active jax backend."""
    if params.execution != "auto":
        return params.execution
    import jax

    return "device" if jax.default_backend() in ("tpu", "gpu") else "host"


def _pairwise_np(a: np.ndarray, b: np.ndarray, metric: str) -> np.ndarray:
    """Host-side GEMM-expansion distance matrix (numpy mirror of metrics.pairwise)."""
    ip = a @ b.T
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = np.linalg.norm(a, axis=-1, keepdims=True)
        bn = np.linalg.norm(b, axis=-1, keepdims=True)
        return 1.0 - ip / np.maximum(an * bn.T, 1e-30)
    a2 = np.sum(a * a, axis=-1)[:, None]
    b2 = np.sum(b * b, axis=-1)[None, :]
    return np.maximum(a2 + b2 - 2.0 * ip, 0.0)


def _nearest_leaders(
    x: np.ndarray, leaders: np.ndarray, k: int, metric: str
) -> np.ndarray:
    """Indices [n, k] of the k nearest leaders for each row of x, ordered by
    ascending distance with ties broken by ascending leader index — the
    same total order ``lax.top_k`` produces, so the device assignment step
    can reproduce these decisions bit for bit."""
    d = _pairwise_np(x, leaders, metric)
    k = min(k, leaders.shape[0])
    return np.argsort(d, axis=1, kind="stable")[:, :k]


def _merge_small(
    buckets: list[np.ndarray], c_min: int, c_max: int, rng: np.random.Generator
) -> list[np.ndarray]:
    """Randomly merge buckets smaller than c_min, never exceeding c_max."""
    small = [b for b in buckets if len(b) < c_min]
    keep = [b for b in buckets if len(b) >= c_min]
    if not small:
        return keep
    order = rng.permutation(len(small))
    cur: list[np.ndarray] = []
    cur_len = 0
    for j in order:
        b = small[j]
        if cur_len + len(b) > c_max and cur:
            # dedupe: fanout may place a point in several merged buckets
            keep.append(np.unique(np.concatenate(cur)))
            cur, cur_len = [], 0
        cur.append(b)
        cur_len += len(b)
    if cur:
        keep.append(np.unique(np.concatenate(cur)))
    return keep


# ---------------------------------------------------------------------------
# Stage-1 assignment backends (host oracle / jitted device step)
# ---------------------------------------------------------------------------
#
# Both backends implement the same contract for one subproblem:
#   (x, idx, leader_pos, f, metric, ctx) -> (order, starts)
# where ``order`` are positions into the row-major [m, f] assignment table
# stably sorted by assigned-leader id, and ``starts`` [n_leaders + 1] are
# the per-leader group boundaries (searchsorted).  Bucket l is then
# ``idx[order[starts[l]:starts[l+1]] // f]``.  Stable sorting makes the
# permutation unique given the keys, so host and device grouping agree
# whenever the assignments do.

def _assign_host(x, idx, leader_pos, f, metric, ctx):
    leaders = x[idx[leader_pos]]
    assign = _nearest_leaders(x[idx], leaders, f, metric)      # [m, f]
    flat = assign.reshape(-1)
    order = np.argsort(flat, kind="stable")
    starts = np.searchsorted(flat[order], np.arange(len(leader_pos) + 1))
    return order, starts


def _next_pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


@functools.lru_cache(maxsize=32)
def _make_carve_step(f: int, metric: str, sub: int):
    """Compile the fixed-shape per-subproblem carve step.

    step(xj, idx_pad, lead_pad, m, n_lead) -> (order, starts) where xj is
    the device-resident dataset, idx_pad [R] / lead_pad [L] are padded
    point/leader index blocks (R, L powers of two — shape specialization
    stays logarithmic in n), and m / n_lead are the true counts as traced
    scalars.  The leader GEMM runs over ``sub``-row sub-batches via
    ``lax.map`` so the [sub, L] distance tile is the only large
    intermediate; grouping is a stable sort + searchsorted on device.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.leader_assign import leader_assign

    def step(xj, idx_pad, lead_pad, m, n_lead):
        r = idx_pad.shape[0]
        l = lead_pad.shape[0]
        leaders = xj[lead_pad]                                  # [L, d]
        lead_ok = jnp.arange(l, dtype=jnp.int32) < n_lead

        def block(ids_sub):
            return leader_assign(xj[ids_sub], leaders, f, metric=metric,
                                 leader_valid=lead_ok)

        a = jax.lax.map(block, idx_pad.reshape(r // sub, sub))  # [R/sub, sub, f]
        a = a.reshape(r, f)
        row_ok = jnp.arange(r, dtype=jnp.int32) < m
        # padded rows key to the sentinel l: they stably sort after every
        # real leader group, so the valid prefix of ``order`` is exactly
        # the host oracle's permutation of the [m, f] table
        key = jnp.where(row_ok[:, None], a, jnp.int32(l)).reshape(-1)
        order = jnp.argsort(key, stable=True).astype(jnp.int32)
        starts = jnp.searchsorted(
            key[order], jnp.arange(l + 1, dtype=jnp.int32)).astype(jnp.int32)
        return order, starts

    return jax.jit(step)


def _assign_device(x, idx, leader_pos, f, metric, ctx):
    import jax.numpy as jnp

    xj, sub_cfg = ctx
    m, nl = len(idx), len(leader_pos)
    r_pad = _next_pow2(max(m, 8))
    sub = min(_next_pow2(sub_cfg), r_pad)
    l_pad = _next_pow2(max(nl, 2))
    idx_pad = np.zeros(r_pad, np.int32)
    idx_pad[:m] = idx
    lead_pad = np.zeros(l_pad, np.int32)
    lead_pad[:nl] = idx[leader_pos]
    step = _make_carve_step(f, metric, sub)
    order, starts = step(xj, jnp.asarray(idx_pad), jnp.asarray(lead_pad),
                         jnp.asarray(np.int32(m)), jnp.asarray(np.int32(nl)))
    return np.asarray(order), np.asarray(starts)[: nl + 1]


def _carve_worklist(
    x: np.ndarray,
    params: RBCParams,
    seed: int | None,
    assign_fn: Callable,
    ctx,
) -> list[np.ndarray]:
    """Algorithm 5's recursion as an explicit worklist, shared by the host
    and device assignment backends (identical RNG stream consumption, so
    both produce identical leaves when the assignments agree)."""
    rng = np.random.default_rng(params.seed if seed is None else seed)
    n = x.shape[0]
    leaves: list[np.ndarray] = []
    # worklist of (point-index-array, depth)
    stack: list[tuple[np.ndarray, int]] = [(np.arange(n, dtype=np.int64), 0)]
    while stack:
        idx, depth = stack.pop()
        if len(idx) <= params.c_max:
            leaves.append(idx)
            continue
        n_leaders = int(
            np.clip(round(params.p_samp * len(idx)), 2, params.leader_cap)
        )
        leader_pos = rng.choice(len(idx), size=n_leaders, replace=False)
        f = min(params.fanout_at(depth), n_leaders)
        order, starts = assign_fn(x, idx, leader_pos, f, params.metric, ctx)
        buckets: list[np.ndarray] = []
        for s, e in zip(starts[:-1], starts[1:]):
            if e > s:
                buckets.append(idx[order[s:e] // f])
        buckets = _merge_small(buckets, params.c_min, params.c_max, rng)
        for b in buckets:
            if len(b) <= params.c_max:
                leaves.append(b)
            elif len(b) == len(idx):
                # no progress (duplicate-heavy data: every point assigned
                # to one leader) — the bucket equals the parent and would
                # recurse forever; force-split by permutation halves
                perm = rng.permutation(len(b))
                half = len(b) // 2
                stack.append((b[perm[:half]], depth + 1))
                stack.append((b[perm[half:]], depth + 1))
            else:
                stack.append((b, depth + 1))
    return leaves


def ball_carve(
    x: np.ndarray,
    params: RBCParams,
    *,
    seed: int | None = None,
    execution: str | None = None,
) -> list[np.ndarray]:
    """Algorithm 5. Returns leaves as arrays of point indices (overlapping).

    ``execution`` overrides ``params.execution``; see the module docstring
    for the strategies.  ``"host"`` and ``"device"`` are bit-identical for
    a fixed seed (modulo backend GEMM parity with numpy — exact on CPU);
    ``"static"`` is the fully-static two-level variant.
    """
    mode = execution if execution is not None else resolve_execution(params)
    if mode == "static":
        padded = ball_carve_device(x, params, seed=seed)
        return [row[row >= 0].astype(np.int64) for row in padded]
    if mode == "device":
        import jax.numpy as jnp

        ctx = (jnp.asarray(x), params.assign_rows)
        return _carve_worklist(x, params, seed, _assign_device, ctx)
    return _carve_worklist(x, params, seed, _assign_host, None)


def ball_carve_replicated(x: np.ndarray, params: RBCParams) -> list[np.ndarray]:
    """``params.replicas`` independent RBC runs; union of leaves (Sec. 5.2)."""
    leaves: list[np.ndarray] = []
    for r in range(params.replicas):
        leaves.extend(ball_carve(x, params, seed=params.seed + 7919 * r))
    return leaves


# ---------------------------------------------------------------------------
# Fully-static two-level device carve (the build_index.py tile-step shape)
# ---------------------------------------------------------------------------

def _round_up(v: int, m: int) -> int:
    return -(-v // m) * m


def _static_shapes(n: int, params: RBCParams) -> dict[str, int]:
    """Static problem sizes for ``ball_carve_device`` (mirrors
    ``DistBuildParams.derived``, generalized to the fanout schedule)."""
    l0 = int(np.clip(round(params.p_samp * n), 2, min(params.leader_cap, n)))
    if _round_up(l0, 8) <= n:     # round to a bucket_chunk-friendly count
        l0 = _round_up(l0, 8)
    f0 = min(params.fanout_at(0), l0)
    # each point also routes to bucket_spill next-nearest leaders; spill
    # replicas only claim capacity primaries left unused, so a replica
    # squeezed out of a skewed over-capacity ball survives in the point's
    # next-best ball instead of being dropped outright
    f0r = min(f0 + max(params.bucket_spill, 0), l0)
    cap_b = _round_up(int(n * f0 / l0 * params.bucket_slack) + 1, 8)
    f1 = params.fanout_at(1)
    # level-1 leader count sized from capacity: per-bucket leaf capacity
    # l1 * c_max must hold cap_b * f1 placements at ~leaf_fill mean fill
    l1 = -(-int(cap_b * f1) // max(int(params.c_max * params.leaf_fill), 1))
    l1 = int(np.clip(l1, 2, min(params.leader_cap, cap_b)))
    f1 = min(f1, l1)
    return dict(l0=l0, f0=f0, f0r=f0r, cap_b=cap_b, l1=l1, f1=f1)


@functools.lru_cache(maxsize=16)
def _make_static_carve(n_pad: int, l0: int, f0: int, f0r: int, cap_b: int,
                       l1: int, f1: int, c_max: int, metric: str, sub: int,
                       bucket_chunk: int, cap_chunk: int):
    """Compile the one-shot two-level carve: level-0 leader GEMM + top-f0r,
    capacity-routed bucket grouping (primary replicas claim capacity
    first, spill replicas fill what is left), strided level-1 leaders,
    level-1 GEMM + top-f1 (per bucket chunk), capacity-routed leaf
    grouping.  Returns leaf_ids [l0 * l1, c_max] int32, -1 padded.

    BOTH assignment levels stream their point gathers: level 0 in ``sub``
    rows and level 1 in ``cap_chunk``-point sub-blocks of each bucket
    (the ``build_index`` tile-step ``assign_chunk`` pattern), so the
    largest points intermediate is [bucket_chunk, cap_chunk, d] — NOT the
    full [bucket_chunk, cap_b, d] bucket gather, whose cap_b ~ n*f0/l0
    rows grow with the dataset and would dominate peak carve memory at
    billion scale (the ROADMAP carve-gather item; proven chunk-bounded by
    the PIPM001 memory audit)."""
    import jax
    import jax.numpy as jnp

    from repro.core.leader_assign import leader_assign
    from repro.distributed.routing import group_by_capacity

    n_leaf = l0 * l1

    def wshuf(*arrs):
        # fixed Weyl permutation (the group_by_capacity shuffle) applied
        # per segment, so overflow drops are unbiased WITHIN a segment
        # while primaries still arrive before spills
        e = arrs[0].shape[0]
        perm = jnp.argsort(
            jnp.arange(e, dtype=jnp.uint32) * jnp.uint32(2654435761))
        return [a[perm] for a in arrs]

    def step(xj, lead0_idx, m):
        leaders0 = xj[lead0_idx]                               # [l0, d]
        pid = jnp.arange(n_pad, dtype=jnp.int32)

        def blk(ids_sub):
            return leader_assign(xj[ids_sub], leaders0, f0r, metric=metric)

        a0 = jax.lax.map(blk, pid.reshape(n_pad // sub, sub))
        a0 = a0.reshape(n_pad, f0r)                            # [n, f0r]
        valid = pid < m
        seg = []
        for lo, hi in ((0, f0), (f0, f0r)):                    # primaries, spills
            if hi == lo:
                continue
            seg.append(wshuf(a0[:, lo:hi].reshape(-1),
                             jnp.repeat(valid, hi - lo),
                             jnp.repeat(pid, hi - lo)))
        keys, ok, pids = (jnp.concatenate(parts)
                          for parts in zip(*seg))
        (bpid,), bval = group_by_capacity(
            keys, ok, l0, cap_b, [pids], shuffle=False)        # [l0, cap_b]

        # level-1 leaders: strided picks from each bucket's grouped slots
        stride = max(cap_b // l1, 1)
        lead1_idx = bpid[:, ::stride][:, :l1]                  # [l0, l1]
        lead1_ok = bval[:, ::stride][:, :l1]

        n_cc = cap_b // cap_chunk

        def bucket_blk(t):
            # gather this chunk's leaders once ([bucket_chunk, l1, d]),
            # then stream the cap_b point axis in cap_chunk sub-blocks so
            # the only large points intermediate is [bucket_chunk,
            # cap_chunk, d] — never the full bucket.  leader_assign is
            # row-independent over points, so the split is bit-identical.
            ids, iok, lids, lok = t
            leaders = xj[jnp.maximum(lids, 0)]

            def cc_blk(u):
                cids, cok = u
                return leader_assign(
                    xj[jnp.maximum(cids, 0)], leaders, f1,
                    metric=metric, point_valid=cok, leader_valid=lok)

            cc = lambda a: jnp.swapaxes(
                a.reshape(a.shape[0], n_cc, cap_chunk), 0, 1)
            a = jax.lax.map(cc_blk, (cc(ids), cc(iok)))
            return jnp.swapaxes(a, 0, 1).reshape(ids.shape[0], cap_b, f1)

        resh = lambda a: a.reshape((l0 // bucket_chunk, bucket_chunk)
                                   + a.shape[1:])
        a1 = jax.lax.map(
            bucket_blk, (resh(bpid), resh(bval), resh(lead1_idx),
                         resh(lead1_ok)))
        a1 = a1.reshape(l0, cap_b, f1)
        # sparse buckets can hold fewer valid level-1 leaders than f1, in
        # which case top-f1 is forced to emit an INF-masked (invalid)
        # leader — drop those placements instead of keying junk leaves
        a1_ok = jnp.take_along_axis(
            lead1_ok, a1.reshape(l0, cap_b * f1), axis=1).reshape(a1.shape)

        leaf_key = (jnp.arange(l0, dtype=jnp.int32)[:, None, None] * l1
                    + a1).reshape(-1)
        inst_ok = jnp.repeat(bval.reshape(-1), f1) & a1_ok.reshape(-1)
        (leaf_ids,), leaf_ok = group_by_capacity(
            leaf_key, inst_ok, n_leaf, c_max,
            [jnp.repeat(bpid.reshape(-1), f1)], shuffle=True)
        return jnp.where(leaf_ok, leaf_ids, -1)                # [n_leaf, c_max]

    return jax.jit(step)


def carve_workspace_bytes(n_pad: int, d: int, l0: int, f0r: int, cap_b: int,
                          l1: int, f1: int, bucket_chunk: int,
                          cap_chunk: int) -> int:
    """Modeled XLA temp bytes of one ``_make_static_carve`` step: the
    [n_pad, f0r] level-0 assignment plus its capacity-routing sort
    buffers (key + validity + payload per replica instance), the
    STREAMED level-1 gather ([bucket_chunk, cap_chunk, d] points +
    [bucket_chunk, l1, d] leaders — never the full [bucket_chunk, cap_b,
    d] bucket), and the leaf placements with their routing sort.
    Validated against the compiled ledger by the memory auditor
    (PIPM004, ~2x above the measured CPU-XLA temp) and priced at the
    deployment envelope by PIPM003 — which is where a regression to the
    bucket-wide gather shows up: at envelope scale that gather alone
    adds a bucket_chunk * cap_b * d term this model does not grant."""
    inst0 = n_pad * f0r
    level0 = inst0 * 4 + 3 * inst0 * 9
    gather1 = bucket_chunk * (cap_chunk * d + l1 * d) * 4
    placements = l0 * cap_b * f1
    level1 = placements * 4 + 3 * placements * 9
    return level0 + gather1 + level1


def carve_chunks(n: int, params: RBCParams) -> dict:
    """The static chunking ``ball_carve_device`` resolves for ``n``
    points: level-0 row sub-batch ``sub``, level-1 bucket group
    ``bucket_chunk`` and point sub-block ``cap_chunk`` (largest divisor
    of ``cap_b`` keeping ``bucket_chunk * cap_chunk`` gathered rows near
    ``params.assign_rows``).  Shared with the memory auditor so the
    audited program is exactly the production one."""
    sh = _static_shapes(n, params)
    sub = min(_next_pow2(params.assign_rows), _next_pow2(max(n, 8)))
    bucket_chunk = next(c for c in (8, 4, 2, 1) if sh["l0"] % c == 0)
    cap_target = min(sh["cap_b"],
                     max(8, params.assign_rows // max(bucket_chunk, 1)))
    cap_chunk = next(c for c in range(cap_target, 0, -1)
                     if sh["cap_b"] % c == 0)
    return dict(sh, sub=sub, n_pad=_round_up(n, sub),
                bucket_chunk=bucket_chunk, cap_chunk=cap_chunk)


def ball_carve_device(
    x: np.ndarray, params: RBCParams, *, seed: int | None = None
) -> np.ndarray:
    """Fully-static two-level RBC on device: ONE jitted program produces the
    padded [L, c_max] leaf matrix directly (the TPU-facing representation
    ``leaves_to_padded`` would build) — no host recursion, no per-leaf
    host lists.  Generalizes the ``launch/build_index.py`` tile-step shape
    to ``params.fanout``.

    Coverage is guaranteed: capacity routing drops overflow replicas under
    skew (spill routing keeps that rare on spread-out data), and any point
    that loses ALL its replicas — duplicate-heavy clusters can overflow
    every ball they hash to — is placed into salvage leaves appended
    host-side (dropped points grouped c_max at a time; for a dense
    cluster these ARE its nearest neighbors).  Empty leaves are filtered
    host-side.
    """
    import jax.numpy as jnp

    n, _ = x.shape
    if n <= params.c_max:
        return leaves_to_padded([np.arange(n, dtype=np.int64)], params.c_max)
    sh = carve_chunks(n, params)
    rng = np.random.default_rng(params.seed if seed is None else seed)
    lead0 = rng.choice(n, size=sh["l0"], replace=False).astype(np.int32)
    n_pad = sh["n_pad"]
    xpad = x if n_pad == n else np.concatenate(
        [x, np.zeros((n_pad - n, x.shape[1]), x.dtype)])
    step = _make_static_carve(
        n_pad, sh["l0"], sh["f0"], sh["f0r"], sh["cap_b"], sh["l1"],
        sh["f1"], params.c_max, params.metric, sh["sub"],
        sh["bucket_chunk"], sh["cap_chunk"])
    leaf_ids = np.asarray(step(jnp.asarray(xpad), jnp.asarray(lead0),
                               jnp.asarray(np.int32(n))))
    leaf_ids = leaf_ids[(leaf_ids >= 0).any(axis=1)]
    # salvage pass: every point must land in at least one leaf
    seen = np.zeros(n, dtype=bool)
    seen[leaf_ids[leaf_ids >= 0]] = True
    if not seen.all():
        lost = np.flatnonzero(~seen)
        salvage = [lost[s: s + params.c_max]
                   for s in range(0, len(lost), params.c_max)]
        leaf_ids = np.concatenate(
            [leaf_ids, leaves_to_padded(salvage, params.c_max)])
    return leaf_ids


def padded_coverage(padded: np.ndarray, n: int) -> int:
    """Number of the ``n`` points that appear in at least one padded leaf."""
    seen = np.zeros(n, dtype=bool)
    ids = padded[padded >= 0]
    seen[ids] = True
    return int(seen.sum())


def partition_padded(
    x: np.ndarray, params: RBCParams,
    method: Literal["rbc", "binary", "kmeans", "sorting_lsh"] = "rbc",
) -> np.ndarray:
    """Stage-1 entry point returning the dense [L, c_max] padded leaf
    matrix.  For ``method="rbc"`` with the static execution strategy the
    matrix comes straight off the device (replicas concatenated); all
    other configurations go through the list-of-leaves path."""
    if method == "rbc" and resolve_execution(params) == "static":
        mats = [ball_carve_device(x, params, seed=params.seed + 7919 * r)
                for r in range(max(params.replicas, 1))]
        return mats[0] if len(mats) == 1 else np.concatenate(mats, axis=0)
    return leaves_to_padded(partition(x, params, method), params.c_max)


# ---------------------------------------------------------------------------
# Ablation partitioners (Appendix A.1)
# ---------------------------------------------------------------------------

def binary_partition(
    x: np.ndarray,
    *,
    c_max: int = 1024,
    replicas: int = 1,
    metric: str = "l2",
    seed: int = 0,
) -> list[np.ndarray]:
    """HCNNG's recursive 2-leader partitioning (A.1.1). Disjoint per replica."""
    leaves: list[np.ndarray] = []
    for r in range(replicas):
        rng = np.random.default_rng(seed + 104729 * r)
        stack = [np.arange(x.shape[0], dtype=np.int64)]
        while stack:
            idx = stack.pop()
            if len(idx) <= c_max:
                leaves.append(idx)
                continue
            two = rng.choice(len(idx), size=2, replace=False)
            d = _pairwise_np(x[idx], x[idx[two]], metric)
            left = d[:, 0] <= d[:, 1]
            if left.all() or (~left).all():
                # degenerate split (duplicate points): permutation halves —
                # guaranteed progress, unlike the old coin-flip mask which
                # could re-push the full subproblem
                perm = rng.permutation(len(idx))
                half = len(idx) // 2
                stack.append(idx[perm[:half]])
                stack.append(idx[perm[half:]])
                continue
            stack.append(idx[left])
            stack.append(idx[~left])
    return leaves


def _lloyd(x: np.ndarray, k: int, iters: int, rng, metric: str) -> np.ndarray:
    centers = x[rng.choice(x.shape[0], size=k, replace=False)].copy()
    for _ in range(iters):
        a = np.argmin(_pairwise_np(x, centers, metric), axis=1)
        for j in range(k):
            m = a == j
            if m.any():
                centers[j] = x[m].mean(axis=0)
    return centers


def kmeans_carve(
    x: np.ndarray, params: RBCParams, *, lloyd_iters: int = 3, seed: int | None = None
) -> list[np.ndarray]:
    """Hierarchical k-means (A.1.2): RBC but leaders are Lloyd centroids."""
    rng = np.random.default_rng(params.seed if seed is None else seed)
    leaves: list[np.ndarray] = []
    stack: list[tuple[np.ndarray, int]] = [(np.arange(x.shape[0], dtype=np.int64), 0)]
    while stack:
        idx, depth = stack.pop()
        if len(idx) <= params.c_max:
            leaves.append(idx)
            continue
        n_leaders = int(np.clip(round(params.p_samp * len(idx)), 2, params.leader_cap))
        centers = _lloyd(x[idx], n_leaders, lloyd_iters, rng, params.metric)
        f = min(params.fanout_at(depth), n_leaders)
        assign = _nearest_leaders(x[idx], centers, f, params.metric)
        flat = assign.reshape(-1)
        src = np.repeat(idx, f)
        order = np.argsort(flat, kind="stable")
        flat_sorted, src_sorted = flat[order], src[order]
        buckets = []
        starts = np.searchsorted(flat_sorted, np.arange(n_leaders))
        ends = np.searchsorted(flat_sorted, np.arange(n_leaders) + 1)
        for s, e in zip(starts, ends):
            if e > s:
                buckets.append(src_sorted[s:e])
        buckets = _merge_small(buckets, params.c_min, params.c_max, rng)
        for b in buckets:
            if len(b) <= params.c_max:
                leaves.append(b)
            elif len(b) == len(idx):
                # duplicate-heavy data: no-progress bucket, same forced
                # permutation-halves split as ball_carve
                perm = rng.permutation(len(b))
                half = len(b) // 2
                stack.append((b[perm[:half]], depth + 1))
                stack.append((b[perm[half:]], depth + 1))
            else:
                stack.append((b, depth + 1))
    return leaves


def bit_lex_order(bits: np.ndarray) -> np.ndarray:
    """Stable lexicographic argsort of boolean rows (column 0 most
    significant).  Bits pack into big-endian uint64 words compared via
    ``np.lexsort``, so ANY number of bits keeps full precision — the old
    float64 accumulator (``key = key*2 + bit``) silently collided for
    n_bits > 53 (float64 mantissa), destroying the sort order."""
    n, n_bits = bits.shape
    words = []
    for w0 in range(0, n_bits, 64):
        chunk = bits[:, w0:w0 + 64]
        word = np.zeros(n, dtype=np.uint64)
        for i in range(chunk.shape[1]):
            word = (word << np.uint64(1)) | chunk[:, i].astype(np.uint64)
        words.append(word)
    # lexsort's LAST key is primary -> reverse so word 0 dominates
    return np.lexsort(tuple(reversed(words)))


def sorting_lsh_partition(
    x: np.ndarray,
    *,
    c_max: int = 1024,
    n_bits: int = 24,
    replicas: int = 1,
    seed: int = 0,
) -> list[np.ndarray]:
    """Sorting-LSH (A.1.3): lexicographic sort on concatenated hyperplane
    bits, consecutive groups of <= c_max.  Overlap via replication only."""
    leaves: list[np.ndarray] = []
    n, d = x.shape
    for r in range(replicas):
        rng = np.random.default_rng(seed + 15485863 * r)
        h = rng.standard_normal((n_bits, d)).astype(x.dtype)
        bits = (x @ h.T) >= 0.0  # [n, n_bits]
        order = bit_lex_order(bits)
        for s in range(0, n, c_max):
            leaves.append(order[s : s + c_max].astype(np.int64))
    return leaves


PARTITIONERS: dict[str, Callable] = {
    "rbc": lambda x, p: ball_carve_replicated(x, p),
    "binary": lambda x, p: binary_partition(
        x, c_max=p.c_max, replicas=max(p.replicas, 1), metric=p.metric, seed=p.seed
    ),
    "kmeans": lambda x, p: kmeans_carve(x, p),
    "sorting_lsh": lambda x, p: sorting_lsh_partition(
        x, c_max=p.c_max, replicas=max(p.replicas, 1), seed=p.seed
    ),
}


def partition(
    x: np.ndarray, params: RBCParams, method: Literal["rbc", "binary", "kmeans", "sorting_lsh"] = "rbc"
) -> list[np.ndarray]:
    return PARTITIONERS[method](x, params)


def leaves_to_padded(
    leaves: list[np.ndarray], c_max: int
) -> np.ndarray:
    """Stack leaves into a dense [L, c_max] int32 matrix, -1 padded.

    This is the TPU-facing representation: every leaf becomes one row of a
    regular batch so all-leaf distance matrices are a single batched GEMM.
    """
    out = np.full((len(leaves), c_max), -1, dtype=np.int32)
    for i, b in enumerate(leaves):
        if len(b) > c_max:
            raise ValueError(f"leaf {i} larger than c_max ({len(b)} > {c_max})")
        out[i, : len(b)] = b
    return out
