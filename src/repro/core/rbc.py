"""Overlapping partitioning for PiPNN (Sec. 4.1, Algorithm 5, Appendix A.1).

The production partitioner is Randomized Ball Carving (RBC) with *multi-level
fanout*: in each subproblem sample ``l = min(P_samp * |P|, leader_cap)``
leaders, assign every point to its ``fanout(depth)`` nearest leaders, recurse
on subproblems larger than ``C_max``; merge subproblems smaller than
``C_min``.  Fanout>1 at the top level(s) replaces whole-procedure replication
(Appendix A.2's cost analysis) — the paper observes recursion depth 2–3
suffices in practice because arity is ~1000.

Also implemented (for the Appendix A.1 ablation benchmarks):
  * binary partitioning (HCNNG style) — 2 random leaders, no fanout analog;
  * hierarchical k-means — leaders chosen by Lloyd iterations instead of
    uniformly at random;
  * sorting-LSH — concatenated hyperplane hashes, lexicographic sort,
    consecutive groups of <= C_max (replication, not fanout).

Orchestration is host-side (recursion over variable-size subproblems is
data-dependent); the inner distance math is a single GEMM per (subproblem,
leaders) pair.  The fully-static distributed two-level variant used for the
multi-pod dry-run lives in ``repro/launch/build_index.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Literal, Sequence

import numpy as np

from repro.core import metrics as _metrics


@dataclasses.dataclass(frozen=True)
class RBCParams:
    c_max: int = 1024          # max leaf size (paper: 1024-2048)
    c_min: int = 64            # min leaf size before merging
    p_samp: float = 0.01       # leader fraction per subproblem
    leader_cap: int = 1000     # hard cap on leaders per subproblem (paper: 1000)
    fanout: Sequence[int] = (10, 3)  # fanout(depth); 1 past the schedule
    replicas: int = 1          # independent RBC runs (quality knob, Sec. 5.2)
    metric: str = "l2"
    seed: int = 0

    def fanout_at(self, depth: int) -> int:
        return self.fanout[depth] if depth < len(self.fanout) else 1


def _pairwise_np(a: np.ndarray, b: np.ndarray, metric: str) -> np.ndarray:
    """Host-side GEMM-expansion distance matrix (numpy mirror of metrics.pairwise)."""
    ip = a @ b.T
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = np.linalg.norm(a, axis=-1, keepdims=True)
        bn = np.linalg.norm(b, axis=-1, keepdims=True)
        return 1.0 - ip / np.maximum(an * bn.T, 1e-30)
    a2 = np.sum(a * a, axis=-1)[:, None]
    b2 = np.sum(b * b, axis=-1)[None, :]
    return np.maximum(a2 + b2 - 2.0 * ip, 0.0)


def _nearest_leaders(
    x: np.ndarray, leaders: np.ndarray, k: int, metric: str
) -> np.ndarray:
    """Indices [n, k] of the k nearest leaders for each row of x."""
    d = _pairwise_np(x, leaders, metric)
    k = min(k, leaders.shape[0])
    if k == 1:
        return np.argmin(d, axis=1)[:, None]
    part = np.argpartition(d, k - 1, axis=1)[:, :k]
    # order the k by distance for determinism
    rows = np.arange(x.shape[0])[:, None]
    order = np.argsort(d[rows, part], axis=1, kind="stable")
    return part[rows, order]


def _merge_small(
    buckets: list[np.ndarray], c_min: int, c_max: int, rng: np.random.Generator
) -> list[np.ndarray]:
    """Randomly merge buckets smaller than c_min, never exceeding c_max."""
    small = [b for b in buckets if len(b) < c_min]
    keep = [b for b in buckets if len(b) >= c_min]
    if not small:
        return keep
    order = rng.permutation(len(small))
    cur: list[np.ndarray] = []
    cur_len = 0
    for j in order:
        b = small[j]
        if cur_len + len(b) > c_max and cur:
            # dedupe: fanout may place a point in several merged buckets
            keep.append(np.unique(np.concatenate(cur)))
            cur, cur_len = [], 0
        cur.append(b)
        cur_len += len(b)
    if cur:
        keep.append(np.unique(np.concatenate(cur)))
    return keep


def ball_carve(
    x: np.ndarray, params: RBCParams, *, seed: int | None = None
) -> list[np.ndarray]:
    """Algorithm 5. Returns leaves as arrays of point indices (overlapping)."""
    rng = np.random.default_rng(params.seed if seed is None else seed)
    n = x.shape[0]
    leaves: list[np.ndarray] = []
    # worklist of (point-index-array, depth)
    stack: list[tuple[np.ndarray, int]] = [(np.arange(n, dtype=np.int64), 0)]
    while stack:
        idx, depth = stack.pop()
        if len(idx) <= params.c_max:
            leaves.append(idx)
            continue
        n_leaders = int(
            np.clip(round(params.p_samp * len(idx)), 2, params.leader_cap)
        )
        leader_pos = rng.choice(len(idx), size=n_leaders, replace=False)
        leaders = x[idx[leader_pos]]
        f = min(params.fanout_at(depth), n_leaders)
        assign = _nearest_leaders(x[idx], leaders, f, params.metric)  # [m, f]
        buckets: list[np.ndarray] = []
        flat = assign.reshape(-1)
        src = np.repeat(idx, f)
        order = np.argsort(flat, kind="stable")
        flat_sorted, src_sorted = flat[order], src[order]
        starts = np.searchsorted(flat_sorted, np.arange(n_leaders))
        ends = np.searchsorted(flat_sorted, np.arange(n_leaders) + 1)
        for s, e in zip(starts, ends):
            if e > s:
                buckets.append(src_sorted[s:e])
        buckets = _merge_small(buckets, params.c_min, params.c_max, rng)
        for b in buckets:
            if len(b) > params.c_max:
                stack.append((b, depth + 1))
            else:
                leaves.append(b)
    return leaves


def ball_carve_replicated(x: np.ndarray, params: RBCParams) -> list[np.ndarray]:
    """``params.replicas`` independent RBC runs; union of leaves (Sec. 5.2)."""
    leaves: list[np.ndarray] = []
    for r in range(params.replicas):
        leaves.extend(ball_carve(x, params, seed=params.seed + 7919 * r))
    return leaves


# ---------------------------------------------------------------------------
# Ablation partitioners (Appendix A.1)
# ---------------------------------------------------------------------------

def binary_partition(
    x: np.ndarray,
    *,
    c_max: int = 1024,
    replicas: int = 1,
    metric: str = "l2",
    seed: int = 0,
) -> list[np.ndarray]:
    """HCNNG's recursive 2-leader partitioning (A.1.1). Disjoint per replica."""
    leaves: list[np.ndarray] = []
    for r in range(replicas):
        rng = np.random.default_rng(seed + 104729 * r)
        stack = [np.arange(x.shape[0], dtype=np.int64)]
        while stack:
            idx = stack.pop()
            if len(idx) <= c_max:
                leaves.append(idx)
                continue
            two = rng.choice(len(idx), size=2, replace=False)
            d = _pairwise_np(x[idx], x[idx[two]], metric)
            left = d[:, 0] <= d[:, 1]
            # guard: degenerate split (duplicate points) -> random halves
            if left.all() or (~left).all():
                left = rng.random(len(idx)) < 0.5
            stack.append(idx[left])
            stack.append(idx[~left])
    return leaves


def _lloyd(x: np.ndarray, k: int, iters: int, rng, metric: str) -> np.ndarray:
    centers = x[rng.choice(x.shape[0], size=k, replace=False)].copy()
    for _ in range(iters):
        a = np.argmin(_pairwise_np(x, centers, metric), axis=1)
        for j in range(k):
            m = a == j
            if m.any():
                centers[j] = x[m].mean(axis=0)
    return centers


def kmeans_carve(
    x: np.ndarray, params: RBCParams, *, lloyd_iters: int = 3, seed: int | None = None
) -> list[np.ndarray]:
    """Hierarchical k-means (A.1.2): RBC but leaders are Lloyd centroids."""
    rng = np.random.default_rng(params.seed if seed is None else seed)
    leaves: list[np.ndarray] = []
    stack: list[tuple[np.ndarray, int]] = [(np.arange(x.shape[0], dtype=np.int64), 0)]
    while stack:
        idx, depth = stack.pop()
        if len(idx) <= params.c_max:
            leaves.append(idx)
            continue
        n_leaders = int(np.clip(round(params.p_samp * len(idx)), 2, params.leader_cap))
        centers = _lloyd(x[idx], n_leaders, lloyd_iters, rng, params.metric)
        f = min(params.fanout_at(depth), n_leaders)
        assign = _nearest_leaders(x[idx], centers, f, params.metric)
        flat = assign.reshape(-1)
        src = np.repeat(idx, f)
        order = np.argsort(flat, kind="stable")
        flat_sorted, src_sorted = flat[order], src[order]
        buckets = []
        starts = np.searchsorted(flat_sorted, np.arange(n_leaders))
        ends = np.searchsorted(flat_sorted, np.arange(n_leaders) + 1)
        for s, e in zip(starts, ends):
            if e > s:
                buckets.append(src_sorted[s:e])
        buckets = _merge_small(buckets, params.c_min, params.c_max, rng)
        for b in buckets:
            (stack.append((b, depth + 1)) if len(b) > params.c_max
             else leaves.append(b))
    return leaves


def sorting_lsh_partition(
    x: np.ndarray,
    *,
    c_max: int = 1024,
    n_bits: int = 24,
    replicas: int = 1,
    seed: int = 0,
) -> list[np.ndarray]:
    """Sorting-LSH (A.1.3): lexicographic sort on concatenated hyperplane
    bits, consecutive groups of <= c_max.  Overlap via replication only."""
    leaves: list[np.ndarray] = []
    n, d = x.shape
    for r in range(replicas):
        rng = np.random.default_rng(seed + 15485863 * r)
        h = rng.standard_normal((n_bits, d)).astype(x.dtype)
        bits = (x @ h.T) >= 0.0  # [n, n_bits]
        # pack bits -> big-endian integer keys (lexicographic == numeric)
        key = np.zeros(n, dtype=np.float64)
        for i in range(n_bits):
            key = key * 2 + bits[:, i]
        order = np.argsort(key, kind="stable")
        for s in range(0, n, c_max):
            leaves.append(order[s : s + c_max].astype(np.int64))
    return leaves


PARTITIONERS: dict[str, Callable] = {
    "rbc": lambda x, p: ball_carve_replicated(x, p),
    "binary": lambda x, p: binary_partition(
        x, c_max=p.c_max, replicas=max(p.replicas, 1), metric=p.metric, seed=p.seed
    ),
    "kmeans": lambda x, p: kmeans_carve(x, p),
    "sorting_lsh": lambda x, p: sorting_lsh_partition(
        x, c_max=p.c_max, replicas=max(p.replicas, 1), seed=p.seed
    ),
}


def partition(
    x: np.ndarray, params: RBCParams, method: Literal["rbc", "binary", "kmeans", "sorting_lsh"] = "rbc"
) -> list[np.ndarray]:
    return PARTITIONERS[method](x, params)


def leaves_to_padded(
    leaves: list[np.ndarray], c_max: int
) -> np.ndarray:
    """Stack leaves into a dense [L, c_max] int32 matrix, -1 padded.

    This is the TPU-facing representation: every leaf becomes one row of a
    regular batch so all-leaf distance matrices are a single batched GEMM.
    """
    out = np.full((len(leaves), c_max), -1, dtype=np.int32)
    for i, b in enumerate(leaves):
        if len(b) > c_max:
            raise ValueError(f"leaf {i} larger than c_max ({len(b)} > {c_max})")
        out[i, : len(b)] = b
    return out
