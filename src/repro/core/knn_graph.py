"""Downstream task (Sec. 5.2, Fig. 6): approximate k-NN graph construction.

Build an ANN index with any of the framework's methods, then query it with
every dataset point; target >= 95% recall of the true k-NN edges.  Index
build time counts toward the end-to-end metric — the regime where PiPNN's
fast construction pays off.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from repro.core import pipnn as _pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k


def knn_graph_pipnn(
    x: np.ndarray,
    *,
    k: int = 10,
    beam: int = 32,
    params: "_pipnn.PiPNNParams | None" = None,
) -> tuple[np.ndarray, dict[str, float]]:
    """Returns ([n, k] neighbor ids excluding self, timing dict)."""
    t0 = time.perf_counter()
    index = _pipnn.build(x, params)
    t_build = time.perf_counter() - t0
    t0 = time.perf_counter()
    # query with k+1 then drop self hits
    found = _pipnn.search(index, x, x, k=k + 1, beam=max(beam, k + 1))
    t_query = time.perf_counter() - t0
    out = np.empty((x.shape[0], k), dtype=np.int64)
    for i in range(x.shape[0]):
        row = found[i]
        row = row[row != i][:k]
        if len(row) < k:
            row = np.pad(row, (0, k - len(row)), constant_values=-1)
        out[i] = row
    return out, {"build": t_build, "query": t_query, "total": t_build + t_query}


def knn_graph_recall(x: np.ndarray, knn: np.ndarray, k: int = 10,
                     metric: str = "l2", sample: int = 2000,
                     seed: int = 0) -> float:
    """Recall of the k-NN graph vs exact ground truth on a point sample."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    truth = brute_force_knn(x, x[idx], k + 1, metric=metric)
    # drop self from truth
    t = np.empty((len(idx), k), dtype=np.int64)
    for j, i in enumerate(idx):
        row = truth[j]
        row = row[row != i][:k]
        t[j] = row
    return recall_at_k(knn[idx], t, k=k)
