"""Vamana (DiskANN) baseline — incremental beam-search construction.

Faithful to Jayaram Subramanya et al. (2019) / ParlayANN's batched variant:
points are inserted in exponentially growing batches; each insertion runs a
beam search on the current graph from the medoid, RobustPrunes the visited
set to pick out-neighbors, then adds reverse edges (pruning any overfull
adjacency list).  Standard two-pass schedule: pass 1 with alpha=1, pass 2
with the target alpha.

This code deliberately exhibits the paper's *search bottleneck*: every
insert is a serial, latency-bound walk over the partial graph.  The
benchmark harness contrasts its build time with PiPNN's batched GEMM build.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.beam_search import medoid as _medoid
from repro.core.robust_prune import robust_prune_np


@dataclasses.dataclass(frozen=True)
class VamanaParams:
    max_deg: int = 32          # R
    beam: int = 64             # L (construction beam width)
    alpha: float = 1.2         # on true distance; squared internally for l2
    passes: int = 1            # 1-pass or 2-pass (Sec. 5.2 comparisons)
    metric: str = "l2"
    seed: int = 0

    def effective_alpha(self) -> float:
        if self.metric == "l2":
            return self.alpha ** 2
        if self.metric == "mips":
            return 1.0
        return self.alpha


def _dist(q: np.ndarray, pts: np.ndarray, metric: str) -> np.ndarray:
    if metric == "mips":
        return -(pts @ q)
    if metric == "cosine":
        return 1.0 - (pts @ q) / np.maximum(
            np.linalg.norm(pts, axis=1) * np.linalg.norm(q), 1e-30
        )
    diff = pts - q[None, :]
    return np.sum(diff * diff, axis=1)


def _greedy_search_visited(
    adj: list[np.ndarray], x: np.ndarray, q: np.ndarray, start: int,
    beam: int, metric: str,
) -> tuple[list[int], int]:
    """Beam search returning the VISITED set (Vamana's candidate pool)."""
    import heapq

    d0 = float(_dist(q, x[start : start + 1], metric)[0])
    frontier = [(d0, start)]
    in_beam = {start: d0}
    visited: dict[int, float] = {}
    comps = 1
    while frontier:
        d, p = heapq.heappop(frontier)
        if p in visited or p not in in_beam:
            continue
        visited[p] = d
        nbrs = adj[p]
        new = [v for v in nbrs if v not in in_beam and v not in visited]
        if len(new):
            nd = _dist(q, x[new], metric)
            comps += len(new)
            for v, dv in zip(new, nd):
                in_beam[v] = float(dv)
                heapq.heappush(frontier, (float(dv), v))
        if len(in_beam) > beam:
            items = sorted(in_beam.items(), key=lambda kv: (kv[1], kv[0]))[:beam]
            in_beam = dict(items)
    return list(visited.keys()), comps


def build_vamana(
    x: np.ndarray, params: VamanaParams | None = None
) -> tuple[np.ndarray, int, dict]:
    """Returns (adjacency [n, R] int32 -1-padded, medoid, stats)."""
    params = params or VamanaParams()
    x = np.ascontiguousarray(x, dtype=np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(params.seed)
    r = params.max_deg
    alpha_final = params.effective_alpha()
    start = _medoid(x, seed=params.seed)

    # random initial graph (DiskANN init): R/2 random out-edges
    adj: list[np.ndarray] = [
        rng.choice(n, size=min(r // 2, n - 1), replace=False) for _ in range(n)
    ]
    for i in range(n):
        adj[i] = adj[i][adj[i] != i]

    total_comps = 0
    t0 = time.perf_counter()
    order = rng.permutation(n)
    for p_i, alpha in enumerate(
        [1.0] * (params.passes - 1) + [alpha_final]
    ):
        for i in order:
            visited, comps = _greedy_search_visited(
                adj, x, x[i], start, params.beam, params.metric
            )
            total_comps += comps
            cand = np.asarray(
                [v for v in visited if v != i] + adj[i].tolist(), dtype=np.int64
            )
            kept = robust_prune_np(
                x[i], cand, x, alpha=alpha, r=r, metric=params.metric
            )
            adj[i] = kept
            # reverse edges
            for v in kept:
                if i in adj[v]:
                    continue
                lst = np.append(adj[v], i)
                if len(lst) > r:
                    lst = robust_prune_np(
                        x[v], lst, x, alpha=alpha, r=r, metric=params.metric
                    )
                adj[v] = lst
    build_time = time.perf_counter() - t0

    graph = np.full((n, r), -1, dtype=np.int32)
    for i in range(n):
        graph[i, : len(adj[i])] = adj[i][:r]
    stats = {
        "build_time": build_time,
        "dist_comps": total_comps,
        "avg_degree": float((graph >= 0).sum() / n),
    }
    return graph, start, stats
