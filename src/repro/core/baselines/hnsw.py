"""HNSW baseline (Malkov & Yashunin 2018) — hierarchical incremental build.

Level assignment is geometric (mult = 1/ln(M)); insertion descends with a
greedy ef=1 search to the node's level, then runs an efConstruction beam at
each level it joins, selecting M neighbors by the simple-closest heuristic
(plus the RNG 'select-neighbors-heuristic' option).  Exhibits the same
search bottleneck as Vamana.
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from repro.core.baselines.vamana import _dist, _greedy_search_visited


@dataclasses.dataclass(frozen=True)
class HNSWParams:
    m: int = 16                 # out-degree per layer (layer0 gets 2M)
    ef_construction: int = 64
    heuristic: bool = True      # RNG neighbor-selection heuristic
    metric: str = "l2"
    seed: int = 0


def _select_neighbors(
    x: np.ndarray, q_i: int, cand: list[int], m: int, metric: str,
    heuristic: bool,
) -> list[int]:
    cand = [c for c in dict.fromkeys(cand) if c != q_i]
    if not cand:
        return []
    d = _dist(x[q_i], x[cand], metric)
    order = np.argsort(d, kind="stable")
    if not heuristic:
        return [cand[o] for o in order[:m]]
    kept: list[int] = []
    for o in order:
        c = cand[o]
        dc = d[o]
        ok = True
        for kpt in kept:
            if _dist(x[c], x[kpt : kpt + 1], metric)[0] < dc:
                ok = False
                break
        if ok:
            kept.append(c)
            if len(kept) >= m:
                break
    # backfill with closest if heuristic kept too few
    if len(kept) < m:
        for o in order:
            if cand[o] not in kept:
                kept.append(cand[o])
                if len(kept) >= m:
                    break
    return kept


def build_hnsw(
    x: np.ndarray, params: HNSWParams | None = None
) -> tuple[np.ndarray, int, dict]:
    """Returns (layer-0 adjacency [n, 2M] int32 -1 padded, entry, stats).

    Querying uses the layer-0 graph from the top entry point, matching how
    the benchmarks evaluate all methods with one shared beam-search engine.
    """
    params = params or HNSWParams()
    x = np.ascontiguousarray(x, dtype=np.float32)
    n = x.shape[0]
    rng = np.random.default_rng(params.seed)
    m = params.m
    mult = 1.0 / math.log(m)
    levels = np.minimum(
        (-np.log(np.maximum(rng.random(n), 1e-12)) * mult).astype(np.int64), 8
    )
    max_level = int(levels.max())
    # adjacency per level: lists of lists
    adj: list[list[list[int]]] = [
        [[] for _ in range(n)] for _ in range(max_level + 1)
    ]
    entry = 0
    entry_level = int(levels[0])
    t0 = time.perf_counter()
    comps = 0
    for i in range(1, n):
        li = int(levels[i])
        ep = entry
        # greedy descend from the top
        for lev in range(entry_level, li, -1):
            improved = True
            while improved:
                improved = False
                nbrs = adj[lev][ep]
                if nbrs:
                    d = _dist(x[i], x[nbrs], params.metric)
                    comps += len(nbrs)
                    j = int(np.argmin(d))
                    if d[j] < _dist(x[i], x[ep : ep + 1], params.metric)[0]:
                        ep = nbrs[j]
                        improved = True
        # ef search + connect at each level from min(li, entry_level) down
        for lev in range(min(li, entry_level), -1, -1):
            adj_lists = [np.asarray(a, dtype=np.int64) for a in adj[lev]]
            visited, c = _greedy_search_visited(
                adj_lists, x, x[i], ep, params.ef_construction, params.metric
            )
            comps += c
            mm = m if lev > 0 else 2 * m
            nbrs = _select_neighbors(
                x, i, visited, mm, params.metric, params.heuristic
            )
            adj[lev][i] = list(nbrs)
            for v in nbrs:
                lst = adj[lev][v]
                if i not in lst:
                    lst.append(i)
                    if len(lst) > mm:
                        adj[lev][v] = _select_neighbors(
                            x, v, lst, mm, params.metric, params.heuristic
                        )
            if nbrs:
                ep = nbrs[0]
        if li > entry_level:
            entry, entry_level = i, li
    build_time = time.perf_counter() - t0

    width = 2 * m
    graph = np.full((n, width), -1, dtype=np.int32)
    for i in range(n):
        row = adj[0][i][:width]
        graph[i, : len(row)] = row
    stats = {
        "build_time": build_time,
        "dist_comps": comps,
        "avg_degree": float((graph >= 0).sum() / n),
        "max_level": max_level,
    }
    return graph, entry, stats
