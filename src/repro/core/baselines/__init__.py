"""Paper baselines (Sec. 5): Vamana (DiskANN), HNSW, HCNNG.

These are the incremental, beam-search-driven builders whose *search
bottleneck* PiPNN eliminates.  They are host-side algorithms by nature
(pointer-chasing over a mutable graph); distance math is vectorized numpy.
Used by the benchmark harness for build-time and QPS/recall comparisons.
"""
from repro.core.baselines.vamana import VamanaParams, build_vamana
from repro.core.baselines.hnsw import HNSWParams, build_hnsw
from repro.core.baselines.hcnng import HCNNGParams, build_hcnng

__all__ = [
    "VamanaParams", "build_vamana",
    "HNSWParams", "build_hnsw",
    "HCNNGParams", "build_hcnng",
]
