"""HCNNG baseline (Munoz et al. 2019) — binary partitioning + leaf MSTs.

The partitioning-based predecessor PiPNN improves on: many replications of
disjoint binary partitioning, a degree-capped MST per leaf, union of all
edges.  No pruning — which is exactly the paper's critique (dense,
directionally-redundant adjacency lists; memory grows with replicas).
Reuses the framework's partitioner and MST leaf method.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.beam_search import medoid as _medoid
from repro.core.leaf import LeafParams, build_leaf_edges
from repro.core.rbc import binary_partition, leaves_to_padded


@dataclasses.dataclass(frozen=True)
class HCNNGParams:
    c_max: int = 1024
    replicas: int = 10          # paper notes HCNNG often needs ~30
    max_deg: int = 90           # the paper's HCNNG setting
    mst_degree_cap: int = 3
    metric: str = "l2"
    seed: int = 0


def build_hcnng(
    x: np.ndarray, params: HCNNGParams | None = None
) -> tuple[np.ndarray, int, dict]:
    """Returns (adjacency [n, max_deg] int32 -1 padded, medoid, stats)."""
    params = params or HCNNGParams()
    x = np.ascontiguousarray(x, dtype=np.float32)
    n = x.shape[0]
    t0 = time.perf_counter()
    leaves = binary_partition(
        x, c_max=params.c_max, replicas=params.replicas,
        metric=params.metric, seed=params.seed,
    )
    padded = leaves_to_padded(leaves, params.c_max)
    edges = build_leaf_edges(
        x, padded,
        LeafParams(method="mst", metric=params.metric,
                   mst_degree_cap=params.mst_degree_cap),
    )
    # union of edges, dedupe, cap degree keeping shortest
    v = edges.valid()
    src, dst, dist = edges.src[v], edges.dst[v], edges.dist[v]
    order = np.lexsort((dst, dist, src))
    src, dst, dist = src[order], dst[order], dist[order]
    graph = np.full((n, params.max_deg), -1, dtype=np.int32)
    fill = np.zeros(n, dtype=np.int32)
    prev = (-1, -1)
    for s, d_, w in zip(src, dst, dist):
        if (s, d_) == prev:
            continue
        prev = (s, d_)
        if fill[s] < params.max_deg:
            graph[s, fill[s]] = d_
            fill[s] += 1
    build_time = time.perf_counter() - t0
    stats = {
        "build_time": build_time,
        "avg_degree": float((graph >= 0).sum() / n),
        "n_leaves": len(leaves),
    }
    return graph, _medoid(x, seed=params.seed), stats
