"""RobustPrune (Algorithm 2, Vamana's α-pruning kernel).

Three forms:
  * ``robust_prune_np``   — faithful sequential reference (numpy); used by the
    Vamana baseline's incremental build and as the oracle in tests.
  * ``robust_prune_mask`` — batch-vectorized greedy over a fixed candidate
    budget (jax.lax.scan over candidate ranks, all points in parallel).
    Semantics identical to the sequential version given the same candidate
    ordering (ascending (dist, id)).
  * ``final_prune``       — PiPNN's final pass (Sec. 4.3): RobustPrune each
    point's HashPrune reservoir (<= l_max candidates, so the O(l^2)
    candidate-candidate distance matrix is tiny).

The paper's 'lazy' variant (App. A.3.3) defers dominance checks to insertion
time; on TPU the batch form already evaluates all dominance tests as dense
masked arithmetic, which subsumes the laziness trick (noted in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics
from repro.core.hashprune import Reservoir, INVALID_ID


def robust_prune_np(
    p: np.ndarray,
    cand_ids: np.ndarray,
    x: np.ndarray,
    *,
    alpha: float = 1.2,
    r: int = 64,
    metric: str = "l2",
) -> np.ndarray:
    """Sequential Algorithm 2.  Returns kept candidate ids (<= r)."""
    cand_ids = np.unique(cand_ids[cand_ids >= 0])
    if cand_ids.size == 0:
        return cand_ids
    c = x[cand_ids]
    if metric == "mips":
        d_pc = -(c @ p)
    elif metric == "cosine":
        d_pc = 1.0 - (c @ p) / np.maximum(
            np.linalg.norm(c, axis=1) * np.linalg.norm(p), 1e-30
        )
    else:
        diff = c - p[None, :]
        d_pc = np.sum(diff * diff, axis=1)
    order = np.lexsort((cand_ids, d_pc))  # (dist, id)
    kept: list[int] = []
    kept_vecs: list[np.ndarray] = []
    alive = np.ones(len(cand_ids), dtype=bool)
    for oi in order:
        if not alive[oi]:
            continue
        kept.append(cand_ids[oi])
        kept_vecs.append(c[oi])
        if len(kept) >= r:
            break
        # prune candidates dominated by the newly kept point
        if metric == "mips":
            d_jc = -(c @ c[oi])
        elif metric == "cosine":
            d_jc = 1.0 - (c @ c[oi]) / np.maximum(
                np.linalg.norm(c, axis=1) * np.linalg.norm(c[oi]), 1e-30
            )
        else:
            diff = c - c[oi][None, :]
            d_jc = np.sum(diff * diff, axis=1)
        alive &= ~(alpha * d_jc <= d_pc)
    return np.asarray(kept, dtype=np.int64)


def _prune_step(carry, r_idx, *, alpha, max_deg):
    """One greedy rank step for all points at once."""
    alive, count, keep, d_pc, d_cc, order = carry
    b = jnp.arange(d_pc.shape[0])
    j = order[:, r_idx]                        # [B] candidate index at this rank
    valid = jnp.isfinite(d_pc[b, j]) & alive[b, j] & (count < max_deg)
    keep = keep.at[b, j].set(keep[b, j] | valid)
    count = count + valid.astype(jnp.int32)
    # dominance: alpha * d(j, c) <= d(p, c)  (squared-L2 note: alpha applies
    # to the stored dissimilarity, matching the baseline implementations)
    dom = alpha * d_cc[b, j, :] <= d_pc       # [B, C]
    alive = alive & ~(dom & valid[:, None])
    return (alive, count, keep, d_pc, d_cc, order), None


@functools.partial(jax.jit, static_argnames=("alpha", "max_deg"))
def robust_prune_mask(
    d_pc: jax.Array,   # [B, C] point->candidate dissimilarity (+inf invalid)
    d_cc: jax.Array,   # [B, C, C] candidate->candidate dissimilarity
    cand_ids: jax.Array,  # [B, C] for deterministic tie-breaking
    *,
    alpha: float = 1.2,
    max_deg: int = 64,
) -> jax.Array:
    """Vectorized RobustPrune.  Returns keep mask [B, C]."""
    bsz, c = d_pc.shape
    # order by (dist, id): scale-free lexicographic via sort of packed keys
    big = jnp.where(cand_ids == INVALID_ID, jnp.int32(2**30), cand_ids)
    _, _, order = jax.lax.sort(
        (d_pc, big, jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (bsz, c))),
        dimension=-1,
        num_keys=2,
    )
    alive = jnp.isfinite(d_pc)
    keep = jnp.zeros_like(alive)
    count = jnp.zeros((bsz,), dtype=jnp.int32)
    step = functools.partial(_prune_step, alpha=alpha, max_deg=max_deg)
    (alive, count, keep, *_), _ = jax.lax.scan(
        step, (alive, count, keep, d_pc, d_cc, order), jnp.arange(c)
    )
    return keep


def final_prune(
    x: jax.Array,
    res: Reservoir,
    *,
    alpha: float = 1.2,
    max_deg: int = 64,
    metric: str = "l2",
    chunk: int = 2048,
) -> tuple[np.ndarray, np.ndarray]:
    """Sec. 4.3 final pass: RobustPrune every reservoir.

    Returns (adjacency [n, max_deg] int32 with -1 padding,
             dists     [n, max_deg] f32 with +inf padding).
    """
    n, l = res.ids.shape
    x = jnp.asarray(x)
    out_ids = np.full((n, max_deg), -1, dtype=np.int32)
    out_d = np.full((n, max_deg), np.inf, dtype=np.float32)

    @jax.jit
    def _chunk(ids, dists):
        safe = jnp.maximum(ids, 0)
        cvecs = x[safe]                                     # [B, L, d]
        d_cc = jax.vmap(lambda a: _metrics.pairwise(a, a, metric))(cvecs)
        d_pc = jnp.where(ids == INVALID_ID, jnp.inf, dists)
        keep = robust_prune_mask(d_pc, d_cc, ids, alpha=alpha, max_deg=max_deg)
        # compact kept entries to the front: sort by (dist-if-kept, id)
        k_d = jnp.where(keep, d_pc, jnp.inf)
        s_d, s_i = jax.lax.sort((k_d, ids), dimension=-1, num_keys=2)
        return s_i[:, :max_deg], s_d[:, :max_deg]

    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        si, sd = _chunk(res.ids[s:e], res.dists[s:e])
        w = min(max_deg, l)
        out_ids[s:e, :w] = np.asarray(si)[:, :w]
        out_d[s:e, :w] = np.asarray(sd)[:, :w]
    out_ids[~np.isfinite(out_d)] = -1
    return out_ids, out_d
