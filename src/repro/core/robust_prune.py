"""RobustPrune (Algorithm 2, Vamana's α-pruning kernel).

Three forms:
  * ``robust_prune_np``   — faithful sequential reference (numpy); used by the
    Vamana baseline's incremental build and as the oracle in tests.
  * ``robust_prune_mask`` — batch-vectorized greedy over a fixed candidate
    budget (jax.lax.scan over candidate ranks, all points in parallel).
    Semantics identical to the sequential version given the same candidate
    ordering (ascending (dist, id)).
  * ``final_prune``       — PiPNN's final pass (Sec. 4.3): RobustPrune each
    point's HashPrune reservoir (<= l_max candidates, so the O(l^2)
    candidate-candidate distance matrix is tiny).

``final_prune`` is device-resident by default: one jitted chunk step slides
over the reservoir with ``lax.dynamic_slice`` and writes results into
persistent [n, max_deg] output buffers via ``lax.dynamic_update_slice``
(buffers donated across steps, so they never reallocate), with a single
device->host transfer at the end — the same bounded-memory streaming
pattern as the Stage 2+3 pipeline.  The previous host-looped variant
(``np.asarray`` sync per chunk) is kept as ``final_prune_host``, the oracle
streaming is property-tested against.  ``prune_reservoir_block`` is the
shared traceable core: the streaming step here and the distributed
final-prune superstep (``launch/build_index.py``) both call it, so the two
builds prune identically.

The paper's 'lazy' variant (App. A.3.3) defers dominance checks to insertion
time; on TPU the batch form already evaluates all dominance tests as dense
masked arithmetic, which subsumes the laziness trick (noted in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics
from repro.core.hashprune import Reservoir, INVALID_ID


def robust_prune_np(
    p: np.ndarray,
    cand_ids: np.ndarray,
    x: np.ndarray,
    *,
    alpha: float = 1.2,
    r: int = 64,
    metric: str = "l2",
) -> np.ndarray:
    """Sequential Algorithm 2.  Returns kept candidate ids (<= r)."""
    cand_ids = np.unique(cand_ids[cand_ids >= 0])
    if cand_ids.size == 0:
        return cand_ids
    c = x[cand_ids]
    if metric == "mips":
        d_pc = -(c @ p)
    elif metric == "cosine":
        d_pc = 1.0 - (c @ p) / np.maximum(
            np.linalg.norm(c, axis=1) * np.linalg.norm(p), 1e-30
        )
    else:
        diff = c - p[None, :]
        d_pc = np.sum(diff * diff, axis=1)
    order = np.lexsort((cand_ids, d_pc))  # (dist, id)
    kept: list[int] = []
    kept_vecs: list[np.ndarray] = []
    alive = np.ones(len(cand_ids), dtype=bool)
    for oi in order:
        if not alive[oi]:
            continue
        kept.append(cand_ids[oi])
        kept_vecs.append(c[oi])
        if len(kept) >= r:
            break
        # prune candidates dominated by the newly kept point
        if metric == "mips":
            d_jc = -(c @ c[oi])
        elif metric == "cosine":
            d_jc = 1.0 - (c @ c[oi]) / np.maximum(
                np.linalg.norm(c, axis=1) * np.linalg.norm(c[oi]), 1e-30
            )
        else:
            diff = c - c[oi][None, :]
            d_jc = np.sum(diff * diff, axis=1)
        alive &= ~(alpha * d_jc <= d_pc)
    return np.asarray(kept, dtype=np.int64)


def _prune_step(carry, r_idx, *, alpha, max_deg):
    """One greedy rank step for all points at once."""
    alive, count, keep, d_pc, d_cc, order = carry
    b = jnp.arange(d_pc.shape[0])
    j = order[:, r_idx]                        # [B] candidate index at this rank
    valid = jnp.isfinite(d_pc[b, j]) & alive[b, j] & (count < max_deg)
    keep = keep.at[b, j].set(keep[b, j] | valid)
    count = count + valid.astype(jnp.int32)
    # dominance: alpha * d(j, c) <= d(p, c)  (squared-L2 note: alpha applies
    # to the stored dissimilarity, matching the baseline implementations)
    dom = alpha * d_cc[b, j, :] <= d_pc       # [B, C]
    alive = alive & ~(dom & valid[:, None])
    return (alive, count, keep, d_pc, d_cc, order), None


@functools.partial(jax.jit, static_argnames=("alpha", "max_deg"))
def robust_prune_mask(
    d_pc: jax.Array,   # [B, C] point->candidate dissimilarity (+inf invalid)
    d_cc: jax.Array,   # [B, C, C] candidate->candidate dissimilarity
    cand_ids: jax.Array,  # [B, C] for deterministic tie-breaking
    *,
    alpha: float = 1.2,
    max_deg: int = 64,
) -> jax.Array:
    """Vectorized RobustPrune.  Returns keep mask [B, C]."""
    bsz, c = d_pc.shape
    # order by (dist, id): scale-free lexicographic via sort of packed keys
    big = jnp.where(cand_ids == INVALID_ID, jnp.int32(2**30), cand_ids)
    _, _, order = jax.lax.sort(
        (d_pc, big, jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32), (bsz, c))),
        dimension=-1,
        num_keys=2,
    )
    alive = jnp.isfinite(d_pc)
    keep = jnp.zeros_like(alive)
    count = jnp.zeros((bsz,), dtype=jnp.int32)
    step = functools.partial(_prune_step, alpha=alpha, max_deg=max_deg)
    (alive, count, keep, *_), _ = jax.lax.scan(
        step, (alive, count, keep, d_pc, d_cc, order), jnp.arange(c)
    )
    return keep


def prune_reservoir_block(
    ids: jax.Array,     # [B, L] candidate ids (INVALID_ID padding)
    dists: jax.Array,   # [B, L] point->candidate dissimilarity
    d_cc: jax.Array,    # [B, L, L] candidate->candidate dissimilarity
    *,
    alpha: float,
    max_deg: int,
) -> tuple[jax.Array, jax.Array]:
    """Traceable core of the final pass: RobustPrune a reservoir block.

    The caller supplies ``d_cc`` (host build: gathered vectors through
    ``metrics.pairwise``; distributed build: routed vectors through its own
    GEMM), so both builds share exactly this keep/compact/truncate logic.
    Returns ([B, max_deg] ids with -1 padding, [B, max_deg] dists with +inf
    padding), rows sorted by (dist, id).
    """
    d_pc = jnp.where(ids == INVALID_ID, jnp.inf, dists)
    keep = robust_prune_mask(d_pc, d_cc, ids, alpha=alpha, max_deg=max_deg)
    # compact kept entries to the front: sort by (dist-if-kept, id)
    k_d = jnp.where(keep, d_pc, jnp.inf)
    s_d, s_i = jax.lax.sort((k_d, ids), dimension=-1, num_keys=2)
    l = ids.shape[-1]
    if l >= max_deg:
        s_d, s_i = s_d[..., :max_deg], s_i[..., :max_deg]
    else:
        pad = [(0, 0)] * (s_d.ndim - 1) + [(0, max_deg - l)]
        s_d = jnp.pad(s_d, pad, constant_values=jnp.inf)
        s_i = jnp.pad(s_i, pad, constant_values=-1)
    return jnp.where(jnp.isfinite(s_d), s_i, -1), s_d


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "max_deg", "metric", "chunk"),
    donate_argnums=(0, 1),
)
def _final_prune_step(
    out_ids, out_d, x, res_ids, res_dists, start, *,
    alpha, max_deg, metric, chunk,
):
    """One streaming chunk: slice [chunk, L] of the reservoir at ``start``,
    prune it, write into the donated [n, max_deg] output buffers."""
    ids = jax.lax.dynamic_slice_in_dim(res_ids, start, chunk)
    dists = jax.lax.dynamic_slice_in_dim(res_dists, start, chunk)
    cvecs = x[jnp.maximum(ids, 0)]                          # [chunk, L, d]
    d_cc = jax.vmap(lambda a: _metrics.pairwise(a, a, metric))(cvecs)
    s_i, s_d = prune_reservoir_block(ids, dists, d_cc,
                                     alpha=alpha, max_deg=max_deg)
    out_ids = jax.lax.dynamic_update_slice_in_dim(out_ids, s_i, start, axis=0)
    out_d = jax.lax.dynamic_update_slice_in_dim(out_d, s_d, start, axis=0)
    return out_ids, out_d


def final_prune_workspace_bytes(chunk: int, l_max: int, d: int,
                                max_deg: int) -> int:
    """Modeled XLA temp bytes of one ``_final_prune_step``: the gathered
    [chunk, L, d] candidate vectors, the [chunk, L, L] candidate-candidate
    distance matrix (plus one copy — the scan threads it through its
    carry), and the per-row sort/keep buffers.  Chunk-shaped only: the
    [n, max_deg] outputs are donated buffers, not temp.  Validated by the
    memory auditor at every lattice point (PIPM004); prices the
    deployment envelope (PIPM003)."""
    gathered = chunk * l_max * d * 4
    d_cc = 2 * chunk * l_max * l_max * 4
    sort_keep = 6 * chunk * l_max * 8 + chunk * max_deg * 8
    return gathered + d_cc + sort_keep


def final_prune(
    x: jax.Array,
    res: Reservoir,
    *,
    alpha: float = 1.2,
    max_deg: int = 64,
    metric: str = "l2",
    chunk: int = 2048,
) -> tuple[np.ndarray, np.ndarray]:
    """Sec. 4.3 final pass: RobustPrune every reservoir — device-resident.

    Streams ``chunk``-sized reservoir blocks through one jitted step that
    writes into persistent donated [n, max_deg] buffers; no per-chunk host
    sync (the loop enqueues device work only), one device->host transfer at
    the end.  Bit-identical to ``final_prune_host``.

    Returns (adjacency [n, max_deg] int32 with -1 padding,
             dists     [n, max_deg] f32 with +inf padding).
    """
    n, _ = res.ids.shape
    chunk = max(1, min(chunk, n))
    x = jnp.asarray(x)
    res_ids, res_dists = jnp.asarray(res.ids), jnp.asarray(res.dists)
    out_ids = jnp.full((n, max_deg), -1, dtype=jnp.int32)
    out_d = jnp.full((n, max_deg), jnp.inf, dtype=jnp.float32)
    for s in range(0, n, chunk):
        # the tail chunk re-covers the last full window: rows in the overlap
        # are recomputed from identical inputs, so the double write is
        # idempotent and every compiled shape is [chunk, L]
        out_ids, out_d = _final_prune_step(
            out_ids, out_d, x, res_ids, res_dists, min(s, n - chunk),
            alpha=alpha, max_deg=max_deg, metric=metric, chunk=chunk)
    return np.asarray(out_ids), np.asarray(out_d)


def final_prune_host(
    x: jax.Array,
    res: Reservoir,
    *,
    alpha: float = 1.2,
    max_deg: int = 64,
    metric: str = "l2",
    chunk: int = 2048,
) -> tuple[np.ndarray, np.ndarray]:
    """Host-looped final pass (the pre-streaming oracle).

    Syncs ``np.asarray`` per chunk; kept for property tests asserting the
    streaming variant is bit-identical.
    """
    n, l = res.ids.shape
    x = jnp.asarray(x)
    out_ids = np.full((n, max_deg), -1, dtype=np.int32)
    out_d = np.full((n, max_deg), np.inf, dtype=np.float32)

    @jax.jit
    def _chunk(ids, dists):
        safe = jnp.maximum(ids, 0)
        cvecs = x[safe]                                     # [B, L, d]
        d_cc = jax.vmap(lambda a: _metrics.pairwise(a, a, metric))(cvecs)
        d_pc = jnp.where(ids == INVALID_ID, jnp.inf, dists)
        keep = robust_prune_mask(d_pc, d_cc, ids, alpha=alpha, max_deg=max_deg)
        # compact kept entries to the front: sort by (dist-if-kept, id)
        k_d = jnp.where(keep, d_pc, jnp.inf)
        s_d, s_i = jax.lax.sort((k_d, ids), dimension=-1, num_keys=2)
        return s_i[:, :max_deg], s_d[:, :max_deg]

    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        si, sd = _chunk(res.ids[s:e], res.dists[s:e])
        w = min(max_deg, l)
        out_ids[s:e, :w] = np.asarray(si)[:, :w]
        out_d[s:e, :w] = np.asarray(sd)[:, :w]
    out_ids[~np.isfinite(out_d)] = -1
    return out_ids, out_d
