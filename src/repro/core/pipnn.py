"""PiPNN (Algorithm 4): partition -> pick -> HashPrune -> final prune.

This is the host-orchestrated reference/build path used by tests, examples
and benchmarks; the fully-static multi-pod SPMD build lives in
``repro/launch/build_index.py`` and reuses the same stage functions.

The build is deterministic under a fixed seed (Appendix A.8): RBC is
deterministic given its RNG stream, and HashPrune is history-independent
(Theorem 3.1), so the produced graph is unique regardless of leaf processing
order — tests assert bit-identical rebuilds.

Alpha scale note: ``metrics`` returns *squared* L2.  RobustPrune's alpha is
specified on true distances in the paper (default 1.2); on squared
distances the equivalent multiplier is alpha**2, which ``PiPNNParams``
applies automatically for the l2 metric.  For MIPS (dissimilarity = -ip,
sign-indefinite) alpha scaling is not meaningful and we use alpha=1.0, the
standard DiskANN-MIPS practice.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.core import sketch as _sketch
from repro.core.hashprune import Reservoir, hashprune_flat, INVALID_ID
from repro.core.leaf import EdgeList, LeafParams, build_leaf_edges
from repro.core.rbc import RBCParams, leaves_to_padded, partition
from repro.core.robust_prune import final_prune


@dataclasses.dataclass(frozen=True)
class PiPNNParams:
    rbc: RBCParams = dataclasses.field(default_factory=RBCParams)
    leaf: LeafParams = dataclasses.field(default_factory=LeafParams)
    partitioner: str = "rbc"
    hash_bits: int = 12        # m hyperplanes (paper default 12, Fig. 13)
    l_max: int = 64            # reservoir capacity (paper: 64..192)
    final_prune: bool = True   # Sec. 4.3 (enabled by default in the paper)
    alpha: float = 1.2         # on TRUE distance; squared for l2 internally
    max_deg: int = 64          # final graph degree cap (paper's comparison deg)
    metric: str = "l2"
    seed: int = 0

    def effective_alpha(self) -> float:
        if self.metric == "l2":
            return float(self.alpha) ** 2
        if self.metric == "mips":
            return 1.0
        return float(self.alpha)

    def with_(self, **kw) -> "PiPNNParams":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class PiPNNIndex:
    graph: np.ndarray          # [n, max_deg] int32, -1 padded
    dists: np.ndarray          # [n, max_deg] f32, +inf padded
    start: int                 # entry point (medoid)
    params: PiPNNParams
    timings: dict[str, float]
    stats: dict[str, Any]

    @property
    def n(self) -> int:
        return self.graph.shape[0]

    def average_degree(self) -> float:
        return float((self.graph >= 0).sum() / self.graph.shape[0])


def _hash_edges(
    edges: EdgeList, sketches: np.ndarray
) -> np.ndarray:
    """Residual hashes h_src(dst) for every candidate edge, via sketches."""
    safe_src = np.maximum(edges.src, 0)
    safe_dst = np.maximum(edges.dst, 0)
    h = np.asarray(
        _sketch.hash_from_sketches(
            jnp.asarray(sketches[safe_dst]), jnp.asarray(sketches[safe_src])
        )
    )
    return h.astype(np.int32)


def build(
    x: np.ndarray,
    params: PiPNNParams | None = None,
    *,
    leaves: list[np.ndarray] | None = None,
    knn_fn: Callable | None = None,
) -> PiPNNIndex:
    """Build a PiPNN index over ``x`` [n, d] float32."""
    from repro.core.beam_search import medoid  # local import, avoids cycle

    params = params or PiPNNParams()
    x = np.ascontiguousarray(x, dtype=np.float32)
    n, d = x.shape
    timings: dict[str, float] = {}
    stats: dict[str, Any] = {}

    # --- Stage 1: overlapping partitioning (Sec. 4.1) ---------------------
    t0 = time.perf_counter()
    if leaves is None:
        rbc = dataclasses.replace(params.rbc, metric=params.metric, seed=params.seed)
        leaves = partition(x, rbc, params.partitioner)
    padded = leaves_to_padded(leaves, params.rbc.c_max)
    timings["partition"] = time.perf_counter() - t0
    sizes = np.asarray([len(b) for b in leaves])
    stats["n_leaves"] = len(leaves)
    stats["leaf_size_mean"] = float(sizes.mean()) if len(sizes) else 0.0
    stats["point_repeat"] = float(sizes.sum() / max(n, 1))
    stats["pad_ratio"] = float(padded.size / max(sizes.sum(), 1))

    # --- Stage 2: leaf building -> candidate edges (Sec. 4.2) -------------
    t0 = time.perf_counter()
    leaf = dataclasses.replace(params.leaf, metric=params.metric)
    edges = build_leaf_edges(x, padded, leaf, knn_fn=knn_fn)
    timings["build_leaves"] = time.perf_counter() - t0
    stats["n_candidate_edges"] = int(edges.valid().sum())

    # --- Stage 3: HashPrune (Sec. 3) ---------------------------------------
    t0 = time.perf_counter()
    import jax.random as jrandom

    key = jrandom.PRNGKey(params.seed)
    hyperplanes = _sketch.make_hyperplanes(key, params.hash_bits, d)
    sketches = np.asarray(_sketch.sketch_jit(jnp.asarray(x), hyperplanes))
    hashes = _hash_edges(edges, sketches)
    src = np.where(edges.src >= 0, edges.src, n).astype(np.int32)
    dst = np.where(edges.src >= 0, edges.dst, INVALID_ID).astype(np.int32)
    dist = np.where(edges.src >= 0, edges.dist, np.inf).astype(np.float32)
    res: Reservoir = hashprune_flat(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
        jnp.asarray(dist), n_points=n, l_max=params.l_max,
    )
    timings["hashprune"] = time.perf_counter() - t0

    # --- Stage 4: final prune (Sec. 4.3) -----------------------------------
    t0 = time.perf_counter()
    if params.final_prune:
        graph, dists = final_prune(
            x, res, alpha=params.effective_alpha(), max_deg=params.max_deg,
            metric=params.metric,
        )
    else:
        ids = np.asarray(res.ids)[:, : params.max_deg]
        ds = np.asarray(res.dists)[:, : params.max_deg]
        if ids.shape[1] < params.max_deg:
            pad = params.max_deg - ids.shape[1]
            ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            ds = np.pad(ds, ((0, 0), (0, pad)), constant_values=np.inf)
        graph, dists = ids, ds
    timings["final_prune"] = time.perf_counter() - t0
    timings["total"] = sum(timings.values())

    return PiPNNIndex(
        graph=graph,
        dists=dists,
        start=medoid(x, seed=params.seed),
        params=params,
        timings=timings,
        stats=stats,
    )


def search(
    index: PiPNNIndex,
    x: np.ndarray,
    queries: np.ndarray,
    *,
    k: int = 10,
    beam: int = 32,
    batch: bool = True,
) -> np.ndarray:
    """Query the index; returns [Q, k] neighbor ids."""
    from repro.core import beam_search as bs

    if batch:
        iters = beam + 4
        ids, _ = bs.beam_search_batch(
            jnp.asarray(index.graph), jnp.asarray(x), jnp.asarray(queries),
            start=index.start, beam=beam, iters=iters, metric=index.params.metric,
        )
        return np.asarray(ids)[:, :k]
    out = np.empty((queries.shape[0], k), dtype=np.int64)
    for i, q in enumerate(queries):
        ids, _, _ = bs.beam_search_np(
            index.graph, x, q, start=index.start, beam=beam,
            metric=index.params.metric,
        )
        out[i] = ids[:k] if len(ids) >= k else np.pad(ids, (0, k - len(ids)), constant_values=-1)
    return out
