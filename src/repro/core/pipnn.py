"""PiPNN (Algorithm 4): partition -> pick -> HashPrune -> final prune.

This is the host-orchestrated reference/build path used by tests, examples
and benchmarks; the fully-static multi-pod SPMD build lives in
``repro/launch/build_index.py`` and reuses the same stage functions.

Stage 1 execution strategies, selected by ``RBCParams.execution``
(see ``core/rbc.py``): ``"host"`` is the numpy oracle recursion,
``"device"`` keeps only the variable-size worklist on the host while the
per-subproblem leader GEMM / top-f / bucket grouping run as fixed-shape
jitted steps (bit-identical leaves to the oracle for a fixed seed), and
``"static"`` runs the whole stage as ONE jitted two-level carve
(``ball_carve_device``, the ``build_index.py`` tile-step shape) so
``build(streaming=True)`` executes Stage 1-4 with zero host compute.
All three share the leader-assignment step in ``core/leader_assign.py``
with the SPMD build.  ``stats["partition_execution"]`` records the
resolved strategy; ``stats["partition_uncovered"]`` counts points in no
leaf — an invariant tripwire that should always be 0 (the static path
appends salvage leaves for replicas its capacity routing dropped).

Two Stage-2+3 execution strategies, selected by ``build(..., streaming=)``:

  * STREAMING (default, ``streaming=True``): a device-resident chunk
    pipeline.  For each chunk of leaves one fused jitted step runs the leaf
    kernel — the k-NN methods (``bidirected`` / ``directed`` /
    ``inverted``) or the all-to-all ``robust_prune`` leaf method — emits
    candidate edges as fixed-shape device arrays
    (``leaf.emit_knn_edges_jax`` / ``leaf.emit_robust_prune_edges_jax``),
    computes residual hashes from the precomputed sketches (Pallas
    ``edge_hashes`` on TPU, ``hash_from_sketches`` fallback elsewhere), and
    folds the chunk into the persistent [n, l_max] reservoir with buffer
    donation.  The fold is the SEGMENTED merge by default
    (``PiPNNParams.merge``): one global sort over the chunk's own edges
    plus a bounded per-row merge with the already-sorted reservoir
    (``hashprune.merge_segmented_edges``; Pallas row-merge kernel on TPU
    via ``use_pallas_merge``) — the persistent reservoir never enters a
    global sort.  ``merge="flat"`` selects the reservoir-as-edges re-sort
    fold (``hashprune_merge_flat``), kept as the oracle.  The merge chunk
    (``LeafParams.stream_chunk``) auto-sizes so one chunk's edge buffer is
    ~ the reservoir itself; the leaf GEMM still runs at the ``leaf_chunk``
    VMEM granularity inside the fused step.  Peak intermediate memory is
    O(stream_chunk_edges + n * l_max) = O(n * l_max) in auto mode, and
    there are no host round-trips inside the loop — candidate edges never
    materialize on the host.

  * FLAT (``streaming=False``, and the fallback for the ``mst`` leaf
    method only): materialize the whole candidate edge list on the host,
    then run one global ``hashprune_flat`` sort.  O(E) memory; kept as the
    oracle the streaming path is property-tested against (mergeability
    lemma, hashprune.py).

Stage 4 (``robust_prune.final_prune``) is device-resident too: a donated
[n, max_deg] output buffer pair is filled chunk-by-chunk via
``lax.dynamic_update_slice`` with a single device->host transfer at the
end, so with ``streaming=True`` the entire Stage 2-4 pipeline performs no
per-chunk host syncs.

All paths are bit-identical by HashPrune's mergeability (Theorem 3.1):
tests assert equal graphs on both metrics, for both the segmented and flat
folds, and streaming-vs-host final_prune.

The build is deterministic under a fixed seed (Appendix A.8): RBC is
deterministic given its RNG stream, and HashPrune is history-independent
(Theorem 3.1), so the produced graph is unique regardless of leaf processing
order — tests assert bit-identical rebuilds.

Alpha scale note: ``metrics`` returns *squared* L2.  RobustPrune's alpha is
specified on true distances in the paper (default 1.2); on squared
distances the equivalent multiplier is alpha**2, which ``PiPNNParams``
applies automatically for the l2 metric.  For MIPS (dissimilarity = -ip,
sign-indefinite) alpha scaling is not meaningful and we use alpha=1.0, the
standard DiskANN-MIPS practice.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch as _sketch
from repro.core.hashprune import (INVALID_ID, Reservoir, hashprune_flat,
                                  merge_flat_edges, merge_segmented_edges,
                                  reservoir_init)
from repro.core.leaf import (EdgeList, LeafParams, _leaf_robust_prune,
                             build_leaf_edges, emit_knn_edges_jax,
                             emit_robust_prune_edges_jax, iter_leaf_id_chunks,
                             leaf_knn_jax)
from repro.core.rbc import (RBCParams, leaves_to_padded, padded_coverage,
                            partition_padded, resolve_execution)
from repro.core.robust_prune import final_prune

_KNN_METHODS = ("bidirected", "directed", "inverted")
_STREAM_METHODS = _KNN_METHODS + ("robust_prune",)
# Actual per-entry allocation of candidate-edge arrays, used for the
# apples-to-apples memory stats: a fully materialized edge carries
# src + dst + hash (int32) + dist (f32); the host EdgeList has no hash
# field, and a reservoir slot stores id + hash + dist (its row is implied).
_EDGE_BYTES = 16
_EDGE_BYTES_NOHASH = 12
_SLOT_BYTES = 12


@dataclasses.dataclass(frozen=True)
class PiPNNParams:
    rbc: RBCParams = dataclasses.field(default_factory=RBCParams)
    leaf: LeafParams = dataclasses.field(default_factory=LeafParams)
    partitioner: str = "rbc"
    hash_bits: int = 12        # m hyperplanes (paper default 12, Fig. 13)
    l_max: int = 64            # reservoir capacity (paper: 64..192)
    final_prune: bool = True   # Sec. 4.3 (enabled by default in the paper)
    alpha: float = 1.2         # on TRUE distance; squared for l2 internally
    max_deg: int = 64          # final graph degree cap (paper's comparison deg)
    metric: str = "l2"
    seed: int = 0
    use_pallas_hash: bool | None = None  # None: auto (Pallas on TPU only)
    merge: str = "segmented"   # streaming reservoir fold: "segmented" folds
    #                            each chunk via a chunk-only sort + bounded
    #                            per-row merge; "flat" is the global-re-sort
    #                            oracle (hashprune_merge_flat).  Bit-identical.
    use_pallas_merge: bool | None = None  # None: auto (Pallas on TPU only)

    def effective_alpha(self) -> float:
        if self.metric == "l2":
            return float(self.alpha) ** 2
        if self.metric == "mips":
            return 1.0
        return float(self.alpha)

    def with_(self, **kw) -> "PiPNNParams":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass
class PiPNNIndex:
    graph: np.ndarray          # [n, max_deg] int32, -1 padded
    dists: np.ndarray          # [n, max_deg] f32, +inf padded
    start: int                 # entry point (medoid)
    params: PiPNNParams
    timings: dict[str, float]
    stats: dict[str, Any]

    @property
    def n(self) -> int:
        return self.graph.shape[0]

    def average_degree(self) -> float:
        return float((self.graph >= 0).sum() / self.graph.shape[0])


def _resolve_pallas(params: PiPNNParams) -> tuple[bool, bool, bool]:
    """(use_pallas_hash, use_pallas_merge, interpret) for the Pallas kernels."""
    on_tpu = jax.default_backend() == "tpu"
    use_hash = (on_tpu if params.use_pallas_hash is None
                else bool(params.use_pallas_hash))
    use_merge = (on_tpu if params.use_pallas_merge is None
                 else bool(params.use_pallas_merge))
    return use_hash, use_merge, not on_tpu


def _hash_edges(
    edges: EdgeList, sketches: np.ndarray, *,
    use_pallas: bool = False, interpret: bool = True,
) -> np.ndarray:
    """Residual hashes h_src(dst) for every candidate edge, via sketches."""
    h = _sketch.edge_hashes_from_ids(
        jnp.asarray(sketches), jnp.asarray(edges.src), jnp.asarray(edges.dst),
        use_pallas=use_pallas, interpret=interpret,
    )
    return np.asarray(h).astype(np.int32)


# ---------------------------------------------------------------------------
# Streaming Stage 2+3: fused leaf-kNN -> edge emit -> edge hash -> merge
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=32)
def _make_stream_step(
    knn_fn: Callable | None,
    k: int,
    metric: str,
    method: str,
    use_pallas: bool,
    interpret: bool,
    sub_chunk: int,
    alpha: float,
    max_deg: int,
    merge: str,
    use_pallas_merge: bool,
):
    """Compile the per-chunk fused step.

    step(res_ids, res_hashes, res_dists, xj, sketches, ids_chunk)
      -> (res_ids', res_hashes', res_dists', n_valid_edges)

    ``ids_chunk`` is [stream_chunk, c_max]; the leaf kernel (k-NN or, for
    the ``robust_prune`` method, the all-to-all leaf RobustPrune) runs over
    ``sub_chunk``-sized sub-batches (the VMEM-budget GEMM granularity)
    while edge emission, hashing and the reservoir fold happen once per
    chunk — so the merge cost is amortized over many leaves.  The fold is
    the segmented merge by default (chunk-only global sort + bounded
    per-row reservoir merge); ``merge="flat"`` selects the global-re-sort
    oracle.  The reservoir triplet is donated so the persistent state is
    updated in place across the whole stream.  Cached on (knn_fn identity,
    statics) so repeated builds reuse one executable.
    """
    knn = knn_fn or (lambda pts, valid: leaf_knn_jax(
        pts, valid, k=k, metric=metric))

    def step(res_ids, res_hashes, res_dists, xj, sketches, ids_chunk):
        n = res_ids.shape[0]
        s, c = ids_chunk.shape

        def block(ids_sub):  # [sub_chunk, c_max] -> flat edge arrays
            pts = xj[jnp.maximum(ids_sub, 0)]
            if method == "robust_prune":
                keep, d = _leaf_robust_prune(
                    pts, ids_sub >= 0, metric=metric, alpha=alpha,
                    max_deg=max_deg)
                return emit_robust_prune_edges_jax(ids_sub, keep, d)
            ni, nd = knn(pts, ids_sub >= 0)
            return emit_knn_edges_jax(ids_sub, ni, nd, direction=method)

        # lax.map (not an unrolled python loop): program size stays constant
        # however large the auto-sized stream chunk grows, and the [C, C]
        # working set stays at the sub_chunk VMEM granularity
        src, dst, dist = jax.lax.map(
            block, ids_chunk.reshape(s // sub_chunk, sub_chunk, c))
        src, dst, dist = src.reshape(-1), dst.reshape(-1), dist.reshape(-1)
        h = _sketch.edge_hashes_from_ids(
            sketches, src, dst, use_pallas=use_pallas, interpret=interpret)
        ok = src >= 0
        fold = merge_flat_edges if merge == "flat" else functools.partial(
            merge_segmented_edges, use_pallas=use_pallas_merge,
            interpret=interpret)
        merged = fold(
            res_ids, res_hashes, res_dists,
            jnp.where(ok, src, jnp.int32(n)),
            jnp.where(ok, dst, INVALID_ID),
            jnp.where(ok, h, 0),
            jnp.where(ok, dist, jnp.inf),
        )
        return (merged.ids, merged.hashes, merged.dists,
                jnp.sum(ok, dtype=jnp.int32))

    return jax.jit(step, donate_argnums=(0, 1, 2))


def stream_step_workspace_bytes(
    n: int, l_max: int, s: int, c: int, k: int, *,
    method: str = "bidirected", merge: str = "segmented",
) -> int:
    """Modeled XLA temp bytes of one ``_make_stream_step`` chunk step:
    the emitted src/dst/hash/dist candidate buffers (one [s * epl] set
    plus the padding-masked copies handed to the fold) and the fold's
    own workspace (``hashprune.*_workspace_bytes``).  ``s`` leaves of
    ``c`` padded entries emit ``epl`` edges each — the model's only
    inputs are the CHUNK shape and the reservoir shape, never the total
    emitted edge count E: that is the paper's bounded-memory contract,
    and the memory auditor (``repro.analysis.memory_audit``) validates
    this model against the compiled byte ledger at every lattice point
    (PIPM004) and prices the BigANN-1B per-shard envelope with it
    (PIPM003)."""
    from repro.core.hashprune import (merge_flat_workspace_bytes,
                                      merge_segmented_workspace_bytes)

    if method == "robust_prune":
        epl = c * c
    else:
        epl = (2 if method == "bidirected" else 1) * c * k
    e = s * epl
    emit = 2 * e * _EDGE_BYTES
    fold = (merge_flat_workspace_bytes if merge == "flat"
            else merge_segmented_workspace_bytes)(n, l_max, e)
    return emit + fold


def _stream_edges_per_leaf(leaf: LeafParams, c_max: int) -> int:
    """Candidate-edge buffer entries one padded leaf contributes to the
    fused step (the emitters' fixed output shapes)."""
    if leaf.method == "robust_prune":
        return c_max * c_max      # emit_robust_prune_edges_jax: [C, C] mask
    fan = 2 if leaf.method == "bidirected" else 1
    return fan * c_max * leaf.k   # emit_knn_edges_jax


def _stream_chunk_leaves(
    leaf: LeafParams, n: int, l_max: int, nleaves: int, c_max: int
) -> int:
    """Leaves per streaming merge step (a multiple of ``leaf_chunk``).

    Auto mode sizes the chunk so one chunk's padded candidate-edge buffer
    is ~ the reservoir ([n, l_max] entries): the merge's re-sort work
    then amortizes to O(E / (n * l_max)) passes total while peak
    intermediate memory stays O(n * l_max) — the paper's "no extra
    intermediate memory" contract — instead of O(E).
    """
    lc = max(1, leaf.leaf_chunk)
    if leaf.stream_chunk is not None:
        s = max(lc, int(leaf.stream_chunk))
    else:
        edges_per_leaf = max(1, _stream_edges_per_leaf(leaf, c_max))
        s = max(lc, (n * l_max) // edges_per_leaf)
    s = min(s, max(lc, nleaves))          # never over-allocate past the data
    return -(-s // lc) * lc               # round up to a leaf_chunk multiple


def _build_reservoir_streaming(
    x: np.ndarray,
    leaves_padded: np.ndarray,
    sketches: jax.Array,
    params: PiPNNParams,
    knn_fn: Callable | None,
) -> tuple[Reservoir, int, dict[str, int]]:
    """Stream leaf chunks through the fused step; returns
    (reservoir, n_candidate_edges, memory stats)."""
    leaf = params.leaf
    use_pallas, use_pallas_merge, interpret = _resolve_pallas(params)
    n = x.shape[0]
    nleaves, c_max = leaves_padded.shape
    chunk = _stream_chunk_leaves(leaf, n, params.l_max, nleaves, c_max)
    step = _make_stream_step(
        knn_fn if leaf.method in _KNN_METHODS else None,
        leaf.k, params.metric, leaf.method, use_pallas, interpret,
        max(1, leaf.leaf_chunk), leaf.alpha, leaf.max_deg, params.merge,
        use_pallas_merge)
    xj = jnp.asarray(x)
    res = reservoir_init(n, params.l_max)
    ids_r, hs_r, ds_r = res.ids, res.hashes, res.dists
    counts = []
    for ids in iter_leaf_id_chunks(leaves_padded, chunk):
        ids_r, hs_r, ds_r, cnt = step(ids_r, hs_r, ds_r, xj, sketches,
                                      jnp.asarray(ids))
        counts.append(cnt)  # device scalar: no per-chunk host sync
    # actual allocated candidate-edge bytes: the fused step materializes
    # src/dst/hash/dist for every (padded) chunk entry; `chunk` is already
    # capped at the padded leaf count, so this is the real buffer size
    chunk_entries = chunk * _stream_edges_per_leaf(leaf, c_max)
    if params.merge == "flat":
        # the fold re-expresses the reservoir as n*l_max padding-extended
        # edges and sorts them together with the chunk
        merge_ws = (n * params.l_max + chunk_entries) * _EDGE_BYTES
    else:
        # chunk-only global sort + [n, 2*l_max] per-row merge
        merge_ws = chunk_entries * _EDGE_BYTES + 2 * n * params.l_max * _SLOT_BYTES
    mem = {
        "stream_chunk_leaves": chunk,
        "peak_edge_bytes": chunk_entries * _EDGE_BYTES,
        "edge_bytes_build_leaves": chunk_entries * _EDGE_BYTES,
        "merge_workspace_bytes": merge_ws,
    }
    n_edges = int(np.sum([np.asarray(c) for c in counts])) if counts else 0
    return Reservoir(ids=ids_r, hashes=hs_r, dists=ds_r), n_edges, mem


def build(
    x: np.ndarray,
    params: PiPNNParams | None = None,
    *,
    leaves: list[np.ndarray] | None = None,
    knn_fn: Callable | None = None,
    streaming: bool = True,
) -> PiPNNIndex:
    """Build a PiPNN index over ``x`` [n, d] float32.

    ``streaming=True`` (default) runs Stage 2+3 as the device-resident
    chunk pipeline (bounded memory, no host round-trips); ``False`` forces
    the O(E) flat oracle path.  Both produce bit-identical graphs.

    ``knn_fn``, if given, should be a STABLE callable (e.g. the cached
    ``kernels.ops.make_knn_fn``): the streaming fused step is compiled per
    knn_fn identity, so a fresh lambda per call recompiles every build.
    Under ``streaming=True`` it must also be jit-traceable (pure JAX —
    it runs inside the fused step); pass ``streaming=False`` for a
    host-side/numpy knn_fn.
    """
    from repro.core.beam_search import medoid  # local import, avoids cycle

    params = params or PiPNNParams()
    x = np.ascontiguousarray(x, dtype=np.float32)
    n, d = x.shape
    timings: dict[str, float] = {}
    stats: dict[str, Any] = {}

    # --- Stage 1: overlapping partitioning (Sec. 4.1) ---------------------
    # partition_padded produces the dense [L, c_max] device-facing matrix
    # directly; with rbc.execution="static" the whole stage is ONE jitted
    # two-level carve (ball_carve_device) with zero host recursion, with
    # "device" the host keeps only the worklist while the per-subproblem
    # math runs jitted, and with "host" it is the original numpy oracle.
    t0 = time.perf_counter()
    if leaves is None:
        rbc = dataclasses.replace(params.rbc, metric=params.metric, seed=params.seed)
        padded = partition_padded(x, rbc, params.partitioner)
        stats["partition_execution"] = (
            resolve_execution(rbc) if params.partitioner == "rbc" else "host")
    else:
        padded = leaves_to_padded(leaves, params.rbc.c_max)
        stats["partition_execution"] = "caller"
    timings["partition"] = time.perf_counter() - t0
    sizes = (padded >= 0).sum(axis=1)
    stats["n_leaves"] = int(padded.shape[0])
    stats["leaf_size_mean"] = float(sizes.mean()) if len(sizes) else 0.0
    stats["point_repeat"] = float(sizes.sum() / max(n, 1))
    stats["pad_ratio"] = float(padded.size / max(sizes.sum(), 1))
    stats["partition_uncovered"] = n - padded_coverage(padded, n)

    import jax.random as jrandom

    key = jrandom.PRNGKey(params.seed)
    hyperplanes = _sketch.make_hyperplanes(key, params.hash_bits, d)
    leaf = dataclasses.replace(params.leaf, metric=params.metric)
    lparams = dataclasses.replace(params, leaf=leaf)

    stream_ok = streaming and leaf.method in _STREAM_METHODS
    stats["streaming"] = stream_ok

    if stream_ok:
        # --- Stage 2+3 fused: streaming device-resident pipeline ----------
        # one fused loop: the (tiny) sketch GEMM is charged to the
        # hashprune phase, everything else to build_leaves
        t0 = time.perf_counter()
        sketches = jax.block_until_ready(
            _sketch.sketch_jit(jnp.asarray(x), hyperplanes))
        timings["hashprune"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        res, n_edges, mem = _build_reservoir_streaming(
            x, padded, sketches, lparams, knn_fn)
        jax.block_until_ready(res.ids)
        timings["build_leaves"] = time.perf_counter() - t0
        stats["n_candidate_edges"] = n_edges
        stats.update(mem)
    else:
        # --- Stage 2: leaf building -> candidate edges (Sec. 4.2) ---------
        t0 = time.perf_counter()
        edges = build_leaf_edges(x, padded, leaf, knn_fn=knn_fn)
        timings["build_leaves"] = time.perf_counter() - t0
        stats["n_candidate_edges"] = int(edges.valid().sum())
        # the host EdgeList carries no hash field (12 B/edge); Stage 3 then
        # materializes src/dst/hash/dist device arrays for ALL edges at once
        # (16 B/edge) — that is the actual peak, reported apples-to-apples
        # with the streaming path's chunk buffers
        stats["edge_bytes_build_leaves"] = int(edges.src.size) * _EDGE_BYTES_NOHASH
        stats["merge_workspace_bytes"] = int(edges.src.size) * _EDGE_BYTES
        stats["peak_edge_bytes"] = int(edges.src.size) * _EDGE_BYTES

        # --- Stage 3: HashPrune (Sec. 3) ----------------------------------
        t0 = time.perf_counter()
        use_pallas, _, interpret = _resolve_pallas(params)
        sketches = np.asarray(_sketch.sketch_jit(jnp.asarray(x), hyperplanes))
        hashes = _hash_edges(edges, sketches, use_pallas=use_pallas,
                             interpret=interpret)
        src = np.where(edges.src >= 0, edges.src, n).astype(np.int32)
        dst = np.where(edges.src >= 0, edges.dst, INVALID_ID).astype(np.int32)
        dist = np.where(edges.src >= 0, edges.dist, np.inf).astype(np.float32)
        res = hashprune_flat(
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
            jnp.asarray(dist), n_points=n, l_max=params.l_max,
        )
        timings["hashprune"] = time.perf_counter() - t0

    # --- Stage 4: final prune (Sec. 4.3) -----------------------------------
    t0 = time.perf_counter()
    if params.final_prune:
        graph, dists = final_prune(
            x, res, alpha=params.effective_alpha(), max_deg=params.max_deg,
            metric=params.metric,
        )
    else:
        ids = np.asarray(res.ids)[:, : params.max_deg]
        ds = np.asarray(res.dists)[:, : params.max_deg]
        if ids.shape[1] < params.max_deg:
            pad = params.max_deg - ids.shape[1]
            ids = np.pad(ids, ((0, 0), (0, pad)), constant_values=-1)
            ds = np.pad(ds, ((0, 0), (0, pad)), constant_values=np.inf)
        graph, dists = ids, ds
    timings["final_prune"] = time.perf_counter() - t0
    timings["total"] = sum(timings.values())

    return PiPNNIndex(
        graph=graph,
        dists=dists,
        start=medoid(x, seed=params.seed),
        params=params,
        timings=timings,
        stats=stats,
    )


def serving_index(index: PiPNNIndex, x: np.ndarray, *, dtype=None,
                  mesh=None):
    """The packed device-resident ``ServingIndex`` for ``(index, x)``,
    cached on the index: the first call uploads graph/points/norms (and
    the int8 scales when ``dtype="int8"``) to the device, every later
    call with the same dataset and graph objects reuses the same device
    buffers — zero host->device transfers besides the queries.  With
    ``mesh`` (a single-axis ``jax.sharding.Mesh``) the packing is the
    sharded ``distributed.serving.ShardedServingIndex`` — one
    partition-aligned shard per device; the cache keys on the mesh too,
    so single-device and sharded packings never alias.

    The cache holds strong references to ``x`` AND ``index.graph`` and
    keys on object identity (``is``), so a recycled address of a freed
    array can never alias into a stale hit — and replacing ``index.graph``
    (e.g. re-running a build pass or pruning into a fresh array) after
    the first search invalidates the cache instead of silently serving
    the stale device copy of the old graph.  (In-place element writes to
    the same array object are invisible to any identity key — copy-on-
    write the graph instead.)"""
    from repro.core.serving import ServingIndex

    key = (index.start, index.params.metric,
           None if dtype is None else str(dtype),
           None if mesh is None else id(mesh))
    cached = getattr(index, "_serving", None)
    if (cached is not None and getattr(index, "_serving_x", None) is x
            and getattr(index, "_serving_graph", None) is index.graph
            and getattr(index, "_serving_key", None) == key):
        return cached
    sv = ServingIndex.from_index(index, x, dtype=dtype, mesh=mesh)
    index._serving = sv
    index._serving_x = x
    index._serving_graph = index.graph
    index._serving_key = key
    return sv


def search(
    index: PiPNNIndex,
    x: np.ndarray,
    queries: np.ndarray,
    *,
    k: int = 10,
    beam: int = 32,
    batch: bool = True,
    expansions: int | None = None,
    iters: int | None = None,
    dtype=None,
    mesh=None,
    with_stats: bool = False,
) -> np.ndarray:
    """Query the index; returns [Q, k] neighbor ids, -1-padded when fewer
    than ``k`` neighbors are found (e.g. ``beam < k``).

    ``batch=True`` (the serving path) routes through a cached
    ``ServingIndex``: graph/points/norms live on the device after the
    first call, and queries run the multi-expansion beam search —
    ``expansions`` (default 4) best unvisited entries expanded per step,
    one fused ``[Q, E*R]`` distance block (Pallas gather-distance kernel
    on TPU), early exit on per-query convergence with ``iters`` (default
    ``beam_search.default_iters(beam)``) as the backstop cap.  ``dtype``
    downcasts the serving points copy (e.g. ``jnp.bfloat16``) or, with
    ``dtype="int8"``, serves the scalar-quantized packing (int8 points +
    per-point f32 scales, ~1/4 the f32 points footprint, int8 MXU
    distance kernel).  ``mesh`` (a single-axis ``jax.sharding.Mesh``)
    serves through the sharded packing instead: one partition-aligned
    shard per device under ``shard_map``, per-query results merged across
    shards (``distributed.serving.ShardedServingIndex``).
    ``with_stats=True`` returns ``(ids, stats)`` with per-query
    hop/distance-comp telemetry plus the resolved kernel path.

    ``batch=False`` is the pointer-chasing numpy reference
    (``beam_search_np``) — the recall/parity ORACLE, not a serving path:
    it walks one query at a time on the host and re-indexes ``x`` row by
    row per hop, so its cost is dominated by per-hop latency by design
    (that latency-bound pattern is what the paper eliminates from the
    build, and what the batched path amortizes away at query time).
    """
    from repro.core import beam_search as bs
    from repro.core.validation import validate_queries, validate_search_params

    validate_search_params(k=k, beam=beam)
    if batch:
        sv = serving_index(index, x, dtype=dtype, mesh=mesh)
        return sv.search(queries, k=k, beam=beam,
                         expansions=4 if expansions is None else expansions,
                         iters=iters, with_stats=with_stats)
    if (with_stats or iters is not None or dtype is not None
            or expansions is not None or mesh is not None):
        raise ValueError(
            "with_stats / iters / dtype / expansions / mesh are serving-"
            "path options; the batch=False np oracle expands one vertex "
            "per hop and does not support them")
    queries = validate_queries(queries, dim=x.shape[1])
    out = np.empty((queries.shape[0], k), dtype=np.int64)
    for i, q in enumerate(queries):
        ids, _, _ = bs.beam_search_np(
            index.graph, x, q, start=index.start, beam=beam,
            metric=index.params.metric,
        )
        out[i] = ids[:k] if len(ids) >= k else np.pad(ids, (0, k - len(ids)), constant_values=-1)
    return out
