"""Leaf building (Sec. 4.2, Appendix A.3): pick candidate edges inside each
leaf of the overlapping partition.

TPU-native shape discipline: leaves are padded to ``c_max`` and stacked into
a regular batch ``[L, c_max]`` so that the all-pairs distance computation for
*every* leaf is one batched GEMM (`metrics.pairwise` under vmap, or the
fused Pallas FlashKNN kernel in ``repro/kernels/leaf_knn.py``).  Padding
entries carry +inf distance and can never enter a top-k.

Methods (A.3 ablation space):
  * ``bidirected`` k-NN  — the paper's default (k=2): edges to AND from each
    point's k nearest co-leaf points;
  * ``directed`` k-NN    — edges to the k nearest only;
  * ``inverted`` k-NN    — edges from the k nearest only;
  * ``mst``              — degree-capped (<=3) MST over the l-NN sparsified
    leaf graph (HCNNG's leaf method);
  * ``robust_prune``     — all-to-all RobustPrune per leaf point.

All methods emit a flat candidate edge list (src, dst, dist) ready for
``hashprune_flat``.  The k-NN methods and ``robust_prune`` additionally
have device-side emitters (``emit_knn_edges_jax`` /
``emit_robust_prune_edges_jax``) that the default streaming build fuses
with the HashPrune fold so candidate edges never land on the host; the
host-side ``build_leaf_edges``/``EdgeList`` path remains the oracle for
those methods, and the only path for ``mst`` (host-side Kruskal).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics
from repro.core.robust_prune import robust_prune_mask

LeafMethod = Literal["bidirected", "directed", "inverted", "mst", "robust_prune"]


@dataclasses.dataclass(frozen=True)
class LeafParams:
    method: LeafMethod = "bidirected"
    k: int = 2                 # leaf k-NN parameter (paper default 2, Fig. 11)
    metric: str = "l2"
    alpha: float = 1.2         # robust_prune leaf method only
    max_deg: int = 64          # robust_prune leaf method only
    mst_degree_cap: int = 3
    mst_sparsify: int = 10     # l-NN sparsification before Kruskal (A.3.1)
    leaf_chunk: int = 8        # leaves per batched GEMM launch (VMEM budget)
    stream_chunk: int | None = None  # leaves per streaming merge step; None =
    #                            auto-size so one chunk's candidate edges are
    #                            ~ the [n, l_max] reservoir (merge cost then
    #                            amortizes to O(E / (n*l_max)) global sorts
    #                            while peak memory stays reservoir-bounded)


@dataclasses.dataclass
class EdgeList:
    """Flat candidate edges. Padding rows have src == INVALID (-1)."""

    src: np.ndarray   # int32 [E]
    dst: np.ndarray   # int32 [E]
    dist: np.ndarray  # float32 [E]

    def valid(self) -> np.ndarray:
        return self.src >= 0

    def concat(self, other: "EdgeList") -> "EdgeList":
        return EdgeList(
            src=np.concatenate([self.src, other.src]),
            dst=np.concatenate([self.dst, other.dst]),
            dist=np.concatenate([self.dist, other.dist]),
        )


def iter_leaf_id_chunks(leaves_padded: np.ndarray, chunk: int):
    """Yield fixed-shape [chunk, c_max] int32 blocks of ``leaves_padded``.

    The last block is -1-padded to a full chunk so every block has the same
    static shape (one jit compilation for the whole stream).
    """
    nleaves, c = leaves_padded.shape
    chunk = max(1, chunk)
    for s in range(0, nleaves, chunk):
        ids = leaves_padded[s : s + chunk]
        if ids.shape[0] < chunk:
            pad = np.full((chunk - ids.shape[0], c), -1, dtype=np.int32)
            ids = np.concatenate([ids, pad], axis=0)
        yield ids


# ---------------------------------------------------------------------------
# Batched leaf distance matrices + k-NN picking (pure-JAX path)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "metric"))
def leaf_knn_jax(
    pts: jax.Array,     # [B, C, d] gathered leaf points (pad rows arbitrary)
    valid: jax.Array,   # [B, C] bool
    *,
    k: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Per-leaf k nearest co-leaf neighbors.

    Returns (nbr_idx [B, C, k] in-leaf indices, nbr_dist [B, C, k]); invalid
    slots yield (-1, +inf).
    """
    d = jax.vmap(lambda a: _metrics.pairwise(a, a, metric))(pts)  # [B, C, C]
    c = pts.shape[1]
    eye = jnp.eye(c, dtype=bool)
    mask = valid[:, None, :] & valid[:, :, None] & ~eye[None]
    d = jnp.where(mask, d, jnp.inf)
    # top-k smallest: negate for lax.top_k
    neg, idx = jax.lax.top_k(-d, k)
    nd = -neg
    ok = jnp.isfinite(nd)
    return jnp.where(ok, idx, -1), jnp.where(ok, nd, jnp.inf)


def _emit_knn_edges(
    leaf_ids: np.ndarray,   # [B, C] global ids (-1 pad)
    nbr_idx: np.ndarray,    # [B, C, k] in-leaf indices (-1 pad)
    nbr_dist: np.ndarray,   # [B, C, k]
    direction: str,
) -> EdgeList:
    b, c, k = nbr_idx.shape
    rows = np.broadcast_to(leaf_ids[:, :, None], (b, c, k))
    safe = np.maximum(nbr_idx, 0)
    cols = np.take_along_axis(
        np.broadcast_to(leaf_ids[:, None, :], (b, c, c)), safe, axis=2
    )
    ok = (nbr_idx >= 0) & (rows >= 0) & (rows != cols)  # no self loops
    # (rows == cols can only arise from duplicate ids within a leaf; RBC
    # dedupes on merge, but guard against custom partitioners)
    src = np.where(ok, rows, -1).reshape(-1).astype(np.int32)
    dst = np.where(ok, cols, -1).reshape(-1).astype(np.int32)
    dist = np.where(ok, nbr_dist, np.inf).reshape(-1).astype(np.float32)
    fwd = EdgeList(src, dst, dist)
    if direction == "directed":
        return fwd
    rev = EdgeList(dst.copy(), src.copy(), dist.copy())
    if direction == "inverted":
        return rev
    return fwd.concat(rev)  # bidirected


def emit_knn_edges_jax(
    leaf_ids: jax.Array,   # [B, C] global ids (-1 pad)
    nbr_idx: jax.Array,    # [B, C, k] in-leaf indices (-1 pad)
    nbr_dist: jax.Array,   # [B, C, k]
    *,
    direction: str,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Device-side ``_emit_knn_edges``: flat (src, dst, dist) arrays.

    Fixed output shape [B*C*k] (or [2*B*C*k] bidirected); invalid slots are
    (-1, -1, +inf).  Traceable — the streaming build fuses this into the
    per-chunk jitted step so candidate edges never bounce through the host.
    """
    b, c, k = nbr_idx.shape
    rows = jnp.broadcast_to(leaf_ids[:, :, None], (b, c, k))
    safe = jnp.maximum(nbr_idx, 0)
    cols = jnp.take_along_axis(
        jnp.broadcast_to(leaf_ids[:, None, :], (b, c, c)), safe, axis=2
    )
    ok = (nbr_idx >= 0) & (rows >= 0) & (rows != cols)  # no self loops
    src = jnp.where(ok, rows, -1).reshape(-1).astype(jnp.int32)
    dst = jnp.where(ok, cols, -1).reshape(-1).astype(jnp.int32)
    dist = jnp.where(ok, nbr_dist, jnp.inf).reshape(-1).astype(jnp.float32)
    if direction == "directed":
        return src, dst, dist
    if direction == "inverted":
        return dst, src, dist
    return (jnp.concatenate([src, dst]), jnp.concatenate([dst, src]),
            jnp.concatenate([dist, dist]))  # bidirected


def emit_robust_prune_edges_jax(
    leaf_ids: jax.Array,   # [B, C] global ids (-1 pad)
    keep: jax.Array,       # [B, C, C] bool keep mask from _leaf_robust_prune
    d: jax.Array,          # [B, C, C] masked leaf distance matrix
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Device-side edge emitter for the ``robust_prune`` leaf method.

    Fixed output shape [B*C*C]; invalid slots are (-1, -1, +inf).  The
    ``robust_prune`` analogue of ``emit_knn_edges_jax``: traceable, so the
    streaming build fuses leaf RobustPrune into the per-chunk jitted step
    and its kept edges never bounce through the host.  Emits the same edge
    set as the host path in ``build_leaf_edges`` (which compacts via
    ``np.nonzero``), just padded instead of compacted — HashPrune's
    order-freedom makes the two interchangeable downstream.
    """
    b, c, _ = keep.shape
    rows = jnp.broadcast_to(leaf_ids[:, :, None], (b, c, c))
    cols = jnp.broadcast_to(leaf_ids[:, None, :], (b, c, c))
    ok = keep & (rows >= 0) & (cols >= 0)
    src = jnp.where(ok, rows, -1).reshape(-1).astype(jnp.int32)
    dst = jnp.where(ok, cols, -1).reshape(-1).astype(jnp.int32)
    dist = jnp.where(ok, d, jnp.inf).reshape(-1).astype(jnp.float32)
    return src, dst, dist


def _mst_edges(leaf_ids: np.ndarray, d: np.ndarray, valid: np.ndarray,
               cap: int, sparsify: int) -> EdgeList:
    """Degree-capped Kruskal per leaf over the l-NN sparsified graph."""
    srcs, dsts, dists = [], [], []
    b = leaf_ids.shape[0]
    for li in range(b):
        v = valid[li]
        n = int(v.sum())
        if n < 2:
            continue
        dm = d[li][:n, :n].copy()
        np.fill_diagonal(dm, np.inf)
        l = min(sparsify, n - 1)
        nbr = np.argpartition(dm, l - 1, axis=1)[:, :l]
        rows = np.repeat(np.arange(n), l)
        cols = nbr.reshape(-1)
        w = dm[rows, cols]
        order = np.argsort(w, kind="stable")
        parent = np.arange(n)

        def find(a):
            while parent[a] != a:
                parent[a] = parent[parent[a]]
                a = parent[a]
            return a

        deg = np.zeros(n, dtype=np.int32)
        gids = leaf_ids[li][:n]
        for e in order:
            a, bb = rows[e], cols[e]
            if deg[a] >= cap or deg[bb] >= cap:
                continue
            ra, rb = find(a), find(bb)
            if ra == rb:
                continue
            parent[ra] = rb
            deg[a] += 1
            deg[bb] += 1
            srcs += [gids[a], gids[bb]]
            dsts += [gids[bb], gids[a]]
            dists += [w[e], w[e]]
    return EdgeList(
        np.asarray(srcs, dtype=np.int32),
        np.asarray(dsts, dtype=np.int32),
        np.asarray(dists, dtype=np.float32),
    )


@functools.partial(jax.jit, static_argnames=("metric", "alpha", "max_deg"))
def _leaf_robust_prune(pts, valid, *, metric, alpha, max_deg):
    d = jax.vmap(lambda a: _metrics.pairwise(a, a, metric))(pts)
    c = pts.shape[1]
    eye = jnp.eye(c, dtype=bool)
    mask = valid[:, None, :] & valid[:, :, None] & ~eye[None]
    d = jnp.where(mask, d, jnp.inf)
    b = pts.shape[0]
    # flatten leaves into the batch dim: each leaf row is one "point"
    d_pc = d.reshape(b * c, c)
    d_cc = jnp.broadcast_to(d[:, None, :, :], (b, c, c, c)).reshape(b * c, c, c)
    ids = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None], (b * c, c))
    keep = robust_prune_mask(d_pc, d_cc, ids, alpha=alpha, max_deg=max_deg)
    return keep.reshape(b, c, c), d


def build_leaf_edges(
    x: np.ndarray,
    leaves_padded: np.ndarray,  # [L, c_max] int32, -1 pad
    params: LeafParams,
    knn_fn=None,
) -> EdgeList:
    """Run the configured leaf method over all leaves; return candidate edges.

    ``knn_fn`` optionally overrides the (pts, valid, k, metric) -> (idx, dist)
    inner kernel — the Pallas FlashKNN kernel plugs in here.
    """
    xj = jnp.asarray(x)
    knn = knn_fn or (lambda pts, valid: leaf_knn_jax(
        pts, valid, k=params.k, metric=params.metric))
    pieces: list[EdgeList] = []
    for ids in iter_leaf_id_chunks(leaves_padded, params.leaf_chunk):
        valid = ids >= 0
        pts = xj[jnp.maximum(jnp.asarray(ids), 0)]
        vj = jnp.asarray(valid)
        if params.method in ("bidirected", "directed", "inverted"):
            ni, nd = knn(pts, vj)
            pieces.append(
                _emit_knn_edges(ids, np.asarray(ni), np.asarray(nd), params.method)
            )
        elif params.method == "mst":
            d = jax.vmap(lambda a: _metrics.pairwise(a, a, params.metric))(pts)
            pieces.append(
                _mst_edges(ids, np.asarray(d), valid, params.mst_degree_cap,
                           params.mst_sparsify)
            )
        elif params.method == "robust_prune":
            keep, d = _leaf_robust_prune(
                pts, vj, metric=params.metric, alpha=params.alpha,
                max_deg=params.max_deg,
            )
            keep = np.asarray(keep)
            d = np.asarray(d)
            li, ri, ci = np.nonzero(keep)
            src = ids[li, ri]
            dst = ids[li, ci]
            ok = (src >= 0) & (dst >= 0)
            pieces.append(EdgeList(
                src[ok].astype(np.int32), dst[ok].astype(np.int32),
                d[li, ri, ci][ok].astype(np.float32),
            ))
        else:
            raise ValueError(f"unknown leaf method {params.method!r}")
    # One concatenate per field: the previous per-piece ``EdgeList.concat``
    # loop re-copied the accumulated prefix every iteration (O(E^2) bytes).
    if not pieces:
        return EdgeList(np.empty(0, np.int32), np.empty(0, np.int32),
                        np.empty(0, np.float32))
    return EdgeList(
        src=np.concatenate([p.src for p in pieces]),
        dst=np.concatenate([p.dst for p in pieces]),
        dist=np.concatenate([p.dist for p in pieces]),
    )
