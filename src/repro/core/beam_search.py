"""Beam search over a navigation graph (Algorithm 1) — the query path.

Three implementations:

  * ``beam_search_np``  — faithful pointer-chasing reference (numpy).  This is
    the latency-bound pattern whose *elimination from construction* is the
    paper's whole point; we keep it as the recall/parity oracle.
  * ``beam_search_single`` — the original fixed-shape batched port: one
    expansion per iteration per query, two full ``lax.sort``s of length
    ``beam + R`` per step, fixed ``iters`` budget.  Retained as the perf
    baseline (``bench_qps_recall`` measures the multi-expansion speedup
    against it) and as a second agreement oracle.
  * ``beam_search_batch`` — the serving engine: **multi-expansion** beam
    search.  Each step selects the ``E`` best unvisited beam entries at
    once, gathers their ``E*R`` neighbors, computes the whole ``[Q, E*R]``
    distance block in one shot (optionally via the fused Pallas
    gather-distance kernel), then folds the new candidates into the
    always-sorted beam with SORT-FREE rank-based bounded merges (one per
    expanded row) — the ``hashprune_merge_segmented`` Pallas-row-merge
    trick: neither the beam nor the candidates ever enter a ``lax.sort``
    (profiling showed XLA CPU's variadic sort dominating the old engine).
    Visited state is carried as per-slot flags that survive the merge.
    The loop is a ``lax.while_loop`` with per-query convergence ("every
    live beam entry visited") and the ``iters`` budget as backstop; it
    returns per-query hop and distance-computation telemetry.

Graphs are padded adjacency matrices [n, R] int32 with -1 padding (plus an
optional medoid entry point, the standard Vamana choice).  For repeated
queries against one index use ``core/serving.ServingIndex`` (what
``pipnn.search`` does), which prepacks graph/points/norms on device once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics
from repro.kernels import ref as _ref


def default_iters(beam: int) -> int:
    """Default backstop iteration cap for the serving engines: ``beam + 4``
    (the legacy fixed budget).  Single-sourced here — the engine's
    ``iters=None`` resolution and ``ServingIndex.search`` telemetry both
    use it, so the reported ``iters_cap`` can never drift from what the
    loop actually ran with."""
    return beam + 4


def medoid(x: np.ndarray, sample: int = 4096, seed: int = 0) -> int:
    """Approximate medoid: the sample point nearest the dataset mean."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    mean = x.mean(axis=0, keepdims=True)
    d = np.sum((x[idx] - mean) ** 2, axis=1)
    return int(idx[np.argmin(d)])


def _dist_np(q: np.ndarray, pts: np.ndarray, metric: str) -> np.ndarray:
    if metric == "mips":
        return -(pts @ q)
    if metric == "cosine":
        return 1.0 - (pts @ q) / np.maximum(
            np.linalg.norm(pts, axis=1) * np.linalg.norm(q), 1e-30
        )
    diff = pts - q[None, :]
    return np.sum(diff * diff, axis=1)


def beam_search_np(
    graph: np.ndarray,
    x: np.ndarray,
    q: np.ndarray,
    *,
    start: int,
    beam: int,
    metric: str = "l2",
    max_visits: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Algorithm 1.  Returns (beam ids sorted by dist, dists, n_dist_comps)."""
    import heapq

    d0 = float(_dist_np(q, x[start : start + 1], metric)[0])
    frontier = [(d0, start)]           # min-heap of unvisited beam entries
    in_beam = {start: d0}
    visited: set[int] = set()
    comps = 1
    limit = max_visits or 10 * beam
    while frontier and len(visited) < limit:
        d, p = heapq.heappop(frontier)
        if p in visited or p not in in_beam:
            continue  # stale entry (visited, or truncated out of the beam)
        visited.add(p)
        nbrs = graph[p]
        nbrs = nbrs[nbrs >= 0]
        new = [v for v in nbrs if v not in in_beam and v not in visited]
        if new:
            nd = _dist_np(q, x[new], metric)
            comps += len(new)
            for v, dv in zip(new, nd):
                in_beam[v] = float(dv)
                heapq.heappush(frontier, (float(dv), v))
        if len(in_beam) > beam:
            # keep the L closest seen (visited or not); frontier entries for
            # dropped ids are skipped lazily above
            items = sorted(in_beam.items(), key=lambda kv: (kv[1], kv[0]))[:beam]
            in_beam = dict(items)
    items = sorted(in_beam.items(), key=lambda kv: (kv[1], kv[0]))
    ids = np.asarray([v for v, _ in items], dtype=np.int64)
    ds = np.asarray([dv for _, dv in items], dtype=np.float32)
    return ids, ds, comps


@functools.partial(jax.jit, static_argnames=("beam", "iters", "metric"))
def beam_search_single(
    graph: jax.Array,   # [n, R] int32, -1 pad
    x: jax.Array,       # [n, d]
    queries: jax.Array,  # [Q, d]
    *,
    start: int,
    beam: int,
    iters: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Single-expansion fixed-iteration beam search (the legacy engine).

    Expands ONE vertex per step per query and pays two full sorts of
    length ``beam + R`` per step; no convergence check.  Kept as the
    baseline the multi-expansion engine is benchmarked against.
    Returns (ids, dists) [Q, beam].
    """
    n, r = graph.shape
    inf = jnp.float32(jnp.inf)

    def one(q):
        d0 = _metrics.point_to_points(q, x[start][None, :], metric)[0]
        ids = jnp.full((beam,), -1, dtype=jnp.int32).at[0].set(start)
        ds = jnp.full((beam,), inf).at[0].set(d0)
        vis = jnp.zeros((beam,), dtype=bool)

        def step(state, _):
            ids, ds, vis = state
            # best unvisited beam slot
            cand = jnp.where(vis | (ids < 0), inf, ds)
            j = jnp.argmin(cand)
            done = ~jnp.isfinite(cand[j])
            p = jnp.maximum(ids[j], 0)
            vis = vis.at[j].set(True)
            nbr = graph[p]                                  # [R]
            ok = (nbr >= 0) & ~done
            nv = x[jnp.maximum(nbr, 0)]                     # [R, d]
            nd = _metrics.pairwise(q[None, :], nv, metric)[0]
            nd = jnp.where(ok, nd, inf)
            # merge: concat beam + neighbors, dedupe by id keeping min dist
            all_ids = jnp.concatenate([ids, jnp.where(ok, nbr, -1)])
            all_ds = jnp.concatenate([ds, nd])
            all_vis = jnp.concatenate([vis, jnp.zeros((r,), dtype=bool)])
            # dedupe: sort by (id, dist); duplicates keep first (min dist,
            # and visited flag OR'd via segment trick: visited dupes sort
            # with their dist — the visited copy in the beam has the same
            # dist so flags propagate through the (id, dist, ~vis) sort)
            o_id, o_ds, o_nvis = jax.lax.sort(
                (all_ids, all_ds, (~all_vis).astype(jnp.int32)),
                dimension=0, num_keys=3,
            )
            dup = (o_id == jnp.roll(o_id, 1))
            dup = dup.at[0].set(False)
            o_ds = jnp.where(dup | (o_id < 0), inf, o_ds)
            # truncate to best `beam` by dist
            o_ds, o_id, o_nvis = jax.lax.sort(
                (o_ds, o_id, o_nvis), dimension=0, num_keys=2
            )
            ids = o_id[:beam]
            ds = o_ds[:beam]
            vis = o_nvis[:beam] == 0
            ids = jnp.where(jnp.isfinite(ds), ids, -1)
            return (ids, ds, vis), None

        (ids, ds, vis), _ = jax.lax.scan(step, (ids, ds, vis), None, length=iters)
        return ids, ds

    return jax.vmap(one)(queries)


# ---------------------------------------------------------------------------
# Multi-expansion serving engine
# ---------------------------------------------------------------------------

KERNEL_PATHS = ("vmem", "hbm", "xla")


def resolve_kernel_path(
    x,
    scales=None,
    *,
    kernel_path: str | None = None,
    use_pallas: bool | None = None,
    vmem_budget: int | None = None,
) -> str:
    """Resolve which gather-distance implementation serves this points
    block: ``"vmem"`` (Pallas, points VMEM-resident), ``"hbm"`` (Pallas,
    points stay in HBM, neighbor rows streamed via async DMA), or
    ``"xla"`` (``kernels.ref`` gather — the CPU path).

    ``kernel_path`` forces a specific path.  The legacy ``use_pallas``
    boolean maps ``True`` -> vmem-if-it-fits-else-hbm and ``False`` ->
    xla.  With neither given: on TPU, ``fits_vmem`` (under
    ``vmem_budget``, or the env-configurable default) picks vmem vs hbm —
    an oversized shard now STREAMS instead of silently dropping to the
    XLA gather; off-TPU the XLA path wins (interpret-mode Pallas is a
    test vehicle, not a serving path).
    """
    if kernel_path is not None:
        if kernel_path not in KERNEL_PATHS:
            raise ValueError(f"kernel_path must be one of {KERNEL_PATHS}, "
                             f"got {kernel_path!r}")
        return kernel_path
    from repro.kernels.gather_distance import fits_vmem

    fits = (fits_vmem(x, budget=vmem_budget) if scales is None
            else fits_vmem(x, scales, budget=vmem_budget))
    if use_pallas is not None:
        return ("vmem" if fits else "hbm") if use_pallas else "xla"
    if jax.default_backend() == "tpu":
        return "vmem" if fits else "hbm"
    return "xla"


def merge_block(ids, ds, vis, bids, bds):
    """Fold one [Q, M] candidate block into a sorted [Q, L] beam.

    Rank-based bounded merge — the ``hashprune_merge_segmented``
    Pallas-row-merge trick, with NO sort anywhere (XLA CPU's variadic
    sort is the old engine's dominant cost): after deduping, ids are
    disjoint so (dist, id) keys are strictly ordered and every valid
    entry's output slot is its rank on its own side plus the count of
    smaller keys on the other side.  The beam's own rank is its slot
    index (it stays sorted across merges); the block's comes from one
    M^2 lex compare.  Visited flags ride along on the beam side; new
    entries arrive unvisited; slots past the merged count keep the
    (-1, inf, unvisited) pad.

    Module-level because it is ALSO the cross-shard top-k merge of the
    sharded serving path (``distributed.serving.cross_shard_topk``):
    per-shard beams are disjoint id sets, exactly the dedup contract
    below.  Duplicate candidate ids must carry identical dists (same
    point, same query, same formula) — keeping the first copy is then
    exact; ids already in the beam keep the beam's (flagged) copy.
    """
    beam = ids.shape[1]
    m = bids.shape[1]
    inf = jnp.float32(jnp.inf)
    iota_l = jnp.arange(beam, dtype=jnp.int32)
    iota_m = jnp.arange(m, dtype=jnp.int32)
    lt = lambda d1, i1, d2, i2: (d1 < d2) | ((d1 == d2) & (i1 < i2))
    dup = jnp.any((bids[:, :, None] == bids[:, None, :])
                  & (iota_m[None, :] < iota_m[:, None])[None], axis=2)
    beam_ids = jnp.where(ids >= 0, ids, -2)  # don't match -1 candidates
    member = jnp.any(bids[:, :, None] == beam_ids[:, None, :], axis=2)
    bds = jnp.where(dup | member | (bids < 0), inf, bds)
    va = jnp.isfinite(ds)                    # [Q, L]
    vb = jnp.isfinite(bds)                   # [Q, M]
    b_lt_b = lt(bds[:, None, :], bids[:, None, :],
                bds[:, :, None], bids[:, :, None])      # [Q, M, M']
    rank_b = jnp.sum(vb[:, None, :] & b_lt_b, axis=2, dtype=jnp.int32)
    b_lt_a = lt(bds[:, None, :], bids[:, None, :],
                ds[:, :, None], ids[:, :, None])        # [Q, L, M]
    pos_a = jnp.where(va, iota_l[None, :] + jnp.sum(
        vb[:, None, :] & b_lt_a, axis=2, dtype=jnp.int32), beam)
    pos_b = jnp.where(vb, rank_b + jnp.sum(
        va[:, :, None] & ~b_lt_a, axis=1, dtype=jnp.int32), beam)
    # distinct ranks for every valid entry => at most one source per
    # output slot; positions >= beam fall off the end (the truncation)
    oh_a = pos_a[:, None, :] == iota_l[None, :, None]   # [Q, L_out, L]
    oh_b = pos_b[:, None, :] == iota_l[None, :, None]   # [Q, L_out, M]
    pick_a = jnp.any(oh_a, axis=2)
    pick_b = jnp.any(oh_b, axis=2)
    sum_a = lambda v: jnp.sum(jnp.where(oh_a, v[:, None, :], 0), axis=2)
    sum_b = lambda v: jnp.sum(jnp.where(oh_b, v[:, None, :], 0), axis=2)
    new_ids = jnp.where(pick_a, sum_a(ids),
                        jnp.where(pick_b, sum_b(bids), -1))
    new_ds = jnp.where(pick_a, sum_a(ds),
                       jnp.where(pick_b, sum_b(bds), inf))
    new_vis = jnp.any(oh_a & vis[:, None, :], axis=2)
    return new_ids, new_ds, new_vis


@functools.partial(
    jax.jit,
    static_argnames=("beam", "iters", "metric", "expansions", "early_exit",
                     "kernel_path", "interpret"),
)
def _beam_search_multi(
    graph: jax.Array,    # [n, R] int32, -1 pad
    x: jax.Array,        # [n, d] (f32/downcast, or int8 when scales given)
    norms: jax.Array,    # [n] f32 metric-dependent point norms (metrics.point_norms)
    queries: jax.Array,  # [Q, d]
    start,               # scalar entry point (dynamic)
    scales,              # [n] f32 int8 dequant scales, or None (f32 path)
    *,
    beam: int,
    iters: int,
    metric: str,
    expansions: int,
    early_exit: bool,
    kernel_path: str,
    interpret: bool,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array, jax.Array]:
    """Batched multi-expansion beam search core.

    Returns (ids [Q, beam], dists [Q, beam], hops [Q], dist_comps [Q],
    converged [Q]).  ``hops`` counts vertices expanded, ``dist_comps``
    distance evaluations (including the entry point).  ``converged`` is
    the loop's own per-query stop predicate evaluated on the FINAL state
    (no live unvisited beam entry): True means the query reached its
    fixed point — more iterations cannot change its beam — and False
    means the ``iters`` backstop cut it off mid-walk (a straggler the
    serving loop reruns with a larger cap).  ``kernel_path`` selects the
    distance block implementation ("vmem" | "hbm" | "xla" —
    ``resolve_kernel_path``).  See ``beam_search_batch`` for semantics.
    """
    n, r = graph.shape
    nq = queries.shape[0]
    e = max(1, min(int(expansions), beam))
    c = e * r
    inf = jnp.float32(jnp.inf)
    q32 = queries.astype(jnp.float32)

    if scales is not None:
        # int8 scalar-quantized serving: the distance block is the
        # quantized kernel/oracle triple; query norm terms are computed
        # ONCE per batch and passed to every side as DATA (a query is just
        # a point on the norm side, so point_norms is the one mapping; f32
        # reductions are not jit/eager bit-stable, so no side may
        # recompute them)
        q_norms = _metrics.point_norms(q32, metric)
        if kernel_path == "vmem":
            from repro.kernels.gather_distance import gather_distance_int8

            def dist_fn(x, norms, q, ids, metric):
                return gather_distance_int8(x, scales, norms, q, q_norms,
                                            ids, metric=metric,
                                            interpret=interpret)
        elif kernel_path == "hbm":
            from repro.kernels.gather_distance import gather_distance_int8_hbm

            def dist_fn(x, norms, q, ids, metric):
                return gather_distance_int8_hbm(x, scales, norms, q, q_norms,
                                                ids, metric=metric,
                                                interpret=interpret)
        else:
            # the query batch is loop-invariant: quantize it ONCE here
            # instead of per step (row-local + order-independent, so the
            # bits match the kernel's per-tile quantization exactly)
            q8, sq = _ref.quantize_symmetric(q32)

            def dist_fn(x, norms, q, ids, metric):
                return _ref.gather_distance_int8_core(x, scales, norms, q8,
                                                      sq, q_norms, ids,
                                                      metric=metric)
    elif kernel_path == "vmem":
        from repro.kernels.gather_distance import gather_distance

        dist_fn = functools.partial(gather_distance, interpret=interpret)
    elif kernel_path == "hbm":
        from repro.kernels.gather_distance import gather_distance_hbm

        dist_fn = functools.partial(gather_distance_hbm, interpret=interpret)
    else:
        dist_fn = _ref.gather_distance_ref

    d0 = dist_fn(x, norms, q32,
                 jnp.full((nq, 1), start, dtype=jnp.int32), metric=metric)[:, 0]
    ids = jnp.full((nq, beam), -1, jnp.int32).at[:, 0].set(start)
    ds = jnp.full((nq, beam), inf).at[:, 0].set(d0)
    vis = jnp.zeros((nq, beam), dtype=bool)
    hops = jnp.zeros((nq,), jnp.int32)
    comps = jnp.ones((nq,), jnp.int32)     # the entry-point distance

    rows = jnp.arange(nq)[:, None]

    def cond(state):
        t, ids, ds, vis, _, _ = state
        live = jnp.any(~vis & (ids >= 0) & jnp.isfinite(ds))
        budget = t < iters
        return budget & live if early_exit else budget

    def body(state):
        t, ids, ds, vis, hops, comps = state
        # --- select the E best unvisited beam entries per query -----------
        masked = jnp.where(vis | (ids < 0), inf, ds)
        negv, pos = jax.lax.top_k(-masked, e)           # [Q, E] beam slots
        valid_e = jnp.isfinite(negv)
        vis = vis.at[rows, pos].set(True)
        p = jnp.take_along_axis(ids, pos, axis=1)       # [Q, E]
        # --- gather their E*R neighbors + one-shot distance block ---------
        nbr = graph[jnp.maximum(jnp.where(valid_e, p, -1), 0)]   # [Q, E, R]
        ok = (nbr >= 0) & valid_e[:, :, None]
        cids = jnp.where(ok, nbr, -1).reshape(nq, c)
        cds = dist_fn(x, norms, q32, cids, metric=metric)        # [Q, C]
        hops = hops + jnp.sum(valid_e, axis=1, dtype=jnp.int32)
        comps = comps + jnp.sum(cids >= 0, axis=1, dtype=jnp.int32)
        # --- fold the E neighbor rows into the beam, one bounded merge
        # per row: total merge work scales LINEARLY in E (each row merge
        # is O(R^2 + R*L) compares) while the distance block, expansion
        # selection and loop-carry costs amortize over E expansions
        for j in range(e):
            sl = slice(j * r, (j + 1) * r)
            ids, ds, vis = merge_block(ids, ds, vis, cids[:, sl], cds[:, sl])
        return (t + 1, ids, ds, vis, hops, comps)

    state = (jnp.int32(0), ids, ds, vis, hops, comps)
    _, ids, ds, vis, hops, comps = jax.lax.while_loop(cond, body, state)
    # the loop's own per-query stop predicate on the final state: a query
    # with no live unvisited entry is at its fixed point, one cut off by
    # the iters backstop is not (the straggler the serving loop redrives)
    converged = ~jnp.any(~vis & (ids >= 0) & jnp.isfinite(ds), axis=1)
    return ids, ds, hops, comps, converged


def beam_search_batch(
    graph,
    x,
    queries,
    *,
    start: int,
    beam: int,
    iters: int | None = None,
    metric: str = "l2",
    expansions: int = 4,
    norms=None,
    scales=None,
    early_exit: bool = True,
    use_pallas: bool | None = None,
    kernel_path: str | None = None,
    vmem_budget: int | None = None,
    interpret: bool | None = None,
    with_stats: bool = False,
):
    """Batched multi-expansion beam search.  Returns (ids, dists) [Q, beam].

    Each step expands the ``expansions`` best unvisited beam entries at
    once: their ``expansions * R`` neighbors are gathered and scored in one
    distance block — the fused Pallas gather-distance kernel, VMEM-resident
    when the points fit the budget and HBM-streaming when they don't
    (``kernel_path`` / ``resolve_kernel_path``; on TPU the Pallas paths
    auto-enable, the XLA gather is the CPU path) — then
    folded into the always-sorted beam via sort-free rank-based bounded
    merges, one per expanded row — the per-step selection, distance
    dispatch and loop-carry costs are amortized over ``E*R`` candidates
    while each row merge stays O(R^2 + R*beam) compares.

    ``iters`` is a CAP, not a schedule: the loop runs under
    ``lax.while_loop`` and exits as soon as every query has converged
    (all live beam entries visited — exactly the np reference's
    termination), so a generous cap costs nothing.  ``iters=None``
    defaults to ``default_iters(beam)`` (``beam + 4``, the legacy budget; with early exit the
    typical hop count is ~``beam / expansions``).  ``early_exit=False``
    forces the full cap (the converged state is a fixed point, so results
    are identical — tested).

    ``norms`` are the metric-dependent point norms
    (``metrics.point_norms``); pass the precomputed array to skip the
    per-call reduction (``ServingIndex`` does).  ``with_stats=True``
    additionally returns per-query telemetry (hops, dist_comps,
    converged — the per-query stop predicate on the final state, False
    when the ``iters`` backstop cut the walk off before its fixed
    point).

    ``scales`` switches on the int8 scalar-quantized serving path: ``x``
    must then be the int8 packing (``ref.quantize_symmetric``) and
    ``scales`` its [n] f32 per-point dequant scales, with ``norms`` the
    EXACT pre-quantization f32 norms (required — they cannot be recovered
    from the int8 copy).  Distances come from the quantized
    kernel/oracle pair; the 4x-smaller points block also widens the
    ``fits_vmem`` auto-enable window on TPU.
    """
    graph = jnp.asarray(graph)
    x = jnp.asarray(x)
    queries = jnp.asarray(queries)
    if scales is not None:
        if x.dtype != jnp.int8:
            raise TypeError(
                "scales given but points are not int8 — pack them with "
                "kernels.ref.quantize_symmetric")
        if norms is None:
            raise ValueError(
                "int8 serving needs the exact f32 point norms computed "
                "BEFORE quantization (metrics.point_norms on the f32 "
                "points); they cannot be recovered from the int8 copy")
        scales = jnp.asarray(scales)
    if iters is None:
        iters = default_iters(beam)
    path = resolve_kernel_path(x, scales, kernel_path=kernel_path,
                               use_pallas=use_pallas,
                               vmem_budget=vmem_budget)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if norms is None:
        norms = _metrics.point_norms(x, metric)
    ids, ds, hops, comps, converged = _beam_search_multi(
        graph, x, jnp.asarray(norms), queries, start, scales,
        beam=beam, iters=int(iters), metric=metric,
        expansions=int(expansions), early_exit=bool(early_exit),
        kernel_path=path, interpret=bool(interpret),
    )
    if with_stats:
        return ids, ds, hops, comps, converged
    return ids, ds


def pad_ids(ids: np.ndarray, k: int) -> np.ndarray:
    """Truncate / -1-pad a [Q, *] id matrix to exactly [Q, k].

    The shared miss-counting convention: a row with fewer than ``k``
    neighbors (e.g. ``beam < k``) is padded with -1, which can never match
    ground truth — ``recall_at_k`` then counts the gap as misses."""
    ids = np.asarray(ids)[:, :k]
    if ids.shape[1] < k:
        ids = np.pad(ids, ((0, 0), (0, k - ids.shape[1])),
                     constant_values=-1)
    return ids


def recall_at_k(
    found: np.ndarray, truth: np.ndarray, k: int = 10
) -> float:
    """Mean k@k recall (Definition 2) over queries.

    Vectorized set intersection: a found entry scores iff it appears
    anywhere in the truth row AND is the first occurrence of its value in
    the found row (set semantics — duplicates count once, exactly like the
    original per-row ``set`` intersection).
    """
    f = np.asarray(found)[:, :k]
    t = np.asarray(truth)[:, :k]
    kf = f.shape[1]
    earlier = np.tril(np.ones((kf, kf), dtype=bool), -1)      # j' < j
    dup = np.any((f[:, :, None] == f[:, None, :]) & earlier[None], axis=2)
    in_t = np.any(f[:, :, None] == t[:, None, :], axis=2)
    hits = int(np.sum(in_t & ~dup))
    return hits / (len(found) * k)


def brute_force_knn(
    x: np.ndarray, queries: np.ndarray, k: int, metric: str = "l2",
    chunk: int = 1024,
) -> np.ndarray:
    """Exact k-NN ground truth (chunked GEMM)."""
    out = np.empty((queries.shape[0], k), dtype=np.int64)
    for s in range(0, queries.shape[0], chunk):
        q = queries[s : s + chunk]
        if metric == "mips":
            d = -(q @ x.T)
        elif metric == "cosine":
            d = 1.0 - (q @ x.T) / np.maximum(
                np.linalg.norm(q, axis=1)[:, None] * np.linalg.norm(x, axis=1)[None, :],
                1e-30,
            )
        else:
            d = (
                np.sum(q * q, axis=1)[:, None]
                + np.sum(x * x, axis=1)[None, :]
                - 2.0 * (q @ x.T)
            )
        idx = np.argpartition(d, k - 1, axis=1)[:, :k]
        rows = np.arange(q.shape[0])[:, None]
        order = np.argsort(d[rows, idx], axis=1, kind="stable")
        out[s : s + chunk] = idx[rows, order]
    return out
