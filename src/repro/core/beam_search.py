"""Beam search over a navigation graph (Algorithm 1) — the query path.

Two implementations:

  * ``beam_search_np``  — faithful pointer-chasing reference (numpy).  This is
    the latency-bound pattern whose *elimination from construction* is the
    paper's whole point; we keep it for querying (recall/QPS measurement).
  * ``beam_search_batch`` — fixed-shape, fully-jittable batched variant
    (vmapped over queries).  State per query: a beam of (dist, id, visited)
    triples maintained by sort; each step visits the best unvisited node,
    merges its <=R neighbors, dedupes by id, truncates to L.  Termination is
    a fixed iteration budget (beam width L bounds useful steps).  This is the
    TPU-shaped serving path.

Graphs are padded adjacency matrices [n, R] int32 with -1 padding (plus an
optional medoid entry point, the standard Vamana choice).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as _metrics


def medoid(x: np.ndarray, sample: int = 4096, seed: int = 0) -> int:
    """Approximate medoid: the sample point nearest the dataset mean."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    idx = rng.choice(n, size=min(sample, n), replace=False)
    mean = x.mean(axis=0, keepdims=True)
    d = np.sum((x[idx] - mean) ** 2, axis=1)
    return int(idx[np.argmin(d)])


def _dist_np(q: np.ndarray, pts: np.ndarray, metric: str) -> np.ndarray:
    if metric == "mips":
        return -(pts @ q)
    if metric == "cosine":
        return 1.0 - (pts @ q) / np.maximum(
            np.linalg.norm(pts, axis=1) * np.linalg.norm(q), 1e-30
        )
    diff = pts - q[None, :]
    return np.sum(diff * diff, axis=1)


def beam_search_np(
    graph: np.ndarray,
    x: np.ndarray,
    q: np.ndarray,
    *,
    start: int,
    beam: int,
    metric: str = "l2",
    max_visits: int | None = None,
) -> tuple[np.ndarray, np.ndarray, int]:
    """Algorithm 1.  Returns (beam ids sorted by dist, dists, n_dist_comps)."""
    import heapq

    d0 = float(_dist_np(q, x[start : start + 1], metric)[0])
    frontier = [(d0, start)]           # min-heap of unvisited beam entries
    in_beam = {start: d0}
    visited: set[int] = set()
    comps = 1
    limit = max_visits or 10 * beam
    while frontier and len(visited) < limit:
        d, p = heapq.heappop(frontier)
        if p in visited or p not in in_beam:
            continue  # stale entry (visited, or truncated out of the beam)
        visited.add(p)
        nbrs = graph[p]
        nbrs = nbrs[nbrs >= 0]
        new = [v for v in nbrs if v not in in_beam and v not in visited]
        if new:
            nd = _dist_np(q, x[new], metric)
            comps += len(new)
            for v, dv in zip(new, nd):
                in_beam[v] = float(dv)
                heapq.heappush(frontier, (float(dv), v))
        if len(in_beam) > beam:
            # keep the L closest seen (visited or not); frontier entries for
            # dropped ids are skipped lazily above
            items = sorted(in_beam.items(), key=lambda kv: (kv[1], kv[0]))[:beam]
            in_beam = dict(items)
    items = sorted(in_beam.items(), key=lambda kv: (kv[1], kv[0]))
    ids = np.asarray([v for v, _ in items], dtype=np.int64)
    ds = np.asarray([dv for _, dv in items], dtype=np.float32)
    return ids, ds, comps


@functools.partial(jax.jit, static_argnames=("beam", "iters", "metric"))
def beam_search_batch(
    graph: jax.Array,   # [n, R] int32, -1 pad
    x: jax.Array,       # [n, d]
    queries: jax.Array,  # [Q, d]
    *,
    start: int,
    beam: int,
    iters: int,
    metric: str = "l2",
) -> tuple[jax.Array, jax.Array]:
    """Batched fixed-iteration beam search.  Returns (ids, dists) [Q, beam]."""
    n, r = graph.shape
    inf = jnp.float32(jnp.inf)

    def one(q):
        d0 = _metrics.point_to_points(q, x[start][None, :], metric)[0]
        ids = jnp.full((beam,), -1, dtype=jnp.int32).at[0].set(start)
        ds = jnp.full((beam,), inf).at[0].set(d0)
        vis = jnp.zeros((beam,), dtype=bool)

        def step(state, _):
            ids, ds, vis = state
            # best unvisited beam slot
            cand = jnp.where(vis | (ids < 0), inf, ds)
            j = jnp.argmin(cand)
            done = ~jnp.isfinite(cand[j])
            p = jnp.maximum(ids[j], 0)
            vis = vis.at[j].set(True)
            nbr = graph[p]                                  # [R]
            ok = (nbr >= 0) & ~done
            nv = x[jnp.maximum(nbr, 0)]                     # [R, d]
            nd = _metrics.pairwise(q[None, :], nv, metric)[0]
            nd = jnp.where(ok, nd, inf)
            # merge: concat beam + neighbors, dedupe by id keeping min dist
            all_ids = jnp.concatenate([ids, jnp.where(ok, nbr, -1)])
            all_ds = jnp.concatenate([ds, nd])
            all_vis = jnp.concatenate([vis, jnp.zeros((r,), dtype=bool)])
            # dedupe: sort by (id, dist); duplicates keep first (min dist,
            # and visited flag OR'd via segment trick: visited dupes sort
            # with their dist — the visited copy in the beam has the same
            # dist so flags propagate through the (id, dist, ~vis) sort)
            o_id, o_ds, o_nvis = jax.lax.sort(
                (all_ids, all_ds, (~all_vis).astype(jnp.int32)),
                dimension=0, num_keys=3,
            )
            dup = (o_id == jnp.roll(o_id, 1))
            dup = dup.at[0].set(False)
            o_ds = jnp.where(dup | (o_id < 0), inf, o_ds)
            # truncate to best `beam` by dist
            o_ds, o_id, o_nvis = jax.lax.sort(
                (o_ds, o_id, o_nvis), dimension=0, num_keys=2
            )
            ids = o_id[:beam]
            ds = o_ds[:beam]
            vis = o_nvis[:beam] == 0
            ids = jnp.where(jnp.isfinite(ds), ids, -1)
            return (ids, ds, vis), None

        (ids, ds, vis), _ = jax.lax.scan(step, (ids, ds, vis), None, length=iters)
        return ids, ds

    return jax.vmap(one)(queries)


def recall_at_k(
    found: np.ndarray, truth: np.ndarray, k: int = 10
) -> float:
    """Mean k@k recall (Definition 2) over queries."""
    hits = 0
    for f, t in zip(found, truth):
        hits += len(set(f[:k].tolist()) & set(t[:k].tolist()))
    return hits / (len(found) * k)


def brute_force_knn(
    x: np.ndarray, queries: np.ndarray, k: int, metric: str = "l2",
    chunk: int = 1024,
) -> np.ndarray:
    """Exact k-NN ground truth (chunked GEMM)."""
    out = np.empty((queries.shape[0], k), dtype=np.int64)
    for s in range(0, queries.shape[0], chunk):
        q = queries[s : s + chunk]
        if metric == "mips":
            d = -(q @ x.T)
        elif metric == "cosine":
            d = 1.0 - (q @ x.T) / np.maximum(
                np.linalg.norm(q, axis=1)[:, None] * np.linalg.norm(x, axis=1)[None, :],
                1e-30,
            )
        else:
            d = (
                np.sum(q * q, axis=1)[:, None]
                + np.sum(x * x, axis=1)[None, :]
                - 2.0 * (q @ x.T)
            )
        idx = np.argpartition(d, k - 1, axis=1)[:, :k]
        rows = np.arange(q.shape[0])[:, None]
        order = np.argsort(d[rows, idx], axis=1, kind="stable")
        out[s : s + chunk] = idx[rows, order]
    return out
