"""Residualized LSH sketches (paper Eq. 1 and the Implementation paragraph
of Sec. 3).

For a point ``p`` and candidate ``c``, HashPrune's individualized hash is

    h_p(c)[i] = 1  if  H_i . (c - p) >= 0  else 0,   i = 1..m

Instead of touching the d-dimensional vectors, we precompute m-dimensional
*sketches* ``Sketch(v) = v @ H.T``; then ``H_i.(c - p) = Sketch(c)[i] -
Sketch(p)[i]`` and the hash is the packed sign-bit pattern of the sketch
difference.  m <= 16 so hashes pack into a uint16 (matching the paper's
8-byte reservoir slot layout: 4B id + 2B hash + 2B bf16 distance).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

MAX_BITS = 16

_POW2 = 2 ** jnp.arange(MAX_BITS, dtype=jnp.int32)  # bit i -> weight 2^i


def make_hyperplanes(key: jax.Array, m: int, d: int, dtype=jnp.float32) -> jax.Array:
    """Sample ``m`` random hyperplane normals through the origin, shape [m, d]."""
    if not 1 <= m <= MAX_BITS:
        raise ValueError(f"m must be in [1, {MAX_BITS}], got {m}")
    return jax.random.normal(key, (m, d), dtype=dtype)


def sketch(x: jax.Array, hyperplanes: jax.Array) -> jax.Array:
    """Project points [..., d] onto hyperplanes -> sketches [..., m].

    One GEMM over the whole dataset; the only place the full-dimensional
    vectors are touched by the hashing machinery.
    """
    return x @ hyperplanes.T


def hash_from_sketches(cand_sketch: jax.Array, point_sketch: jax.Array) -> jax.Array:
    """Packed residual hash h_p(c) from sketches.

    cand_sketch: [..., m] sketches of candidates c
    point_sketch: [..., m] sketches of the owning points p (broadcastable)
    returns int32 in [0, 2^m), the concatenated sign bits of Sketch(c)-Sketch(p).
    """
    bits = (cand_sketch - point_sketch) >= 0.0  # [..., m] bool
    m = bits.shape[-1]
    return jnp.sum(bits.astype(jnp.int32) * _POW2[:m], axis=-1)


@functools.partial(jax.jit)
def sketch_jit(x: jax.Array, hyperplanes: jax.Array) -> jax.Array:
    return sketch(x, hyperplanes)


def edge_hashes_from_ids(
    sketches: jax.Array,   # [n, m] precomputed point sketches
    src: jax.Array,        # [E] int32 edge sources (may contain -1 padding)
    dst: jax.Array,        # [E] int32 edge destinations
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Residual hashes h_src(dst) [E] int32 for a flat edge list.

    Gathers the two sketch rows per edge and packs the sign bits, either
    through the fused Pallas kernel (``use_pallas=True``; ``interpret``
    selects the CPU fallback executor) or the pure-jnp
    ``hash_from_sketches``.  Both produce identical int32 hashes.
    Traceable: the streaming build calls this inside its fused chunk step.
    """
    s_sk = sketches[jnp.maximum(src, 0)]
    d_sk = sketches[jnp.maximum(dst, 0)]
    if use_pallas:
        from repro.kernels.edge_hash import edge_hashes  # no core->kernels cycle

        return edge_hashes(s_sk, d_sk, interpret=interpret)
    return hash_from_sketches(d_sk, s_sk)


def collision_probability(theta: jax.Array, m: int) -> jax.Array:
    """P[h_p(c) = h_p(c')] = (1 - theta/pi)^m for residual angle theta.

    The classic SimHash bound (Charikar'02) the paper cites in 'Why HashPrune
    Works'.  Used by tests to sanity-check the empirical collision rate.
    """
    return (1.0 - theta / jnp.pi) ** m
