"""Shared jitted leader-assignment step: the Stage-1 inner loop.

Every PiPNN partitioning variant reduces to the same primitive: given a
block of points and a (possibly padded) set of leaders, compute the
dissimilarity matrix as one GEMM (Sec. 4.1 / 4.2 — the paper's bulk-GEMM
insight) and select each point's ``f`` nearest leaders.  This module is
the single implementation used by

  * the host-orchestrated device ``ball_carve`` (``core/rbc.py``) — the
    recursion's per-subproblem math,
  * the fully-static two-level ``ball_carve_device`` (``core/rbc.py``),
  * the distributed SPMD build's level-0 bucket selection and level-1
    ``assign_chunk`` (``launch/build_index.py``).

The arithmetic mirrors the numpy oracle ``rbc._pairwise_np`` exactly
(same GEMM expansion, same ``max(d, 0)`` clamp for l2) and the top-f
selection uses ``lax.top_k`` on negated distances, which orders equal
distances by ascending leader index — the same tie-break as a stable
argsort.  On this container's CPU backend the XLA GEMM is bit-identical
to numpy's, so device leader assignment reproduces the host oracle's
decisions bit for bit (asserted by tests/test_partitioners.py).

``use_pallas=True`` routes the distance matrix through the Pallas MXU
kernel (``kernels/distance.py``) and the selection through the Pallas
partial-sort (``kernels/topk.py``) — the TPU production path, which keeps
the same semantics but is not tie-break-pinned to the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk import topf

INF = jnp.float32(jnp.inf)

__all__ = ["leader_dists", "leader_assign", "topf"]


def leader_dists(points: jax.Array, leaders: jax.Array,
                 *, metric: str = "l2") -> jax.Array:
    """Dissimilarity matrix [..., n, l] between ``points`` [..., n, d] and
    ``leaders`` [..., l, d] via the GEMM expansion (batched over leading
    dims).  Mirrors ``rbc._pairwise_np`` term for term."""
    ip = jnp.einsum("...nd,...ld->...nl", points, leaders)
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = jnp.sqrt(jnp.sum(points * points, axis=-1))[..., :, None]
        bn = jnp.sqrt(jnp.sum(leaders * leaders, axis=-1))[..., None, :]
        return 1.0 - ip / jnp.maximum(an * bn, 1e-30)
    a2 = jnp.sum(points * points, axis=-1)[..., :, None]
    b2 = jnp.sum(leaders * leaders, axis=-1)[..., None, :]
    return jnp.maximum(a2 + b2 - 2.0 * ip, 0.0)


def leader_assign(
    points: jax.Array,          # [..., n, d]
    leaders: jax.Array,         # [..., l, d]
    f: int,
    *,
    metric: str = "l2",
    point_valid: jax.Array | None = None,    # [..., n] bool
    leader_valid: jax.Array | None = None,   # [..., l] bool
    use_pallas: bool = False,
    interpret: bool | None = None,           # None: interpret off-TPU only
) -> jax.Array:
    """Indices [..., n, f] of each point's f nearest leaders, ordered by
    ascending dissimilarity (ties by ascending leader index).

    Invalid leaders are masked to +inf (never selected while
    ``f <= n_valid_leaders``); invalid points see an all-inf row, whose
    arbitrary top-f output callers must mask downstream by their own
    validity — the same contract as the SPMD build's ``assign_chunk``.
    """
    if use_pallas:
        from repro.kernels.distance import pairwise_distance
        from repro.kernels.ops import default_interpret
        if interpret is None:
            interpret = default_interpret()
        batched = points.ndim >= 3
        pb = points if batched else points[None]
        lb = leaders if batched else leaders[None]
        d = pairwise_distance(pb.reshape((-1,) + pb.shape[-2:]),
                              lb.reshape((-1,) + lb.shape[-2:]),
                              metric=metric, interpret=interpret)
        d = d.reshape(points.shape[:-1] + (leaders.shape[-2],))
    else:
        d = leader_dists(points, leaders, metric=metric)
    if leader_valid is not None:
        d = jnp.where(leader_valid[..., None, :], d, INF)
    if point_valid is not None:
        d = jnp.where(point_valid[..., :, None], d, INF)
    if use_pallas:
        from repro.kernels.topk import rowwise_topk
        ni, _ = rowwise_topk(d.reshape((-1,) + d.shape[-2:]), k=f,
                             interpret=interpret)
        return ni.reshape(d.shape[:-1] + (f,))
    return topf(d, f)
