"""Dissimilarity measures used throughout PiPNN.

The paper evaluates on L2 (BigANN/DEEP/SPACEV/Turing/OpenAI) and MIPS
(WikiCohere, Text2Image).  All measures here are *dissimilarities*: smaller is
closer.  Squared L2 is used internally (order-equivalent to L2, cheaper, and
what the GEMM expansion produces natively).
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

Metric = Literal["l2", "mips", "cosine"]

VALID_METRICS = ("l2", "mips", "cosine")


def _check(metric: str) -> None:
    if metric not in VALID_METRICS:
        raise ValueError(f"unknown metric {metric!r}; expected one of {VALID_METRICS}")


def pairwise(a: jax.Array, b: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Dense dissimilarity matrix between rows of ``a`` [n,d] and ``b`` [m,d].

    Uses the GEMM expansion ``||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b`` so the
    hot loop is a matrix product (the paper's core implementation insight,
    Sec. 4.2 / Supplement A.4 — Eigen on CPU, the MXU here).
    """
    _check(metric)
    ip = a @ b.T  # [n, m] — the GEMM
    if metric == "mips":
        return -ip
    if metric == "cosine":
        an = jnp.linalg.norm(a, axis=-1, keepdims=True)
        bn = jnp.linalg.norm(b, axis=-1, keepdims=True)
        return 1.0 - ip / jnp.maximum(an * bn.T, 1e-30)
    # squared L2
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    d = a2 + b2 - 2.0 * ip
    return jnp.maximum(d, 0.0)


def point_to_points(q: jax.Array, xs: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Dissimilarity from a single point ``q`` [d] to rows of ``xs`` [m,d]."""
    return pairwise(q[None, :], xs, metric)[0]


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_jit(a: jax.Array, b: jax.Array, metric: Metric = "l2") -> jax.Array:
    return pairwise(a, b, metric)


@functools.partial(jax.jit, static_argnames=("metric",))
def point_norms(x: jax.Array, metric: Metric = "l2") -> jax.Array:
    """Metric-dependent per-point norms used by the gather-distance path
    (``kernels.ref.gather_distance_ref`` / the Pallas kernel): squared L2
    norms for ``l2``, L2 norms for ``cosine``, zeros for ``mips`` (unused).
    Always f32 — compute these BEFORE any points-dtype downcast so the
    norm half of the expansion keeps full precision.
    """
    _check(metric)
    x32 = x.astype(jnp.float32)
    if metric == "cosine":
        return jnp.linalg.norm(x32, axis=-1)
    if metric == "l2":
        return jnp.sum(x32 * x32, axis=-1)
    return jnp.zeros((x.shape[0],), jnp.float32)
