"""PiPNN core: the paper's contribution as composable JAX modules."""
from repro.core.hashprune import (
    Reservoir,
    hashprune_batch,
    hashprune_flat,
    hashprune_merge,
    hashprune_merge_flat,
    hashprune_stream,
    reservoir_init,
)
from repro.core.leaf import EdgeList, LeafParams, build_leaf_edges
from repro.core.pipnn import (PiPNNIndex, PiPNNParams, build, search,
                              serving_index)
from repro.core.rbc import RBCParams, ball_carve, leaves_to_padded, partition
from repro.core.serving import ServingIndex

__all__ = [
    "Reservoir", "hashprune_batch", "hashprune_flat", "hashprune_merge",
    "hashprune_merge_flat", "hashprune_stream", "reservoir_init", "EdgeList",
    "LeafParams", "build_leaf_edges", "PiPNNIndex", "PiPNNParams", "build",
    "search", "serving_index", "ServingIndex", "RBCParams", "ball_carve",
    "leaves_to_padded", "partition",
]
