"""HashPrune — the paper's core contribution (Sec. 3, Algorithm 3).

An online, *history-independent* pruning reservoir.  Per point ``p`` a
reservoir holds at most ``l_max`` candidates keyed by the residual LSH hash
``h_p(c)`` (see sketch.py):

  * a candidate colliding with a stored one keeps whichever is closer to p;
  * a non-colliding candidate into a full reservoir evicts the farthest
    stored candidate iff the newcomer is closer.

Theorem 3.1 (history independence) has a closed form which this module
exploits for the TPU-native batch path:

    R(C) = the l_max nearest-of {min-dist candidate of each hash bucket}.

Two consequences we rely on (and property-test):

  (1) ORDER-FREEDOM: any insertion order yields R(C) — so the batch
      implementation may sort instead of probing a hash table (a
      latency-bound pattern TPUs cannot do).
  (2) MERGEABILITY: R(R(C1) ∪ C2) = R(C1 ∪ C2).  Proof sketch: bucket
      minima only decrease as candidates are added, so a candidate outside
      the l_max nearest bucket-minima of C1 can never re-enter after more
      candidates arrive.  This licenses bounded-memory streaming of
      *batches* (one leaf / one shard at a time) while holding only the
      [n, l_max] reservoir.  Two fold entry points, both donation-friendly
      so the [n, l_max] state never reallocates:

        * ``hashprune_merge_segmented`` (the ``pipnn.build`` and SPMD tile
          step default): applies the lemma twice — the chunk is reduced to
          its own [n, l_max] reservoir by ONE global sort over just the
          chunk's edges, then folded into the persistent reservoir by a
          bounded per-row width-2*l_max merge (per-row sort fallback, or
          the rank-based Pallas kernel in ``kernels/segmented_merge.py``).
          The persistent reservoir never enters a global sort.
        * ``hashprune_merge_flat`` (the oracle): re-expresses the reservoir
          as a flat edge list and re-sorts it together with the chunk —
          simple, but every fold pays O((n*l_max + E_chunk) log ...) sort
          work.  The segmented fold is property-tested bit-identical to it.

Tie-breaking: the paper implicitly assumes general position (distinct
distances).  We make determinism unconditional by ordering candidates by the
lexicographic key (dist, id); both implementations here use it, so they are
bit-identical even with duplicated candidates or tied distances.

Layout note: the paper packs a reservoir slot into 8 bytes (4B id, 2B hash,
2B bf16 dist).  We keep SoA arrays (ids int32, hashes int32, dists f32 —
bf16 optional) which is the TPU-friendly equivalent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

INVALID_ID = jnp.int32(-1)
INF = jnp.float32(jnp.inf)


class Reservoir(NamedTuple):
    """Batched HashPrune state for n points. All arrays [n, l_max]."""

    ids: jax.Array    # int32, INVALID_ID marks an empty slot
    hashes: jax.Array  # int32 packed residual hash (< 2^16)
    dists: jax.Array  # float32, +inf marks an empty slot

    @property
    def l_max(self) -> int:
        return self.ids.shape[-1]


def reservoir_init(n: int, l_max: int) -> Reservoir:
    return Reservoir(
        ids=jnp.full((n, l_max), INVALID_ID, dtype=jnp.int32),
        hashes=jnp.zeros((n, l_max), dtype=jnp.int32),
        dists=jnp.full((n, l_max), INF, dtype=jnp.float32),
    )


# ---------------------------------------------------------------------------
# Closed-form batch evaluation (the TPU path)
# ---------------------------------------------------------------------------

def _dedup_bucket_min(hashes, dists, ids):
    """Sort candidates by (hash, dist, id); keep only each hash-run's head.

    Returns (dists', ids', hashes') sorted with non-heads masked to
    (+inf, INVALID_ID).  Works on the trailing axis; leading axes batch.
    """
    # lexicographic sort: primary hash, secondary dist, tertiary id
    s_hash, s_dist, s_id = jax.lax.sort(
        (hashes, dists, ids), dimension=-1, num_keys=3
    )
    prev = jnp.roll(s_hash, 1, axis=-1)
    first = jnp.ones_like(s_hash, dtype=bool).at[..., 1:].set(
        s_hash[..., 1:] != prev[..., 1:]
    )
    # Padding entries carry id == INVALID_ID and dist == +inf; hide them too.
    valid = s_id != INVALID_ID
    keep = first & valid
    return (
        jnp.where(keep, s_dist, INF),
        jnp.where(keep, s_id, INVALID_ID),
        jnp.where(keep, s_hash, jnp.int32(0x7FFFFFFF)),
    )


@functools.partial(jax.jit, static_argnames=("l_max",))
def hashprune_batch(
    cand_ids: jax.Array,
    cand_hashes: jax.Array,
    cand_dists: jax.Array,
    *,
    l_max: int,
) -> Reservoir:
    """Evaluate HashPrune's closed form on padded per-point candidate lists.

    cand_ids/hashes/dists: [n, n_cand] (INVALID_ID / +inf padding).
    Returns the Reservoir( [n, l_max] ) — identical to streaming Alg. 3.
    """
    d, i, h = _dedup_bucket_min(cand_hashes, cand_dists, cand_ids)
    # top-l_max by (dist, id): one more lexicographic sort, then truncate
    s_d, s_i, s_h = jax.lax.sort((d, i, h), dimension=-1, num_keys=2)
    n_cand = cand_ids.shape[-1]
    if n_cand >= l_max:
        s_d, s_i, s_h = s_d[..., :l_max], s_i[..., :l_max], s_h[..., :l_max]
    else:
        pad = l_max - n_cand
        s_d = jnp.pad(s_d, [(0, 0)] * (s_d.ndim - 1) + [(0, pad)], constant_values=INF)
        s_i = jnp.pad(s_i, [(0, 0)] * (s_i.ndim - 1) + [(0, pad)], constant_values=-1)
        s_h = jnp.pad(s_h, [(0, 0)] * (s_h.ndim - 1) + [(0, pad)], constant_values=0)
    s_h = jnp.where(s_i == INVALID_ID, 0, s_h)
    return Reservoir(ids=s_i, hashes=s_h, dists=s_d)


@functools.partial(jax.jit)
def hashprune_merge(res: Reservoir, batch: Reservoir | None = None,
                    cand_ids: jax.Array | None = None,
                    cand_hashes: jax.Array | None = None,
                    cand_dists: jax.Array | None = None) -> Reservoir:
    """Merge a new candidate batch into an existing reservoir.

    Valid by the mergeability lemma above; output == one-shot closed form on
    the union of everything ever inserted.
    """
    if batch is not None:
        cand_ids, cand_hashes, cand_dists = batch.ids, batch.hashes, batch.dists
    ids = jnp.concatenate([res.ids, cand_ids], axis=-1)
    hashes = jnp.concatenate([res.hashes, cand_hashes], axis=-1)
    dists = jnp.concatenate([res.dists, cand_dists], axis=-1)
    return hashprune_batch(ids, hashes, dists, l_max=res.l_max)


# ---------------------------------------------------------------------------
# Flat-edge-list evaluation (used by the PiPNN pipeline: one lexicographic
# sort over ALL candidate edges of ALL points at once)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_points", "l_max"))
def hashprune_flat(
    src: jax.Array,
    dst: jax.Array,
    hashes: jax.Array,
    dists: jax.Array,
    *,
    n_points: int,
    l_max: int,
) -> Reservoir:
    """HashPrune over a flat edge list [(src -> dst, hash, dist)].

    Padding edges use src == n_points (sorts to the end, scattered with
    mode='drop').  This is the PiPNN hot path after leaf building: one
    global sort replaces n independent hash tables.
    """
    e = src.shape[0]
    # (1) bucket-min: sort by (src, hash, dist, dst); heads of (src, hash) runs
    s_src, s_hash, s_dist, s_dst = jax.lax.sort(
        (src, hashes, dists, dst), dimension=0, num_keys=4
    )
    same = (s_src == jnp.roll(s_src, 1)) & (s_hash == jnp.roll(s_hash, 1))
    same = same.at[0].set(False)
    keep = (~same) & (s_src < n_points) & (s_dst != INVALID_ID)
    m_dist = jnp.where(keep, s_dist, INF)
    m_src = jnp.where(keep, s_src, jnp.int32(n_points))
    # (2) per-src top-l_max by (dist, dst): sort by (src, dist, dst)
    f_src, f_dist, f_dst, f_hash = jax.lax.sort(
        (m_src, m_dist, s_dst, s_hash), dimension=0, num_keys=3
    )
    idx = jnp.arange(e, dtype=jnp.int32)
    seg_start = f_src != jnp.roll(f_src, 1)
    seg_start = seg_start.at[0].set(True)
    start_idx = jax.lax.cummax(jnp.where(seg_start, idx, 0))
    rank = idx - start_idx
    ok = (rank < l_max) & (f_src < n_points) & jnp.isfinite(f_dist)
    out = reservoir_init(n_points, l_max)
    row = jnp.where(ok, f_src, n_points)  # out-of-bounds => dropped
    col = jnp.where(ok, rank, l_max)
    ids = out.ids.at[row, col].set(f_dst, mode="drop")
    hs = out.hashes.at[row, col].set(f_hash, mode="drop")
    ds = out.dists.at[row, col].set(f_dist, mode="drop")
    return Reservoir(ids=ids, hashes=hs, dists=ds)


def reservoir_as_edges(
    ids: jax.Array, hashes: jax.Array, dists: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Flatten a reservoir [n, l_max] back into a flat edge list.

    Empty slots become padding edges (src == n) in the ``hashprune_flat``
    convention, so the result can be concatenated with a fresh candidate
    chunk and re-pruned — the mergeability lemma's R(C1) ∪ C2.
    """
    n, l_max = ids.shape
    row = jnp.broadcast_to(
        jnp.arange(n, dtype=jnp.int32)[:, None], (n, l_max)
    ).reshape(-1)
    flat_ids = ids.reshape(-1)
    empty = flat_ids == INVALID_ID
    src = jnp.where(empty, jnp.int32(n), row)
    return src, flat_ids, hashes.reshape(-1), dists.reshape(-1)


def merge_flat_edges(res_ids, res_hashes, res_dists,
                     src, dst, hashes, dists) -> Reservoir:
    """Traceable body of ``hashprune_merge_flat`` (no jit, no donation).

    Call this form when fusing the merge into a larger jitted step — the
    streaming ``pipnn.build`` chunk step and the distributed tile step both
    inline it so leaf k-NN, edge emission, hashing and the reservoir fold
    compile into one program.
    """
    n, l_max = res_ids.shape
    r_src, r_dst, r_h, r_d = reservoir_as_edges(res_ids, res_hashes, res_dists)
    return hashprune_flat(
        jnp.concatenate([r_src, src]),
        jnp.concatenate([r_dst, dst]),
        jnp.concatenate([r_h, hashes]),
        jnp.concatenate([r_d, dists]),
        n_points=n, l_max=l_max,
    )


# Buffer donation lets XLA reuse the old reservoir's [n, l_max] buffers for
# the new one, so the persistent state never reallocates across chunks.
# (On backends without donation support this silently degrades to a copy.)
_merge_flat_jit = jax.jit(merge_flat_edges, donate_argnums=(0, 1, 2))


def hashprune_merge_flat(
    res: Reservoir,
    src: jax.Array,
    dst: jax.Array,
    hashes: jax.Array,
    dists: jax.Array,
) -> Reservoir:
    """Fold a flat candidate-edge chunk into an existing reservoir.

    Equivalent (bit-identical, not just set-equal) to running
    ``hashprune_flat`` once over every edge ever folded in, by the
    mergeability lemma: the reservoir is re-expressed as a flat edge list
    and re-pruned together with the chunk in one global sort.  Peak
    intermediate memory is O(n*l_max + len(src)) — independent of the
    total number of candidate edges.

    ``res`` is DONATED: do not reuse it after the call.  Padding edges use
    the ``hashprune_flat`` convention (src == n, dst == INVALID_ID,
    dist == +inf).
    """
    ids, hs, ds = _merge_flat_jit(res.ids, res.hashes, res.dists,
                                  src, dst, hashes, dists)
    return Reservoir(ids=ids, hashes=hs, dists=ds)


# ---------------------------------------------------------------------------
# Segmented merge: chunk-local bucket dedup + bounded per-row reservoir merge
# ---------------------------------------------------------------------------

def merge_segmented_edges(res_ids, res_hashes, res_dists,
                          src, dst, hashes, dists, *,
                          use_pallas: bool = False,
                          interpret: bool = True) -> Reservoir:
    """Segmented fold of a flat candidate-edge chunk into a reservoir.

    ``merge_flat_edges`` re-expresses the whole [n, l_max] reservoir as a
    flat edge list and re-sorts it together with the chunk: every fold pays
    two global O((n*l_max + E_chunk) log ...) multi-key sorts.  This path
    exploits two invariants instead:

      (1) the chunk alone can be bucket-deduped and row-bucketed by ONE
          global sort over just its own edges (``hashprune_flat`` on the
          chunk -> a [n, l_max] chunk reservoir), and
      (2) both reservoirs are per-row sorted by (dist, id) with one slot
          per hash bucket, so folding them is a BOUNDED per-row merge on
          width-2*l_max rows (R(R(C1) ∪ R(C2)) = R(C1 ∪ C2) by Thm 3.1
          applied twice) — the persistent reservoir never enters a global
          sort at all.

    Bit-identical to ``merge_flat_edges`` (both produce rows sorted by
    (dist, id) with identical padding), which stays as the oracle.

    ``use_pallas`` routes the per-row merge through the
    ``kernels/segmented_merge.py`` kernel (rank-based merge of two sorted
    rows + cross-reservoir bucket dedup, no sort); the fallback is the
    per-row ``hashprune_batch`` sort.  Traceable either way — the streaming
    chunk step and the SPMD tile step inline it.
    """
    n, l_max = res_ids.shape
    chunk_res = hashprune_flat(src, dst, hashes, dists,
                               n_points=n, l_max=l_max)
    if use_pallas:
        from repro.kernels.segmented_merge import merge_sorted_reservoirs

        return merge_sorted_reservoirs(
            res_ids, res_hashes, res_dists,
            chunk_res.ids, chunk_res.hashes, chunk_res.dists,
            interpret=interpret)
    return hashprune_batch(
        jnp.concatenate([res_ids, chunk_res.ids], axis=-1),
        jnp.concatenate([res_hashes, chunk_res.hashes], axis=-1),
        jnp.concatenate([res_dists, chunk_res.dists], axis=-1),
        l_max=l_max)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"),
                   donate_argnums=(0, 1, 2))
def _merge_segmented_jit(res_ids, res_hashes, res_dists,
                         src, dst, hashes, dists, *, use_pallas, interpret):
    return merge_segmented_edges(res_ids, res_hashes, res_dists,
                                 src, dst, hashes, dists,
                                 use_pallas=use_pallas, interpret=interpret)


def hashprune_merge_segmented(
    res: Reservoir,
    src: jax.Array,
    dst: jax.Array,
    hashes: jax.Array,
    dists: jax.Array,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
) -> Reservoir:
    """Donating jit wrapper over ``merge_segmented_edges``.

    Same contract as ``hashprune_merge_flat`` (``res`` is DONATED; padding
    edges use src == n / dst == INVALID_ID / dist == +inf), but the global
    sort work per fold is O(E_chunk log E_chunk) instead of
    O((n*l_max + E_chunk) log (n*l_max + E_chunk)).
    """
    ids, hs, ds = _merge_segmented_jit(
        res.ids, res.hashes, res.dists, src, dst, hashes, dists,
        use_pallas=use_pallas, interpret=interpret)
    return Reservoir(ids=ids, hashes=hs, dists=ds)


# ---------------------------------------------------------------------------
# Workspace models (validated by the memory auditor, PIPM004)
# ---------------------------------------------------------------------------

def merge_flat_workspace_bytes(n: int, l_max: int, e: int) -> int:
    """Modeled XLA temp bytes of one ``_merge_flat_jit`` fold: the
    reservoir re-expressed as ``n * l_max`` padding edges concatenated
    with the ``e``-edge chunk (src/dst/hash/dist, 16 B/entry), plus one
    sorted copy of the concatenation.  The model is an upper bound the
    memory auditor checks the compiled ledger against at every lattice
    point (``repro.analysis.memory_audit``, PIPM004) and prices the
    deployment envelope with (PIPM003) — keep it in sync with the fold."""
    entries = n * l_max + e
    return 2 * entries * 16


def merge_segmented_workspace_bytes(n: int, l_max: int, e: int) -> int:
    """Modeled XLA temp bytes of one ``_merge_segmented_jit`` fold: the
    chunk-only global sort (``e`` edges in and one sorted copy), the
    [n, l_max] chunk reservoir it produces, and the width-2*l_max
    concatenated rows of the bounded per-row merge plus its sorted copy
    (12 B id+hash+dist per slot).  Independent of the total emitted edge
    count E — only the chunk and the reservoir appear.  Validated by
    PIPM004; priced at the envelope by PIPM003."""
    chunk_sort = 2 * e * 16
    chunk_res = n * l_max * 12
    # concat + sorted copy would be 4 reservoir-sized slot images, but the
    # donated rows are reused in place; the compiled ledger measures ~1x
    # (CPU XLA), so 2x is the calibrated upper bound PIPM004 enforces
    row_merge = 2 * n * l_max * 12
    return chunk_sort + chunk_res + row_merge


# ---------------------------------------------------------------------------
# Streaming reference (faithful Algorithm 3) — the oracle for property tests
# ---------------------------------------------------------------------------

def _less(d1, i1, d2, i2):
    """(dist, id) lexicographic strict less-than."""
    return (d1 < d2) | ((d1 == d2) & (i1 < i2))


def _insert_one(state, cand):
    ids, hashes, dists = state
    cid, chash, cdist = cand
    l_max = ids.shape[0]
    occupied = ids != INVALID_ID
    is_valid = cid != INVALID_ID

    match = occupied & (hashes == chash)
    any_match = jnp.any(match)
    # position of the (unique) hash match
    mpos = jnp.argmax(match)
    closer = _less(cdist, cid, dists[mpos], ids[mpos])

    count = jnp.sum(occupied)
    has_room = count < l_max
    # first empty slot
    epos = jnp.argmax(~occupied)
    # farthest occupied slot by (dist, id) — evict the max
    far_key = jnp.where(occupied, dists, -INF)
    zpos = jnp.argmax(far_key)  # ids tie-break: see note below
    # break dist ties toward larger id (mirror of (dist,id) max)
    is_max_d = occupied & (dists == far_key[zpos]) & jnp.isfinite(far_key[zpos])
    zpos = jnp.where(
        jnp.any(is_max_d), jnp.argmax(jnp.where(is_max_d, ids, -2)), zpos
    )
    evict_ok = _less(cdist, cid, dists[zpos], ids[zpos])

    # decide the write position (or no write)
    write = is_valid & (
        (any_match & closer) | (~any_match & (has_room | evict_ok))
    )
    pos = jnp.where(any_match, mpos, jnp.where(has_room, epos, zpos))
    ids = jnp.where(write, ids.at[pos].set(cid), ids)
    hashes = jnp.where(write, hashes.at[pos].set(chash), hashes)
    dists = jnp.where(write, dists.at[pos].set(cdist), dists)
    return (ids, hashes, dists), None


@functools.partial(jax.jit, static_argnames=("l_max",))
def hashprune_stream(
    cand_ids: jax.Array,
    cand_hashes: jax.Array,
    cand_dists: jax.Array,
    *,
    l_max: int,
) -> Reservoir:
    """Sequential Algorithm 3 for ONE point (candidates [n_cand]).

    O(n_cand * l_max) scan — the reference semantics.  vmap for batches.
    """
    init = (
        jnp.full((l_max,), INVALID_ID, dtype=jnp.int32),
        jnp.zeros((l_max,), dtype=jnp.int32),
        jnp.full((l_max,), INF, dtype=jnp.float32),
    )
    (ids, hashes, dists), _ = jax.lax.scan(
        _insert_one, init, (cand_ids, cand_hashes, cand_dists)
    )
    return Reservoir(ids=ids[None], hashes=hashes[None], dists=dists[None])


def canonicalize(res: Reservoir) -> Reservoir:
    """Sort reservoir slots by (dist, id) so representations compare equal."""
    d, i, h = jax.lax.sort((res.dists, res.ids, res.hashes), dimension=-1, num_keys=2)
    h = jnp.where(i == INVALID_ID, 0, h)
    return Reservoir(ids=i, hashes=h, dists=d)
