"""Declared host<->device transfer boundaries for the serving paths.

A serving call should touch the host exactly twice: queries in, results
out.  Anything else — a numpy array falling into a jit dispatch, an index
packed on one device getting resharded across the mesh on EVERY call — is
an implicit transfer jax performs silently, and at pod scale it is the
difference between serving from HBM and serving from the host NIC.

This module makes the two legitimate boundaries EXPLICIT and everything
else a hard error:

  * ``to_device(x[, sharding])`` / ``to_host(x)`` are the only sanctioned
    crossings.  Each wraps its transfer in a local
    ``jax.transfer_guard("allow")`` scope, so serving code routed through
    them keeps working even when the caller holds the whole call under
    ``jax.transfer_guard("disallow")`` — the configuration the test
    fixture (tests/conftest.py) and the PIPS004 lint audit run under,
    where any *unrouted* transfer raises instead of silently shipping
    bytes.
  * ``ledger()`` counts crossings per scope.  The SPMD auditor
    (``analysis/spmd_audit.py``, rule PIPS004) replays a sharded search
    under a ledger and gates the counts against the serving path's
    declared per-call budget
    (``ShardedServingIndex.TRANSFER_BUDGET``).

Counting is thread-local and zero-cost when no ledger is active.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
import numpy as np

_LOCAL = threading.local()


def _active() -> dict | None:
    return getattr(_LOCAL, "counts", None)


def _bump(kind: str) -> None:
    counts = _active()
    if counts is not None:
        counts[kind] += 1


@contextlib.contextmanager
def ledger():
    """Count declared boundary crossings: yields a live
    ``{"h2d": int, "d2h": int}`` dict that updates as ``to_device`` /
    ``to_host`` run inside the scope.  Nests; the inner scope shadows."""
    prev = _active()
    _LOCAL.counts = {"h2d": 0, "d2h": 0}
    try:
        yield _LOCAL.counts
    finally:
        _LOCAL.counts = prev


def to_device(x, sharding=None):
    """The batch-ENTRY boundary: one declared host->device transfer.

    With ``sharding`` (e.g. a replicated ``NamedSharding`` for a query
    batch entering a mesh program) the result is committed to it, so the
    downstream jit dispatch never needs an implicit reshard."""
    with jax.transfer_guard("allow"):
        out = (jax.device_put(x, sharding) if sharding is not None
               else jnp.asarray(x))
    _bump("h2d")
    return out


def to_host(x) -> np.ndarray:
    """The batch-EXIT boundary: one declared device->host transfer."""
    with jax.transfer_guard("allow"):
        out = np.asarray(x)
    _bump("d2h")
    return out
