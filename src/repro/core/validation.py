"""Input hardening at the public serving boundary.

The serving engines are fixed-shape jit programs: garbage that reaches
them does not fail, it PROPAGATES.  A single NaN query row poisons every
distance it touches (NaN compares false everywhere, so the beam silently
fills with arbitrary ids), a ``k <= 0`` flows into ``lax.top_k`` and dies
with an opaque XLA shape error three layers down, and a query matrix of
the wrong width gathers out-of-range rows.  None of those should get past
the boundary, and the error should say which REQUEST is at fault — under
continuous batching one caller's garbage must never take down the other
queries sharing its batch (the serving loop rejects the poisoned rows
individually and serves the rest).

``validate_queries`` / ``validate_search_params`` are shared by every
public entry: ``pipnn.search``, ``ServingIndex.search``,
``ShardedServingIndex.search`` and ``launch.serve.Retriever.retrieve``.
"""
from __future__ import annotations

import numpy as np


class InvalidQueryError(ValueError):
    """A query batch failed boundary validation.

    ``rows`` lists the offending row indices (empty for batch-level
    failures such as a wrong shape or a non-numeric dtype), ``reason``
    is a machine-usable tag ("nan_inf" | "shape" | "dtype") — the
    serving loop maps ``rows`` back to request ids and rejects exactly
    those requests instead of the whole batch.
    """

    def __init__(self, message: str, *, rows=(), reason: str = "invalid"):
        super().__init__(message)
        self.rows = tuple(int(r) for r in rows)
        self.reason = reason


def nonfinite_rows(queries: np.ndarray) -> np.ndarray:
    """Indices of rows containing any NaN/Inf entry (the poison check,
    exposed separately so the serving loop can pre-screen per request)."""
    q = np.asarray(queries)
    bad = ~np.isfinite(q).all(axis=tuple(range(1, q.ndim)))
    return np.nonzero(bad)[0]


def validate_queries(queries, dim: int | None = None) -> np.ndarray:
    """Validate a query batch at the serving boundary; returns the batch
    as a C-contiguous float32 [Q, d] array.

    Rejects with a structured :class:`InvalidQueryError`:
      * non-numeric / non-castable dtypes (``reason="dtype"``),
      * anything but a 2-D [Q, d] matrix, or a width mismatch against
        the index's ``dim`` when given (``reason="shape"``),
      * rows containing NaN/Inf (``reason="nan_inf"``, ``rows`` set) —
        a NaN distance compares false against every beam entry and
        silently corrupts the result instead of failing.
    """
    try:
        q = np.ascontiguousarray(queries, dtype=np.float32)
    except (TypeError, ValueError) as e:
        raise InvalidQueryError(
            f"queries are not castable to float32: {e}",
            reason="dtype") from e
    if q.ndim != 2:
        raise InvalidQueryError(
            f"queries must be a 2-D [Q, d] batch, got shape {q.shape} "
            f"(a single query is queries[None, :])", reason="shape")
    if dim is not None and q.shape[0] and q.shape[1] != dim:
        raise InvalidQueryError(
            f"query width {q.shape[1]} does not match the index "
            f"dimension {dim}", reason="shape")
    rows = nonfinite_rows(q)
    if rows.size:
        head = ", ".join(str(r) for r in rows[:8])
        more = "" if rows.size <= 8 else f", ... ({rows.size} total)"
        raise InvalidQueryError(
            f"query rows [{head}{more}] contain NaN/Inf — a non-finite "
            f"query poisons every distance it touches; drop or fix the "
            f"rows (InvalidQueryError.rows lists them)",
            rows=rows, reason="nan_inf")
    return q


def validate_search_params(*, k: int, beam: int) -> None:
    """``k`` / ``beam`` guards shared by every search entry: both flow
    into ``lax.top_k`` / fixed-shape beam buffers, where a non-positive
    value is an opaque XLA shape error (or an empty result) instead of
    the obvious ValueError."""
    if int(k) <= 0:
        raise ValueError(f"k must be >= 1, got {k}")
    if int(beam) <= 0:
        raise ValueError(f"beam must be >= 1, got {beam}")
