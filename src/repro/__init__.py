"""repro: PiPNN (Pick-in-Partitions Nearest Neighbors) on JAX/TPU.

A production-grade multi-pod framework implementing the PiPNN graph-index
construction algorithm (HashPrune online pruning + randomized ball carving +
GEMM leaf building), an LM architecture zoo for the assigned dry-run matrix,
and the distributed runtime (mesh, launcher, checkpointing, roofline).
"""
__version__ = "1.0.0"
