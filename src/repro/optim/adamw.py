"""AdamW with configurable state dtypes + cosine schedule + clipping +
microbatched gradient accumulation.

Memory policy knobs (per-arch configs pick them; llama3-405b on 256 chips
needs ``moment_dtype=bf16`` to fit — the accounting is in EXPERIMENTS.md):

  * ``moment_dtype``: f32 (default) or bf16 moments (halves optimizer HBM);
  * master params stay in the params' own dtype; updates computed in f32.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(cfg: AdamWConfig, params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ))


def update(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def accumulate_grads(loss_fn, params, batch: Any, n_micro: int,
                     constraint_fn=None):
    """Microbatched grad accumulation via lax.scan over batch splits.

    batch leaves must have leading dim divisible by n_micro.  Returns
    (mean loss, mean grads).  The scan keeps only one microbatch's
    activations live — the activation-memory knob for the big archs.

    ``constraint_fn(key, x) -> x`` re-pins the sharding of each
    microbatch-split leaf.  This matters: the [B, ...] -> [n_micro, B/m,
    ...] reshape cannot preserve a data-axis sharding on dim 0, and
    without an explicit constraint GSPMD replicates the batch — every
    activation downstream then loses its data-parallel sharding (observed
    as a full-batch [32, 8, 512, 4096] attention-score tensor per device
    in the llama3 dry-run).
    """
    if n_micro == 1:
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        return loss, grads

    def split(key, x):
        # VLM "positions" are [3, B, T]: the batch dim is axis 1
        axis = 1 if key == "positions" else 0
        b = x.shape[axis]
        assert b % n_micro == 0, f"batch {b} % micro {n_micro}"
        return jnp.moveaxis(
            x.reshape(x.shape[:axis] + (n_micro, b // n_micro)
                      + x.shape[axis + 1:]),
            axis, 0,
        )

    micro = {k: split(k, v) for k, v in batch.items()}
    if constraint_fn is not None:
        micro = {k: constraint_fn(k, v) for k, v in micro.items()}
    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        acc_loss, acc_g = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        acc_g = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc_g, g)
        return (acc_loss + loss, acc_g), None

    (tot_loss, tot_g), _ = jax.lax.scan(body, (jnp.float32(0.0), zero), micro)
    inv = 1.0 / n_micro
    return tot_loss * inv, jax.tree.map(lambda g: g * inv, tot_g)
