"""Optimizers: AdamW w/ dtype policies, schedules, grad accumulation."""
from repro.optim.adamw import (
    AdamWConfig, AdamWState, accumulate_grads, global_norm, init, schedule,
    update,
)
