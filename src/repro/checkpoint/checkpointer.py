"""Sharded, async, elastic checkpointing.

Layout: ``<dir>/step_<n>/``, one ``.npy`` per pytree leaf plus a JSON
manifest (tree structure, shapes, dtypes, step, data-pipeline counter).
Writes happen on a background thread (training continues into the next
step while the previous checkpoint drains — async checkpointing), with an
atomic ``COMMIT`` marker written last; restore ignores uncommitted dirs,
so a failure mid-write can never corrupt the restore path.

Elastic: leaves are saved as LOGICAL (fully-gathered) arrays; ``restore``
re-shards onto whatever mesh/sharding the caller provides, so a checkpoint
taken on 256 chips restores onto 512 (or onto 1 CPU for debugging).
Deletion keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np

COMMIT = "COMMIT"
MANIFEST = "manifest.json"


def _flatten_with_names(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        name = "_".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        names.append(name)
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue[tuple | None]" = queue.Queue(maxsize=2)
        self._worker = threading.Thread(target=self._drain, daemon=True)
        self._worker.start()
        self._error: Exception | None = None

    # ------------------------------------------------------------- write --
    def save(self, step: int, tree: Any, extra: dict | None = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory now; write to disk asynchronously."""
        if self._error:
            raise self._error
        names, leaves, _ = _flatten_with_names(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        self._q.put((step, names, host, extra or {}))
        if blocking:
            self._q.join()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            try:
                self._write(*item)
            except Exception as e:  # surfaced on next save()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, step: int, names, host, extra):
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for name, arr in zip(names, host):
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"][name] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype)
            }
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, COMMIT), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        self._q.join()
        if self._error:
            raise self._error

    def close(self):
        self._q.put(None)
        self._q.join()

    # -------------------------------------------------------------- read --
    def committed_steps(self) -> list[int]:
        out = []
        for d in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, d)
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(full, COMMIT)):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Any,
                shard_fn: Callable[[str, np.ndarray], Any] | None = None):
        """Restore into the structure of ``like``; optionally re-shard each
        leaf via ``shard_fn(name, array) -> jax.Array`` (elastic restore).

        Returns (tree, extra_dict)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        if not os.path.exists(os.path.join(path, COMMIT)):
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        names, leaves, treedef = _flatten_with_names(like)
        out = []
        for name, leaf in zip(names, leaves):
            arr = np.load(os.path.join(path, name + ".npy"))
            want = tuple(getattr(leaf, "shape", arr.shape))
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"leaf {name}: checkpoint shape {arr.shape} != {want}")
            out.append(shard_fn(name, arr) if shard_fn else arr)
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest["extra"]
