"""Async sharded checkpointing with atomic commits + elastic restore."""
from repro.checkpoint.checkpointer import Checkpointer
