from repro.roofline.analysis import (  # noqa: F401
    HW, V5E, CellRoofline, analyze_compiled, collective_bytes,
)
