from repro.roofline.analysis import (  # noqa: F401
    HW, CellRoofline, analyze_compiled, collective_bytes, model_flops,
)
