"""Three-term roofline from a compiled (AOT) PiPNN program.

Consumed by the memory-bound auditor (``repro.analysis.memory_audit``,
rule PIPM006): every registered jitted hot path — the streaming build
chunk step, the reservoir folds, the final prune, the static carve, the
serving engine, the sharded search body and the cross-shard merge — gets
a three-term v5e estimate recorded alongside the memory envelope
(``memory_envelope.json``), so the bench trajectory (BENCH_build /
BENCH_qps) can be judged against hardware limits.  GGNN and CAGRA
(PAPERS.md) both show the binding constraint for graph-ANN on
accelerators is memory footprint and bandwidth, not FLOPs — which is why
the roofline prices all three terms instead of a FLOPs-only estimate.

No real TPU exists in this container, so the "profile" is the compiled
module itself:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_wire_bytes_per_device / link_bw

``compiled.cost_analysis()`` reports the PER-DEVICE partitioned module's
flops and bytes (the SPMD partitioner has already divided the global
program by the mesh), so dividing by per-chip peaks directly yields
seconds — equivalent to the global formula  HLO_FLOPs / (chips * peak).

collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO text
(``compiled.as_text()``) and sum wire traffic per collective with the
standard ring/bidirectional cost model:

  all-reduce      2 * bytes * (g-1)/g     (reduce-scatter + all-gather)
  all-gather      bytes_out * (g-1)/g
  reduce-scatter  bytes_in  * (g-1)/g
  all-to-all      bytes * (g-1)/g
  collective-permute  bytes               (point-to-point)

where g = participating group size parsed from ``replica_groups`` (both the
explicit {{0,1,..}} and the iota [a,b]<=[n] encodings).

Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (set in ``HW``).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}


def _default_hbm_bytes() -> float:
    # single-sourced with PIPS003 / PIPM003 (kernels/tiling.hbm_budget):
    # the roofline's fits-HBM bit and the lint gates price the same number
    from repro.kernels.tiling import hbm_budget

    return float(hbm_budget())


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: float = dataclasses.field(
        default_factory=_default_hbm_bytes)  # HBM capacity per chip


V5E = HW()


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # [a,b]<=[n]: a groups of size b
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str, *, n_devices: int) -> dict[str, Any]:
    """Sum per-device wire bytes of every collective in post-SPMD HLO.

    Returns {"total": bytes, "by_op": {op: bytes}, "count": int,
             "ops": [(op, bytes, group)] top-40 largest}.
    """
    by_op: dict[str, float] = {}
    ops: list[tuple[str, float, int]] = []
    count = 0
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        # async pairs: count -start, skip -done (result type repeats there)
        if f"{op}-done" in line.split("=", 1)[1][:120]:
            continue
        g = _group_size(line, n_devices)
        nbytes = _shape_bytes(type_str)
        if g <= 1 or nbytes == 0:
            continue
        frac = (g - 1) / g
        if op == "all-reduce":
            wire = 2 * nbytes * frac
        elif op == "all-gather":
            wire = nbytes * frac                  # result is the full gather
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)               # result is 1/g of input
        elif op == "all-to-all":
            wire = nbytes * frac
        else:                                     # collective-permute
            wire = nbytes
        by_op[op] = by_op.get(op, 0.0) + wire
        ops.append((op, wire, g))
        count += 1
    ops.sort(key=lambda t: -t[1])
    return {
        "total": sum(by_op.values()),
        "by_op": by_op,
        "count": count,
        "ops": ops[:40],
    }


# ---------------------------------------------------------------------------
# Roofline assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CellRoofline:
    name: str
    mesh: str
    n_devices: int
    kind: str
    # raw per-device numbers
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_op: dict
    bytes_per_device: float          # peak HBM residency (memory_analysis)
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    # useful-work accounting
    useful_flops_global: float = 0.0
    useful_ratio: float = 0.0        # useful / (hlo_flops * n_devices)
    fits_hbm: bool = True
    note: str = ""

    def finalize(self, hw: HW = V5E) -> "CellRoofline":
        self.t_compute = self.hlo_flops / hw.peak_flops
        self.t_memory = self.hlo_bytes / hw.hbm_bw
        self.t_collective = self.coll_bytes / hw.link_bw
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        self.dominant = max(terms, key=terms.get)
        if self.useful_flops_global:
            total = self.hlo_flops * self.n_devices
            self.useful_ratio = self.useful_flops_global / max(total, 1.0)
        self.fits_hbm = self.bytes_per_device <= hw.hbm_bytes
        return self

    def bound_seconds(self) -> float:
        """Roofline lower bound on step time (max of the three terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["coll_by_op"] = {k: float(v) for k, v in self.coll_by_op.items()}
        return d


def analyze_compiled(compiled, *, name: str, mesh_name: str, n_devices: int,
                     kind: str, useful_flops: float = 0.0,
                     hw: HW = V5E, hlo_text: str | None = None,
                     note: str = "") -> CellRoofline:
    """Build a CellRoofline from a jax AOT ``compiled`` object.

    Terms come from the trip-count-aware HLO walker (``hlo_cost``), NOT
    from ``compiled.cost_analysis()`` — the latter counts every lax.scan
    body once (verified: a length-17 scan reports 1x the body flops),
    which is off by ~n_layers for every scanned-stack model here.  The
    raw cost_analysis numbers are kept in the record for cross-checking.
    """
    from repro.roofline import hlo_cost

    cost = compiled.cost_analysis()
    if isinstance(cost, list):             # older jax returns [dict]
        cost = cost[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    walk = hlo_cost.analyze(text, n_devices=n_devices)
    flops = walk.flops
    byts = walk.bytes
    coll = {"total": walk.coll_bytes, "by_op": walk.coll_by_op,
            "ops": walk.coll_ops}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem[k] = float(getattr(ma, k, 0.0) or 0.0)
    except Exception:
        pass
    resident = (mem.get("argument_size_in_bytes", 0.0)
                + mem.get("output_size_in_bytes", 0.0)
                + mem.get("temp_size_in_bytes", 0.0)
                - mem.get("alias_size_in_bytes", 0.0))

    if not note:
        note = (f"cost_analysis(raw, scan-body-once): "
                f"flops={float(cost.get('flops', 0.0)):.3e} "
                f"bytes={float(cost.get('bytes accessed', 0.0)):.3e}; "
                f"walker: {walk.n_while} whiles, "
                f"{walk.unknown_trip} unknown trip counts")
    return CellRoofline(
        name=name, mesh=mesh_name, n_devices=n_devices, kind=kind,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes=float(coll["total"]), coll_by_op=coll["by_op"],
        bytes_per_device=resident,
        useful_flops_global=useful_flops, note=note,
    ).finalize(hw)


def dump(rooflines: list[CellRoofline], path: str) -> None:
    with open(path, "w") as f:
        json.dump([r.to_json() for r in rooflines], f, indent=1)


def fmt_seconds(s: float) -> str:
    if s <= 0:
        return "0"
    if s < 1e-3:
        return f"{s * 1e6:.1f}us"
    if s < 1:
        return f"{s * 1e3:.2f}ms"
    return f"{s:.3f}s"


def markdown_table(rooflines: list[CellRoofline]) -> str:
    hdr = ("| cell | mesh | kind | compute | memory | collective | dominant "
           "| useful/HLO | HBM/chip | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in rooflines:
        rows.append(
            f"| {r.name} | {r.mesh} | {r.kind} | {fmt_seconds(r.t_compute)} "
            f"| {fmt_seconds(r.t_memory)} | {fmt_seconds(r.t_collective)} "
            f"| **{r.dominant}** | {r.useful_ratio:.2f} "
            f"| {r.bytes_per_device / 1e9:.2f}GB "
            f"| {'yes' if r.fits_hbm else 'NO'} |"
        )
    return hdr + "\n".join(rows)
