"""Trip-count-aware cost model over post-SPMD HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a while-loop body
ONCE, independent of its trip count — so every lax.scan (over layers, over
attention q-chunks, over SSD chunks) makes flops/bytes/collectives wrong
by the trip count (126x for llama3's layer scan).  XLA however records
``backend_config={"known_trip_count":{"n":"..."}}`` on each while op, so an
HLO-text walk can attribute costs exactly:

  * FLOPs       — from ``dot`` ops: 2 * prod(result dims) * prod(contracted
                  lhs dims).  (Transformer/PiPNN compute is all dots; the
                  elementwise remainder is <1% and intentionally ignored.)
  * HBM bytes   — operands + result of top-level memory-moving ops
                  (fusion, dot, copy, sort, gather/scatter, dynamic-slice/
                  update, reduce, transpose, concatenate, broadcast, pad,
                  convert, collectives).  Tuple-shuffling ops (bitcast,
                  get-tuple-element, tuple, parameter, constant) are free.
  * collective  — wire bytes per collective op with the standard ring cost
                  model (see ``wire_bytes_for``).

The walk starts at ENTRY with multiplier 1; a ``while`` multiplies its body
and condition by the known trip count (nested scans compose); ``fusion``
computations are descended for *flops only* (their internals don't touch
HBM); call/conditional descend at the same multiplier.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Iterable

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s1": 1, "u1": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]"
    r"(?:\{[\d,]*\})?))\s*([\w\-]+)\(([^)]*)\)(.*)$")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\]))")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply"
                       r"|branch_computations)=\{?%?([\w.\-]+)")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose operands+result plausibly round-trip HBM when at top level
_MEM_OPS = {
    "fusion", "dot", "copy", "sort", "gather", "scatter", "dynamic-slice",
    "dynamic-update-slice", "reduce", "transpose", "concatenate",
    "broadcast", "pad", "reshape", "select-and-scatter",
    "reduce-window", "iota", "rng-bit-generator", "cholesky",
    "triangular-solve", "convolution", "custom-call", "reverse", "select",
    "compare", "add", "multiply", "subtract", "divide", "exponential",
    "tanh", "rsqrt", "maximum", "minimum", "clamp", "slice",
} | set(COLLECTIVES) | {c + "-start" for c in COLLECTIVES}

# XLA:CPU's float-normalization pass widens bf16 programs to f32 with
# convert ops that do not exist in the TPU lowering; converts/bitcasts are
# treated as transparent so the roofline models the TPU program.
_TRANSPARENT = {"convert", "bitcast"}


def shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def shape_dims(type_str: str) -> tuple[str, list[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "opaque", []
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    types: dict[str, str]   # local op/param name -> type string


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        if not line:
            continue
        if not line.startswith(" "):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(2), [], {})
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                for pname, ptype in _PARAM_RE.findall(m.group(3)):
                    cur.types[pname] = ptype
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, operands, attrs = m.groups()
        # operand entries may carry inline types ("f32[64,256]{1,0} %x") whose
        # commas break a naive split; pull the %-prefixed names directly
        ops = re.findall(r"%([\w.\-]+)", operands)
        if not ops:  # older prints: no % prefix, maybe still inline-typed
            ops = [o.strip().split(" ")[-1]
                   for o in operands.split(",") if o.strip()]
        op = Op(name, rtype, opcode, ops, attrs)
        cur.ops.append(op)
        cur.types[name] = rtype
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    _, rdims = shape_dims(op.result_type)
    out = 1.0
    for d in rdims:
        out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    contracted = 1.0
    if m and op.operands:
        lhs_type = comp.types.get(op.operands[0], "")
        _, ldims = shape_dims(lhs_type)
        for i in (int(x) for x in m.group(1).split(",") if x):
            if i < len(ldims):
                contracted *= ldims[i]
    return 2.0 * out * contracted


def _group_size(attrs: str, default: int) -> int:
    m = _GROUPS_EXPL_RE.search(attrs)
    if m:
        first = m.group(1).split("},{")[0].strip("{}")
        return len([t for t in first.split(",") if t.strip() != ""])
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    return default


def wire_bytes_for(opcode: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if opcode.startswith("all-reduce"):
        return 2.0 * nbytes * frac
    if opcode.startswith("all-gather"):
        return nbytes * frac              # result is the gathered tensor
    if opcode.startswith("reduce-scatter"):
        return nbytes * (g - 1)           # result is 1/g of the input
    if opcode.startswith("all-to-all"):
        return nbytes * frac
    return float(nbytes)                  # collective-permute


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    coll_ops: list = dataclasses.field(default_factory=list)
    mem_ops: list = dataclasses.field(default_factory=list)   # top byte movers
    n_while: int = 0
    unknown_trip: int = 0

    def add_bytes(self, op_name: str, opcode: str, b: float, mult: float):
        self.bytes += b * mult
        self.mem_ops.append((opcode, op_name, b * mult, mult))

    def add_collective(self, opcode: str, wire: float, g: int, mult: float):
        key = opcode.replace("-start", "")
        self.coll_by_op[key] = self.coll_by_op.get(key, 0.0) + wire * mult
        self.coll_bytes += wire * mult
        self.coll_ops.append((key, wire, g, mult))


def analyze(text: str, *, n_devices: int) -> HloCost:
    comps, entry = parse_hlo(text)
    cost = HloCost()

    def visit(comp_name: str, mult: float, flops_only: bool = False):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                cost.n_while += 1
                m = _TRIP_RE.search(op.attrs)
                trip = int(m.group(1)) if m else 1
                if not m:
                    cost.unknown_trip += 1
                for target in _call_targets(op):
                    visit(target, mult * trip, flops_only)
                continue
            if oc == "fusion":
                if not flops_only:
                    cost.add_bytes(op.name, oc, _fusion_bytes(op, comp), mult)
                for target in _call_targets(op):
                    visit(target, mult, flops_only=True)
                continue
            if oc in ("call", "conditional", "async-start"):
                for target in _call_targets(op):
                    visit(target, mult, flops_only)
                continue
            if oc == "dot":
                cost.flops += mult * _dot_flops(op, comp)
                if not flops_only:
                    cost.add_bytes(op.name, oc, _op_bytes(op, comp), mult)
                continue
            if oc == "convolution":
                # rare here (frontends stubbed); approximate via result*2*K
                cost.flops += mult * 2.0 * shape_bytes(op.result_type)
                if not flops_only:
                    cost.bytes += mult * _op_bytes(op, comp)
                continue
            base = oc.replace("-start", "").replace("-done", "")
            if base in COLLECTIVES and not oc.endswith("-done"):
                if not flops_only:
                    nbytes = _coll_payload_bytes(op, comp)
                    g = _group_size(op.attrs, n_devices)
                    cost.add_collective(base, wire_bytes_for(base, nbytes, g),
                                        g, mult)
                    cost.add_bytes(op.name, base, _op_bytes(op, comp), mult)
                continue
            if not flops_only and oc in _MEM_OPS:
                cost.add_bytes(op.name, oc, _op_bytes(op, comp), mult)

    def _op_bytes(op: Op, comp: Computation) -> float:
        """HBM traffic of one op.  Sliced accesses only touch the slice:

          * dynamic-slice / gather / slice read ``result`` bytes, not the
            full operand (XLA reads the addressed window);
          * dynamic-update-slice writes (and reads) the ``update`` operand
            region in place — the big operand is aliased, not copied.
        """
        oc = op.opcode
        if oc in ("dynamic-slice", "gather", "slice"):
            return 2.0 * shape_bytes(op.result_type)
        if oc == "dynamic-update-slice" and len(op.operands) >= 2:
            upd = comp.types.get(op.operands[1], "")
            return 2.0 * shape_bytes(upd)
        total = float(shape_bytes(op.result_type))
        for o in op.operands:
            t = comp.types.get(o)
            if t:
                total += shape_bytes(t)
        return total

    def _fusion_bytes(op: Op, comp: Computation) -> float:
        """Traffic of a fusion: parameters used only through slicing ops
        inside the fused computation count their sliced windows, not the
        whole array (the layer-stacked weight/cache tensors threaded
        through scan bodies would otherwise be charged in full each
        iteration).  A DUS root writes its update region in place."""
        called = None
        for target in _call_targets(op):
            called = comps.get(target)
            break
        if called is None:
            return _op_bytes(op, comp)
        pnames = [n for n in called.types if n.startswith("param")]
        # parameters are declared in order param_0, param_1, ...
        pnames.sort(key=lambda s: [int(x) for x in re.findall(r"\d+", s)]
                    or [0])

        def terminal_uses(name: str, depth: int = 0) -> list[Op]:
            """Users of ``name``, looking through convert/bitcast chains."""
            out: list[Op] = []
            for o in called.ops:
                if name in o.operands:
                    if o.opcode in _TRANSPARENT and depth < 8:
                        out.extend(terminal_uses(o.name, depth + 1))
                    else:
                        out.append(o)
            return out

        def windowed_bytes(pname: str, u: Op) -> float | None:
            """Bytes actually touched if the use is a windowed access."""
            if u.opcode in ("dynamic-slice", "gather", "slice"):
                return float(shape_bytes(u.result_type))
            if u.opcode == "dynamic-update-slice" and u.operands \
                    and u.operands[0] == pname:
                return 0.0   # in-place target; root handling counts the update
            return None

        total = 0.0
        for i, operand in enumerate(op.operands):
            t = comp.types.get(operand, "")
            if i >= len(pnames):
                total += shape_bytes(t)
                continue
            uses = terminal_uses(pnames[i])
            win = [windowed_bytes(pnames[i], u) for u in uses]
            # NB: transparent chains rename the value; a DUS targeting the
            # converted alias still means in-place on TPU — match by chain.
            if uses and all(w is not None or
                            (u.opcode == "dynamic-update-slice")
                            for u, w in zip(uses, win)):
                total += sum(w or 0.0 for w in win)
            else:
                total += shape_bytes(t)
        # root: look through transparent wrappers for an in-place DUS
        root = called.ops[-1] if called.ops else None
        by_name = {o.name: o for o in called.ops}
        depth = 0
        while root is not None and root.opcode in _TRANSPARENT and depth < 8:
            root = by_name.get(root.operands[0]) if root.operands else None
            depth += 1
        if root is not None and root.opcode == "dynamic-update-slice" \
                and len(root.operands) >= 2:
            total += 2.0 * shape_bytes(called.types.get(root.operands[1], ""))
        else:
            total += shape_bytes(op.result_type)
        return total

    def _coll_payload_bytes(op: Op, comp: Computation) -> int:
        # use the LARGER of result / first operand (all-gather result vs
        # reduce-scatter operand conventions)
        rb = shape_bytes(op.result_type)
        ob = max((shape_bytes(comp.types.get(o, "")) for o in op.operands),
                 default=0)
        if op.opcode.startswith("reduce-scatter"):
            return rb   # wire model multiplies by (g-1)
        if op.opcode.startswith("all-gather"):
            return rb
        return max(rb, ob)

    def _call_targets(op: Op) -> Iterable[str]:
        return _CALLS_RE.findall(op.attrs)

    visit(entry, 1.0)
    cost.coll_ops.sort(key=lambda t: -t[1] * t[3])
    cost.coll_ops = cost.coll_ops[:40]
    cost.mem_ops.sort(key=lambda t: -t[2])
    cost.mem_ops = cost.mem_ops[:40]
    return cost
