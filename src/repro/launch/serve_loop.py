"""Resilient ANN serving loop: continuous batching with straggler drain,
SLO-aware graceful degradation, and shard-failure survival.

``ServingIndex`` / ``ShardedServingIndex`` answer one batch at a time;
production serving is a LOOP under open load, and everything interesting
happens at the loop level.  This module is that loop, built on telemetry
and primitives the engines already expose:

  * **Bounded admission with backpressure.**  ``submit`` enqueues into a
    bounded queue; when full it rejects with :class:`QueueFull` carrying
    a ``retry_after`` estimate (queue depth x the measured per-request
    service rate) instead of buffering unboundedly — load shedding at
    the edge, the only place it is cheap.
  * **Continuous batching + two-phase straggler drain.**  ``step`` forms
    a batch up to ``query_chunk`` and serves it in two phases.  Phase 1
    runs with a REDUCED iters cap (``drain_iters``): under the engine's
    batched ``lax.while_loop`` one slow query holds every batchmate
    hostage to the full backstop, so capping low drains the converged
    majority early — convergence is a fixed point (the early-exit parity
    test), so a query the ``converged`` telemetry marks done returns
    results BIT-IDENTICAL to a full single-phase run.  Phase 2 reruns
    only the stragglers, padded to the fixed ``straggler_chunk`` (one
    compiled variant, not one per straggler count — the recompile-audit
    rule), under the full ``backstop_iters`` cap.
  * **Deadline propagation.**  Requests carry an optional deadline;
    expired requests are answered ``timeout`` without burning a search,
    and a straggler whose deadline passes phase 1 gets its (valid,
    possibly unconverged) phase-1 beam back flagged ``partial`` rather
    than paying for phase 2.
  * **Per-request poison isolation.**  NaN/Inf rows are screened out of
    the formed batch per request (``core.validation``): the poisoned
    request alone gets a structured ``invalid:nan_inf`` error result and
    its batchmates are served normally.
  * **SLO-aware graceful degradation.**  A precomputed ladder of
    operating points (beam / expansions — derived from BENCH_qps.json
    measurements via :func:`ladder_from_bench` when available) is walked
    DOWN when queue depth or the rolling p99
    (``distributed.fault_tolerance.RollingPercentile``) crosses its
    threshold, and back UP after a sustained recovery; every shift logs
    the measured recall bound being traded.
  * **Shard-failure survival.**  A search failure attributable to a
    shard (the exception carries a ``.shard`` attribute — e.g.
    ``testing.faults.InjectedShardFailure``) tombstones that shard
    (``mark_shard_down``) and retries the SAME batch against the
    survivors; tombstoned shards are re-probed every ``probe_every``
    steps and re-admitted when ``probe_shard`` succeeds.

Everything is deterministic under an injected ``clock`` and the
fault schedules of ``repro.testing.faults`` — the regression tests
replay shard loss, poisoned payloads and stragglers bit-for-bit.
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Callable

import numpy as np

from repro.core.validation import (InvalidQueryError, validate_queries,
                                   validate_search_params)
from repro.distributed.fault_tolerance import RollingPercentile

__all__ = [
    "OperatingPoint", "QueueFull", "Request", "Result", "ServeLoop",
    "default_ladder", "ladder_from_bench",
]


class QueueFull(RuntimeError):
    """Admission rejected: the bounded request queue is at capacity.

    ``retry_after`` (seconds) estimates when a slot frees up — queue
    depth times the measured per-request service time."""

    def __init__(self, depth: int, retry_after: float):
        super().__init__(
            f"request queue full ({depth} pending); retry in "
            f"~{retry_after:.3f}s")
        self.depth = int(depth)
        self.retry_after = float(retry_after)


@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One rung of the degradation ladder.

    ``recall_bound`` is the measured recall at this rung (from
    BENCH_qps.json when derived by :func:`ladder_from_bench`) — what a
    downshift trades away, logged at shift time; None = unmeasured."""

    name: str
    beam: int
    expansions: int = 4
    recall_bound: float | None = None
    qps: float | None = None


@dataclasses.dataclass
class Request:
    rid: int
    query: np.ndarray                 # [d] f32
    deadline: float | None            # absolute, in the loop's clock
    enqueued_at: float


@dataclasses.dataclass
class Result:
    """One request's outcome.  ``error`` is None on success, else a
    structured tag ("invalid:nan_inf" | "timeout"); ``partial`` marks a
    straggler answered with its phase-1 beam because its deadline could
    not afford phase 2."""

    rid: int
    ids: np.ndarray | None            # [k] int64 global ids, -1 pad
    error: str | None = None
    latency: float = 0.0
    op_point: str = ""
    phase: int = 0                    # 1 = drained, 2 = straggler rerun
    partial: bool = False

    @property
    def ok(self) -> bool:
        return self.error is None


def default_ladder(beam: int = 32) -> tuple[OperatingPoint, ...]:
    """Static fallback ladder when no bench measurements exist: full
    quality, then half the beam with narrower expansion, then a floor
    rung that keeps serving at minimum cost."""
    return (
        OperatingPoint(f"full_b{beam}", beam=beam, expansions=4),
        OperatingPoint(f"degraded_b{max(8, beam // 2)}",
                       beam=max(8, beam // 2), expansions=2),
        OperatingPoint(f"floor_b{max(4, beam // 4)}",
                       beam=max(4, beam // 4), expansions=1),
    )


def straggler_workspace_bytes(straggler_chunk: int, n: int, d: int, r: int,
                              max_beam: int, expansions: int = 4) -> int:
    """Modeled XLA temp bytes of the straggler-rerun dispatch: the same
    engine program as the drain pass but at the fixed ``straggler_chunk``
    batch, the ladder's WIDEST beam and the full ``backstop_iters`` cap
    (iters only bounds the while loop — it never shapes a buffer, so the
    model is the engine model at the straggler shape).  Registered with
    the memory auditor as its own program (the compile is distinct) and
    validated per lattice point (PIPM004) / priced at the per-shard
    envelope (PIPM003)."""
    from repro.core.serving import engine_workspace_bytes

    return engine_workspace_bytes(straggler_chunk, n, d, r, max_beam,
                                  expansions)


def ladder_from_bench(path, *, max_rungs: int = 4
                      ) -> tuple[OperatingPoint, ...] | None:
    """Derive the degradation ladder from BENCH_qps.json measurements.

    Serving-engine records (``engine`` "serve_E{n}" / "serve", with
    ``beam``/``recall``/``qps``) are reduced to the recall/qps PARETO
    FRONTIER ordered by descending recall — every downshift then trades
    a MEASURED recall bound for a measured throughput gain; dominated
    points (same or worse recall at no more qps) never become rungs.
    Returns None when the file is missing or holds no usable records
    (callers fall back to :func:`default_ladder`)."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return None
    entries = data if isinstance(data, list) else [data]
    points: dict[tuple[int, int], OperatingPoint] = {}
    for entry in entries:
        for rec in entry.get("records", ()):
            engine = str(rec.get("engine", ""))
            if not engine.startswith("serve"):
                continue
            beam, recall = rec.get("beam"), rec.get("recall")
            if beam is None or recall is None:
                continue
            exp = 4
            if "_E" in engine:
                try:
                    exp = int(engine.rsplit("_E", 1)[1])
                except ValueError:
                    continue
            elif engine != "serve":
                continue            # serve_i8 etc.: different packing
            key = (int(beam), exp)
            prev = points.get(key)
            if prev is None or float(recall) > (prev.recall_bound or 0.0):
                points[key] = OperatingPoint(
                    f"serve_b{beam}_E{exp}", beam=int(beam),
                    expansions=exp, recall_bound=float(recall),
                    qps=(None if rec.get("qps") is None
                         else float(rec["qps"])))
    if not points:
        return None
    ladder, best_qps = [], -np.inf
    for p in sorted(points.values(),
                    key=lambda p: (-(p.recall_bound or 0.0),
                                   -(p.qps or 0.0))):
        if (p.qps or 0.0) > best_qps or not ladder:
            ladder.append(p)
            best_qps = p.qps or 0.0
    return tuple(ladder[:max_rungs])


class ServeLoop:
    """The resilient serving loop over a ``ServingIndex`` or
    ``ShardedServingIndex`` (anything with the engines' ``search``
    signature and ``converged`` telemetry).

    ``clock`` is injectable (tests pass a fake) and is the loop's ONLY
    time source — deadlines, latencies and the p99 window all read it.
    ``two_phase=False`` degenerates to classic single-phase batching
    (the baseline ``bench_serving_loop.py`` compares against).
    """

    def __init__(
        self,
        index,
        *,
        k: int = 10,
        query_chunk: int = 32,
        straggler_chunk: int = 8,
        max_queue: int = 256,
        drain_iters: int | None = None,
        backstop_iters: int | None = None,
        ladder: tuple[OperatingPoint, ...] | None = None,
        slo_p99: float | None = None,
        queue_high: int | None = None,
        min_p99_samples: int = 20,
        shift_cooldown: int = 4,
        probe_every: int = 4,
        max_retries: int | None = None,
        two_phase: bool = True,
        clock: Callable[[], float] = time.monotonic,
        on_event: Callable[[str, dict], None] | None = None,
    ):
        from repro.core.beam_search import default_iters

        self.index = index
        self.k = int(k)
        self.query_chunk = int(query_chunk)
        self.straggler_chunk = max(1, min(int(straggler_chunk),
                                          self.query_chunk))
        self.max_queue = int(max_queue)
        self.ladder = tuple(ladder) if ladder else default_ladder()
        for p in self.ladder:
            validate_search_params(k=self.k, beam=p.beam)
        max_beam = max(p.beam for p in self.ladder)
        # phase 1 drains at roughly half the backstop: low enough that a
        # straggler cannot hold the batch to the full cap, high enough
        # that typical queries converge inside it (see BENCH_serving)
        self.drain_iters = int(drain_iters if drain_iters is not None
                               else max(4, default_iters(max_beam) // 2))
        self.backstop_iters = int(
            backstop_iters if backstop_iters is not None
            else default_iters(max_beam))
        self.slo_p99 = slo_p99
        self.queue_high = int(queue_high if queue_high is not None
                              else 2 * self.query_chunk)
        self.min_p99_samples = int(min_p99_samples)
        self.shift_cooldown = int(shift_cooldown)
        self.probe_every = int(probe_every)
        # a retry per shard survives even the every-shard-but-one drill
        n_shards = getattr(index, "n_shards", 1)
        self.max_retries = int(max_retries if max_retries is not None
                               else n_shards)
        self.two_phase = bool(two_phase)
        self.clock = clock
        self.on_event = on_event

        self._dim = int(index.points.shape[-1])
        self._queue: collections.deque[Request] = collections.deque()
        self._next_rid = 0
        self._rung = 0                 # index into self.ladder (0 = best)
        self._steps = 0
        self._last_shift_step = -10**9
        self._p99 = RollingPercentile(window=256)
        self._service_ema = 0.0        # seconds per request, smoothed
        self.counters = collections.Counter()

    # ---------------------------------------------------------- admission --
    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def operating_point(self) -> OperatingPoint:
        return self.ladder[self._rung]

    def submit(self, query: np.ndarray, *, deadline_s: float | None = None
               ) -> int:
        """Enqueue one request; returns its rid.

        Raises :class:`QueueFull` (with ``retry_after``) at capacity and
        :class:`InvalidQueryError` for a malformed query SHAPE — shape
        errors are the submitter's bug and fail fast, while non-finite
        VALUES are accepted here and answered with a structured error
        result at serve time (the poison drill: a NaN payload must flow
        through the loop without hurting its batchmates)."""
        if len(self._queue) >= self.max_queue:
            self.counters["rejected"] += 1
            retry = max(0.001, len(self._queue)
                        * max(self._service_ema, 1e-4))
            raise QueueFull(len(self._queue), retry)
        q = np.asarray(query, dtype=np.float32).reshape(-1)
        if q.shape[0] != self._dim:
            raise InvalidQueryError(
                f"query width {q.shape[0]} does not match the index "
                f"dimension {self._dim}", reason="shape")
        rid = self._next_rid
        self._next_rid += 1
        now = self.clock()
        self._queue.append(Request(
            rid=rid, query=q,
            deadline=None if deadline_s is None else now + deadline_s,
            enqueued_at=now))
        return rid

    # ------------------------------------------------------------ serving --
    def step(self) -> list[Result]:
        """Serve one batch: form it from the queue head, screen poison,
        run the two-phase search, adapt the operating point.  Returns a
        Result per request taken off the queue this step (empty when the
        queue was empty)."""
        self._steps += 1
        if self.probe_every and self._steps % self.probe_every == 0:
            self._probe_tombstones()
        batch: list[Request] = []
        while self._queue and len(batch) < self.query_chunk:
            batch.append(self._queue.popleft())
        if not batch:
            return []
        now = self.clock()
        results: list[Result] = []
        live: list[Request] = []
        for r in batch:
            if r.deadline is not None and now >= r.deadline:
                self.counters["timeout"] += 1
                results.append(Result(r.rid, None, error="timeout",
                                      latency=now - r.enqueued_at))
            elif not np.isfinite(r.query).all():
                self.counters["invalid"] += 1
                results.append(Result(r.rid, None, error="invalid:nan_inf",
                                      latency=now - r.enqueued_at))
            else:
                live.append(r)
        if live:
            results.extend(self._serve(live))
        self._adapt()
        return results

    def run_until_drained(self, *, max_steps: int = 10**6) -> list[Result]:
        out: list[Result] = []
        steps = 0
        while self._queue and steps < max_steps:
            out.extend(self.step())
            steps += 1
        return out

    # ------------------------------------------------------------ internal --
    def _emit(self, kind: str, **detail) -> None:
        if self.on_event is not None:
            self.on_event(kind, detail)

    def _search(self, queries: np.ndarray, *, iters: int, chunk: int):
        """One engine dispatch with shard-failure survival: an exception
        carrying ``.shard`` tombstones that shard and retries the SAME
        batch against the survivors (bounded by ``max_retries``)."""
        op = self.operating_point
        for attempt in range(self.max_retries + 1):
            try:
                return self.index.search(
                    queries, k=self.k, beam=op.beam,
                    expansions=op.expansions, iters=iters,
                    query_chunk=chunk, with_stats=True)
            except Exception as e:  # noqa: BLE001 — filtered just below
                shard = getattr(e, "shard", None)
                if (shard is None or attempt >= self.max_retries
                        or not hasattr(self.index, "mark_shard_down")):
                    raise
                self.index.mark_shard_down(int(shard))
                self.counters["shards_marked_down"] += 1
                self._emit("shard_down", shard=int(shard),
                           step=self._steps)
        raise AssertionError("unreachable")  # pragma: no cover

    def _probe_tombstones(self) -> None:
        probe = getattr(self.index, "probe_shard", None)
        if probe is None:
            return
        for s in getattr(self.index, "down_shards", ()):
            if probe(s):
                self.counters["shards_readmitted"] += 1
                self._emit("shard_up", shard=int(s), step=self._steps)

    def _serve(self, live: list[Request]) -> list[Result]:
        op = self.operating_point
        q = validate_queries(
            np.stack([r.query for r in live]), dim=self._dim)
        t0 = self.clock()
        if not self.two_phase:
            ids, _ = self._search(q, iters=self.backstop_iters,
                                  chunk=self.query_chunk)
            return [self._finish(r, ids[i], phase=1, t0=t0)
                    for i, r in enumerate(live)]
        ids1, stats1 = self._search(q, iters=self.drain_iters,
                                    chunk=self.query_chunk)
        conv = np.asarray(stats1["converged"], bool)
        results = []
        t1 = self.clock()
        stragglers, s_rows = [], []
        for i, r in enumerate(live):
            if conv[i]:
                results.append(self._finish(r, ids1[i], phase=1, t0=t0,
                                            now=t1))
            elif r.deadline is not None and t1 >= r.deadline:
                # phase 2 cannot make its deadline: answer with the
                # valid (possibly unconverged) phase-1 beam, flagged
                self.counters["partial"] += 1
                results.append(self._finish(r, ids1[i], phase=1, t0=t0,
                                            now=t1, partial=True))
            else:
                stragglers.append(r)
                s_rows.append(i)
        self.counters["drained_phase1"] += len(results)
        if stragglers:
            self.counters["rerun_phase2"] += len(stragglers)
            for c0 in range(0, len(stragglers), self.straggler_chunk):
                part = stragglers[c0 : c0 + self.straggler_chunk]
                qs = q[np.asarray(s_rows[c0 : c0 + self.straggler_chunk])]
                ids2, _ = self._search(qs, iters=self.backstop_iters,
                                       chunk=self.straggler_chunk)
                results.extend(self._finish(r, ids2[j], phase=2, t0=t0)
                               for j, r in enumerate(part))
        return results

    def _finish(self, r: Request, ids, *, phase: int, t0: float,
                now: float | None = None, partial: bool = False) -> Result:
        now = self.clock() if now is None else now
        latency = now - r.enqueued_at
        self._p99.record(latency)
        service = now - t0
        self._service_ema = (0.2 * service + 0.8 * self._service_ema
                             if self._service_ema else service)
        self.counters["served"] += 1
        return Result(r.rid, np.asarray(ids), latency=latency,
                      op_point=self.operating_point.name, phase=phase,
                      partial=partial)

    def _adapt(self) -> None:
        """Walk the ladder: DOWN when queue depth or rolling p99 breaches
        its threshold, UP after a sustained recovery (hysteresis: half
        the thresholds, plus a cooldown between shifts)."""
        if self._steps - self._last_shift_step < self.shift_cooldown:
            return
        p99 = (self._p99.percentile(99.0)
               if len(self._p99) >= self.min_p99_samples else None)
        depth = self.queue_depth
        overloaded = depth > self.queue_high or (
            self.slo_p99 is not None and p99 is not None
            and p99 > self.slo_p99)
        recovered = depth <= self.queue_high // 2 and (
            self.slo_p99 is None or p99 is None or p99 < 0.5 * self.slo_p99)
        if overloaded and self._rung + 1 < len(self.ladder):
            self._shift(self._rung + 1, "downshift", depth=depth, p99=p99)
        elif recovered and self._rung > 0:
            self._shift(self._rung - 1, "upshift", depth=depth, p99=p99)

    def _shift(self, rung: int, kind: str, **detail) -> None:
        old, new = self.ladder[self._rung], self.ladder[rung]
        self._rung = rung
        self._last_shift_step = self._steps
        self.counters[kind] += 1
        self._emit(kind, from_point=old.name, to_point=new.name,
                   recall_bound_from=old.recall_bound,
                   recall_bound_to=new.recall_bound, step=self._steps,
                   **detail)
