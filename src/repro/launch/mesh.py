"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run target meshes:

  * single-pod: 16 x 16  = 256 chips, axes ("data", "model")
  * multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model")

On hardware with more devices than the mesh needs (e.g. the dry-run's 512
virtual CPU devices hosting a 256-chip mesh) the first ``prod(shape)``
devices are used.  ``make_local_mesh`` builds whatever mesh the actually
available devices support — used by train.py / serve.py / tests.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    if len(devices) > need:
        return jax.make_mesh(shape, axes, devices=devices[:need])
    raise RuntimeError(
        f"production mesh {shape} needs {need} devices, have {len(devices)} "
        "(the dry-run sets XLA_FLAGS=--xla_force_host_platform_device_count"
        "=512 before importing jax)"
    )


def make_local_mesh(model_parallel: int = 1) -> Mesh:
    """A ("data", "model") mesh over whatever devices exist right now."""
    devices = jax.devices()
    n = len(devices)
    if n % model_parallel != 0:
        raise ValueError(f"{n} devices not divisible by mp={model_parallel}")
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), devices=devices)
