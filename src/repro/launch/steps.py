"""Step builders shared by train.py and serve.py.

One place defines, per (architecture x shape-cell):

  * the jit-able step function      (train_step / prefill_step / serve_step)
  * its abstract inputs             (ShapeDtypeStruct pytrees, no allocation)
  * its in/out shardings on a mesh  (from repro.distributed.sharding rules)

so the launchers all compile EXACTLY the same programs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.models import model_zoo
from repro.optim import adamw


def build_model(arch: ArchConfig, *, smoke: bool = False,
                act_sharding=None, attn_impl: str | None = None,
                moe_impl: str | None = None) -> model_zoo.Model:
    cfg = arch.smoke_model if smoke else arch.model
    if act_sharding is not None and hasattr(cfg, "act_sharding"):
        cfg = dataclasses.replace(cfg, act_sharding=act_sharding)
    if attn_impl is not None and hasattr(cfg, "attn_impl"):
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    if moe_impl is not None and hasattr(cfg, "moe_impl"):
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    return model_zoo.build(cfg, arch.family)


def act_sharding_for(mesh: Mesh, policy: str, batch: int,
                     seq: int) -> NamedSharding:
    """[B, T, D] activation pin for the policy.

    Batch over every axis the policy allows; when the batch cannot cover
    the model axis (e.g. 32-sequence 32k prefill), fall back to batch
    over (pod, data) + SEQUENCE over model — sequence parallelism keeps
    all chips busy without replicating compute.
    """
    axes = shd.all_axes(mesh) if policy in ("fsdp", "ep_dp") \
        else shd.data_axes(mesh)
    if shd._dim_ok(batch, mesh, axes):
        return NamedSharding(mesh, P(axes, None, None))
    da = shd.data_axes(mesh)
    b_ax = da if shd._dim_ok(batch, mesh, da) else None
    s_ax = "model" if (policy in ("fsdp", "ep_dp")
                       and "model" in mesh.axis_names
                       and seq % mesh.shape["model"] == 0) else None
    return NamedSharding(mesh, P(b_ax, s_ax, None))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState


def make_train_step(model: model_zoo.Model, opt_cfg: adamw.AdamWConfig,
                    n_micro: int = 1, mesh: Mesh | None = None,
                    policy: str = "fsdp_tp") -> Callable:
    constraint = microbatch_constraint(mesh, policy) \
        if mesh is not None else None

    def train_step(state: TrainState, batch):
        loss, grads = adamw.accumulate_grads(
            model.loss_fn, state.params, batch, n_micro,
            constraint_fn=constraint)
        params, opt, metrics = adamw.update(
            opt_cfg, grads, state.opt, state.params)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return train_step


def microbatch_constraint(mesh: Mesh, policy: str = "fsdp_tp"):
    """Re-pin data-axis sharding after the microbatch reshape (see
    adamw.accumulate_grads): leaves are [n_micro, B/m, ...] (or
    [n_micro, 3, B/m, T] for VLM positions)."""
    da = shd.all_axes(mesh) if policy in ("fsdp", "ep_dp") \
        else shd.data_axes(mesh)
    da2 = shd.data_axes(mesh)

    def constrain(key, x):
        bdim = 2 if key == "positions" else 1
        axes = da if shd._dim_ok(x.shape[bdim], mesh, da) else \
            (da2 if shd._dim_ok(x.shape[bdim], mesh, da2) else None)
        spec = P(*(None,) * bdim, axes, *(None,) * (x.ndim - bdim - 1))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


def abstract_train_state(model: model_zoo.Model,
                         opt_cfg: adamw.AdamWConfig) -> TrainState:
    """ShapeDtypeStruct pytree of the full train state — no allocation."""
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(lambda p: adamw.init(opt_cfg, p), params)
    return TrainState(params=params, opt=opt)


def train_state_shardings(state: TrainState, mesh: Mesh,
                          family: str, policy: str = "fsdp_tp") -> TrainState:
    pshard = shd.params_shardings(state.params, mesh, family, policy)
    rep = NamedSharding(mesh, P())
    return TrainState(
        params=pshard,
        opt=adamw.AdamWState(step=rep, m=pshard, v=pshard),
    )


# ---------------------------------------------------------------------------
# Serve (prefill / decode)
# ---------------------------------------------------------------------------

def make_prefill_step(model: model_zoo.Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch, max_len)

    return prefill_step


def make_serve_step(model: model_zoo.Model) -> Callable:
    """One decode step: next-token logits given a KV/SSM cache."""

    def serve_step(params, token, cache):
        return model.decode_step(params, token, cache)

    return serve_step


# ---------------------------------------------------------------------------
# Cell assembly: everything the dry-run needs for one (arch x shape x mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CellProgram:
    """A jit-ready (fn, abstract args, shardings) triple for one cell."""
    name: str
    kind: str                    # train | prefill | decode
    fn: Callable
    args: tuple                  # ShapeDtypeStruct pytrees
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jitted().lower(*self.args)


def _batch_sharding_tree(batch_spec: dict, mesh: Mesh,
                         policy: str = "fsdp_tp"):
    return shd.batch_shardings(batch_spec, mesh, policy)


def _logits_sharding(mesh: Mesh, batch: int, vocab: int) -> NamedSharding:
    """[B, V] logits: batch over data, vocab over model (when divisible).

    The unembedding table is vocab-sharded over `model`, so logits land
    model-sharded on V naturally; keeping them that way avoids an
    all-gather of a [B, 152k] f32 tensor at the step boundary.
    """
    da = shd.data_axes(mesh)
    b_ax = da if shd._dim_ok(batch, mesh, da) else None
    v_ax = "model" if shd._dim_ok(vocab, mesh, "model") else None
    return NamedSharding(mesh, P(b_ax, v_ax))


def cell_program(arch: ArchConfig, cell: ShapeCell, mesh: Mesh,
                 *, smoke: bool = False,
                 opt_cfg: adamw.AdamWConfig | None = None) -> CellProgram:
    """Build the compile unit for one (arch x shape) on ``mesh``."""
    family = arch.family
    policy = arch.parallelism
    # decode wants weights RESIDENT (TP), not ZeRO-3-gathered per token:
    # a 1-token step under fsdp re-gathers every layer's weights for
    # almost no compute (measured 2-3x worse decode bounds), so decode
    # cells of fsdp archs fall back to the fsdp_tp layout.
    if cell.kind == "decode" and policy == "fsdp":
        policy = "fsdp_tp"
    # fsdp policies shard the sequence, not the heads: use the
    # sequence-parallel flash variant (no q-scan to break the sharding)
    attn_impl = "flash_sp" if policy in ("fsdp", "ep_dp") else None
    model = build_model(
        arch, smoke=smoke, attn_impl=attn_impl,
        moe_impl="ep_a2a" if policy == "ep_dp" else None,
        act_sharding=act_sharding_for(
            mesh, policy, cell.global_batch, cell.seq_len))
    rep = NamedSharding(mesh, P())
    scalars_rep = functools.partial(jax.tree.map, lambda _: rep)

    if cell.kind == "train":
        opt_cfg = opt_cfg or adamw.AdamWConfig(
            moment_dtype=getattr(arch.model, "param_dtype", jnp.float32))
        n_micro = arch.microbatch(cell.name)
        fn = make_train_step(model, opt_cfg, n_micro, mesh=mesh,
                             policy=policy)
        state = abstract_train_state(model, opt_cfg)
        st_shard = train_state_shardings(state, mesh, family, policy)
        batch = model.train_batch_spec(cell.global_batch, cell.seq_len)
        b_shard = _batch_sharding_tree(batch, mesh, policy)
        out_shardings = (st_shard, {"grad_norm": rep, "lr": rep, "loss": rep})
        return CellProgram(
            name=f"{arch.arch_id}:{cell.name}", kind="train", fn=fn,
            args=(state, batch), in_shardings=(st_shard, b_shard),
            out_shardings=out_shardings, donate_argnums=(0,),
        )

    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = shd.params_shardings(params, mesh, family, policy)

    if cell.kind == "prefill":
        fn = make_prefill_step(model, cell.seq_len)
        batch = model.prefill_batch_spec(cell.global_batch, cell.seq_len)
        b_shard = _batch_sharding_tree(batch, mesh, policy)
        cache = model.init_cache_spec(cell.global_batch, cell.seq_len)
        c_shard = shd.cache_shardings(cache, mesh, policy)
        vocab = getattr(arch.model, "vocab", 0)
        logits_shard = _logits_sharding(mesh, cell.global_batch, vocab)
        return CellProgram(
            name=f"{arch.arch_id}:{cell.name}", kind="prefill", fn=fn,
            args=(params, batch), in_shardings=(p_shard, b_shard),
            out_shardings=(logits_shard, c_shard),
        )

    if cell.kind == "decode":
        fn = make_serve_step(model)
        token = model.decode_spec(cell.global_batch)
        t_shard = NamedSharding(
            mesh, shd.batch_spec("tokens", token, mesh, policy))
        cache = model.init_cache_spec(cell.global_batch, cell.seq_len)
        c_shard = shd.cache_shardings(cache, mesh, policy)
        vocab = getattr(arch.model, "vocab", 0)
        logits_shard = _logits_sharding(mesh, cell.global_batch, vocab)
        return CellProgram(
            name=f"{arch.arch_id}:{cell.name}", kind="decode", fn=fn,
            args=(params, token, cache),
            in_shardings=(p_shard, t_shard, c_shard),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(2,),
        )

    raise ValueError(f"unknown cell kind {cell.kind!r}")


def input_specs(arch: ArchConfig, cell: ShapeCell, *,
                smoke: bool = False) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step."""
    return cell_program(
        arch, cell,
        mesh=jax.make_mesh((1, 1), ("data", "model"),
                           devices=jax.devices()[:1]),
        smoke=smoke,
    ).args
