"""Fault-tolerant training driver.

Runs any ``--arch`` on whatever devices exist (full config for clusters,
``--smoke`` reduced config for CPU), with the production substrate wired
end-to-end:

  * pjit train step with the per-family sharding rules (steps.py);
  * counter-based resumable data pipeline (data/pipeline.py);
  * async, committed, elastic checkpoints (checkpoint/) — ``--resume``
    restarts from the newest committed step on a possibly different mesh;
  * RunGuard (SIGTERM -> checkpoint at the step boundary) + StepWatchdog
    straggler flagging (distributed/fault_tolerance.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 50 --batch 16 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 20 --ckpt-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import TokenPipeline, TokenPipelineConfig
from repro.distributed import sharding as shd
from repro.distributed.fault_tolerance import RunGuard, StepWatchdog
from repro.launch import steps
from repro.launch.mesh import make_local_mesh
from repro.optim import adamw


def make_batch_fn(model, family: str, pipe: TokenPipeline, seq: int):
    """Adapt the token pipeline to the family's batch dict."""
    d_model = getattr(model.config, "d_model", 0)

    def get(step: int):
        b = pipe.batch(step)
        if family == "encdec":
            rng = np.random.default_rng(step)
            b["frames"] = rng.standard_normal(
                (b["tokens"].shape[0], seq, d_model)).astype(np.float32) \
                .astype(jnp.bfloat16)
        if family == "vlm":
            pos = np.broadcast_to(
                np.arange(seq, dtype=np.int32)[None],
                b["tokens"].shape)
            b["positions"] = np.broadcast_to(pos[None], (3,) + pos.shape)
        return b

    return get


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_config(args.arch)
    model = steps.build_model(arch, smoke=args.smoke)
    mesh = make_local_mesh(args.model_parallel)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps),
                                total_steps=args.steps)

    train_step = steps.make_train_step(model, opt_cfg, args.micro,
                                   mesh=mesh,
                                   policy=arch.parallelism)
    state_shapes = steps.abstract_train_state(model, opt_cfg)
    st_shard = steps.train_state_shardings(state_shapes, mesh,
                                       arch.family,
                                       arch.parallelism)
    batch_spec = model.train_batch_spec(args.batch, args.seq)
    b_shard = shd.batch_shardings(batch_spec, mesh, arch.parallelism)

    jit_step = jax.jit(train_step, in_shardings=(st_shard, b_shard),
                       out_shardings=None, donate_argnums=(0,))

    vocab = getattr(model.config, "vocab")
    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))
    get_batch = make_batch_fn(model, arch.family, pipe, args.seq)

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    guard = RunGuard()
    watchdog = StepWatchdog(
        on_straggler=lambda s, t, mu: print(
            f"[watchdog] step {s} took {t:.2f}s (mean {mu:.2f}s) — "
            "straggler flagged", flush=True))

    start_step = 0
    if ckpt and args.resume and ckpt.latest_step() is not None:
        from repro.distributed.elastic import restore_to_mesh
        state, extra = restore_to_mesh(
            ckpt, ckpt.latest_step(), state_shapes, mesh, arch.family,
            arch.parallelism)
        # opt/step live inside the state; data pipeline resumes by counter
        start_step = int(extra.get("step", ckpt.latest_step()))
        print(f"resumed from step {start_step} onto "
              f"{len(jax.devices())} device(s)")
    else:
        def init_fn(key):
            params = model.init(key)
            return steps.TrainState(params=params,
                                    opt=adamw.init(opt_cfg, params))

        with mesh:
            state = jax.jit(init_fn, out_shardings=st_shard)(
                jax.random.PRNGKey(args.seed))

    losses = []
    for step in range(start_step, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in get_batch(step).items()}
        state, metrics = jit_step(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        watchdog.record(step, dt)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt * 1e3:7.1f}ms",
                  flush=True)
        want_ckpt = ckpt and (
            (step + 1) % args.ckpt_every == 0 or guard.should_stop
            or step == args.steps - 1)
        if want_ckpt:
            ckpt.save(step + 1, state, extra={"step": step + 1},
                      blocking=guard.should_stop)
        if guard.should_stop:
            print(f"preemption requested: checkpointed at step {step + 1}, "
                  "exiting cleanly")
            break
    if ckpt:
        ckpt.wait()
        ckpt.close()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({len(watchdog.flagged)} straggler step(s) flagged)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
