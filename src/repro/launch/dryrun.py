import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-touching import: jax locks the device count at
# first init, and the production meshes below need 512 placeholder devices.
# Only the dry-run sets this — tests/benches see the real (1) device.

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402

from repro.configs.base import SHAPES, ArchConfig, ShapeCell      # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config           # noqa: E402
from repro.launch import steps                                    # noqa: E402
from repro.launch.mesh import make_production_mesh                # noqa: E402
from repro.roofline import analysis as roofline                   # noqa: E402

MESHES = {
    "single": dict(multi_pod=False, n_devices=256),
    "multi": dict(multi_pod=True, n_devices=512),
}


# ---------------------------------------------------------------------------
# Useful-FLOPs accounting (MODEL_FLOPS = 6*N*D / 2*N*D, N_active for MoE)
# ---------------------------------------------------------------------------

def count_params(arch: ArchConfig) -> tuple[float, float]:
    """(total params, active params) from the abstract param tree.

    MoE expert stacks (4-D ``moe``-scoped leaves) count top_k/E of their
    size toward the active total; everything else counts fully.  Tied
    embeddings count once — the unembed matmul's FLOPs are then exactly
    6*d*V per train token, which the 6*N*D formula already includes.
    """
    model = steps.build_model(arch)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    moe = getattr(arch.model, "moe", None)
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    total = active = 0.0
    for path, leaf in flat:
        name = "_".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        size = float(np.prod(leaf.shape))
        total += size
        if moe is not None and "moe" in name and leaf.ndim == 4:
            active += size * moe.top_k / moe.n_experts
        else:
            active += size
    return total, active


def useful_flops(arch: ArchConfig, cell: ShapeCell) -> float:
    _, active = count_params(arch)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
    elif cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
    else:                                   # decode: one token per sequence
        tokens = cell.global_batch
    return roofline.model_flops(active, tokens, cell.kind)


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def run_cell(arch: ArchConfig, cell: ShapeCell, mesh_name: str,
             out_dir: str, *, force: bool = False) -> dict:
    tag = f"{arch.arch_id}__{cell.name}__{mesh_name}"
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    info = MESHES[mesh_name]
    mesh = make_production_mesh(multi_pod=info["multi_pod"])
    t0 = time.perf_counter()
    record: dict = {"cell": f"{arch.arch_id}:{cell.name}", "mesh": mesh_name,
                    "n_devices": info["n_devices"], "kind": cell.kind}
    try:
        with mesh:
            prog = steps.cell_program(arch, cell, mesh)
            lowered = prog.lower()
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

            ma = compiled.memory_analysis()
            print(f"[{tag}] memory_analysis: {ma}")
            ca = compiled.cost_analysis()
            if isinstance(ca, list):
                ca = ca[0]
            print(f"[{tag}] cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")

            roof = roofline.analyze_compiled(
                compiled, name=record["cell"], mesh_name=mesh_name,
                n_devices=info["n_devices"], kind=cell.kind,
                useful_flops=useful_flops(arch, cell),
            )
            record.update(roof.to_json())
            record["lower_s"] = round(t_lower, 2)
            record["compile_s"] = round(t_compile, 2)
            record["ok"] = True
            del compiled, lowered, prog
    except Exception as e:
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{tag}] FAILED: {record['error']}", file=sys.stderr)

    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    status = "ok" if record.get("ok") else "FAIL"
    print(f"[{tag}] {status} "
          f"(lower {record.get('lower_s', '-')}s, "
          f"compile {record.get('compile_s', '-')}s, "
          f"dominant {record.get('dominant', '-')})")
    return record


# ---------------------------------------------------------------------------
# The PiPNN distributed index-build workload (the paper's own technique)
# ---------------------------------------------------------------------------

def run_index_build(mesh_name: str, out_dir: str, *, n_points: int,
                    dim: int, force: bool = False,
                    variant: str = "baseline") -> dict:
    from repro.launch import build_index

    tag = f"pipnn-index-build-{variant}__n{n_points}_d{dim}__{mesh_name}"
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    info = MESHES[mesh_name]
    mesh = make_production_mesh(multi_pod=info["multi_pod"])
    record: dict = {"cell": tag, "mesh": mesh_name,
                    "n_devices": info["n_devices"], "kind": "index_build"}
    t0 = time.perf_counter()
    try:
        with mesh:
            lowered = build_index.lower_build_step(
                mesh, n_points=n_points, dim=dim, variant=variant)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            print(f"[{tag}] memory_analysis: {compiled.memory_analysis()}")
            roof = roofline.analyze_compiled(
                compiled, name=tag, mesh_name=mesh_name,
                n_devices=info["n_devices"], kind="index_build",
                useful_flops=build_index.useful_flops(n_points, dim),
            )
            record.update(roof.to_json())
            record["lower_s"] = round(t_lower, 2)
            record["compile_s"] = round(t_compile, 2)
            record["ok"] = True
    except Exception as e:
        record["ok"] = False
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{tag}] FAILED: {record['error']}", file=sys.stderr)

    os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[{tag}] {'ok' if record.get('ok') else 'FAIL'}")
    return record


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AOT multi-pod dry-run: lower+compile every "
                    "(arch x shape x mesh) cell; no data is allocated.")
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--workload", choices=["lm", "index_build"],
                    default="lm")
    ap.add_argument("--index-points", type=int, default=1 << 30)
    ap.add_argument("--index-dim", type=int, default=128)
    ap.add_argument("--index-variant", default="baseline")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.workload == "index_build":
        ok = True
        for m in meshes:
            rec = run_index_build(m, args.out, n_points=args.index_points,
                                  dim=args.index_dim, force=args.force,
                                  variant=args.index_variant)
            ok &= rec.get("ok", False)
        return 0 if ok else 1

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    n_fail = 0
    for arch_id in archs:
        arch = get_config(arch_id)
        cells = arch.runnable_cells()
        if args.shape != "all":
            cells = [c for c in cells if c.name == args.shape]
            if not cells:
                skip = dict(arch.skipped_cells())
                if args.shape in skip:
                    print(f"[{arch_id}:{args.shape}] SKIPPED: "
                          f"{skip[args.shape]}")
                    continue
        for cell in cells:
            for m in meshes:
                rec = run_cell(arch, cell, m, args.out, force=args.force)
                n_fail += 0 if rec.get("ok") else 1
        for name, why in arch.skipped_cells():
            print(f"[{arch_id}:{name}] SKIPPED: {why}")
    print(f"dry-run done, {n_fail} failures")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
