"""Distributed PiPNN index build — the paper's technique as a static,
multi-pod SPMD program (DESIGN.md §4, and the paper's §6 future-work item:
"PiPNN's approach is a natural fit for distributed data processing").

The build is a bulk-synchronous pipeline of two jitted supersteps, each
expressed with ``jax.shard_map`` + explicit ``all_to_all`` routing so the
dry-run compiles the EXACT collective schedule a 512-chip run would use:

  tile step (``make_tile_step``), per 2^24-point tile:
    1. local sketches + level-0 leader GEMM -> top-f0 bucket ids   [local]
    2. capacity-routed all_to_all: point replicas -> bucket owners [A2A #1]
    3. level-1 leader GEMM + top-f1 -> leaf grouping               [local]
    4. batched leaf all-pairs GEMM + top-k -> bidirected edges     [local]
    5. capacity-routed all_to_all: edges -> src owner              [A2A #2]
    6. HashPrune closed form + reservoir merge (Thm 3.1 licenses
       the per-tile streaming — mergeability)                      [local]

  final prune step (``make_final_prune_step``):
    7. request/response all_to_all for candidate vectors           [A2A #3,4]
    8. batched RobustPrune over each reservoir                     [local]

Everything is static-shape: routing uses MoE-style per-destination
capacities with slack; overflow is dropped (counted in stats).  The same
code runs on 1 CPU device (S=1 collectives are identity) — tests compare
its output quality against the host-orchestrated build.

Variants (the §Perf hillclimb knobs for the paper's own workload):
  * ``baseline``  — f32 vectors routed, f32 leaf GEMM (paper-faithful).
  * ``quantized`` — int8 vectors + f32 scale routed (4x less wire), int8
    leaf GEMM with i32 accumulation (paper §6 future-work, realized).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import sketch as _sketch
from repro.core.hashprune import (INVALID_ID, Reservoir, merge_flat_edges,
                                  merge_segmented_edges, reservoir_init)
from repro.core.leader_assign import leader_assign
from repro.distributed import compat as _compat
from repro.core.robust_prune import prune_reservoir_block
from repro.distributed.routing import group_by_capacity

INF = jnp.float32(jnp.inf)
_shard_map = _compat.shard_map_norep


# ---------------------------------------------------------------------------
# Static configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DistBuildParams:
    dim: int = 128
    n_tile: int = 1 << 24        # points per superstep tile
    m_bits: int = 12
    l0: int = 1024               # level-0 leaders (global, paper cap 1000)
    f0: int = 10                 # top-level fanout      (paper Sec. 4.1)
    l1: int = 1152               # level-1 leaders per bucket (sized so the
    #                              target leaf fill is ~55%: skewed leaves
    #                              stay under the hard c_max cap)
    f1: int = 3                  # second-level fanout   (paper: ~3)
    c_max: int = 1024            # leaf size cap
    k: int = 2                   # leaf k-NN (paper default, Fig. 11)
    l_max: int = 64              # HashPrune reservoir
    max_deg: int = 64
    alpha: float = 1.44          # RobustPrune alpha^2 (squared-l2 space)
    bucket_slack: float = 1.3
    leaf_slack: float = 1.0      # leaves already have c_max as the hard cap
    edge_slack: float = 1.3
    assign_chunk: int = 2048     # level-1 GEMM chunk rows
    leaf_chunk: int = 8          # leaves per batched GEMM launch
    prune_chunk: int = 2048
    route_dtype: str = "f32"     # "f32" | "int8" (quantized variant)
    leaf_dtype: str = "f32"      # "f32" | "bf16": dtype of the materialized
    #                              leaf distance matrix (bf16 halves the
    #                              dominant HBM traffic; ranking-only use)
    merge: str = "segmented"     # reservoir fold in the tile step:
    #                              "segmented" sorts only the received edge
    #                              chunk and does a bounded per-row merge;
    #                              "flat" is the global-re-sort oracle

    @classmethod
    def tiny(cls, **kw) -> "DistBuildParams":
        """CPU-test scale."""
        base = dict(dim=16, n_tile=2048, l0=16, f0=3, l1=32, f1=2,
                    c_max=128, k=2, l_max=32, max_deg=24,
                    assign_chunk=256, leaf_chunk=4, prune_chunk=256,
                    bucket_slack=2.0, edge_slack=2.0)
        base.update(kw)
        return cls(**base)

    def derived(self, n_shards: int) -> dict[str, int]:
        assert self.n_tile % n_shards == 0, (self.n_tile, n_shards)
        assert self.l0 % n_shards == 0, "l0 must divide over shards"
        n_loc = self.n_tile // n_shards
        nb_loc = self.l0 // n_shards
        # level-0 dispatch capacity per destination shard
        cap_send = _round_up(
            int(n_loc * self.f0 / n_shards * self.bucket_slack) + 1, 8)
        # per-bucket capacity (points landing in one level-0 bucket)
        cap_b = _round_up(
            int(self.n_tile * self.f0 / self.l0 * self.bucket_slack) + 1,
            self.assign_chunk)
        n_leaf = nb_loc * self.l1
        n_leaf = _round_up(n_leaf, self.leaf_chunk)
        e_loc = nb_loc * cap_b  # leaf instances before fanout
        n_edges = n_leaf * self.c_max * self.k * 2
        cap_edge = _round_up(
            int(n_edges / n_shards * self.edge_slack) + 1, 8)
        cap_req = _round_up(
            int(n_loc * self.l_max / n_shards * self.edge_slack) + 1, 8)
        return dict(n_loc=n_loc, nb_loc=nb_loc, cap_send=cap_send,
                    cap_b=cap_b, n_leaf=n_leaf, n_edges=n_edges,
                    cap_edge=cap_edge, cap_req=cap_req, e_loc=e_loc)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def mesh_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(mesh.axis_names)


# ---------------------------------------------------------------------------
# Tile superstep
# ---------------------------------------------------------------------------



def _quantize(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    # the repo's ONE symmetric int8 scheme — shared with the quantized
    # ServingIndex packing and the gather-distance kernel's query side
    from repro.kernels.ref import quantize_symmetric

    return quantize_symmetric(v)


def _route_pack(v: jax.Array, p: DistBuildParams):
    if p.route_dtype == "int8":
        return _quantize(v)
    return v, None


def _route_unpack(v: jax.Array, scale, p: DistBuildParams) -> jax.Array:
    if p.route_dtype == "int8":
        return v.astype(jnp.float32) * scale[..., None]
    return v


def _leaf_pair_dists_neg(vecs: jax.Array, p: DistBuildParams) -> jax.Array:
    """NEGATED all-pairs squared-L2 for a [B, C, d] leaf batch
    (2<a,b> - |a|^2 - |b|^2), so ``lax.top_k`` selects nearest neighbors
    directly — the separate negate pass over the [C, C] matrix was 25% of
    the tile step's HBM bytes.  ``leaf_dtype=bf16`` halves the rest (the
    matrix is only ever used for ranking).

    quantized variant: int8 x int8 GEMM with i32 accumulation, rescaled —
    the MXU-native path the paper lists as future work.
    """
    if p.route_dtype == "int8":
        q, scale = _quantize(vecs)
        ip = jnp.einsum("bcd,bed->bce", q.astype(jnp.int32),
                        q.astype(jnp.int32),
                        preferred_element_type=jnp.int32)
        ip = ip.astype(jnp.float32) * scale[:, :, None] * scale[:, None, :]
        v = vecs.astype(jnp.float32)
        n2 = jnp.sum(v * v, axis=-1)
    else:
        v = vecs
        ip = jnp.einsum("bcd,bed->bce", v, v)
        n2 = jnp.sum(v * v, axis=-1)
    neg = jnp.minimum(2.0 * ip - n2[:, :, None] - n2[:, None, :], 0.0)
    if p.leaf_dtype == "bf16":
        neg = neg.astype(jnp.bfloat16)
    return neg


def make_tile_step(mesh: Mesh, p: DistBuildParams):
    """Returns tile_step(points, hyperplanes, reservoir) -> (reservoir, stats).

    points [n_tile, d] and the reservoir are sharded over ALL mesh axes
    (dim 0); hyperplanes [m, d] replicated.
    """
    axes = mesh_axes(mesh)
    S = int(np.prod([mesh.shape[a] for a in axes]))
    dv = p.derived(S)
    n_loc, nb_loc = dv["n_loc"], dv["nb_loc"]

    def shard_body(points, hyperplanes, res_ids, res_hash, res_dist):
        points = points.astype(jnp.float32)
        me = jax.lax.axis_index(axes)
        gid0 = me * n_loc + jnp.arange(n_loc, dtype=jnp.int32)

        # ---- 1. sketches + level-0 leaders --------------------------------
        sk = points @ hyperplanes.T                       # [n_loc, m]
        lead_stride = n_loc // (p.l0 // S)
        lead_local = points[::lead_stride][: p.l0 // S]   # [l0/S, d]
        leaders0 = jax.lax.all_gather(
            lead_local, axes, axis=0, tiled=True)         # [l0, d]
        bucket = leader_assign(points, leaders0, p.f0)    # [n_loc, f0]

        # ---- 2. route point replicas to bucket owners ---------------------
        flat_bucket = bucket.reshape(-1)                  # [n_loc*f0]
        owner = flat_bucket % S
        rep = lambda a: jnp.repeat(a, p.f0, axis=0)
        vec_r, scale_r = _route_pack(rep(points), p)
        pay = [vec_r, rep(sk), rep(gid0), flat_bucket]
        if scale_r is not None:
            pay.append(scale_r)
        (sent, sent_valid) = group_by_capacity(
            owner, jnp.ones_like(owner, bool), S, dv["cap_send"], pay)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axes,
                                split_axis=0, concat_axis=0, tiled=True)
        recv = [a2a(x) for x in sent]
        recv_valid = a2a(sent_valid)
        n_recv = S * dv["cap_send"]
        recv = [x.reshape((n_recv,) + x.shape[2:]) for x in recv]
        recv_valid = recv_valid.reshape(-1)
        if scale_r is not None:
            r_vec, r_sk, r_gid, r_bucket, r_scale = recv
        else:
            (r_vec, r_sk, r_gid, r_bucket), r_scale = recv, None
        # dispatch capacity overflow (dropped replicas)
        drop_dispatch = (jnp.int32(n_loc * p.f0)
                         - jnp.sum(sent_valid.astype(jnp.int32)))

        # regroup into my local buckets: bucket b lives at slot b // S
        bslot = jnp.where(recv_valid, r_bucket // S, nb_loc)
        pay2 = [r_vec, r_sk, r_gid]
        if r_scale is not None:
            pay2.append(r_scale)
        grouped, g_valid = group_by_capacity(
            bslot, recv_valid, nb_loc, dv["cap_b"], pay2)
        if r_scale is not None:
            b_vec, b_sk, b_gid, b_scale = grouped
        else:
            (b_vec, b_sk, b_gid), b_scale = grouped, None
        b_vecf = _route_unpack(b_vec, b_scale, p)         # [nb, capB, d] f32
        b_vecf = jnp.where(g_valid[..., None], b_vecf, 0.0)

        # ---- 3. level-1 leaders + leaf assignment -------------------------
        l1_stride = max(dv["cap_b"] // p.l1, 1)
        lead1 = b_vecf[:, ::l1_stride][:, : p.l1]          # [nb, l1, d]
        lead1_ok = g_valid[:, ::l1_stride][:, : p.l1]      # [nb, l1]

        def assign_chunk(chunk_vec, chunk_valid):
            # shared Stage-1 leader-assignment step: batched GEMM + top-f1
            return leader_assign(
                chunk_vec, lead1, p.f1, point_valid=chunk_valid,
                leader_valid=lead1_ok)                    # [nb, ch, f1]

        n_chunks = dv["cap_b"] // p.assign_chunk
        cvecs = b_vecf.reshape(nb_loc, n_chunks, p.assign_chunk, p.dim)
        cval = g_valid.reshape(nb_loc, n_chunks, p.assign_chunk)
        leader1 = jax.lax.map(
            lambda t: assign_chunk(t[0], t[1]),
            (jnp.swapaxes(cvecs, 0, 1), jnp.swapaxes(cval, 0, 1)),
        )                                                  # [nc, nb, ch, f1]
        leader1 = jnp.swapaxes(leader1, 0, 1).reshape(
            nb_loc, dv["cap_b"], p.f1)

        # leaf key = bucket_slot * l1 + leader1 ; group to [n_leaf, c_max]
        binst = nb_loc * dv["cap_b"]
        leaf_key = (jnp.arange(nb_loc, dtype=jnp.int32)[:, None, None] * p.l1
                    + leader1).reshape(-1)
        inst_valid = jnp.repeat(g_valid.reshape(-1), p.f1)
        rep1 = lambda a: jnp.repeat(
            a.reshape((binst,) + a.shape[2:]), p.f1, axis=0)
        pay3 = [rep1(b_vecf), rep1(b_sk), rep1(b_gid)]
        (lf_vec, lf_sk, lf_gid), lf_valid = group_by_capacity(
            leaf_key, inst_valid, dv["n_leaf"], p.c_max, pay3, shuffle=True)

        # ---- 4. leaf all-pairs GEMM + bidirected k-NN edges ---------------
        def leaf_chunk_edges(vec, skc, gidc, val):
            nd_mat = _leaf_pair_dists_neg(vec, p)          # [ch, C, C] (-d2)
            eye = jnp.eye(p.c_max, dtype=bool)
            bad = (~val[:, None, :]) | (~val[:, :, None]) | eye[None]
            # duplicate gids (same point via two buckets) -> mask
            dup = gidc[:, :, None] == gidc[:, None, :]
            neg_inf = jnp.asarray(-jnp.inf, nd_mat.dtype)
            nd_mat = jnp.where(bad | (dup & ~eye[None]), neg_inf, nd_mat)
            nd, ni = jax.lax.top_k(nd_mat, p.k)            # [ch, C, k]
            nd = -nd.astype(jnp.float32)
            src = jnp.broadcast_to(gidc[:, :, None], ni.shape)
            # per-leaf gathers (vmap keeps these O(C*k), no CxC broadcast)
            dst = jax.vmap(lambda g, i: g[i])(gidc, ni)        # [ch, C, k]
            sks = jnp.broadcast_to(skc[:, :, None, :],
                                   ni.shape + (p.m_bits,))
            skd = jax.vmap(lambda s, i: s[i])(skc, ni)         # [ch, C, k, m]
            ok = jnp.isfinite(nd) & (dst != INVALID_ID) & (src != INVALID_ID)
            # out-edge src->dst hashed h_src(dst); in-edge dst->src h_dst(src)
            h_out = _sketch.hash_from_sketches(skd, sks)
            h_in = _sketch.hash_from_sketches(sks, skd)
            e_src = jnp.stack([src, dst], -1)
            e_dst = jnp.stack([dst, src], -1)
            e_h = jnp.stack([h_out, h_in], -1)
            e_d = jnp.stack([nd, nd], -1)
            e_ok = jnp.stack([ok, ok], -1)
            return (jnp.where(e_ok, e_src, INVALID_ID).reshape(-1),
                    jnp.where(e_ok, e_dst, INVALID_ID).reshape(-1),
                    jnp.where(e_ok, e_h, 0).reshape(-1),
                    jnp.where(e_ok, e_d, INF).reshape(-1))

        nl_chunks = dv["n_leaf"] // p.leaf_chunk
        resh = lambda a: a.reshape((nl_chunks, p.leaf_chunk) + a.shape[1:])
        e_src, e_dst, e_h, e_d = jax.lax.map(
            lambda t: leaf_chunk_edges(*t),
            (resh(lf_vec.astype(jnp.float32)), resh(lf_sk), resh(lf_gid),
             resh(lf_valid)),
        )
        e_src, e_dst = e_src.reshape(-1), e_dst.reshape(-1)
        e_h, e_d = e_h.reshape(-1), e_d.reshape(-1)

        # ---- 5. route edges home ------------------------------------------
        e_owner = jnp.where(e_src >= 0, e_src // n_loc, S)
        (s_edges, s_ok) = group_by_capacity(
            e_owner, e_src >= 0, S, dv["cap_edge"],
            [e_src, e_dst, e_h, e_d])
        r_edges = [a2a(x) for x in s_edges]
        r_ok = a2a(s_ok).reshape(-1)
        m_src, m_dst, m_h, m_d = [
            x.reshape((S * dv["cap_edge"],) + x.shape[2:]) for x in r_edges]

        # ---- 6. HashPrune: fold flat edges straight into the reservoir ----
        # same fused fold as the streaming host build (mergeability lemma);
        # "segmented" sorts only this superstep's edge chunk and merges the
        # persistent reservoir per row, "flat" re-sorts reservoir-as-edges
        # together with the chunk
        lsrc = jnp.where(r_ok, m_src - me * n_loc, n_loc)
        fold = merge_flat_edges if p.merge == "flat" else merge_segmented_edges
        merged = fold(
            res_ids, res_hash, res_dist,
            lsrc, jnp.where(r_ok, m_dst, INVALID_ID), m_h,
            jnp.where(r_ok, m_d, INF))
        stats = jax.lax.psum(jnp.stack([
            jnp.sum(r_ok.astype(jnp.int32)),       # edges received
            jnp.sum(recv_valid.astype(jnp.int32)),  # replicas received
            drop_dispatch.astype(jnp.int32),        # dispatch drops
        ]), axes)
        return merged.ids, merged.hashes, merged.dists, stats

    sharded = P(axes)
    rep = P()
    step = _shard_map(
        shard_body, mesh=mesh,
        in_specs=(sharded, rep, sharded, sharded, sharded),
        out_specs=(sharded, sharded, sharded, rep),
    )

    def tile_step(points, hyperplanes, res: Reservoir):
        ids, hs, ds, stats = step(points, hyperplanes,
                                  res.ids, res.hashes, res.dists)
        return Reservoir(ids, hs, ds), stats

    # the raw shard_map program (flat args, no Reservoir wrapper) — what
    # the SPMD auditor (analysis/spmd_audit.py) traces and lowers
    tile_step.shard_step = step
    return tile_step


# ---------------------------------------------------------------------------
# Final prune superstep (request/response vector exchange + RobustPrune)
# ---------------------------------------------------------------------------

def make_final_prune_step(mesh: Mesh, p: DistBuildParams):
    axes = mesh_axes(mesh)
    S = int(np.prod([mesh.shape[a] for a in axes]))
    dv = p.derived(S)
    n_loc = dv["n_loc"]

    def shard_body(points, res_ids, res_dists):
        points = points.astype(jnp.float32)
        me = jax.lax.axis_index(axes)
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axes,
                                split_axis=0, concat_axis=0, tiled=True)
        flat_ids = res_ids.reshape(-1)                     # [n_loc*l_max]
        valid = flat_ids != INVALID_ID
        owner = jnp.where(valid, flat_ids // n_loc, S)
        slot = jnp.arange(n_loc * p.l_max, dtype=jnp.int32)
        (s_req, s_ok) = group_by_capacity(
            owner, valid, S, dv["cap_req"], [flat_ids, slot])
        s_cand, s_slot = s_req                             # s_slot stays local
        r_cand = a2a(s_cand)                               # [S, capR]
        r_ok = a2a(s_ok)
        lidx = jnp.clip(r_cand - me * n_loc, 0, n_loc - 1)
        r_vecs = points[lidx]                              # [S, capR, d]
        r_vecs = jnp.where(r_ok[..., None], r_vecs, 0.0)
        # response a2a: slice s of the result is what owner s produced for
        # MY requests, i.e. aligned with my send buffer s_cand[s] — so my
        # own (local) s_slot / s_ok describe its layout.
        b_vecs = a2a(r_vecs)
        gat = jnp.zeros((n_loc * p.l_max, p.dim), jnp.float32)
        gat = gat.at[jnp.where(s_ok, s_slot, n_loc * p.l_max).reshape(-1)
                     ].set(b_vecs.reshape(-1, p.dim), mode="drop")
        cand_vecs = gat.reshape(n_loc, p.l_max, p.dim)

        def prune_chunk(t):
            # d_cc from the routed vectors; the keep/compact/truncate logic
            # is the shared block the host build's final_prune also uses
            ids, dists, vecs = t
            ip = jnp.einsum("bld,bmd->blm", vecs, vecs)
            n2 = jnp.sum(vecs * vecs, axis=-1)
            d_cc = jnp.maximum(
                n2[:, :, None] + n2[:, None, :] - 2.0 * ip, 0.0)
            return prune_reservoir_block(ids, dists, d_cc,
                                         alpha=p.alpha, max_deg=p.max_deg)

        nch = n_loc // p.prune_chunk
        resh = lambda a: a.reshape((nch, p.prune_chunk) + a.shape[1:])
        gid, gd = jax.lax.map(
            prune_chunk, (resh(res_ids), resh(res_dists), resh(cand_vecs)))
        return (gid.reshape(n_loc, p.max_deg),
                gd.reshape(n_loc, p.max_deg))

    sharded = P(axes)
    return _shard_map(
        shard_body, mesh=mesh,
        in_specs=(sharded, sharded, sharded),
        out_specs=(sharded, sharded),
    )


# ---------------------------------------------------------------------------
# Drivers: dry-run lowering + a real (small-scale) runnable build
# ---------------------------------------------------------------------------

def production_params(dim: int, variant: str = "baseline") -> DistBuildParams:
    if variant == "quantized":
        return DistBuildParams(dim=dim, route_dtype="int8")
    if variant == "opt":          # the full beyond-paper stack
        return DistBuildParams(dim=dim, route_dtype="int8",
                               leaf_dtype="bf16")
    if variant == "bf16leaf":
        return DistBuildParams(dim=dim, leaf_dtype="bf16")
    return DistBuildParams(dim=dim)


def lower_build_step(mesh: Mesh, *, n_points: int, dim: int,
                     variant: str = "baseline"):
    """AOT-lower one tile superstep (+ the collective schedule) on ``mesh``.

    ``n_points`` is the full dataset size (2^30 at billion scale); the
    compiled unit processes one n_tile tile — the build runs
    ceil(n_points / n_tile) such steps, all identical.
    """
    if variant == "final_prune":
        return lower_final_prune_step(mesh, dim=dim)
    p = production_params(dim, variant)
    axes = mesh_axes(mesh)
    sh = NamedSharding(mesh, P(axes))
    rep = NamedSharding(mesh, P())
    step = make_tile_step(mesh, p)
    pts = jax.ShapeDtypeStruct((p.n_tile, p.dim), jnp.float32, sharding=sh)
    hp = jax.ShapeDtypeStruct((p.m_bits, p.dim), jnp.float32, sharding=rep)
    res = Reservoir(
        ids=jax.ShapeDtypeStruct((p.n_tile, p.l_max), jnp.int32, sharding=sh),
        hashes=jax.ShapeDtypeStruct((p.n_tile, p.l_max), jnp.int32,
                                    sharding=sh),
        dists=jax.ShapeDtypeStruct((p.n_tile, p.l_max), jnp.float32,
                                   sharding=sh),
    )
    return jax.jit(step, donate_argnums=(2,)).lower(pts, hp, res)


def lower_final_prune_step(mesh: Mesh, *, dim: int):
    p = production_params(dim)
    axes = mesh_axes(mesh)
    sh = NamedSharding(mesh, P(axes))
    step = make_final_prune_step(mesh, p)
    pts = jax.ShapeDtypeStruct((p.n_tile, p.dim), jnp.float32, sharding=sh)
    ids = jax.ShapeDtypeStruct((p.n_tile, p.l_max), jnp.int32, sharding=sh)
    ds = jax.ShapeDtypeStruct((p.n_tile, p.l_max), jnp.float32, sharding=sh)
    return jax.jit(step).lower(pts, ids, ds)


def useful_flops(n_points: int, dim: int,
                 p: DistBuildParams | None = None) -> float:
    """Algorithmically-required MACs*2 for ONE tile step (matches the
    compiled unit): level-0 GEMM + level-1 GEMM + leaf all-pairs + sketch."""
    p = p or production_params(dim)
    n = p.n_tile
    per_point = (p.l0 + p.f0 * p.l1 + p.f0 * p.f1 * p.c_max + p.m_bits)
    return 2.0 * n * per_point * p.dim


def build_distributed(x: np.ndarray, mesh: Mesh,
                      p: DistBuildParams, *, seed: int = 0,
                      final_prune: bool = True):
    """Runnable distributed build (used by tests at small scale on CPU).

    Streams x tile-by-tile through the tile step (HashPrune mergeability
    licenses this), then runs the final-prune superstep.  Returns
    (graph [n, max_deg], dists [n, max_deg]).
    """
    n, d = x.shape
    assert d == p.dim
    pad_n = _round_up(n, p.n_tile)
    if pad_n != n:
        filler = x[np.random.default_rng(seed).integers(0, n, pad_n - n)]
        x = np.concatenate([x, filler + 1e3], 0)  # far-away pad points
    key = jax.random.PRNGKey(seed)
    hp = _sketch.make_hyperplanes(key, p.m_bits, p.dim)
    tile_step = make_tile_step(mesh, p)
    res = reservoir_init(p.n_tile, p.l_max)
    graph_parts, dist_parts = [], []
    fp_step = make_final_prune_step(mesh, p)
    for t0 in range(0, pad_n, p.n_tile):
        tile = jnp.asarray(x[t0: t0 + p.n_tile])
        res_t, _ = tile_step(tile, hp, reservoir_init(p.n_tile, p.l_max))
        # convert tile-local ids to global ids
        res_t = Reservoir(
            ids=jnp.where(res_t.ids >= 0, res_t.ids + t0, res_t.ids),
            hashes=res_t.hashes, dists=res_t.dists)
        if final_prune:
            # final prune needs tile-local ids for vector routing
            lids = jnp.where(res_t.ids >= 0, res_t.ids - t0, res_t.ids)
            gid, gd = fp_step(tile, lids, res_t.dists)
            gid = jnp.where(gid >= 0, gid + t0, gid)
        else:
            gid, gd = res_t.ids[:, : p.max_deg], res_t.dists[:, : p.max_deg]
        graph_parts.append(np.asarray(gid))
        dist_parts.append(np.asarray(gd))
    graph = np.concatenate(graph_parts)[:n]
    dists = np.concatenate(dist_parts)[:n]
    # drop edges pointing at pad points
    bad = graph >= n
    graph = np.where(bad, -1, graph)
    dists = np.where(bad, np.inf, dists)
    return graph, dists
