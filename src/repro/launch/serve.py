"""Batched serving driver: prefill + decode with a sharded KV cache.

Implements the production serving shape the decode_32k / long_500k dry-run
cells compile: one ``prefill`` per request batch, then a jit'd
``serve_step`` (one token for every active sequence) in a decode loop,
with greedy or temperature sampling and continuous slot refill between
batches.  Works for every family (KV cache, SSM state, or hybrid).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 16 --batch 8 --prompt-len 32 --max-new 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch import steps
from repro.launch.mesh import make_local_mesh


class Server:
    """Holds compiled prefill/decode programs + sharded params."""

    def __init__(self, arch_id: str, *, smoke: bool = True,
                 model_parallel: int = 1, max_len: int = 256,
                 seed: int = 0):
        self.arch = get_config(arch_id)
        self.model = steps.build_model(self.arch, smoke=smoke)
        self.mesh = make_local_mesh(model_parallel)
        self.max_len = max_len
        p_shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        self.p_shard = shd.params_shardings(p_shapes, self.mesh,
                                            self.arch.family,
                                            self.arch.parallelism)
        with self.mesh:
            self.params = jax.jit(
                self.model.init, out_shardings=self.p_shard)(
                jax.random.PRNGKey(seed))
        self._prefill = jax.jit(
            steps.make_prefill_step(self.model, max_len))
        self._decode = jax.jit(steps.make_serve_step(self.model))
        self.vocab = getattr(self.model.config, "vocab")
        self.d_model = getattr(self.model.config, "d_model", 0)

    def make_batch(self, tokens: np.ndarray) -> dict:
        b, t = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if self.arch.family == "vlm":
            pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                   (b, t))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, b, t))
        if self.arch.family == "encdec":
            rng = np.random.default_rng(0)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, t, self.d_model)),
                dtype=jnp.bfloat16)
        return batch

    def generate(self, prompts: np.ndarray, max_new: int, *,
                 temperature: float = 0.0, seed: int = 0):
        """prompts: [B, T] int32.  Returns (tokens [B, max_new], stats)."""
        b = prompts.shape[0]
        t0 = time.perf_counter()
        with self.mesh:
            logits, cache = self._prefill(self.params,
                                          self.make_batch(prompts))
        t_prefill = time.perf_counter() - t0
        out = np.zeros((b, max_new), dtype=np.int32)
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, temperature, key)
        t0 = time.perf_counter()
        for i in range(max_new):
            out[:, i] = np.asarray(tok)[:, 0]
            with self.mesh:
                logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        return out, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * max_new / max(t_decode, 1e-9),
        }

    def _sample(self, logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        g = jax.random.categorical(key, logits / temperature)
        return g[:, None].astype(jnp.int32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    server = Server(args.arch, smoke=args.smoke,
                    model_parallel=args.model_parallel,
                    max_len=args.prompt_len + args.max_new,
                    seed=args.seed)
    rng = np.random.default_rng(args.seed)
    queue = rng.integers(0, server.vocab,
                         (args.requests, args.prompt_len)).astype(np.int32)
    done = 0
    agg_tok_s, batches = [], 0
    while done < args.requests:            # continuous batching: slot refill
        chunk = queue[done: done + args.batch]
        if chunk.shape[0] < args.batch:    # pad the final partial batch
            pad = np.repeat(chunk[-1:], args.batch - chunk.shape[0], axis=0)
            chunk = np.concatenate([chunk, pad], axis=0)
        toks, stats = server.generate(chunk, args.max_new,
                                      temperature=args.temperature,
                                      seed=args.seed + done)
        done += args.batch
        batches += 1
        agg_tok_s.append(stats["decode_tok_per_s"])
        print(f"batch {batches}: prefill {stats['prefill_s'] * 1e3:.1f}ms, "
              f"decode {stats['decode_tok_per_s']:.1f} tok/s")
    print(f"served {min(done, args.requests)} requests in {batches} batches; "
          f"mean decode throughput {np.mean(agg_tok_s):.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
