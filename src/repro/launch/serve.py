"""Batched serving driver: prefill + decode with a sharded KV cache.

Implements the production serving shape the decode_32k / long_500k dry-run
cells compile: one ``prefill`` per request batch, then a jit'd
``serve_step`` (one token for every active sequence) in a decode loop,
with greedy or temperature sampling and continuous slot refill between
batches.  Works for every family (KV cache, SSM state, or hybrid).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --requests 16 --batch 8 --prompt-len 32 --max-new 32

Also here: ``Retriever``, the ANN side of the serving stack — a PiPNN
index packed device-resident (``core.serving.ServingIndex``) with a
selectable points precision (``points_dtype`` "f32" | "bf16" | "int8";
int8 is the scalar-quantized packing, ~1/4 the points footprint, int8 MXU
distance kernel).  ``examples/rag_serve.py`` threads it in front of the
LM server for retrieval-augmented generation.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed import sharding as shd
from repro.launch import steps
from repro.launch.mesh import make_local_mesh


RETRIEVER_DTYPES = ("f32", "bf16", "int8")


class Retriever:
    """Device-resident ANN retrieval for the serving stack.

    Wraps a PiPNN index + its corpus embeddings as a packed
    ``ServingIndex`` so every ``retrieve`` call transfers nothing but the
    query embeddings.  ``points_dtype`` selects the serving precision of
    the corpus copy: "f32" (exact), "bf16" (half the footprint), or
    "int8" (scalar-quantized: int8 vectors + per-point f32 scales, ~1/4
    the points footprint, distances via the int8 MXU gather-distance
    kernel with exact norm terms).  ``mesh`` (a single-axis
    ``jax.sharding.Mesh``) serves through the sharded packing — one
    partition-aligned corpus shard per device, per-query results merged
    across shards (``distributed.serving.ShardedServingIndex``).
    """

    def __init__(self, corpus_emb: np.ndarray, index=None, *,
                 points_dtype: str = "f32", metric: str | None = None,
                 build_params=None, seed: int = 0, mesh=None):
        """``metric`` defaults to the prebuilt ``index``'s (or explicit
        ``build_params``') own metric — serving ALWAYS uses the index's,
        so passing a disagreeing one is a loud error, not a silent
        reinterpretation — and to "mips" when building fresh with default
        params (``seed`` only applies to that default build)."""
        from repro.core import pipnn
        from repro.core.serving import ServingIndex

        if points_dtype not in RETRIEVER_DTYPES:
            raise ValueError(f"points_dtype must be one of "
                             f"{RETRIEVER_DTYPES}, got {points_dtype!r}")
        if index is not None:
            if metric is not None and index.params.metric != metric:
                raise ValueError(
                    f"metric={metric!r} does not match the prebuilt "
                    f"index's metric={index.params.metric!r}")
        elif build_params is not None:
            if metric is not None and build_params.metric != metric:
                raise ValueError(
                    f"metric={metric!r} does not match "
                    f"build_params.metric={build_params.metric!r}")
        elif metric is None:
            metric = "mips"
        if index is None:
            from repro.core.leaf import LeafParams
            from repro.core.pipnn import PiPNNParams
            from repro.core.rbc import RBCParams

            if build_params is None:
                # MIPS alpha-pruning over-sparsifies hub-structured
                # graphs; keep the HashPrune reservoir as-is (standard
                # DiskANN-MIPS practice)
                build_params = PiPNNParams(
                    rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
                    leaf=LeafParams(k=2), metric=metric, max_deg=32,
                    final_prune=(metric != "mips"), seed=seed)
            index = pipnn.build(corpus_emb, build_params)
        self.index = index
        dtype = {"f32": None, "bf16": jnp.bfloat16, "int8": "int8"}[
            points_dtype]
        self.points_dtype = points_dtype
        self.sv = ServingIndex.from_index(index, corpus_emb, dtype=dtype,
                                          mesh=mesh)

    def retrieve(self, q_emb: np.ndarray, *, k: int = 2,
                 beam: int = 32) -> np.ndarray:
        """Top-k corpus ids [Q, k] for a query-embedding batch.

        The boundary is hardened: ``k``/``beam`` must be >= 1 and the
        embeddings must be a finite 2-D float batch of the corpus width —
        NaN/Inf rows raise a structured
        :class:`repro.core.validation.InvalidQueryError` naming the rows
        (an embedding-service glitch must never silently poison the
        retrieval beams of the whole batch)."""
        from repro.core.validation import (validate_queries,
                                           validate_search_params)

        validate_search_params(k=k, beam=beam)
        q = validate_queries(q_emb, dim=int(self.sv.points.shape[-1]))
        return self.sv.search(q, k=k, beam=beam)

    def device_bytes(self) -> int:
        return self.sv.device_bytes()


class Server:
    """Holds compiled prefill/decode programs + sharded params."""

    def __init__(self, arch_id: str, *, smoke: bool = True,
                 model_parallel: int = 1, max_len: int = 256,
                 seed: int = 0):
        self.arch = get_config(arch_id)
        self.model = steps.build_model(self.arch, smoke=smoke)
        self.mesh = make_local_mesh(model_parallel)
        self.max_len = max_len
        p_shapes = jax.eval_shape(self.model.init, jax.random.PRNGKey(0))
        self.p_shard = shd.params_shardings(p_shapes, self.mesh,
                                            self.arch.family,
                                            self.arch.parallelism)
        with self.mesh:
            self.params = jax.jit(
                self.model.init, out_shardings=self.p_shard)(
                jax.random.PRNGKey(seed))
        self._prefill = jax.jit(
            steps.make_prefill_step(self.model, max_len))
        self._decode = jax.jit(steps.make_serve_step(self.model))
        self.vocab = getattr(self.model.config, "vocab")
        self.d_model = getattr(self.model.config, "d_model", 0)

    def make_batch(self, tokens: np.ndarray) -> dict:
        b, t = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if self.arch.family == "vlm":
            pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                   (b, t))
            batch["positions"] = jnp.broadcast_to(pos[None], (3, b, t))
        if self.arch.family == "encdec":
            rng = np.random.default_rng(0)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((b, t, self.d_model)),
                dtype=jnp.bfloat16)
        return batch

    def generate(self, prompts: np.ndarray, max_new: int, *,
                 temperature: float = 0.0, seed: int = 0):
        """prompts: [B, T] int32.  Returns (tokens [B, max_new], stats)."""
        b = prompts.shape[0]
        t0 = time.perf_counter()
        with self.mesh:
            logits, cache = self._prefill(self.params,
                                          self.make_batch(prompts))
        t_prefill = time.perf_counter() - t0
        out = np.zeros((b, max_new), dtype=np.int32)
        key = jax.random.PRNGKey(seed)
        tok = self._sample(logits, temperature, key)
        t0 = time.perf_counter()
        for i in range(max_new):
            out[:, i] = np.asarray(tok)[:, 0]
            with self.mesh:
                logits, cache = self._decode(self.params, tok, cache)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        return out, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * max_new / max(t_decode, 1e-9),
        }

    def _sample(self, logits, temperature, key):
        if temperature <= 0:
            return jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        g = jax.random.categorical(key, logits / temperature)
        return g[:, None].astype(jnp.int32)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    server = Server(args.arch, smoke=args.smoke,
                    model_parallel=args.model_parallel,
                    max_len=args.prompt_len + args.max_new,
                    seed=args.seed)
    rng = np.random.default_rng(args.seed)
    queue = rng.integers(0, server.vocab,
                         (args.requests, args.prompt_len)).astype(np.int32)
    done = 0
    agg_tok_s, batches = [], 0
    while done < args.requests:            # continuous batching: slot refill
        chunk = queue[done: done + args.batch]
        if chunk.shape[0] < args.batch:    # pad the final partial batch
            pad = np.repeat(chunk[-1:], args.batch - chunk.shape[0], axis=0)
            chunk = np.concatenate([chunk, pad], axis=0)
        toks, stats = server.generate(chunk, args.max_new,
                                      temperature=args.temperature,
                                      seed=args.seed + done)
        done += args.batch
        batches += 1
        agg_tok_s.append(stats["decode_tok_per_s"])
        print(f"batch {batches}: prefill {stats['prefill_s'] * 1e3:.1f}ms, "
              f"decode {stats['decode_tok_per_s']:.1f} tok/s")
    print(f"served {min(done, args.requests)} requests in {batches} batches; "
          f"mean decode throughput {np.mean(agg_tok_s):.1f} tok/s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
