"""End-to-end driver: PiPNN as the retrieval substrate of a serving stack.

Pipeline (the paper's RAG motivation, Sec. 1, realized):
  1. build a PiPNN index over a corpus of document embeddings;
  2. serve an LM (any --arch, reduced config on CPU) with batched
     requests: each request embeds its prompt, retrieves top-k documents
     by MIPS through the PiPNN graph, prepends the retrieved doc tokens,
     then prefill+decode generates the continuation.

  PYTHONPATH=src python examples/rag_serve.py --arch qwen2-7b \
      --requests 8 --batch 4 --ann-dtype int8
"""
import argparse
import time

import numpy as np

from repro.launch.serve import RETRIEVER_DTYPES, Retriever, Server

DOC_LEN = 16


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--corpus", type=int, default=8192)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--topk", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--ann-dtype", choices=RETRIEVER_DTYPES, default="f32",
                    help="serving precision of the corpus copy; int8 = "
                         "scalar-quantized packing (~1/4 the footprint)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)

    # --- 1. corpus: embeddings + token payloads --------------------------
    t0 = time.perf_counter()
    centers = rng.standard_normal((64, args.dim)) * 2.0
    assign = rng.integers(0, 64, args.corpus)
    corpus_emb = (centers[assign]
                  + 0.5 * rng.standard_normal((args.corpus, args.dim))
                  ).astype(np.float32)
    retriever = Retriever(corpus_emb, points_dtype=args.ann_dtype,
                          metric="mips", seed=0)
    print(f"[index] {args.corpus} docs indexed in "
          f"{time.perf_counter() - t0:.2f}s "
          f"(avg deg {retriever.index.average_degree():.1f}, "
          f"{args.ann_dtype} serving copy: "
          f"{retriever.device_bytes() / 1e6:.2f} MB on device)")

    # --- 2. server --------------------------------------------------------
    max_len = args.topk * DOC_LEN + args.prompt_len + args.max_new
    server = Server(args.arch, smoke=True, max_len=max_len)
    doc_tokens = rng.integers(0, server.vocab,
                              (args.corpus, DOC_LEN)).astype(np.int32)

    # prompt "embedder": project prompt token ids into corpus space (stub
    # for a real encoder; deterministic so retrieval is reproducible)
    proj = rng.standard_normal((args.prompt_len, args.dim)).astype(np.float32)

    served = 0
    t_all = time.perf_counter()
    while served < args.requests:
        b = min(args.batch, args.requests - served)
        prompts = rng.integers(0, server.vocab,
                               (b, args.prompt_len)).astype(np.int32)
        q_emb = (prompts / server.vocab) @ proj          # [b, dim]
        hits = retriever.retrieve(q_emb, k=args.topk, beam=32)
        aug = np.concatenate(
            [doc_tokens[hits.reshape(b, -1)].reshape(b, -1), prompts],
            axis=1)
        toks, stats = server.generate(aug, args.max_new)
        served += b
        print(f"[serve] batch of {b}: retrieved {args.topk} docs/req, "
              f"prefill {stats['prefill_s'] * 1e3:.0f}ms, "
              f"decode {stats['decode_tok_per_s']:.0f} tok/s")
    dt = time.perf_counter() - t_all
    print(f"[done] {served} RAG requests in {dt:.2f}s "
          f"({served / dt:.2f} req/s end-to-end)")


if __name__ == "__main__":
    main()
