"""Quickstart: build a PiPNN index, query it, check recall.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.data.pipeline import VectorPipelineConfig, make_queries, make_vectors


def main():
    # 1. data: 16k Gaussian-mixture vectors, 200 held-out queries
    cfg = VectorPipelineConfig(n=16384, dim=48, n_clusters=64, seed=0)
    x = make_vectors(cfg)
    queries = make_queries(cfg, 200)

    # 2. build — the paper's pipeline: RBC partition -> leaf 2-NN via
    #    batched GEMM -> HashPrune -> final RobustPrune
    params = PiPNNParams(
        rbc=RBCParams(c_max=512, c_min=64, fanout=(4, 2)),
        leaf=LeafParams(k=3),
        hash_bits=12, l_max=64, max_deg=32, alpha=1.3, seed=0,
    )
    t0 = time.perf_counter()
    index = pipnn.build(x, params)
    print(f"built index over {x.shape[0]} points in "
          f"{time.perf_counter() - t0:.2f}s "
          f"(phases: { {k: round(v, 2) for k, v in index.timings.items()} })")
    print(f"average degree {index.average_degree():.1f}, "
          f"{index.stats['n_leaves']} leaves, "
          f"point repeat {index.stats['point_repeat']:.1f}x")

    # 3. query with beam search; 10@10 recall vs brute force
    t0 = time.perf_counter()
    found = pipnn.search(index, x, queries, k=10, beam=96)
    qps = len(queries) / (time.perf_counter() - t0)
    truth = brute_force_knn(x, queries, 10)
    print(f"10@10 recall {recall_at_k(found, truth, 10):.3f} "
          f"at {qps:.0f} QPS (beam 96)")


if __name__ == "__main__":
    main()
