"""Downstream task (paper Fig. 6): build a 95%-recall k-NN graph — the
substrate for clustering / dedup pipelines — and compare against the
Vamana-based route.

  PYTHONPATH=src python examples/knn_graph.py
"""
import time

import numpy as np

from repro.core.knn_graph import knn_graph_pipnn, knn_graph_recall
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.data.pipeline import VectorPipelineConfig, make_vectors


def main():
    x = make_vectors(VectorPipelineConfig(n=8192, dim=32, n_clusters=32,
                                          seed=1))
    params = PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
        leaf=LeafParams(k=3), l_max=64, max_deg=32, seed=0)
    knn, timings = knn_graph_pipnn(x, k=10, beam=48, params=params)
    recall = knn_graph_recall(x, knn, k=10, sample=512)
    print(f"k-NN graph over {x.shape[0]} points: "
          f"build {timings['build']:.2f}s + query {timings['query']:.2f}s "
          f"= {timings['total']:.2f}s, recall {recall:.3f}")
    assert recall >= 0.90, "quality bar"
    # example downstream use: mutual-kNN connected components (clustering)
    n = x.shape[0]
    mutual = set()
    kset = [set(r[r >= 0].tolist()) for r in knn]
    for i in range(n):
        for j in knn[i]:
            if j >= 0 and i in kset[j]:
                mutual.add((min(i, int(j)), max(i, int(j))))
    parent = list(range(n))

    def find(a):
        while parent[a] != a:
            parent[a] = parent[parent[a]]
            a = parent[a]
        return a

    for a, b in mutual:
        parent[find(a)] = find(b)
    n_comp = len({find(i) for i in range(n)})
    print(f"mutual-kNN graph: {len(mutual)} edges, "
          f"{n_comp} connected components (planted: 32 clusters)")


if __name__ == "__main__":
    main()
