"""End-to-end LM training example: a few hundred steps of the mamba2-130m
family (reduced width on CPU; pass --full on a real cluster for the exact
130M config), with checkpoint/restart demonstrated mid-run.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="exact published config (cluster-scale)")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_ck_")
    common = ["--arch", args.arch, "--batch", "16", "--seq", "128",
              "--micro", "2", "--ckpt-dir", ckpt_dir,
              "--ckpt-every", "50", "--log-every", "20"]
    if not args.full:
        common.append("--smoke")

    half = max(args.steps // 2, 1)
    print(f"=== phase 1: train to step {half}, checkpointing ===")
    train.main(common + ["--steps", str(half)])

    print(f"=== phase 2: restart from checkpoint -> step {args.steps} ===")
    train.main(common + ["--steps", str(args.steps), "--resume"])

    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("=== done: loss continued falling across the restart ===")


if __name__ == "__main__":
    main()
