"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward/train step and one prefill+decode step on
CPU, assert output shapes and no NaNs.  (Full configs are exercised only
via the dry-run, per the assignment.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model_zoo

KEY = jax.random.PRNGKey(0)


def _smoke_batch(model, b=2, t=16):
    spec = model.train_batch_spec(b, t)
    rng = np.random.default_rng(0)
    out = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            if k == "positions":
                pos = np.broadcast_to(np.arange(t, dtype=np.int32), s.shape)
                out[k] = jnp.asarray(pos.copy())
            else:
                hi = getattr(model.config, "vocab", 256)
                out[k] = jnp.asarray(
                    rng.integers(0, min(hi, 250), s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_full_config_matches_assignment(arch_id):
    cfg = get_config(arch_id)
    m = cfg.model
    expect = {
        "llama3-405b": (126, 16384, 128, 8, 53248),
        "internlm2-20b": (48, 6144, 48, 8, 16384),
        "qwen2-7b": (28, 3584, 28, 4, 18944),
        "qwen3-14b": (40, 5120, 40, 8, 17408),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512),
        "grok-1-314b": (64, 6144, 48, 8, 32768),
        "whisper-tiny": (4, 384, 6, 6, 1536),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240),
    }
    if arch_id == "mamba2-130m":
        assert (m.n_layers, m.d_model, m.vocab, m.d_state) == \
            (24, 768, 50288, 128)
        return
    L, d, h, kv, ff = expect[arch_id]
    assert m.n_layers == L and m.d_model == d and m.d_ff == ff
    assert m.n_heads == h and m.n_kv_heads == kv
    if arch_id == "granite-moe-1b-a400m":
        assert (m.moe.n_experts, m.moe.top_k) == (32, 8)
    if arch_id == "grok-1-314b":
        assert (m.moe.n_experts, m.moe.top_k) == (8, 2)
    if arch_id == "qwen3-14b":
        assert m.qk_norm
    if arch_id in ("qwen2-7b", "qwen2-vl-7b"):
        assert m.qkv_bias
    if arch_id == "qwen2-vl-7b":
        assert m.mrope_sections is not None


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    cfg = get_config(arch_id)
    model = model_zoo.build(cfg.smoke_model, cfg.family)
    params = model.init(KEY)
    batch = _smoke_batch(model)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{arch_id} loss {loss}"
    for path, leaf in jax.tree_util.tree_flatten_with_path(grads)[0]:
        a = np.asarray(leaf)
        assert np.isfinite(a).all(), f"{arch_id} NaN grad at {path}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch_id):
    cfg = get_config(arch_id)
    model = model_zoo.build(cfg.smoke_model, cfg.family)
    params = model.init(KEY)
    b, t, max_len = 2, 8, 16
    spec = model.prefill_batch_spec(b, t)
    rng = np.random.default_rng(1)
    batch = {}
    for k, s in spec.items():
        if s.dtype == jnp.int32:
            if k == "positions":
                batch[k] = jnp.asarray(np.broadcast_to(
                    np.arange(t, dtype=np.int32), s.shape).copy())
            else:
                batch[k] = jnp.asarray(rng.integers(0, 250, s.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    logits, cache = model.prefill(params, batch, max_len)
    vocab = model.config.vocab
    assert logits.shape == (b, vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all(), arch_id
    tok = jnp.asarray(rng.integers(0, 250, (b, 1)), jnp.int32)
    logits2, cache2 = model.decode_step(params, tok, cache)
    assert logits2.shape == (b, vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all(), arch_id
    assert int(cache2.index) == int(cache.index) + 1


def test_registry_covers_all_ten():
    assert len(ARCH_IDS) == 10
    fams = {get_config(a).family for a in ARCH_IDS}
    assert fams == {"dense", "moe", "encdec", "vlm", "ssm", "hybrid"}


def test_long_500k_eligibility():
    eligible = {a for a in ARCH_IDS if get_config(a).sub_quadratic}
    assert eligible == {"mamba2-130m", "zamba2-2.7b"}
    # 40-cell accounting: 10 archs x 4 shapes; 8 long_500k skips documented
    total_runnable = sum(len(get_config(a).runnable_cells()) for a in ARCH_IDS)
    total_skipped = sum(len(get_config(a).skipped_cells()) for a in ARCH_IDS)
    assert total_runnable == 32
    assert total_runnable + total_skipped == 40
