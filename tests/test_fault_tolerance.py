"""Seed fault-tolerance primitives: RunGuard signal handling,
StepWatchdog sigma-flagging on synthetic latency traces, and the
RollingPercentile SLO signal the serving loop's degradation controller
reads."""
import signal

import numpy as np
import pytest

from repro.distributed.fault_tolerance import (RollingPercentile, RunGuard,
                                               StepWatchdog)


# ---------------------------------------------------------------- RunGuard --

def test_runguard_installs_and_restores_handlers():
    prev_term = signal.getsignal(signal.SIGTERM)
    prev_int = signal.getsignal(signal.SIGINT)
    guard = RunGuard()
    assert signal.getsignal(signal.SIGTERM) == guard._handler
    assert signal.getsignal(signal.SIGINT) == guard._handler
    guard.restore_handlers()
    assert signal.getsignal(signal.SIGTERM) == prev_term
    assert signal.getsignal(signal.SIGINT) == prev_int


def test_runguard_sigterm_flips_should_stop():
    guard = RunGuard()
    try:
        assert not guard.should_stop
        signal.raise_signal(signal.SIGTERM)
        assert guard.should_stop
    finally:
        guard.restore_handlers()


def test_runguard_double_sigterm_is_idempotent():
    """A second SIGTERM (the scheduler re-sending before the step
    boundary) must not crash or un-set the stop request."""
    guard = RunGuard()
    try:
        signal.raise_signal(signal.SIGTERM)
        signal.raise_signal(signal.SIGTERM)
        assert guard.should_stop
    finally:
        guard.restore_handlers()


def test_runguard_no_install_leaves_signals_alone():
    prev = signal.getsignal(signal.SIGTERM)
    guard = RunGuard(install_handlers=False)
    assert signal.getsignal(signal.SIGTERM) == prev
    assert not guard.should_stop
    guard.restore_handlers()    # no-op, nothing recorded


# ------------------------------------------------------------ StepWatchdog --

def test_watchdog_flags_straggler_on_synthetic_trace():
    seen = []
    wd = StepWatchdog(sigma=4.0, min_samples=10,
                      on_straggler=lambda s, t, mu: seen.append((s, t, mu)))
    rng = np.random.default_rng(0)
    base = 1.0 + 0.01 * rng.standard_normal(30)
    for i, t in enumerate(base):
        assert not wd.record(i, float(t))
    assert wd.record(30, 2.5)           # 2.5x the mean: a straggler
    assert wd.flagged and wd.flagged[-1][0] == 30
    assert seen and seen[0][0] == 30 and seen[0][1] == 2.5
    assert seen[0][2] == pytest.approx(1.0, abs=0.05)


def test_watchdog_respects_min_samples():
    wd = StepWatchdog(min_samples=10)
    for i in range(9):
        wd.record(i, 0.001)
    # 9 samples recorded: still warming up, even an absurd outlier passes
    assert not wd.record(9, 100.0)


def test_watchdog_sigma_and_ratio_must_both_trip():
    """High variance trace: a step above 1.5x the mean but within sigma
    is NOT flagged (and vice versa) — both conditions gate."""
    wd = StepWatchdog(sigma=4.0, min_samples=10)
    trace = [1.0, 2.0] * 10            # mu ~ 1.5, sd ~ 0.5
    for i, t in enumerate(trace):
        wd.record(i, t)
    assert not wd.record(99, 3.0)      # 2x mean (ratio trips) but ~3 sigma
    assert wd.record(100, 4.0)         # ~4+ sigma AND > 1.5x mean


# ------------------------------------------------------- RollingPercentile --

def test_rolling_percentile_matches_numpy():
    rp = RollingPercentile(window=128)
    rng = np.random.default_rng(1)
    xs = rng.exponential(0.05, size=100)
    for x in xs:
        rp.record(float(x))
    assert len(rp) == 100
    assert rp.percentile(99) == pytest.approx(
        float(np.percentile(xs, 99)), rel=1e-9)
    assert rp.percentile(50) == pytest.approx(
        float(np.percentile(xs, 50)), rel=1e-9)


def test_rolling_percentile_window_bounds_memory():
    rp = RollingPercentile(window=16)
    for i in range(100):
        rp.record(float(i))
    assert len(rp) == 16
    # only the last 16 samples (84..99) remain in the window
    assert rp.percentile(0) == 84.0
    assert rp.percentile(100) == 99.0


def test_rolling_percentile_empty_is_zero():
    assert RollingPercentile().percentile(99) == 0.0
