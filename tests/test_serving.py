"""Serving-path tests: multi-expansion beam search vs the np pointer-chasing
oracle (exact agreement + recall parity), early-exit semantics, telemetry,
ServingIndex packing/caching, and the vectorized recall_at_k."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import pipnn
from repro.core import beam_search as bs
from repro.core.beam_search import (beam_search_batch, beam_search_np,
                                    beam_search_single, brute_force_knn,
                                    medoid, recall_at_k)
from repro.core.serving import ServingIndex

EXPANSIONS = (1, 2, 4, 8)


def _grid_points(n, d, seed=0, lo=0, hi=30):
    """Small-integer coordinates: every distance (GEMM expansion OR the np
    reference's diff-based formula) is exact in f32, so batch and np
    engines see bit-identical dissimilarities and tie-breaks."""
    rng = np.random.default_rng(seed)
    return rng.integers(lo, hi, (n, d)).astype(np.float32)


def _np_ids(graph, x, q, start, beam, metric="l2", k=10):
    out = np.full((q.shape[0], k), -1, dtype=np.int64)
    for i in range(q.shape[0]):
        ids, _, _ = beam_search_np(graph, x, q[i], start=start, beam=beam,
                                   metric=metric)
        out[i, : min(k, len(ids))] = ids[:k]
    return out


# ------------------------------------------------- exact / parity vs np ---

@pytest.mark.parametrize("expansions", EXPANSIONS)
def test_exact_agreement_one_hop_graph(expansions):
    """Complete one-hop graph: every engine must return THE top-k exactly
    (identical ids in identical order — ties break by (dist, id) in both
    the np reference and the batch engine)."""
    n, d, k = 64, 8, 10
    x = _grid_points(n, d, seed=1)
    # start connects to everything; everything connects back to start
    graph = np.full((n, n - 1), -1, dtype=np.int32)
    for i in range(n):
        graph[i] = [j for j in range(n) if j != i]
    q = _grid_points(12, d, seed=2)
    start = 3
    ids_b, _ = beam_search_batch(graph, x, q, start=start, beam=16,
                                 expansions=expansions)
    got = np.asarray(ids_b)[:, :k]
    want = _np_ids(graph, x, q, start, beam=16, k=k)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
@pytest.mark.parametrize("expansions", (1, 4))
def test_recall_parity_vs_np(metric, expansions):
    """Random kNN graph, generous budget: the batch engine's 10@10 sets
    must match the np oracle's query by query (same beam, same start)."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal((400, 12)).astype(np.float32)
    truth = brute_force_knn(x, x, 13, metric=metric)
    graph = truth[:, 1:13].astype(np.int32)
    q = rng.standard_normal((16, 12)).astype(np.float32)
    start = medoid(x)
    ids_b, _ = beam_search_batch(graph, x, q, start=start, beam=24, iters=40,
                                 metric=metric, expansions=expansions)
    agree = 0
    for i in range(q.shape[0]):
        ids_n, _, _ = beam_search_np(graph, x, q[i], start=start, beam=24,
                                     metric=metric)
        agree += len(set(np.asarray(ids_b)[i, :10].tolist())
                     & set(ids_n[:10].tolist()))
    assert agree >= 0.95 * q.shape[0] * 10, f"{metric}: {agree}"


@pytest.mark.parametrize("expansions", (1, 4))
def test_multi_matches_single_engine_recall(expansions):
    rng = np.random.default_rng(3)
    x = rng.standard_normal((500, 16)).astype(np.float32)
    truth = brute_force_knn(x, x, 17)
    graph = truth[:, 1:17].astype(np.int32)
    q = rng.standard_normal((32, 16)).astype(np.float32)
    gt = brute_force_knn(x, q, 10)
    start = medoid(x)
    ids_m, _ = beam_search_batch(graph, x, q, start=start, beam=24,
                                 expansions=expansions)
    ids_s, _ = beam_search_single(jnp.asarray(graph), jnp.asarray(x),
                                  jnp.asarray(q), start=start, beam=24,
                                  iters=28)
    r_m = recall_at_k(np.asarray(ids_m)[:, :10], gt, 10)
    r_s = recall_at_k(np.asarray(ids_s)[:, :10], gt, 10)
    assert r_m >= r_s - 0.02, (r_m, r_s)


# ----------------------------------------- ragged rows / degenerate graphs ---

def test_padded_rows_ragged_degrees():
    """-1-padded adjacency rows with wildly varying degree: the engine
    must skip pads, keep the beam duplicate-free, and stay in agreement
    with the np oracle.  (Exact equality is NOT guaranteed on random
    graphs with small beams — truncation drops visited flags the np
    reference keeps globally — so this asserts overlap + invariants; the
    one-hop and disconnected-graph tests pin exact order.)"""
    rng = np.random.default_rng(5)
    n, d = 120, 6
    x = _grid_points(n, d, seed=5)
    truth = brute_force_knn(x, x, 9)
    graph = np.full((n, 8), -1, dtype=np.int32)
    for i in range(n):
        deg = int(rng.integers(1, 9))
        graph[i, :deg] = truth[i, 1 : 1 + deg]
    q = _grid_points(8, d, seed=6)
    start = medoid(x)
    ids_b, ds_b = beam_search_batch(graph, x, q, start=start, beam=16,
                                    iters=40, expansions=4)
    ids_b, ds_b = np.asarray(ids_b), np.asarray(ds_b)
    assert ((ids_b >= -1) & (ids_b < n)).all()
    assert (np.isfinite(ds_b) == (ids_b >= 0)).all()
    for row in ids_b:           # no duplicate live entries
        live = row[row >= 0]
        assert len(set(live.tolist())) == len(live)
    want = _np_ids(graph, x, q, start, beam=16, k=8)
    agree = sum(len(set(a[:8].tolist()) & set(b[b >= 0].tolist()))
                for a, b in zip(ids_b, want))
    assert agree >= 0.9 * 8 * q.shape[0], agree


def test_disconnected_start_region_early_exit():
    """Start's component has 5 nodes: the beam holds exactly those, padded
    with -1, and the while_loop exits after ~5 hops, far below the cap."""
    n, d = 40, 4
    x = _grid_points(n, d, seed=9)
    graph = np.full((n, 2), -1, dtype=np.int32)
    comp = [0, 1, 2, 3, 4]
    for a, b in zip(comp, comp[1:] + comp[:1]):
        graph[a] = [b, comp[(comp.index(a) + 2) % 5]]
    for i in range(5, n):       # a second, unreachable cycle
        graph[i] = [(i + 1 - 5) % (n - 5) + 5, -1]
    q = _grid_points(6, d, seed=10)
    ids, ds, hops, comps, _ = beam_search_batch(
        graph, x, q, start=0, beam=16, expansions=2, with_stats=True)
    ids = np.asarray(ids)
    assert set(ids[0][ids[0] >= 0].tolist()) == set(comp)
    assert (np.asarray(hops) <= 5).all()
    assert (ids[:, 5:] == -1).all()
    want = _np_ids(graph, x, q, 0, beam=16, k=16)
    np.testing.assert_array_equal(ids.astype(np.int64), want)


@pytest.mark.parametrize("expansions", (1, 3, 4))
def test_early_exit_matches_capped_run(expansions):
    """Convergence is a fixed point: stopping early returns exactly the
    ids (and dists) the full-cap run returns."""
    rng = np.random.default_rng(11)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    truth = brute_force_knn(x, x, 13)
    graph = truth[:, 1:13].astype(np.int32)
    q = rng.standard_normal((10, 8)).astype(np.float32)
    start = medoid(x)
    kw = dict(start=start, beam=20, iters=64, expansions=expansions)
    ids_e, ds_e = beam_search_batch(graph, x, q, early_exit=True, **kw)
    ids_c, ds_c = beam_search_batch(graph, x, q, early_exit=False, **kw)
    np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_c))
    np.testing.assert_array_equal(np.asarray(ds_e), np.asarray(ds_c))


def test_telemetry_counts():
    rng = np.random.default_rng(13)
    x = rng.standard_normal((200, 8)).astype(np.float32)
    truth = brute_force_knn(x, x, 9)
    graph = truth[:, 1:9].astype(np.int32)
    q = rng.standard_normal((6, 8)).astype(np.float32)
    ids, ds, hops, comps, _ = beam_search_batch(
        graph, x, q, start=medoid(x), beam=12, expansions=4, with_stats=True)
    hops, comps = np.asarray(hops), np.asarray(comps)
    assert (hops >= 1).all() and (hops <= (12 + 4) * 4).all()  # cap * E
    # comps counts the entry point + every gathered valid neighbor
    assert (comps >= 1 + hops).all()
    assert (comps <= 1 + hops * graph.shape[1]).all()


# ----------------------------------------------------------- ServingIndex ---

@pytest.fixture(scope="module")
def built():
    from repro.core.leaf import LeafParams
    from repro.core.pipnn import PiPNNParams
    from repro.core.rbc import RBCParams

    rng = np.random.default_rng(0)
    x = rng.standard_normal((1500, 24)).astype(np.float32)
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2), l_max=32, max_deg=16, seed=1)
    return pipnn.build(x, p), x


def test_serving_index_search_recall(built):
    idx, x = built
    q = x[:64] + 0.01 * np.random.default_rng(1).standard_normal(
        (64, x.shape[1])).astype(np.float32)
    truth = brute_force_knn(x, q, 10)
    sv = ServingIndex.from_index(idx, x)
    found = sv.search(q, k=10, beam=48)
    assert found.shape == (64, 10)
    assert recall_at_k(found, truth, 10) > 0.85


def test_pipnn_search_caches_serving_index(built, monkeypatch):
    """Zero host->device transfers after the first search on an unchanged
    index: the packed ServingIndex (graph/points/norms device buffers) is
    built exactly once and reused."""
    idx, x = built
    q = x[:8]
    calls = {"n": 0}
    orig = ServingIndex.from_index.__func__

    def counting(cls, index, xx, *, dtype=None, **kw):
        calls["n"] += 1
        return orig(cls, index, xx, dtype=dtype, **kw)

    monkeypatch.setattr(ServingIndex, "from_index", classmethod(counting))
    idx._serving = None   # reset any cache from other tests
    idx._serving_key = None
    first = pipnn.search(idx, x, q, k=5, beam=16)
    sv1 = idx._serving
    again = pipnn.search(idx, x, q, k=5, beam=16)
    sv2 = idx._serving
    assert calls["n"] == 1
    assert sv1 is sv2
    assert sv1.points is sv2.points and sv1.graph is sv2.graph
    np.testing.assert_array_equal(first, again)
    # a different dataset object invalidates the cache
    x2 = x.copy()
    pipnn.search(idx, x2, q, k=5, beam=16)
    assert calls["n"] == 2


def test_serving_query_chunking_matches_full(built):
    idx, x = built
    q = x[:50]
    sv = ServingIndex.from_index(idx, x)
    full = sv.search(q, k=10, beam=24)
    chunked = sv.search(q, k=10, beam=24, query_chunk=16)
    np.testing.assert_array_equal(full, chunked)


def test_serving_dtype_downcast(built):
    idx, x = built
    q = x[:64]
    truth = brute_force_knn(x, q, 10)
    sv16 = ServingIndex.from_index(idx, x, dtype=jnp.bfloat16)
    assert sv16.points.dtype == jnp.bfloat16
    assert sv16.norms.dtype == jnp.float32
    assert sv16.device_bytes() < ServingIndex.from_index(idx, x).device_bytes()
    r16 = recall_at_k(sv16.search(q, k=10, beam=48), truth, 10)
    assert r16 > 0.8, r16


def test_pipnn_search_beam_lt_k_pads(built):
    idx, x = built
    q = x[:5]
    out = pipnn.search(idx, x, q, k=10, beam=4)
    assert out.shape == (5, 10)
    assert (out[:, 4:] == -1).all()
    assert (out[:, :4] >= 0).all()


def test_pipnn_search_oracle_rejects_serving_options(built):
    idx, x = built
    q = x[:2]
    with pytest.raises(ValueError):
        pipnn.search(idx, x, q, k=5, beam=16, batch=False, with_stats=True)
    with pytest.raises(ValueError):
        pipnn.search(idx, x, q, k=5, beam=16, batch=False, iters=8)
    # regression: a non-default `expansions` used to be silently IGNORED
    # on the oracle path (it expands one vertex per hop by construction),
    # letting callers believe they had swept E
    with pytest.raises(ValueError):
        pipnn.search(idx, x, q, k=5, beam=16, batch=False, expansions=8)
    with pytest.raises(ValueError):
        pipnn.search(idx, x, q, k=5, beam=16, batch=False, dtype="int8")
    # the default (expansions=None) still runs the oracle
    out = pipnn.search(idx, x, q, k=5, beam=16, batch=False)
    assert out.shape == (2, 5)


def test_pipnn_search_with_stats(built):
    idx, x = built
    q = x[:6]
    out, stats = pipnn.search(idx, x, q, k=5, beam=16, with_stats=True)
    assert out.shape == (6, 5)
    assert stats["hops"].shape == (6,)
    assert stats["dist_comps"].shape == (6,)
    assert stats["iters_cap"] == 20


def test_serving_stats_iters_cap_single_sourced(built):
    """Regression: the engine's default cap and the reported ``iters_cap``
    both come from ``beam_search.default_iters`` — they used to be two
    hard-coded ``beam + 4`` copies that could silently drift."""
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    for beam in (5, 16, 33):
        _, stats = sv.search(x[:3], k=4, beam=beam, with_stats=True)
        assert stats["iters_cap"] == bs.default_iters(beam)
    _, stats = sv.search(x[:3], k=4, beam=16, iters=7, with_stats=True)
    assert stats["iters_cap"] == 7


def test_serving_empty_query_batch_short_circuits(built, monkeypatch):
    """Regression: an empty batch with ``query_chunk`` set used to pad up
    to a 1-row chunk and dispatch a full device search; now nq == 0
    returns immediately with correctly-shaped outputs."""
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    calls = {"n": 0}
    orig = bs.beam_search_batch

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(bs, "beam_search_batch", counting)
    empty = np.empty((0, x.shape[1]), np.float32)
    for kw in ({}, {"query_chunk": 16}, {"query_chunk": 1}):
        out = sv.search(empty, k=10, beam=8, **kw)
        assert out.shape == (0, 10) and out.dtype == np.int64
    out, stats = sv.search(empty, k=3, beam=8, query_chunk=4,
                           with_stats=True)
    assert out.shape == (0, 3)
    assert stats["hops"].shape == (0,)
    assert stats["dist_comps"].shape == (0,)
    assert stats["iters_cap"] == bs.default_iters(8)
    assert calls["n"] == 0


def test_pipnn_serving_cache_invalidated_by_graph_change(built):
    """Regression: the serving cache keyed on (start, metric, dtype) and
    the dataset object but NOT the graph, so replacing ``index.graph``
    after the first search silently served the stale device copy."""
    idx, x = built
    q = x[:8]
    idx._serving = None
    idx._serving_key = None
    idx._serving_graph = None
    first = pipnn.search(idx, x, q, k=5, beam=16)
    sv1 = idx._serving
    # a trivial replacement graph: every row points at vertex 0 only
    old_graph = idx.graph
    try:
        idx.graph = np.full_like(old_graph, -1)
        idx.graph[:, 0] = 0
        degraded = pipnn.search(idx, x, q, k=5, beam=16)
        assert idx._serving is not sv1, "stale ServingIndex reused"
        # the degenerate graph can only ever reach vertex 0 + the start
        assert set(np.unique(degraded)) <= {-1, 0, idx.start}
        # and restoring the original graph object restores the results
        idx.graph = old_graph
        again = pipnn.search(idx, x, q, k=5, beam=16)
        np.testing.assert_array_equal(first, again)
    finally:
        idx.graph = old_graph


# ------------------------------------------------------------ int8 serving ---

def test_serving_int8_packing_and_device_bytes(built):
    idx, x = built
    n, d = x.shape
    r = idx.graph.shape[1]
    sv = ServingIndex.from_index(idx, x)
    sv8 = ServingIndex.from_index(idx, x, dtype="int8")
    assert sv8.points.dtype == jnp.int8
    assert sv8.scales is not None and sv8.scales.dtype == jnp.float32
    assert sv8.norms.dtype == jnp.float32
    # exact accounting: graph + int8 points + f32 norms + f32 scales
    assert sv8.device_bytes() == n * r * 4 + n * d + n * 4 + n * 4
    assert sv.device_bytes() == n * r * 4 + n * d * 4 + n * 4
    assert sv8.device_bytes() < sv.device_bytes()
    # jnp.int8 / np.int8 spellings select the same packing
    sv8b = ServingIndex.from_index(idx, x, dtype=jnp.int8)
    assert sv8b.points.dtype == jnp.int8 and sv8b.scales is not None


def test_serving_int8_points_footprint_quarter():
    """On a points-dominated (BigANN-shaped, d=128) packing the int8 copy
    is <= ~1/3 of the f32 total; the points block itself is exactly 1/4."""
    rng = np.random.default_rng(2)
    x = rng.standard_normal((512, 128)).astype(np.float32)
    g = np.zeros((512, 16), np.int32)
    sv = ServingIndex.from_graph(g, x, 0)
    sv8 = ServingIndex.from_graph(g, x, 0, dtype="int8")
    assert sv8.points.size * sv8.points.dtype.itemsize == \
        (sv.points.size * sv.points.dtype.itemsize) // 4
    assert sv8.device_bytes() <= 0.35 * sv.device_bytes()


@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_serving_int8_recall_parity(metric):
    """int8 serving must stay within 0.02 recall of f32 serving on every
    metric (the norm halves are exact; only the inner product rounds)."""
    rng = np.random.default_rng(17)
    x = rng.standard_normal((1200, 24)).astype(np.float32)
    truth = brute_force_knn(x, x, 17, metric=metric)
    graph = truth[:, 1:17].astype(np.int32)
    q = rng.standard_normal((48, 24)).astype(np.float32)
    gt = brute_force_knn(x, q, 10, metric=metric)
    start = medoid(x)
    sv = ServingIndex.from_graph(graph, x, start, metric=metric)
    sv8 = ServingIndex.from_graph(graph, x, start, metric=metric,
                                  dtype="int8")
    r32 = recall_at_k(sv.search(q, k=10, beam=32), gt, 10)
    r8 = recall_at_k(sv8.search(q, k=10, beam=32), gt, 10)
    assert r8 >= r32 - 0.02, (metric, r32, r8)


def test_serving_int8_pallas_interpret_matches_ref_path(built):
    """The int8 Pallas serving path (interpret mode) returns the same
    neighbors as the int8 XLA oracle path — the kernel pair is bit-equal,
    so the searches are too."""
    idx, x = built
    q = x[:24]
    sv8 = ServingIndex.from_index(idx, x, dtype="int8")
    a = sv8.search(q, k=10, beam=24, use_pallas=False)
    b = sv8.search(q, k=10, beam=24, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(a, b)


def test_serving_int8_degenerate_data():
    """Constant / zero datasets: clamped scales keep every distance
    finite and the search still returns valid ids."""
    for x in (np.zeros((64, 8), np.float32),
              np.full((64, 8), 3.0, np.float32)):
        graph = np.stack([(np.arange(64, dtype=np.int32) + 1) % 64,
                          (np.arange(64, dtype=np.int32) + 2) % 64], axis=1)
        sv8 = ServingIndex.from_graph(graph, x, 0, dtype="int8")
        q = np.zeros((3, 8), np.float32)
        out, stats = sv8.search(q, k=5, beam=8, with_stats=True)
        assert out.shape == (3, 5)
        assert (out >= 0).all() and (out < 64).all()


def test_pipnn_search_int8_end_to_end(built):
    """dtype="int8" threads through pipnn.search -> cached ServingIndex
    -> quantized engine, at recall parity with the f32 serving path.
    (The cache is a SINGLE slot keyed by (start, metric, dtype) + data/
    graph identity: switching dtype repacks and replaces it — hold your
    own ServingIndex instances to serve both precisions side by side.)"""
    idx, x = built
    q = x[:64] + 0.01 * np.random.default_rng(3).standard_normal(
        (64, x.shape[1])).astype(np.float32)
    truth = brute_force_knn(x, q, 10)
    r32 = recall_at_k(pipnn.search(idx, x, q, k=10, beam=48), truth, 10)
    found8 = pipnn.search(idx, x, q, k=10, beam=48, dtype="int8")
    r8 = recall_at_k(found8, truth, 10)
    assert r8 >= r32 - 0.02, (r32, r8)
    sv8 = idx._serving
    assert sv8.points.dtype == jnp.int8
    # same dataset + graph + dtype => cache hit
    pipnn.search(idx, x, q, k=10, beam=48, dtype="int8")
    assert idx._serving is sv8


def test_beam_search_batch_int8_guards():
    """scales without int8 points (or without exact norms) is an error —
    silent misuse would serve garbage distances."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((32, 8)).astype(np.float32)
    graph = np.zeros((32, 2), np.int32)
    q = x[:2]
    from repro.kernels.ref import quantize_symmetric
    from repro.core.metrics import point_norms
    x8, scl = quantize_symmetric(jnp.asarray(x))
    with pytest.raises(TypeError):
        beam_search_batch(graph, x, q, start=0, beam=4, scales=scl)
    with pytest.raises(ValueError):
        beam_search_batch(graph, x8, q, start=0, beam=4, scales=scl)
    # proper call: int8 points + scales + exact norms
    ids, _ = beam_search_batch(graph, x8, q, start=0, beam=4, scales=scl,
                               norms=point_norms(jnp.asarray(x), "l2"))
    assert np.asarray(ids).shape == (2, 4)


def test_serving_pallas_interpret_path_matches(built):
    """The fused Pallas gather-distance serving path (interpret mode on
    CPU) returns the same neighbors as the jnp fallback path."""
    idx, x = built
    q = x[:24]
    sv = ServingIndex.from_index(idx, x)
    a = sv.search(q, k=10, beam=24, use_pallas=False)
    b = sv.search(q, k=10, beam=24, use_pallas=True, interpret=True)
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------- kernel-path selection ---

def test_serving_kernel_path_auto_xla_on_cpu(built):
    """On a CPU backend the auto-selection is the XLA gather, and the
    served path is surfaced both on the index and in telemetry."""
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    assert sv.kernel_path == "xla"
    _, stats = sv.search(x[:4], k=5, with_stats=True)
    assert stats["kernel_path"] == "xla"
    # the empty-batch short-circuit reports the path too
    _, stats0 = sv.search(np.zeros((0, x.shape[1]), np.float32), k=5,
                          with_stats=True)
    assert stats0["kernel_path"] == "xla"


@pytest.mark.parametrize("path", ["vmem", "hbm"])
def test_serving_forced_kernel_path_matches_xla(built, path):
    """Forcing either Pallas path (interpret mode) returns the same
    neighbors as the XLA gather, and the stats record the forced path."""
    idx, x = built
    q = x[:24]
    sv = ServingIndex.from_index(idx, x)
    a = sv.search(q, k=10, beam=24, kernel_path="xla")
    b, stats = sv.search(q, k=10, beam=24, kernel_path=path,
                         interpret=True, with_stats=True)
    np.testing.assert_array_equal(a, b)
    assert stats["kernel_path"] == path


def test_serving_int8_forced_hbm_matches_xla(built):
    """int8 + streaming kernel end to end: bit-equal distances => the
    same neighbors as the int8 XLA oracle path."""
    idx, x = built
    q = x[:24]
    sv8 = ServingIndex.from_index(idx, x, dtype="int8")
    a = sv8.search(q, k=10, beam=24, kernel_path="xla")
    b = sv8.search(q, k=10, beam=24, kernel_path="hbm", interpret=True)
    np.testing.assert_array_equal(a, b)


def test_serving_kernel_path_rejects_unknown(built):
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    with pytest.raises(ValueError):
        sv.search(x[:2], k=5, kernel_path="dma")


def test_resolve_kernel_path_use_pallas_and_budget():
    """The legacy boolean still works: True -> vmem when the block fits
    the (overridable) budget, hbm when it does not; False -> xla."""
    from repro.core.beam_search import resolve_kernel_path

    x = jnp.zeros((1000, 32), jnp.float32)          # 128 KB
    assert resolve_kernel_path(x, use_pallas=False) == "xla"
    assert resolve_kernel_path(x, use_pallas=True) == "vmem"
    assert resolve_kernel_path(x, use_pallas=True,
                               vmem_budget=64 * 1024) == "hbm"
    # explicit kernel_path beats everything
    assert resolve_kernel_path(x, kernel_path="hbm",
                               use_pallas=False) == "hbm"


def test_serving_vmem_budget_threads_to_selection(built):
    """A ServingIndex-level budget reshapes the auto-selection the legacy
    boolean maps through: under a tiny budget use_pallas=True serves the
    streaming kernel and still returns the XLA path's neighbors."""
    idx, x = built
    q = x[:16]
    tiny = ServingIndex.from_index(idx, x, vmem_budget=1024)
    big = ServingIndex.from_index(idx, x)
    a = big.search(q, k=10, beam=24, use_pallas=False)
    b, stats = tiny.search(q, k=10, beam=24, use_pallas=True,
                           interpret=True, with_stats=True)
    assert stats["kernel_path"] == "hbm"
    np.testing.assert_array_equal(a, b)
    c, stats2 = big.search(q, k=10, beam=24, use_pallas=True,
                           interpret=True, with_stats=True)
    assert stats2["kernel_path"] == "vmem"
    np.testing.assert_array_equal(a, c)


# ------------------------------------------------------------ recall_at_k ---

def _recall_at_k_loop(found, truth, k):
    hits = 0
    for f, t in zip(found, truth):
        hits += len(set(f[:k].tolist()) & set(t[:k].tolist()))
    return hits / (len(found) * k)


@pytest.mark.parametrize("seed", range(4))
def test_recall_at_k_matches_set_loop(seed):
    rng = np.random.default_rng(seed)
    q, k = 37, 10
    found = rng.integers(-1, 40, (q, k)).astype(np.int64)
    truth = rng.integers(0, 40, (q, k)).astype(np.int64)
    # inject duplicates and -1 runs (set semantics must match exactly)
    found[::3, 1] = found[::3, 0]
    found[::5, 2:] = -1
    assert recall_at_k(found, truth, k) == pytest.approx(
        _recall_at_k_loop(found, truth, k))


def test_recall_at_k_known_value():
    f = np.array([[1, 2, 3], [4, 5, 6]])
    t = np.array([[1, 2, 9], [4, 5, 6]])
    assert recall_at_k(f, t, 3) == pytest.approx(5 / 6)


def test_resolve_kernel_path_env_budget_parsing(monkeypatch):
    """Env-override hygiene: malformed / negative values fall back to the
    default with a warning (a serving process must not crash at dispatch
    over an env typo); 0 is a real budget meaning "nothing fits"."""
    from repro.core.beam_search import resolve_kernel_path
    from repro.kernels.gather_distance import (_VMEM_POINTS_BUDGET,
                                               vmem_points_budget)

    for bad in ("8MiB", "1e6", "-5"):
        monkeypatch.setenv("PIPNN_VMEM_POINTS_BUDGET", bad)
        assert vmem_points_budget() == _VMEM_POINTS_BUDGET
    monkeypatch.setenv("PIPNN_VMEM_POINTS_BUDGET", "")
    assert vmem_points_budget() == _VMEM_POINTS_BUDGET

    # zero: every Pallas request streams (the tiniest block "doesn't fit")
    monkeypatch.setenv("PIPNN_VMEM_POINTS_BUDGET", "0")
    assert vmem_points_budget() == 0
    tiny = jnp.zeros((8, 8), jnp.float32)
    assert resolve_kernel_path(tiny, use_pallas=True) == "hbm"

    # huge: a block far past the default budget goes VMEM-resident
    monkeypatch.setenv("PIPNN_VMEM_POINTS_BUDGET", str(1 << 40))
    big = jnp.zeros((1 << 16, 128), jnp.float32)       # 32 MiB
    assert resolve_kernel_path(big, use_pallas=True) == "vmem"


def test_resolve_kernel_path_vmem_budget_boundary_shapes():
    """The vmem->hbm boundary prices blocks at the TPU-tile-padded
    footprint: a narrow-d block lane-pads to 128 columns, so it crosses
    the budget at the same row count as a full-width block."""
    from repro.core.beam_search import resolve_kernel_path
    from repro.kernels.gather_distance import fits_vmem

    budget = 1 << 20
    # (2048, 128) f32 is exactly 1 MiB padded -> last shape that fits
    assert fits_vmem(jnp.zeros((2048, 128), jnp.float32), budget=budget)
    assert not fits_vmem(jnp.zeros((2056, 128), jnp.float32), budget=budget)
    # d=8 lane-pads to 128: same boundary despite 16x fewer payload bytes
    assert fits_vmem(jnp.zeros((2048, 8), jnp.float32), budget=budget)
    assert not fits_vmem(jnp.zeros((2056, 8), jnp.float32), budget=budget)
    assert resolve_kernel_path(jnp.zeros((2056, 8), jnp.float32),
                               use_pallas=True, vmem_budget=budget) == "hbm"
    # int8 sublane tile is 32 rows: 4x headroom, minus the f32 scales row
    pts8 = jnp.zeros((2048, 128), jnp.int8)            # 256 KiB padded
    scl = jnp.zeros((2048,), jnp.float32)
    assert fits_vmem(pts8, scl, budget=budget)
    assert resolve_kernel_path(pts8, scl, use_pallas=True,
                               vmem_budget=budget) == "vmem"


def test_resolve_kernel_path_legacy_use_pallas_mapping():
    """The full legacy-boolean truth table, f32 and int8: False always
    means xla; True means vmem-if-fits-else-hbm; explicit kernel_path
    wins over both."""
    from repro.core.beam_search import resolve_kernel_path

    x = jnp.zeros((512, 128), jnp.float32)             # 256 KiB
    s = jnp.zeros((512,), jnp.float32)
    for scales in (None, s):
        assert resolve_kernel_path(x, scales, use_pallas=False) == "xla"
        assert resolve_kernel_path(x, scales, use_pallas=True) == "vmem"
        assert resolve_kernel_path(x, scales, use_pallas=True,
                                   vmem_budget=1) == "hbm"
        for forced in ("vmem", "hbm", "xla"):
            assert resolve_kernel_path(x, scales, kernel_path=forced,
                                       use_pallas=False) == forced
    with pytest.raises(ValueError):
        resolve_kernel_path(x, kernel_path="dma")


# ----------------------------------------------------- boundary hardening ---

def test_search_guards_nonpositive_k_and_beam(built):
    """k/beam <= 0 must be a clear ValueError at the boundary, not an
    opaque XLA shape error three layers down (Issue 9)."""
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    with pytest.raises(ValueError, match="k must be >= 1"):
        sv.search(x[:2], k=0)
    with pytest.raises(ValueError, match="beam must be >= 1"):
        sv.search(x[:2], k=5, beam=0)
    with pytest.raises(ValueError, match="k must be >= 1"):
        pipnn.search(idx, x, x[:2], k=-3)
    with pytest.raises(ValueError, match="beam must be >= 1"):
        pipnn.search(idx, x, x[:2], k=5, beam=-1, batch=False)


def test_search_rejects_nan_inf_rows_with_row_list(built):
    from repro.core.validation import InvalidQueryError

    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    q = np.array(x[:5])
    q[1, 0] = np.nan
    q[3, 2] = np.inf
    with pytest.raises(InvalidQueryError) as ei:
        sv.search(q, k=5)
    assert ei.value.reason == "nan_inf"
    assert ei.value.rows == (1, 3)
    # clean rows of the same batch serve fine once the poison is dropped
    ok = sv.search(np.delete(q, [1, 3], axis=0), k=5)
    assert (ok[:, 0] >= 0).all()


def test_search_rejects_bad_shapes_and_width(built):
    from repro.core.validation import InvalidQueryError

    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    with pytest.raises(InvalidQueryError, match=r"2-D"):
        sv.search(x[0], k=5)                      # 1-D single query
    with pytest.raises(InvalidQueryError, match="width"):
        sv.search(x[:3, :7], k=5)                 # wrong dimension
    with pytest.raises(InvalidQueryError, match="castable"):
        pipnn.search(idx, x, np.array([["a", "b"]]), k=5, batch=False)


def test_converged_telemetry(built):
    """with_stats exposes per-query convergence — the straggler signal
    the two-phase serving loop drains on: True at a generous cap, False
    when the iters backstop cuts the walk off early."""
    idx, x = built
    sv = ServingIndex.from_index(idx, x)
    _, stats = sv.search(x[:6], k=5, beam=16, with_stats=True)
    conv = stats["converged"]
    assert conv.shape == (6,) and conv.dtype == bool
    assert conv.all()                 # default cap: every query converges
    ids1, stats1 = sv.search(x[:6], k=5, beam=16, iters=1, with_stats=True)
    assert not stats1["converged"].any()
    # the backstop-capped ids are still a valid (if unconverged) beam
    assert (np.asarray(ids1)[:, 0] >= 0).all()
