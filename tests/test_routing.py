"""Properties of the capacity-routed group-by (shared by the distributed
PiPNN build and the EP MoE dispatch)."""
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

given = hypothesis.given
settings = hypothesis.settings

import jax.numpy as jnp

from repro.distributed.routing import group_by_capacity


@settings(deadline=None, max_examples=30)
@given(st.data())
def test_group_by_capacity_properties(data):
    rng_seed = data.draw(st.integers(0, 2**16))
    n = data.draw(st.integers(1, 200))
    n_groups = data.draw(st.integers(1, 8))
    cap = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(rng_seed)
    keys = rng.integers(0, n_groups, n).astype(np.int32)
    valid = rng.random(n) > 0.2
    payload = np.arange(n, dtype=np.int32)

    (out,), mask = group_by_capacity(
        jnp.asarray(keys), jnp.asarray(valid), n_groups, cap,
        [jnp.asarray(payload)])
    out, mask = np.asarray(out), np.asarray(mask)

    # every emitted slot holds a valid entry routed to the right group
    for g in range(n_groups):
        got = out[g][mask[g]]
        assert all(keys[i] == g and valid[i] for i in got)
        assert len(set(got.tolist())) == len(got), "duplicates"
        expect = min(int((valid & (keys == g)).sum()), cap)
        assert len(got) == expect, "drops only on capacity overflow"
    # nothing valid is lost unless its group was full
    emitted = set(out[mask].tolist())
    for i in range(n):
        if valid[i] and int((valid & (keys == keys[i])).sum()) <= cap:
            assert i in emitted


def test_shuffle_drops_are_unbiased():
    """With shuffle, overflow drops shouldn't all hit the tail indices."""
    n, cap = 4096, 64
    keys = np.zeros(n, dtype=np.int32)           # one hot group
    (out,), mask = group_by_capacity(
        jnp.asarray(keys), jnp.ones(n, bool), 1, cap,
        [jnp.arange(n, dtype=jnp.int32)], shuffle=True)
    kept = np.asarray(out)[0][np.asarray(mask)[0]]
    assert kept.max() > n // 2, "shuffled keep-set must span the range"
    assert kept.min() < n // 2


def test_invalid_never_emitted():
    keys = jnp.asarray(np.zeros(16, np.int32))
    valid = jnp.asarray(np.zeros(16, bool))
    (out,), mask = group_by_capacity(keys, valid, 2, 8,
                                     [jnp.arange(16, dtype=jnp.int32)])
    assert not np.asarray(mask).any()
    assert (np.asarray(out) == -1).all()
