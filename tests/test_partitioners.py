"""Partition-invariant suite for all four Stage-1 partitioners, the
device-vs-host ``ball_carve`` bit-identity contract, and the
degenerate-data regressions (duplicate-heavy inputs) this PR hardens
against:

  * ``ball_carve`` / ``kmeans_carve`` used to recurse forever when every
    point of a subproblem assigned to one leader (bucket == parent);
  * ``binary_partition``'s coin-flip fallback could produce an empty side
    and re-push the full subproblem;
  * ``sorting_lsh_partition`` packed hash bits into a float64 key that
    silently collided for n_bits > 53.

Deliberately hypothesis-free (seeded rng sweeps) so everything runs in
the container, like tests/test_streaming_build.py.
"""
import numpy as np
import pytest

from repro.core import pipnn
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import (
    RBCParams,
    ball_carve,
    ball_carve_device,
    binary_partition,
    bit_lex_order,
    kmeans_carve,
    padded_coverage,
    partition,
    partition_padded,
    sorting_lsh_partition,
)

METHODS = ("rbc", "binary", "kmeans", "sorting_lsh")


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(3)
    return rng.standard_normal((1500, 12)).astype(np.float32)


def _check_invariants(leaves, n, c_max):
    seen = np.zeros(n, dtype=bool)
    for leaf in leaves:
        assert 0 < len(leaf) <= c_max
        assert len(np.unique(leaf)) == len(leaf), "duplicate id inside a leaf"
        seen[leaf] = True
    assert seen.all(), "every point must land in at least one leaf"


# ---------------------------------------------------------------- suite ---

@pytest.mark.parametrize("metric", ["l2", "mips"])
@pytest.mark.parametrize("method", METHODS)
def test_invariants_coverage_capacity_determinism(data, method, metric):
    p = RBCParams(c_max=96, c_min=12, p_samp=0.02, fanout=(3, 2),
                  metric=metric, seed=5)
    a = partition(data, p, method)
    _check_invariants(a, data.shape[0], p.c_max)
    b = partition(data, p, method)
    assert len(a) == len(b)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la, lb)


@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_device_ball_carve_bit_identical_to_host(data, metric):
    """The host-orchestrated device carve consumes the same RNG stream and
    reproduces the numpy oracle's assignment decisions, so the leaves are
    bit-identical for a fixed seed."""
    p = RBCParams(c_max=128, c_min=16, p_samp=0.02, fanout=(3, 2),
                  metric=metric, seed=9)
    host = ball_carve(data, p, execution="host")
    dev = ball_carve(data, p, execution="device")
    assert len(host) == len(dev)
    for lh, ld in zip(host, dev):
        np.testing.assert_array_equal(lh, ld)


def test_execution_override_beats_params(data):
    p = RBCParams(c_max=128, c_min=16, fanout=(3,), seed=2, execution="device")
    dev = ball_carve(data, p)                      # params say device
    host = ball_carve(data, p, execution="host")   # call-site override
    assert len(dev) == len(host)
    for lh, ld in zip(host, dev):
        np.testing.assert_array_equal(lh, ld)


# ------------------------------------------------- degenerate regressions ---

def test_ball_carve_duplicate_points_terminates():
    """Regression: all-identical points used to recurse forever (every point
    assigns to one leader -> bucket == parent re-pushed with no progress).
    The progress guard force-splits by permutation halves."""
    x = np.ones((600, 8), dtype=np.float32)
    p = RBCParams(c_max=64, c_min=8, p_samp=0.05, fanout=(3,), seed=0)
    leaves = ball_carve(x, p, execution="host")
    _check_invariants(leaves, x.shape[0], p.c_max)
    # device orchestration shares the worklist + guard: still bit-identical
    dev = ball_carve(x, p, execution="device")
    assert len(dev) == len(leaves)
    for lh, ld in zip(leaves, dev):
        np.testing.assert_array_equal(lh, ld)


def test_kmeans_carve_duplicate_points_terminates():
    x = np.ones((500, 6), dtype=np.float32)
    p = RBCParams(c_max=64, c_min=8, p_samp=0.05, fanout=(2,), seed=1)
    leaves = kmeans_carve(x, p)
    _check_invariants(leaves, x.shape[0], p.c_max)


def test_binary_partition_duplicate_points_terminates():
    """Regression: the degenerate-split guard used a coin-flip mask that
    could leave one side empty and re-push the whole subproblem; the
    permutation-halves split guarantees progress."""
    x = np.ones((400, 4), dtype=np.float32)
    leaves = binary_partition(x, c_max=16, seed=3)
    _check_invariants(leaves, x.shape[0], 16)
    # binary partitioning is disjoint: sizes must sum to n exactly
    assert sum(len(b) for b in leaves) == x.shape[0]


def test_bit_lex_order_full_precision_past_53_bits():
    """Regression: the float64 key (key = key*2 + bit) lost bits past the
    f64 mantissa, collapsing distinct 64-bit codes onto one key."""
    bits = np.zeros((4, 64), dtype=bool)
    bits[:, :50] = True          # identical 50-bit prefix
    bits[1, 60] = True
    bits[2, 63] = True
    bits[3, 60:] = True
    order = bit_lex_order(bits)
    # lexicographic: row0 (all-zero tail) < row2 (bit 63) < row1 (bit 60)
    # < row3 (bits 60..63); the old float key tied all four
    np.testing.assert_array_equal(order, [0, 2, 1, 3])
    # stability: identical rows keep their original relative order
    dup = np.tile(bits[3], (3, 1))
    np.testing.assert_array_equal(bit_lex_order(dup), [0, 1, 2])


def test_bit_lex_order_matches_float_key_when_exact():
    """For n_bits <= 53 the uint64 packing must reproduce the old float64
    ordering exactly (no behavior change where the old key was lossless)."""
    rng = np.random.default_rng(11)
    bits = rng.random((300, 24)) < 0.5
    key = np.zeros(300, dtype=np.float64)
    for i in range(24):
        key = key * 2 + bits[:, i]
    np.testing.assert_array_equal(
        bit_lex_order(bits), np.argsort(key, kind="stable"))


def test_sorting_lsh_64_bits(data):
    leaves = sorting_lsh_partition(data, c_max=64, n_bits=64, seed=2)
    _check_invariants(leaves, data.shape[0], 64)
    again = sorting_lsh_partition(data, c_max=64, n_bits=64, seed=2)
    for la, lb in zip(leaves, again):
        np.testing.assert_array_equal(la, lb)


# ------------------------------------------------- shared leader_assign ---

@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_leader_assign_matches_numpy_oracle(metric):
    import jax.numpy as jnp

    from repro.core.leader_assign import leader_assign
    from repro.core.rbc import _nearest_leaders

    rng = np.random.default_rng(13)
    x = rng.standard_normal((200, 10)).astype(np.float32)
    leaders = x[rng.choice(200, 17, replace=False)]
    want = _nearest_leaders(x, leaders, 4, metric)
    got = np.asarray(leader_assign(jnp.asarray(x), jnp.asarray(leaders), 4,
                                   metric=metric))
    np.testing.assert_array_equal(got, want)


def test_leader_assign_pallas_path_matches_default():
    """The Pallas distance + rowwise_topk route (interpret mode on CPU)
    selects the same leaders as the jnp path."""
    import jax.numpy as jnp

    from repro.core.leader_assign import leader_assign

    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.standard_normal((3, 64, 8)).astype(np.float32))
    leaders = jnp.asarray(rng.standard_normal((3, 12, 8)).astype(np.float32))
    lead_ok = jnp.asarray(np.arange(12) < 10)[None, :].repeat(3, 0)
    base = leader_assign(x, leaders, 3, leader_valid=lead_ok)
    pallas = leader_assign(x, leaders, 3, leader_valid=lead_ok,
                           use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(pallas), np.asarray(base))


def test_leader_assign_masks_invalid_leaders():
    import jax.numpy as jnp

    from repro.core.leader_assign import leader_assign

    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.standard_normal((30, 6)).astype(np.float32))
    leaders = jnp.asarray(rng.standard_normal((8, 6)).astype(np.float32))
    ok = jnp.asarray(np.arange(8) < 5)
    got = np.asarray(leader_assign(x, leaders, 3, leader_valid=ok))
    assert got.max() < 5, "masked leaders must never be selected"


# ---------------------------------------------------- static device carve ---

def test_static_ball_carve_invariants(data):
    p = RBCParams(c_max=128, c_min=16, fanout=(3, 2), seed=4,
                  execution="static")
    padded = ball_carve_device(data, p)
    assert padded.ndim == 2 and padded.shape[1] == p.c_max
    sizes = (padded >= 0).sum(axis=1)
    assert (sizes > 0).all(), "empty leaves must be filtered"
    ids = padded[padded >= 0]
    assert ids.min() >= 0 and ids.max() < data.shape[0]
    for row in padded:
        v = row[row >= 0]
        assert len(np.unique(v)) == len(v), "duplicate id inside a leaf"
    # coverage is guaranteed (salvage leaves catch capacity-drop victims)
    n = data.shape[0]
    assert padded_coverage(padded, n) == n
    # deterministic given the seed
    np.testing.assert_array_equal(padded, ball_carve_device(data, p))
    # partition_padded routes rbc+static through the same path
    np.testing.assert_array_equal(padded, partition_padded(data, p))


def test_static_ball_carve_covers_duplicate_heavy_data():
    """Regression: a dense duplicate cluster overflows every ball it hashes
    to, so capacity routing dropped most of it — the salvage pass must
    re-add every lost point."""
    rng = np.random.default_rng(21)
    x = np.concatenate([np.zeros((1500, 8), np.float32),
                        rng.standard_normal((500, 8)).astype(np.float32)])
    p = RBCParams(c_max=64, c_min=8, fanout=(3, 2), seed=6,
                  execution="static")
    padded = ball_carve_device(x, p)
    assert padded_coverage(padded, x.shape[0]) == x.shape[0]
    for row in padded:
        v = row[row >= 0]
        assert 0 < len(v) <= p.c_max
        assert len(np.unique(v)) == len(v)
    # all-identical input: still full coverage, bounded leaves
    dup = np.ones((600, 8), np.float32)
    padded = ball_carve_device(dup, p)
    assert padded_coverage(padded, 600) == 600


def test_static_partitioner_end_to_end_build():
    """pipnn.build(streaming=True) with the static partitioner produces a
    searchable index with recall at parity with the recursive RBC build."""
    from repro.core.beam_search import brute_force_knn, recall_at_k

    rng = np.random.default_rng(0)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    q = x[:64] + 0.01 * rng.standard_normal((64, 32)).astype(np.float32)
    truth = brute_force_knn(x, q, 10)
    base = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2)),
                       leaf=LeafParams(k=2), l_max=32, max_deg=16, seed=1)
    recalls = {}
    for tag, rbc_exec in (("host", "host"), ("static", "static")):
        p = base.with_(rbc=RBCParams(c_max=128, c_min=16, fanout=(3, 2),
                                     execution=rbc_exec))
        idx = pipnn.build(x, p, streaming=True)
        assert idx.stats["partition_execution"] == rbc_exec
        ids = pipnn.search(idx, x, q, k=10, beam=64)
        recalls[tag] = recall_at_k(ids, truth, 10)
    assert recalls["static"] >= recalls["host"] - 0.03, recalls
