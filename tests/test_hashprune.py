"""HashPrune unit + property tests.

The crown jewels: Theorem 3.1 (history independence / order-freedom) and the
mergeability lemma, checked by hypothesis against the streaming Algorithm 3
reference and the sort-based closed form.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core.hashprune import (
    INVALID_ID,
    Reservoir,
    canonicalize,
    hashprune_batch,
    hashprune_flat,
    hashprune_merge,
    hashprune_stream,
    reservoir_init,
)


def brute_force_reference(ids, hashes, dists, l_max):
    """Closed form of Thm 3.1, in pure python: nearest per bucket, then
    l_max nearest overall, ties by id."""
    best = {}
    for i, h, d in zip(ids, hashes, dists):
        if i < 0 or not np.isfinite(d):
            continue
        if h not in best or (d, i) < best[h]:
            best[h] = (d, i)
    winners = sorted(best.values())[:l_max]
    return [(i, d) for d, i in winners]


def as_pairs(res: Reservoir):
    res = canonicalize(res)
    ids = np.asarray(res.ids)[0]
    ds = np.asarray(res.dists)[0]
    return [(int(i), float(d)) for i, d in zip(ids, ds) if i != -1]


cand_strategy = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=50),      # id
        st.integers(min_value=0, max_value=7),       # hash (small => collisions)
        st.sampled_from([0.5, 1.0, 1.5, 2.0, 3.0, 5.0]),  # dist (ties likely)
    ),
    min_size=1,
    max_size=40,
)


def _dedupe_id_hash(cands):
    """An id must map to one hash (ids hash deterministically in PiPNN)."""
    seen = {}
    out = []
    for i, h, d in cands:
        h = seen.setdefault(i, h)
        out.append((i, h, d))
    return out


@hypothesis.settings(max_examples=60, deadline=None)
@hypothesis.given(cands=cand_strategy, l_max=st.sampled_from([1, 2, 4, 8]),
                  seed=st.integers(0, 2**31 - 1))
def test_stream_matches_closed_form_any_order(cands, l_max, seed):
    cands = _dedupe_id_hash(cands)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(cands))
    ids = np.array([cands[p][0] for p in perm], dtype=np.int32)
    hs = np.array([cands[p][1] for p in perm], dtype=np.int32)
    ds = np.array([cands[p][2] for p in perm], dtype=np.float32)

    res_s = hashprune_stream(jnp.asarray(ids), jnp.asarray(hs), jnp.asarray(ds), l_max=l_max)
    res_b = hashprune_batch(jnp.asarray(ids)[None], jnp.asarray(hs)[None],
                            jnp.asarray(ds)[None], l_max=l_max)

    # dedupe candidates by id for the reference (same id same hash+dist? dist
    # may differ across duplicates in the stream; reference keeps min (d,i))
    expect = brute_force_reference(ids, hs, ds, l_max)
    assert as_pairs(res_s) == pytest.approx(expect)
    assert as_pairs(res_b) == pytest.approx(expect)


@hypothesis.settings(max_examples=40, deadline=None)
@hypothesis.given(cands=cand_strategy, l_max=st.sampled_from([2, 4, 8]),
                  cut=st.integers(0, 40), seed=st.integers(0, 2**31 - 1))
def test_merge_lemma(cands, l_max, cut, seed):
    """R(R(C1) U C2) == R(C1 U C2) for any split point."""
    cands = _dedupe_id_hash(cands)
    cut = min(cut, len(cands))
    c1, c2 = cands[:cut], cands[cut:]

    def arrs(c):
        if not c:
            return (jnp.full((1, 1), INVALID_ID, jnp.int32),
                    jnp.zeros((1, 1), jnp.int32),
                    jnp.full((1, 1), jnp.inf, jnp.float32))
        return (jnp.asarray([[i for i, _, _ in c]], dtype=jnp.int32),
                jnp.asarray([[h for _, h, _ in c]], dtype=jnp.int32),
                jnp.asarray([[d for _, _, d in c]], dtype=jnp.float32))

    r1 = hashprune_batch(*arrs(c1), l_max=l_max)
    merged = hashprune_merge(r1, cand_ids=arrs(c2)[0], cand_hashes=arrs(c2)[1],
                             cand_dists=arrs(c2)[2])
    oneshot = hashprune_batch(*arrs(cands), l_max=l_max)
    assert as_pairs(merged) == pytest.approx(as_pairs(oneshot))


def test_flat_matches_batch_multi_point():
    rng = np.random.default_rng(3)
    n, e = 20, 500
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, 50, e).astype(np.int32)
    # deterministic hash per (src, dst) pair
    hashes = ((src * 31 + dst * 7) % 16).astype(np.int32)
    dist = ((dst * 131 + src * 17) % 97 / 10.0).astype(np.float32)
    l_max = 8
    res = hashprune_flat(jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
                         jnp.asarray(dist), n_points=n, l_max=l_max)
    for p in range(n):
        m = src == p
        expect = brute_force_reference(dst[m], hashes[m], dist[m], l_max)
        got = as_pairs(Reservoir(res.ids[p:p+1], res.hashes[p:p+1], res.dists[p:p+1]))
        assert got == pytest.approx(expect), f"point {p}"


def test_flat_drops_padding():
    n = 4
    src = jnp.asarray([0, 1, n, n], dtype=jnp.int32)  # last two are padding
    dst = jnp.asarray([1, 0, INVALID_ID, INVALID_ID], dtype=jnp.int32)
    hashes = jnp.zeros(4, jnp.int32)
    dist = jnp.asarray([1.0, 1.0, np.inf, np.inf], dtype=jnp.float32)
    res = hashprune_flat(src, dst, hashes, dist, n_points=n, l_max=4)
    ids = np.asarray(res.ids)
    assert ids[0, 0] == 1 and ids[1, 0] == 0
    assert (ids[2:] == -1).all()
    assert (ids[:2, 1:] == -1).all()


def test_reservoir_capacity_and_eviction():
    # 5 distinct hashes, l_max 3 -> keep 3 nearest
    ids = jnp.asarray([[10, 11, 12, 13, 14]], dtype=jnp.int32)
    hs = jnp.asarray([[0, 1, 2, 3, 4]], dtype=jnp.int32)
    ds = jnp.asarray([[5.0, 1.0, 3.0, 2.0, 4.0]], dtype=jnp.float32)
    res = hashprune_batch(ids, hs, ds, l_max=3)
    assert as_pairs(res) == [(11, 1.0), (13, 2.0), (12, 3.0)]


def test_collision_keeps_closer():
    ids = jnp.asarray([[10, 11]], dtype=jnp.int32)
    hs = jnp.asarray([[7, 7]], dtype=jnp.int32)
    ds = jnp.asarray([[2.0, 1.0]], dtype=jnp.float32)
    res = hashprune_batch(ids, hs, ds, l_max=8)
    assert as_pairs(res) == [(11, 1.0)]


def test_empty_input():
    res = hashprune_batch(
        jnp.full((2, 3), INVALID_ID, jnp.int32),
        jnp.zeros((2, 3), jnp.int32),
        jnp.full((2, 3), jnp.inf, jnp.float32),
        l_max=4,
    )
    assert (np.asarray(res.ids) == -1).all()


def test_stream_order_invariance_direct():
    """Directly permute the stream and compare reservoirs (Thm 3.1)."""
    rng = np.random.default_rng(0)
    ids = np.arange(30, dtype=np.int32)
    hs = (ids % 5).astype(np.int32)
    ds = rng.uniform(0, 10, 30).astype(np.float32)
    base = None
    for trial in range(5):
        perm = rng.permutation(30)
        r = hashprune_stream(jnp.asarray(ids[perm]), jnp.asarray(hs[perm]),
                             jnp.asarray(ds[perm]), l_max=4)
        pairs = as_pairs(r)
        if base is None:
            base = pairs
        assert pairs == pytest.approx(base)
