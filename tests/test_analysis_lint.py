"""Per-rule fixtures for the static contract checker (repro.analysis).

Every rule gets a minimal positive fixture (the checker must fire) and a
negative twin (it must stay quiet) — plus the acceptance-level assertions:
the full linter is clean on this repository with the EMPTY checked-in
baseline, and the recompilation audit proves the serving engine compiles
a bounded number of jit variants across a session sweep.
"""
from __future__ import annotations

import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import ast_lint, contracts, jaxpr_audit, lint
from repro.analysis.contracts import (KernelSpec, PallasCallRecord,
                                      capture_pallas_calls, check_record)

REPO = pathlib.Path(__file__).resolve().parents[1]


def rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# AST lint — PIPA001-PIPA004
# ---------------------------------------------------------------------------

def test_ast_traced_branch_fires():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n")
    fs = ast_lint.lint_source(src, "fx.py")
    assert rules(fs) == ["PIPA001"] and fs[0].line == 4


def test_ast_traced_branch_propagates_through_assignment():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    y = x * 2\n"
        "    while y.sum() > 0:\n"
        "        y = y - 1\n"
        "    return y\n")
    assert rules(ast_lint.lint_source(src, "fx.py")) == ["PIPA001"]


def test_ast_static_and_metadata_branches_are_quiet():
    src = (
        "import functools, jax\n"
        "@functools.partial(jax.jit, static_argnames=('flag',))\n"
        "def f(x, flag, opt=None):\n"
        "    if flag:\n"
        "        return x\n"
        "    if opt is None:\n"
        "        opt = 0\n"
        "    if x.shape[0] > 4 and len(x.shape) == 2:\n"
        "        return x[:4]\n"
        "    return x + opt\n")
    assert ast_lint.lint_source(src, "fx.py") == []


def test_ast_host_sync_fires():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    a = float(x)\n"
        "    b = x.sum().item()\n"
        "    c = np.asarray(x)\n"
        "    return a + b + c\n")
    fs = ast_lint.lint_source(src, "fx.py")
    assert [f.rule for f in fs] == ["PIPA002"] * 3


def test_ast_host_sync_on_static_shape_is_quiet():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    rows = int(x.shape[0])\n"
        "    total = np.prod(x.shape)\n"
        "    return x.reshape(rows, total // rows)\n")
    assert ast_lint.lint_source(src, "fx.py") == []


def test_ast_mutable_default_fires():
    src = "def f(a, out=[], cfg={}):\n    return out\n"
    fs = ast_lint.lint_source(src, "fx.py")
    assert [f.rule for f in fs] == ["PIPA003"] * 2


def test_ast_missing_static_shape_param_fires():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x, k):\n"
        "    return x[:k]\n")
    fs = ast_lint.lint_source(src, "fx.py")
    assert rules(fs) == ["PIPA004"] and "'k'" in fs[0].message


def test_ast_call_form_jit_detected():
    src = (
        "import jax\n"
        "def factory():\n"
        "    def step(state, beam):\n"
        "        return state\n"
        "    return jax.jit(step, static_argnames=('beam',))\n")
    assert ast_lint.lint_source(src, "fx.py") == []
    # same, but beam left traced -> flagged
    src_bad = src.replace(", static_argnames=('beam',)", "")
    assert rules(ast_lint.lint_source(src_bad, "fx.py")) == ["PIPA004"]


def test_ast_package_scan_of_repo_is_clean():
    assert ast_lint.lint_package(REPO / "src" / "repro", root=REPO) == []


# ---------------------------------------------------------------------------
# kernel contracts — PIPK001-PIPK005
# ---------------------------------------------------------------------------

def _spec(name="fixture"):
    return KernelSpec(name, "repro.kernels.gather_distance",
                      "repro.kernels.ref:gather_distance_ref", lambda: [])


class _Block:
    """Stand-in BlockSpec for direct check_record tests."""

    def __init__(self, block_shape, index_map=None):
        self.block_shape = block_shape
        self.index_map = index_map or (lambda *g: tuple(0 for _ in block_shape))


def _record(specs_avals, grid, out=(), scratch=()):
    return PallasCallRecord(
        grid=grid,
        out_shape=tuple(jax.ShapeDtypeStruct(s, d) for _, (s, d) in out),
        in_specs=[b for b, _ in specs_avals],
        out_specs=tuple(b for b, _ in out),
        scratch_shapes=tuple(scratch),
        arg_avals=tuple((s, np.dtype(d)) for _, (s, d) in specs_avals))


def test_contract_vmem_overflow_fires():
    # one grid-invariant f32 block of 24 MiB > the 16 MiB capacity
    rec = _record(
        [(_Block((24 * 1024, 256)), ((24 * 1024, 256), np.float32))],
        grid=(1,))
    assert rules(check_record(rec, _spec(), "case")) == ["PIPK001"]


def test_contract_vmem_double_buffers_grid_varying_blocks():
    # 5 MiB block, grid-varying -> 10 MiB working set: fits 16, not 8
    block = _Block((10 * 1024, 128), lambda r: (r, 0))
    rec = _record([(block, ((20 * 1024, 128), np.float32))], grid=(2,))
    assert check_record(rec, _spec(), "c") == []
    assert rules(check_record(rec, _spec(), "c", capacity=8 << 20)) == \
        ["PIPK001"]


def test_contract_tile_misalignment_fires():
    # (5, 128) f32: sublane 5 is not 1, not %8, not the extent
    rec = _record([(_Block((5, 128)), ((40, 128), np.float32))], grid=(1,))
    assert "PIPK002" in rules(check_record(rec, _spec(), "c"))
    # (16, 128) int8 against a larger extent: 16 is not %32
    rec8 = _record([(_Block((16, 128)), ((64, 128), np.int8))], grid=(1,))
    assert "PIPK002" in rules(check_record(rec8, _spec(), "c"))
    # full-extent trailing dims are exempt even when unaligned
    ok = _record([(_Block((8, 100)), ((8, 100), np.float32))], grid=(1,))
    assert check_record(ok, _spec(), "c") == []


def test_contract_grid_undercover_fires():
    # 2 grid steps x 8 rows cover 16 of 32 rows
    block = _Block((8, 128), lambda r: (r, 0))
    rec = _record([(block, ((32, 128), np.float32))], grid=(2,))
    assert rules(check_record(rec, _spec(), "c")) == ["PIPK003"]
    full = _record([(block, ((32, 128), np.float32))], grid=(4,))
    assert check_record(full, _spec(), "c") == []


def test_contract_missing_oracle_fires():
    import dataclasses
    bad = dataclasses.replace(contracts.REGISTRY[0],
                              oracle="repro.kernels.ref:does_not_exist",
                              cases=lambda: [])
    assert rules(contracts.check_kernel(bad)) == ["PIPK004"]


def test_contract_unregistered_site_census_fires(tmp_path):
    pkg = tmp_path / "src" / "repro" / "kernels"
    pkg.mkdir(parents=True)
    (pkg / "rogue.py").write_text(
        "from jax.experimental import pallas as pl\n"
        "def f(x):\n"
        "    return pl.pallas_call(lambda i, o: None, out_shape=None)(x)\n")
    fs = contracts.check_site_census(tmp_path)
    assert rules(fs) == ["PIPK005"]
    assert fs[0].path == "src/repro/kernels/rogue.py" and fs[0].line == 3


def test_contract_capture_sees_real_blockspecs():
    from repro.kernels.gather_distance import gather_distance

    sds = jax.ShapeDtypeStruct
    recs = capture_pallas_calls(
        gather_distance,
        sds((100, 16), jnp.float32), sds((100,), jnp.float32),
        sds((7, 16), jnp.float32), sds((7, 40), jnp.int32),
        metric="l2")
    assert len(recs) == 1
    rec = recs[0]
    # wrapper pads Q 7->8 (tq), d 16->128 (lane), C 40->128 (lane)
    assert rec.grid == (1,)
    assert tuple(rec.in_specs[0].block_shape) == (8, 128)
    assert rec.arg_avals[1][0] == (8, 128)      # nbr_ids, padded
    # and the captured launch passes every contract check
    assert check_record(rec, _spec("gather_distance"), "probe") == []


def test_contract_registry_covers_every_pallas_site():
    assert contracts.check_site_census(REPO) == []


def test_contract_full_registry_is_clean():
    assert contracts.check_kernel_contracts(root=REPO) == []


def test_contract_admitted_sweep_would_catch_unpadded_pricing():
    """The PIPK001 sweep guards the fits_vmem fix: with the old unpadded
    ``size * itemsize`` pricing, a narrow-d shard is admitted whose
    lane-padded block alone exceeds VMEM capacity."""
    from repro.kernels.tiling import padded_bytes

    d, budget = 8, contracts.VMEM_CAPACITY  # 16 MiB "budget" as the old bound
    n = budget // (d * 4)                   # admitted by unpadded pricing
    assert padded_bytes((n, d), np.float32) > contracts.VMEM_CAPACITY
    rec = _record([(_Block((n, 128)), ((n, 128), np.float32))], grid=(1,))
    assert rules(check_record(rec, _spec(), "c")) == ["PIPK001"]


# ---------------------------------------------------------------------------
# jaxpr audit — PIPJ001-PIPJ004
# ---------------------------------------------------------------------------

def test_jaxpr_host_callback_fires():
    def f(x):
        y = jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1

    fs = jaxpr_audit.trace_and_audit(
        f, (jax.ShapeDtypeStruct((4,), jnp.float32),), "fx.py", "f")
    assert rules(fs) == ["PIPJ001"]


def test_jaxpr_debug_callback_fires_inside_scan():
    def f(x):
        def body(c, v):
            jax.debug.callback(lambda _: None, v)
            return c + v, v
        out, _ = jax.lax.scan(body, x[0], x)
        return out

    fs = jaxpr_audit.trace_and_audit(
        f, (jax.ShapeDtypeStruct((4,), jnp.float32),), "fx.py", "f")
    assert "PIPJ001" in rules(fs)


def test_jaxpr_f64_fires_only_under_x64():
    def f(x):
        return x * np.float64(2.0)

    args = (jax.ShapeDtypeStruct((4,), jnp.float32),)
    assert jaxpr_audit.trace_and_audit(f, args, "fx.py", "f") == []
    with jax.experimental.enable_x64():
        def g(x):
            return x.astype(jnp.float64) * 2.0
        fs = jaxpr_audit.trace_and_audit(g, args, "fx.py", "g")
    assert rules(fs) == ["PIPJ002"]


def test_jaxpr_donation_dropped_fires():
    # no output matches the donated input's shape -> XLA drops the alias
    dropped = jax.jit(lambda x: x[:1] * 2.0, donate_argnums=(0,))
    args = (jax.ShapeDtypeStruct((64, 64), jnp.float32),)
    fs = jaxpr_audit.check_donation(dropped, args, 1, "fx.py", "dropped")
    assert rules(fs) == ["PIPJ003"]
    honored = jax.jit(lambda x: x * 2.0, donate_argnums=(0,))
    assert jaxpr_audit.check_donation(honored, args, 1, "fx.py", "ok") == []


def test_jaxpr_hot_paths_are_clean():
    assert jaxpr_audit.audit_hot_paths() == []


def test_jaxpr_recompilation_bound_holds():
    """Acceptance: the serving engine compiles at most one variant per
    (dtype, beam, expansions) across a session sweeping batch sizes."""
    assert jaxpr_audit.audit_recompilation() == []


def test_jaxpr_recompilation_audit_has_teeth():
    """Without query_chunk padding, batch size leaks into the dispatch
    shape and the audit must flag the cache blowup."""
    fs = jaxpr_audit.audit_recompilation(query_chunk=None)
    assert rules(fs) == ["PIPJ004"]


# ---------------------------------------------------------------------------
# baseline + CLI
# ---------------------------------------------------------------------------

def test_finding_key_is_line_free():
    f = lint.Finding("PIPK001", "src/a.py", 42, "kern", "msg")
    assert f.key == "PIPK001 src/a.py:kern"
    assert "42" in f.render() and "PIPK001" in f.render()


def test_baseline_load_ignores_comments(tmp_path):
    p = tmp_path / "baseline.txt"
    p.write_text("# comment\n\nPIPA003 src/x.py:f\n  PIPK001 src/y.py:g\n")
    assert lint.load_baseline(p) == {"PIPA003 src/x.py:f",
                                     "PIPK001 src/y.py:g"}
    assert lint.load_baseline(tmp_path / "missing.txt") == set()


def test_checked_in_baseline_is_empty():
    assert lint.load_baseline(lint.default_baseline_path()) == set()


def test_cli_list_rules_and_ast_pass():
    env = {"PYTHONPATH": str(REPO / "src")}
    import os
    env = {**os.environ, **env}
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--list-rules"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0 and "PIPK001" in out.stdout
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--pass", "ast"],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout and "RuntimeWarning" not in out.stderr
