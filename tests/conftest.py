"""Shared fixtures.

``no_implicit_transfers`` is the test-side twin of the PIPS004 lint
audit: it holds a block of serving calls under
``jax.transfer_guard("disallow")``, so any host<->device crossing NOT
routed through the declared boundaries (``repro.core.transfers.to_device``
/ ``to_host``, which open local allow-scopes) raises instead of silently
shipping bytes.  Serving-path tests wrap their search calls in it to
prove the path stays implicit-transfer-free as it evolves.
"""
from __future__ import annotations

import contextlib

import pytest


@pytest.fixture
def no_implicit_transfers():
    """Factory fixture: ``with no_implicit_transfers(): sv.search(...)``.

    A factory rather than a plain guard scope so the test controls WHERE
    the guard holds — compilation (first call) is legitimately allowed to
    move constants and must happen outside the guarded block."""
    import jax

    @contextlib.contextmanager
    def guard():
        with jax.transfer_guard("disallow"):
            yield

    return guard
