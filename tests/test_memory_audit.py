"""Memory-bound auditor tests — every PIPM rule gets a positive fixture
(a deliberately broken program/contract the rule MUST flag) and a
negative, plus the registry acceptance run against the checked-in
envelope.

Synthetic specs reuse the auditor's own registry types
(``MemSpec``/``MemProgram``), so the positives exercise the exact code
path the lint pass runs — not a parallel re-implementation.  Every test
that compiles is gated on ``ledger_available()``: a backend without a
usable ``memory_analysis()`` byte ledger skips the whole file's compiled
half, exactly as the lint pass itself skips."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import memory_audit as ma
from repro.analysis.memory_audit import MemProgram, MemSpec

needs_ledger = pytest.mark.skipif(
    not ma.ledger_available(),
    reason="backend exposes no compiled memory_analysis() byte ledger")


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec(name, build, *, base, sweep=None, envelope=None, workspace=None,
          donated_note=""):
    return MemSpec(name=name, path=f"tests/{name}.py", kind="build",
                   base=base, build=build, sweep=sweep or {},
                   envelope=envelope, workspace=workspace,
                   note=donated_note)


def _audit(spec, baseline="self", budget=None):
    """Run audit_spec; ``baseline='self'`` measures once to build a clean
    matching record (PIPM005/006-quiet), None leaves the record absent."""
    if baseline == "self":
        _, record = ma.audit_spec(spec, None, budget=budget)
        findings, _ = ma.audit_spec(spec, record, budget=budget)
        return [f for f in findings if f.rule != "PIPM006"], record
    return ma.audit_spec(spec, baseline, budget=budget)


# ------------------------------------------------------------- PIPM001 ---

def _quadratic_program(pt):
    """Peak bytes scale as n^2 — the exact blowup the bounded-memory
    contract forbids (a build step materializing all-pairs state)."""
    fn = jax.jit(lambda x: x @ x.T)
    return MemProgram(fn, (_sds((pt["n"], 8)),))


def _linear_program(pt):
    fn = jax.jit(lambda x: x + 1.0)
    return MemProgram(fn, (_sds((pt["n"], 8)),))


@needs_ledger
def test_pipm001_flags_superlinear_peak():
    spec = _spec("quad_peak", _quadratic_program, base=dict(n=64),
                 sweep=dict(n=ma.DEFAULT_EXPONENT_BOUND))
    findings, record = _audit(spec)
    assert [f.rule for f in findings] == ["PIPM001"]
    assert "n^" in findings[0].message
    assert record["exponents"]["n"] > 1.5


@needs_ledger
def test_pipm001_quiet_for_linear_peak():
    spec = _spec("lin_peak", _linear_program, base=dict(n=256),
                 sweep=dict(n=ma.DEFAULT_EXPONENT_BOUND))
    findings, record = _audit(spec)
    assert findings == []
    assert record["exponents"]["n"] <= ma.DEFAULT_EXPONENT_BOUND


def test_fit_exponent_recovers_powers():
    xs = [1, 2, 4, 8]
    assert abs(ma.fit_exponent(xs, [3 * x for x in xs]) - 1.0) < 1e-6
    assert abs(ma.fit_exponent(xs, [5 * x * x for x in xs]) - 2.0) < 1e-6


# ------------------------------------------------------------- PIPM002 ---

def _dropped_donation_program(pt):
    """Registry declares arg 0 donated, but the jit carries no
    donate_argnums — the ledger shows zero aliased bytes."""
    fn = jax.jit(lambda x: x * 2.0)
    return MemProgram(fn, (_sds((pt["n"], 8)),), donated=(0,))


def _credited_donation_program(pt):
    fn = jax.jit(lambda x: x * 2.0, donate_argnums=(0,))
    return MemProgram(fn, (_sds((pt["n"], 8)),), donated=(0,))


@needs_ledger
def test_pipm002_flags_uncredited_donation():
    spec = _spec("dropped_donation", _dropped_donation_program,
                 base=dict(n=512))
    findings, _ = _audit(spec)
    assert [f.rule for f in findings] == ["PIPM002"]
    assert "not actually credited" in findings[0].message


@needs_ledger
def test_pipm002_quiet_when_ledger_credits_alias():
    spec = _spec("credited_donation", _credited_donation_program,
                 base=dict(n=512))
    findings, _ = _audit(spec)
    assert findings == []


# ------------------------------------------------------------- PIPM003 ---

@needs_ledger
def test_pipm003_envelope_fires_under_tiny_budget():
    spec = _spec("env_priced", _linear_program, base=dict(n=256),
                 envelope=dict(n=4096))
    findings, record = _audit(spec, budget=1024)
    assert [f.rule for f in findings] == ["PIPM003"]
    assert "PIPNN_DEVICE_HBM_BUDGET" in findings[0].message
    assert record["envelope_bytes"]["total"] > 1024


@needs_ledger
def test_pipm003_quiet_at_default_budget():
    spec = _spec("env_priced_ok", _linear_program, base=dict(n=256),
                 envelope=dict(n=4096))
    findings, _ = _audit(spec)
    assert findings == []


def test_price_envelope_credits_donation_and_workspace():
    spec = _spec("pricer", _credited_donation_program, base=dict(n=256),
                 envelope=dict(n=1024), workspace=lambda pt: 7 * pt["n"])
    env = ma.price_envelope(spec)
    arg = out = 1024 * 8 * 4
    assert env["argument_bytes"] == arg
    assert env["output_bytes"] == out
    assert env["donated_credit"] == out      # donated rows reused in place
    assert env["workspace_bytes"] == 7 * 1024
    assert env["total"] == arg + out - out + 7 * 1024


# ------------------------------------------------------------- PIPM004 ---

def _tempy_program(pt):
    """A large matmul intermediate reduced away — real temp bytes the
    workspace model must account for."""
    fn = jax.jit(lambda x: (x @ x.T).sum())
    return MemProgram(fn, (_sds((pt["n"], 8)),))


@needs_ledger
def test_pipm004_flags_temp_over_workspace_model():
    # model grants zero temp; the [n, n] f32 intermediate (16 MiB at
    # n=2048) blows straight through tol x 0 + 2 MiB slack
    spec = _spec("temp_blowup", _tempy_program, base=dict(n=2048),
                 workspace=lambda pt: 0)
    findings, _ = _audit(spec)
    assert "PIPM004" in [f.rule for f in findings]
    assert "workspace model" in findings[0].message


@needs_ledger
def test_pipm004_quiet_under_honest_model():
    spec = _spec("temp_modeled", _tempy_program, base=dict(n=2048),
                 workspace=lambda pt: pt["n"] * pt["n"] * 4)
    findings, _ = _audit(spec)
    assert findings == []


# ------------------------------------------- PIPM005 / PIPM006 (envelope) ---

@needs_ledger
def test_pipm005_flags_peak_regression_vs_envelope():
    spec = _spec("peak_regressed", _linear_program, base=dict(n=256))
    _, record = ma.audit_spec(spec, None)
    tampered = dict(record)
    tampered["canonical_ledger"] = dict(
        record["canonical_ledger"],
        peak=record["canonical_ledger"]["peak"] / 2.0)
    findings, _ = ma.audit_spec(spec, tampered)
    assert [f.rule for f in findings] == ["PIPM005"]
    assert "regression" in findings[0].message


@needs_ledger
def test_pipm005_tolerates_small_growth():
    spec = _spec("peak_ok", _linear_program, base=dict(n=256))
    _, record = ma.audit_spec(spec, None)
    near = dict(record)
    near["canonical_ledger"] = dict(
        record["canonical_ledger"],
        peak=record["canonical_ledger"]["peak"] / 1.05)
    findings, _ = ma.audit_spec(spec, near)
    assert findings == []


@needs_ledger
def test_pipm006_flags_missing_record():
    spec = _spec("no_record", _linear_program, base=dict(n=256))
    findings, _ = ma.audit_spec(spec, None)
    assert [f.rule for f in findings] == ["PIPM006"]
    assert "--write-envelope" in findings[0].message


@needs_ledger
def test_pipm006_flags_incomplete_record():
    spec = _spec("gutted_record", _linear_program, base=dict(n=256),
                 sweep=dict(n=ma.DEFAULT_EXPONENT_BOUND))
    _, record = ma.audit_spec(spec, None)
    gutted = dict(record, exponents=None, roofline=None)
    findings, _ = ma.audit_spec(spec, gutted)
    assert [f.rule for f in findings] == ["PIPM006"]
    assert "exponents" in findings[0].message


@needs_ledger
def test_pipm006_flags_uncompilable_program():
    def broken(pt):
        raise RuntimeError("boom")

    spec = _spec("uncompilable", broken, base=dict(n=8))
    findings = ma.audit_all(specs=[spec])
    assert [f.rule for f in findings] == ["PIPM006"]
    assert "failed to lower/compile" in findings[0].message


# --------------------------------------------------------- graceful skip ---

def test_audit_all_skips_without_ledger(monkeypatch):
    monkeypatch.setattr(ma, "ledger_available", lambda: False)
    calls = []
    monkeypatch.setattr(ma, "default_specs",
                        lambda: calls.append("built") or [])
    assert ma.audit_all() == []
    assert calls == []       # no spec construction, let alone compiles


@needs_ledger
def test_audit_all_skips_underdeviced_spec():
    import dataclasses

    spec = dataclasses.replace(
        _spec("needs_pod", _linear_program, base=dict(n=8)),
        min_devices=4096)
    assert ma.audit_all(specs=[spec]) == []


# ----------------------------------------------------------- acceptance ---

@needs_ledger
def test_registry_clean_against_checked_in_envelope():
    """The full acceptance run the lint pass executes: every registered
    program measured, swept, priced at the BigANN-1B envelope and checked
    against the checked-in memory_envelope.json — zero findings."""
    assert ma.ENVELOPE_PATH.exists(), \
        "memory_envelope.json missing — run --write-envelope"
    assert ma.audit_all() == []


def test_envelope_file_covers_registry():
    """Every single-device registered program has a complete checked-in
    record (the sharded spec's record exists too, written on a forced
    multi-device host)."""
    programs = ma.load_envelope()
    assert programs, "memory_envelope.json missing or empty"
    for spec in ma.default_specs():
        rec = programs.get(spec.name)
        assert rec is not None, f"{spec.name} missing from envelope"
        for key in ("canonical_ledger", "exponents", "envelope_bytes",
                    "roofline"):
            assert key in rec, f"{spec.name} record missing {key}"
        assert rec["canonical_ledger"]["peak"] > 0
        assert rec["roofline"]["dominant"] in ("compute", "memory",
                                               "collective")


def test_envelope_proves_bounded_memory():
    """The checked-in exponents ARE the paper's bounded-memory proof:
    every build program's peak scales at most ~linearly in every swept
    parameter — in particular the merge folds stay sublinear in the
    emitted edge count e."""
    from repro.kernels.tiling import DEFAULT_HBM_BUDGET

    programs = ma.load_envelope()
    build = {n: r for n, r in programs.items() if r["kind"] == "build"}
    assert len(build) >= 4
    for name, rec in build.items():
        for param, exp in rec["exponents"].items():
            assert exp <= 1.6, f"{name}: {param}^{exp}"
        assert rec["envelope_bytes"]["total"] <= DEFAULT_HBM_BUDGET, name
    for flavor in ("merge_segmented", "merge_flat"):
        assert build[flavor]["exponents"]["e"] < 1.0


def test_every_pipm_rule_documented():
    from repro.analysis.lint import RULES

    for rule in ("PIPM001", "PIPM002", "PIPM003", "PIPM004", "PIPM005",
                 "PIPM006"):
        assert rule in RULES
