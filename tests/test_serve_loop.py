"""Resilient serving loop tests: continuous batching with backpressure,
per-request poison isolation, two-phase straggler drain (bit-identity +
the drain actually firing), deadline propagation, SLO ladder shifts, the
deterministic fault-injection harness, and (>= 4 devices) the full
shard-failure survival drill with tombstone re-admission."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.serving import ServingIndex
from repro.core.validation import InvalidQueryError
from repro.launch.serve_loop import (OperatingPoint, QueueFull, ServeLoop,
                                     default_ladder, ladder_from_bench)
from repro.testing.faults import (FaultPlan, InjectedShardFailure,
                                  inject_faults, poison_queries)

NDEV = len(jax.devices())

multidevice = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def served():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((900, 16)).astype(np.float32)
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2), max_deg=16, seed=1)
    idx = pipnn.build(x, p)
    return ServingIndex.from_index(idx, x), x


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# -------------------------------------------------------------- admission --

def test_queue_full_rejects_with_retry_after(served):
    sv, x = served
    loop = ServeLoop(sv, k=4, query_chunk=4, max_queue=6)
    for i in range(6):
        loop.submit(x[i])
    with pytest.raises(QueueFull) as ei:
        loop.submit(x[6])
    assert ei.value.retry_after > 0
    assert ei.value.depth == 6
    loop.step()                         # frees query_chunk slots
    loop.submit(x[6])                   # now admitted
    assert loop.counters["rejected"] == 1


def test_submit_rejects_wrong_width_immediately(served):
    sv, x = served
    loop = ServeLoop(sv, k=4)
    with pytest.raises(InvalidQueryError) as ei:
        loop.submit(np.zeros(7, np.float32))
    assert ei.value.reason == "shape"
    assert loop.queue_depth == 0


def test_search_entries_reject_bad_k_beam(served):
    sv, _ = served
    with pytest.raises(ValueError, match="k must be >= 1"):
        ServeLoop(sv, k=0)
    with pytest.raises(ValueError, match="beam must be >= 1"):
        ServeLoop(sv, k=4, ladder=(OperatingPoint("bad", beam=0),))


# ------------------------------------------------------- poison isolation --

def test_nan_query_does_not_poison_batchmates(served):
    """The Issue-9 regression: one NaN request in a batch gets a
    structured error result; every batchmate is served the exact ids a
    clean batch would produce."""
    sv, x = served
    rng = np.random.default_rng(3)
    q = rng.standard_normal((8, 16)).astype(np.float32)
    qp = q.copy()
    qp[3, 0] = np.nan
    loop = ServeLoop(sv, k=5, query_chunk=8)
    rids = [loop.submit(qp[i]) for i in range(8)]
    res = {r.rid: r for r in loop.run_until_drained()}
    assert len(res) == 8
    bad = res[rids[3]]
    assert bad.error == "invalid:nan_inf" and bad.ids is None
    clean = sv.search(q, k=5, beam=loop.operating_point.beam,
                      expansions=loop.operating_point.expansions,
                      iters=loop.backstop_iters)
    for i in range(8):
        if i == 3:
            continue
        r = res[rids[i]]
        assert r.ok
        np.testing.assert_array_equal(r.ids, clean[i])


def test_poison_queries_is_deterministic_and_nonempty():
    q = np.zeros((40, 4), np.float32)
    a, rows_a = poison_queries(q, 0.05, seed=9)
    b, rows_b = poison_queries(q, 0.05, seed=9)
    np.testing.assert_array_equal(rows_a, rows_b)
    np.testing.assert_array_equal(a, b)
    assert rows_a.size >= 1                    # 5% of 40 = 2, never 0
    assert np.isnan(a[rows_a, 0]).all()
    c, rows_c = poison_queries(q, 0.001, seed=1, value=np.inf)
    assert rows_c.size == 1 and np.isinf(c[rows_c, 0]).all()


# -------------------------------------------------------- straggler drain --

def _chain_fixture():
    """A path graph with the entry at one end: a query near the far end
    cannot converge inside any reasonable iters cap, while queries near
    the entry converge almost immediately — the deterministic straggler."""
    n, d = 512, 8
    rng = np.random.default_rng(5)
    x = np.zeros((n, d), np.float32)
    x[:, 0] = np.arange(n)
    x[:, 1:] = 0.01 * rng.standard_normal((n, d - 1))
    graph = np.full((n, 2), -1, np.int32)
    graph[:, 0] = np.arange(n) - 1
    graph[: n - 1, 1] = np.arange(1, n)
    sv = ServingIndex.from_graph(graph, x, start=0)
    fast = x[:6] + 0.001
    slow = x[n - 1 :] + 0.001
    return sv, np.concatenate([fast, slow]).astype(np.float32)


def test_two_phase_drain_fires_and_is_bit_identical():
    """Converged queries drained in phase 1 return ids BIT-IDENTICAL to
    a single-phase full-backstop run (convergence is a fixed point), and
    the far-end straggler really is rerun in phase 2."""
    sv, q = _chain_fixture()
    kw = dict(k=4, query_chunk=8, straggler_chunk=2,
              ladder=(OperatingPoint("b8", beam=8, expansions=4),),
              drain_iters=8, backstop_iters=32)
    loop2 = ServeLoop(sv, two_phase=True, **kw)
    rids = [loop2.submit(qi) for qi in q]
    res = {r.rid: r for r in loop2.run_until_drained()}
    assert loop2.counters["rerun_phase2"] >= 1
    assert loop2.counters["drained_phase1"] >= 4
    loop1 = ServeLoop(sv, two_phase=False, **kw)
    rids1 = [loop1.submit(qi) for qi in q]
    res1 = {r.rid: r for r in loop1.run_until_drained()}
    for i in range(len(q)):
        a, b = res[rids[i]], res1[rids1[i]]
        assert a.ok and b.ok
        if a.phase == 1:                       # drained as converged
            np.testing.assert_array_equal(a.ids, b.ids)
    phases = {i: res[rids[i]].phase for i in range(len(q))}
    assert phases[len(q) - 1] == 2             # the far-end straggler


def test_straggler_past_deadline_gets_partial_phase1_result():
    sv, q = _chain_fixture()
    clock = FakeClock()
    loop = ServeLoop(sv, k=4, query_chunk=8, drain_iters=8,
                     ladder=(OperatingPoint("b8", beam=8, expansions=4),),
                     backstop_iters=32, two_phase=True, clock=clock)
    # phase 1 "takes" 1s on the fake clock: tick between submit and the
    # phase boundary by advancing inside the search call
    orig = loop._search

    def ticking_search(*a, **kw):
        clock.t += 1.0
        return orig(*a, **kw)

    loop._search = ticking_search
    for qi in q:
        loop.submit(qi)
    # far-end straggler deadline expires during phase 1
    loop._queue[-1].deadline = 0.5
    res = loop.run_until_drained()
    partial = [r for r in res if r.partial]
    assert len(partial) == 1
    assert partial[0].ok and partial[0].phase == 1
    assert loop.counters["partial"] == 1


def test_expired_deadline_times_out_without_a_search(served):
    sv, x = served
    clock = FakeClock()
    loop = ServeLoop(sv, k=4, clock=clock)
    loop.submit(x[0], deadline_s=0.5)
    loop.submit(x[1])
    clock.t = 1.0
    res = {r.rid: r for r in loop.step()}
    assert res[0].error == "timeout" and res[0].ids is None
    assert res[1].ok
    assert loop.counters["timeout"] == 1


# ------------------------------------------------------------- SLO ladder --

def test_downshift_on_queue_depth_then_upshift_on_recovery(served):
    sv, x = served
    events = []
    loop = ServeLoop(sv, k=4, query_chunk=4, max_queue=64, queue_high=8,
                     shift_cooldown=1,
                     on_event=lambda k, d: events.append((k, d)))
    rng = np.random.default_rng(11)
    q = rng.standard_normal((32, 16)).astype(np.float32)
    for qi in q:
        loop.submit(qi)
    loop.step()                                 # depth 28 > 8: downshift
    assert loop.operating_point.name == loop.ladder[1].name
    down = [d for k, d in events if k == "downshift"]
    assert down and down[0]["from_point"] == loop.ladder[0].name
    loop.run_until_drained()
    loop.step()                                 # empty queue: recovery
    assert loop.operating_point.name == loop.ladder[0].name
    assert any(k == "upshift" for k, _ in events)
    assert loop.counters["downshift"] >= 1
    assert loop.counters["upshift"] >= 1


def test_downshift_on_p99_breach(served):
    sv, x = served
    clock = FakeClock()
    loop = ServeLoop(sv, k=4, query_chunk=4, slo_p99=0.5, queue_high=10**6,
                     min_p99_samples=4, shift_cooldown=0, clock=clock)
    # fabricate a breached latency window, then adapt via an empty step
    for _ in range(8):
        loop._p99.record(2.0)
    loop.submit(x[0])
    loop.step()
    assert loop.operating_point.name == loop.ladder[1].name


def test_ladder_from_bench_builds_pareto_frontier(tmp_path):
    path = tmp_path / "qps.json"
    path.write_text("""[{"records": [
      {"engine": "serve_E4", "beam": 32, "recall": 0.95, "qps": 1000},
      {"engine": "serve_E2", "beam": 16, "recall": 0.90, "qps": 3000},
      {"engine": "serve_E2", "beam": 24, "recall": 0.88, "qps": 2000},
      {"engine": "serve_E1", "beam": 8,  "recall": 0.80, "qps": 9000},
      {"engine": "serve_i8", "beam": 24, "recall": 0.93, "qps": 8000},
      {"engine": "single",   "beam": 32, "recall": 0.96, "qps": 100},
      {"engine": "np_oracle","beam": 24, "recall": 0.94}
    ]}]""")
    ladder = ladder_from_bench(path)
    assert [p.name for p in ladder] == [
        "serve_b32_E4", "serve_b16_E2", "serve_b8_E1"]
    # the dominated point (recall 0.88 at LOWER qps than the 0.90 rung)
    # was pruned; i8/single/oracle records never become rungs
    assert ladder[0].recall_bound == pytest.approx(0.95)
    assert ladder[1].qps == 3000
    assert ladder_from_bench(tmp_path / "missing.json") is None
    assert default_ladder(32)[0].beam == 32


# -------------------------------------------------------- fault injection --

def test_inject_faults_restores_search_even_on_failure(served):
    sv, x = served
    orig = sv.search
    plan = FaultPlan(shard_down={0: (0, None)})
    with pytest.raises(InjectedShardFailure):
        with inject_faults(sv, plan):
            sv.search(x[:2], k=4, beam=8)
    assert sv.search == orig            # instance patch removed
    ids = sv.search(x[:2], k=4, beam=8)
    assert ids.shape == (2, 4)


def test_injected_straggler_and_kernel_fallback(served):
    sv, x = served
    plan = FaultPlan(straggle={1: 0.01}, force_kernel_path={0: "xla"})
    with inject_faults(sv, plan) as inj:
        _, stats = sv.search(x[:2], k=4, beam=8, with_stats=True)
        assert stats["kernel_path"] == "xla"        # forced down-ladder
        sv.search(x[:2], k=4, beam=8)
    kinds = [e[0] for e in inj.events]
    assert kinds == ["kernel_path", "straggle"]
    assert inj.calls == 2


# ----------------------------------------------- shard-failure drill (SPMD) --

@multidevice
def test_shard_failure_survival_drill():
    """The Issue-9 acceptance drill: 1 of S shards killed mid-run, 5%
    NaN queries, one injected straggler.  Every request completes, the
    poisoned rows alone get structured errors, degraded recall holds >=
    0.85x healthy, and the tombstoned shard is re-admitted by probing
    once its outage window closes."""
    s = min(8, NDEV)
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1600, 24)).astype(np.float32)
    q = rng.standard_normal((96, 24)).astype(np.float32)
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2), max_deg=16, seed=1)
    idx = pipnn.build(x, p)
    mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
    ssv = ServingIndex.from_index(idx, x, mesh=mesh)
    truth = brute_force_knn(x, q, 10)
    r_healthy = recall_at_k(np.asarray(ssv.search(q, k=10, beam=32)), truth,
                            10)
    qp, rows = poison_queries(q, 0.05, seed=7)
    plan = FaultPlan(shard_down={s - 1: (1, 6)}, straggle={2: 0.01})
    with inject_faults(ssv, plan) as inj:
        loop = ServeLoop(ssv, k=10, query_chunk=16, straggler_chunk=8,
                         max_queue=128, probe_every=1)
        rid_to_row = {loop.submit(qp[i]): i for i in range(len(qp))}
        res = loop.run_until_drained()
        # keep stepping past the outage window so probing re-admits
        for _ in range(12):
            loop.step()
            if not loop.index.down_shards:
                break
    assert len(res) == len(qp)                      # every request answered
    assert ("shard_failure", 1, s - 1) in inj.events
    bad = sorted(rid_to_row[r.rid] for r in res if r.error)
    assert bad == sorted(rows.tolist())             # exactly the poison
    assert all(r.error == "invalid:nan_inf" for r in res if not r.ok)
    assert loop.counters["shards_marked_down"] == 1
    assert loop.counters["shards_readmitted"] == 1
    assert not ssv.down_shards                      # health fully restored
    ids = np.full((len(qp), 10), -1, np.int64)
    for r in res:
        if r.ok:
            ids[rid_to_row[r.rid]] = r.ids
    ok_rows = np.setdiff1d(np.arange(len(qp)), rows)
    r_deg = recall_at_k(ids[ok_rows], truth[ok_rows], 10)
    assert r_deg >= 0.85 * r_healthy


@multidevice
def test_health_masked_search_survives_dead_shard():
    """Direct engine-level survival: tombstoning a shard keeps every
    query servable at >= 0.85x healthy recall (the dead shard's owned
    rows may still surface through surviving shards' halo ghosts — that
    is the halo doing its job, not a leak), and restoring health
    restores BIT-IDENTICAL results because the all-healthy path skips
    masking entirely."""
    s = min(8, NDEV)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((1200, 16)).astype(np.float32)
    q = rng.standard_normal((24, 16)).astype(np.float32)
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2), max_deg=16, seed=3)
    idx = pipnn.build(x, p)
    mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
    ssv = ServingIndex.from_index(idx, x, mesh=mesh)
    truth = brute_force_knn(x, q, 10)
    before = np.asarray(ssv.search(q, k=10, beam=32))
    r_healthy = recall_at_k(before, truth, 10)
    ssv.mark_shard_down(1)
    assert ssv.down_shards == (1,)
    after, stats = ssv.search(q, k=10, beam=32, with_stats=True)
    assert stats["healthy_shards"] == s - 1
    assert (np.asarray(after)[:, 0] >= 0).all()     # every query served
    assert recall_at_k(np.asarray(after), truth, 10) >= 0.85 * r_healthy
    # restoring health restores bit-identical results (mask path off)
    ssv.mark_shard_up(1)
    np.testing.assert_array_equal(
        np.asarray(ssv.search(q, k=10, beam=32)), before)


@multidevice
def test_leaders_router_reprobes_around_dead_leader():
    s = min(8, NDEV)
    rng = np.random.default_rng(4)
    x = rng.standard_normal((1200, 16)).astype(np.float32)
    q = rng.standard_normal((16, 16)).astype(np.float32)
    p = PiPNNParams(rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
                    leaf=LeafParams(k=2), max_deg=16, seed=3)
    idx = pipnn.build(x, p)
    mesh = Mesh(np.array(jax.devices()[:s]), ("shards",))
    ssv = ServingIndex.from_index(idx, x, mesh=mesh, router="leaders",
                                  n_probes=2)
    ssv.mark_shard_down(0)
    ids, stats = ssv.search(q, k=5, beam=16, with_stats=True)
    assert (np.asarray(ids)[:, 0] >= 0).all()
    # probes re-route to the next-best HEALTHY leaders
    assert stats["n_probes"] == min(2, s - 1)
    assert stats["healthy_shards"] == s - 1
