"""Unit + property tests for RBC partitioning, RobustPrune, leaf building,
beam search, and metrics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import metrics as _metrics
from repro.core.beam_search import (
    beam_search_batch,
    beam_search_np,
    brute_force_knn,
    medoid,
    recall_at_k,
)
from repro.core.leaf import LeafParams, build_leaf_edges, leaf_knn_jax
from repro.core.rbc import (
    RBCParams,
    ball_carve,
    binary_partition,
    kmeans_carve,
    leaves_to_padded,
    partition,
    sorting_lsh_partition,
)
from repro.core.robust_prune import robust_prune_mask, robust_prune_np


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(7)
    return rng.standard_normal((2000, 16)).astype(np.float32)


# --------------------------------------------------------------- metrics ---

@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_pairwise_matches_naive(metric):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((17, 9)).astype(np.float32)
    b = rng.standard_normal((13, 9)).astype(np.float32)
    got = np.asarray(_metrics.pairwise(jnp.asarray(a), jnp.asarray(b), metric))
    naive = np.zeros((17, 13), dtype=np.float32)
    for i in range(17):
        for j in range(13):
            if metric == "l2":
                naive[i, j] = np.sum((a[i] - b[j]) ** 2)
            elif metric == "mips":
                naive[i, j] = -np.dot(a[i], b[j])
            else:
                naive[i, j] = 1 - np.dot(a[i], b[j]) / (
                    np.linalg.norm(a[i]) * np.linalg.norm(b[j])
                )
    np.testing.assert_allclose(got, naive, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- RBC ---

def _check_cover(leaves, n, c_max):
    seen = np.zeros(n, dtype=bool)
    for b in leaves:
        assert len(b) <= c_max
        seen[b] = True
    assert seen.all(), "every point must land in at least one leaf"


@pytest.mark.parametrize("method", ["rbc", "binary", "kmeans", "sorting_lsh"])
def test_partitioners_cover_all_points(data, method):
    p = RBCParams(c_max=128, c_min=16, p_samp=0.02, fanout=(3, 2), seed=1)
    leaves = partition(data, p, method)
    _check_cover(leaves, data.shape[0], p.c_max)


def test_rbc_fanout_overlap(data):
    p = RBCParams(c_max=128, c_min=16, p_samp=0.02, fanout=(3,), seed=1)
    leaves = ball_carve(data, p)
    total = sum(len(b) for b in leaves)
    # fanout 3 at the top should yield roughly 3x point repeats
    assert total >= 2.0 * data.shape[0]


def test_rbc_deterministic(data):
    p = RBCParams(c_max=128, c_min=16, fanout=(3, 2), seed=42)
    a = ball_carve(data, p)
    b = ball_carve(data, p)
    assert len(a) == len(b)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(la, lb)


def test_leaves_to_padded_roundtrip():
    leaves = [np.array([0, 5, 3]), np.array([1])]
    padded = leaves_to_padded(leaves, 4)
    assert padded.shape == (2, 4)
    np.testing.assert_array_equal(padded[0], [0, 5, 3, -1])
    np.testing.assert_array_equal(padded[1], [1, -1, -1, -1])


def test_leaves_to_padded_rejects_oversized():
    with pytest.raises(ValueError):
        leaves_to_padded([np.arange(10)], 4)


# ----------------------------------------------------------- RobustPrune ---

@hypothesis.settings(max_examples=30, deadline=None)
@hypothesis.given(
    n_cand=st.integers(3, 24),
    alpha=st.sampled_from([1.0, 1.2, 1.5]),
    r=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_robust_prune_mask_matches_sequential(n_cand, alpha, r, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((40, 8)).astype(np.float32)
    p_idx = 0
    cand = rng.choice(np.arange(1, 40), size=n_cand, replace=False)
    expect = robust_prune_np(x[p_idx], cand, x, alpha=alpha, r=r, metric="l2")

    d_pc = np.sum((x[cand] - x[p_idx]) ** 2, axis=1).astype(np.float32)
    d_cc = np.sum(
        (x[cand][:, None, :] - x[cand][None, :, :]) ** 2, axis=-1
    ).astype(np.float32)
    keep = robust_prune_mask(
        jnp.asarray(d_pc)[None], jnp.asarray(d_cc)[None],
        jnp.asarray(cand.astype(np.int32))[None], alpha=alpha, max_deg=r,
    )
    got = sorted(cand[np.asarray(keep)[0]].tolist())
    assert got == sorted(expect.tolist())


def test_robust_prune_respects_degree_cap():
    rng = np.random.default_rng(0)
    d_pc = jnp.asarray(rng.uniform(1, 2, (4, 32)).astype(np.float32))
    d_cc = jnp.full((4, 32, 32), 100.0, dtype=jnp.float32)  # nothing dominates
    ids = jnp.broadcast_to(jnp.arange(32, dtype=jnp.int32), (4, 32))
    keep = robust_prune_mask(d_pc, d_cc, ids, alpha=1.2, max_deg=5)
    assert (np.asarray(keep).sum(axis=1) == 5).all()


# ------------------------------------------------------------------ leaf ---

def test_leaf_knn_matches_bruteforce():
    rng = np.random.default_rng(1)
    pts = rng.standard_normal((3, 32, 8)).astype(np.float32)
    valid = np.ones((3, 32), dtype=bool)
    valid[1, 20:] = False  # padded leaf
    ni, nd = leaf_knn_jax(jnp.asarray(pts), jnp.asarray(valid), k=3, metric="l2")
    ni, nd = np.asarray(ni), np.asarray(nd)
    for b in range(3):
        m = valid[b]
        d = np.sum((pts[b][:, None] - pts[b][None]) ** 2, axis=-1)
        d[~m] = np.inf
        d[:, ~m] = np.inf
        np.fill_diagonal(d, np.inf)
        for i in range(32):
            if not m[i]:
                assert (ni[b, i] == -1).all()
                continue
            expect = set(np.argsort(d[i], kind="stable")[:3].tolist())
            assert set(ni[b, i].tolist()) == expect


def test_bidirected_contains_both_directions(data):
    p = RBCParams(c_max=128, c_min=16, fanout=(2,), seed=0)
    leaves = ball_carve(data, p)
    padded = leaves_to_padded(leaves, p.c_max)
    ed = build_leaf_edges(data, padded, LeafParams(method="bidirected", k=2))
    pairs = set(zip(ed.src[ed.valid()].tolist(), ed.dst[ed.valid()].tolist()))
    rev = {(b, a) for a, b in pairs}
    assert pairs == rev


@pytest.mark.parametrize("method", ["directed", "inverted", "mst", "robust_prune"])
def test_leaf_methods_produce_edges(data, method):
    p = RBCParams(c_max=128, c_min=16, fanout=(2,), seed=0)
    leaves = ball_carve(data, p)
    padded = leaves_to_padded(leaves, p.c_max)
    ed = build_leaf_edges(
        data, padded, LeafParams(method=method, k=2, max_deg=16)
    )
    v = ed.valid()
    assert v.sum() > data.shape[0], method
    assert (ed.dst[v] >= 0).all()
    assert np.isfinite(ed.dist[v]).all()
    assert (ed.src[v] != ed.dst[v]).all(), "no self loops"


# ----------------------------------------------------------- beam search ---

def test_beam_search_np_finds_exact_on_full_graph():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((200, 8)).astype(np.float32)
    # complete-ish graph: 32-NN adjacency
    truth = brute_force_knn(x, x, 33)
    graph = truth[:, 1:33].astype(np.int32)
    q = rng.standard_normal((20, 8)).astype(np.float32)
    gt = brute_force_knn(x, q, 10)
    hits = 0
    for i in range(20):
        ids, _, _ = beam_search_np(graph, x, q[i], start=medoid(x), beam=40)
        hits += len(set(ids[:10].tolist()) & set(gt[i].tolist()))
    assert hits / 200 > 0.95


def test_beam_search_batch_agrees_with_np():
    rng = np.random.default_rng(4)
    x = rng.standard_normal((300, 8)).astype(np.float32)
    truth = brute_force_knn(x, x, 17)
    graph = truth[:, 1:17].astype(np.int32)
    q = rng.standard_normal((10, 8)).astype(np.float32)
    start = medoid(x)
    ids_b, _ = beam_search_batch(
        jnp.asarray(graph), jnp.asarray(x), jnp.asarray(q),
        start=start, beam=24, iters=28,
    )
    for i in range(10):
        ids_n, _, _ = beam_search_np(graph, x, q[i], start=start, beam=24)
        got = set(np.asarray(ids_b)[i, :10].tolist())
        expect = set(ids_n[:10].tolist())
        assert len(got & expect) >= 8, f"query {i}: {got} vs {expect}"


def test_recall_at_k():
    f = np.array([[1, 2, 3], [4, 5, 6]])
    t = np.array([[1, 2, 9], [4, 5, 6]])
    assert recall_at_k(f, t, 3) == pytest.approx(5 / 6)
