"""Streaming device-resident build: pipeline-level mergeability property
tests (``hashprune_merge_flat`` and the segmented merge), streaming-vs-flat
bit-identity of the full ``pipnn.build`` (k-NN and ``robust_prune`` leaf
methods), streaming-vs-host ``final_prune`` bit-identity, and the bounded
peak-candidate-memory guarantee.

Deliberately hypothesis-free (seeded rng sweeps) so these run even where
hypothesis is unavailable — they are the pipeline-level counterpart of the
property tests in test_hashprune.py.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pipnn
from repro.core.hashprune import (
    INVALID_ID,
    Reservoir,
    canonicalize,
    hashprune_flat,
    hashprune_merge_flat,
    hashprune_merge_segmented,
    reservoir_as_edges,
    reservoir_init,
)
from repro.core.leaf import LeafParams, build_leaf_edges, emit_knn_edges_jax
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams
from repro.core.robust_prune import final_prune, final_prune_host


def _res_np(res: Reservoir):
    res = canonicalize(res)
    return tuple(np.asarray(a) for a in res)


def _random_edges(rng, n, e, metric):
    """Flat edge list with duplicate edges and tied distances on purpose."""
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    # deterministic hash per (src, dst): an id must hash consistently
    hashes = ((src * 31 + dst * 7) % 16).astype(np.int32)
    # quantized distances => plenty of exact ties; mips => negatives too
    dist = ((dst * 131 + src * 17) % 23 / 4.0).astype(np.float32)
    if metric == "mips":
        dist = dist - 3.0
    # inject exact duplicate edges
    ndup = e // 8
    src[:ndup] = src[e // 2 : e // 2 + ndup]
    dst[:ndup] = dst[e // 2 : e // 2 + ndup]
    hashes[:ndup] = hashes[e // 2 : e // 2 + ndup]
    dist[:ndup] = dist[e // 2 : e // 2 + ndup]
    return src, dst, hashes, dist


@pytest.mark.parametrize("metric", ["l2", "mips"])
@pytest.mark.parametrize("n_chunks", [1, 3, 7])
def test_merge_flat_matches_oneshot(metric, n_chunks):
    """Mergeability at the pipeline level: folding any chunking of a flat
    edge list through ``hashprune_merge_flat`` is bit-identical (after
    canonicalize) to one-shot ``hashprune_flat``."""
    for seed in range(5):
        rng = np.random.default_rng(seed)
        n, e, l_max = 40, 1200, 8
        src, dst, hashes, dist = _random_edges(rng, n, e, metric)
        oneshot = hashprune_flat(
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
            jnp.asarray(dist), n_points=n, l_max=l_max)
        res = reservoir_init(n, l_max)
        bounds = np.linspace(0, e, n_chunks + 1).astype(int)
        for a, b in zip(bounds[:-1], bounds[1:]):
            res = hashprune_merge_flat(
                res, jnp.asarray(src[a:b]), jnp.asarray(dst[a:b]),
                jnp.asarray(hashes[a:b]), jnp.asarray(dist[a:b]))
        for got, want in zip(_res_np(res), _res_np(oneshot)):
            np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("metric", ["l2", "mips"])
@pytest.mark.parametrize("use_pallas", [False, True])
def test_segmented_merge_bit_identical_to_flat_merge(metric, use_pallas):
    """The segmented fold (chunk-only sort + bounded per-row merge; pure-JAX
    and the interpret-mode Pallas kernel) is bit-identical — raw arrays, no
    canonicalize — to ``hashprune_merge_flat``, which stays the oracle."""
    for seed in range(4):
        rng = np.random.default_rng(seed)
        n, e, l_max = 40, 1200, 8
        src, dst, hashes, dist = _random_edges(rng, n, e, metric)
        res_f = reservoir_init(n, l_max)
        res_s = reservoir_init(n, l_max)
        bounds = np.linspace(0, e, 4).astype(int)
        for a, b in zip(bounds[:-1], bounds[1:]):
            args = (jnp.asarray(src[a:b]), jnp.asarray(dst[a:b]),
                    jnp.asarray(hashes[a:b]), jnp.asarray(dist[a:b]))
            res_f = hashprune_merge_flat(res_f, *args)
            res_s = hashprune_merge_segmented(
                res_s, *args, use_pallas=use_pallas, interpret=True)
        for got, want in zip(res_s, res_f):
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segmented_merge_handles_padding_edges():
    """Padding edges (src == n) and INVALID dst must be dropped."""
    n, l_max = 4, 4
    res = reservoir_init(n, l_max)
    src = jnp.asarray([0, n, n], dtype=jnp.int32)
    dst = jnp.asarray([1, INVALID_ID, INVALID_ID], dtype=jnp.int32)
    h = jnp.zeros(3, jnp.int32)
    d = jnp.asarray([1.0, np.inf, np.inf], dtype=jnp.float32)
    res = hashprune_merge_segmented(res, src, dst, h, d)
    ids = np.asarray(res.ids)
    assert ids[0, 0] == 1
    assert (ids[1:] == -1).all() and (ids[0, 1:] == -1).all()


def test_merge_flat_handles_padding_edges():
    """Padding edges (src == n) and INVALID dst must be dropped."""
    n, l_max = 4, 4
    res = reservoir_init(n, l_max)
    src = jnp.asarray([0, n, n], dtype=jnp.int32)
    dst = jnp.asarray([1, INVALID_ID, INVALID_ID], dtype=jnp.int32)
    h = jnp.zeros(3, jnp.int32)
    d = jnp.asarray([1.0, np.inf, np.inf], dtype=jnp.float32)
    res = hashprune_merge_flat(res, src, dst, h, d)
    ids = np.asarray(res.ids)
    assert ids[0, 0] == 1
    assert (ids[1:] == -1).all() and (ids[0, 1:] == -1).all()


def test_reservoir_as_edges_roundtrip():
    """Flatten + re-prune with no new candidates is the identity."""
    rng = np.random.default_rng(2)
    n, e, l_max = 30, 600, 8
    src, dst, hashes, dist = _random_edges(rng, n, e, "l2")
    res = hashprune_flat(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
        jnp.asarray(dist), n_points=n, l_max=l_max)
    s, d_, h, di = reservoir_as_edges(res.ids, res.hashes, res.dists)
    again = hashprune_flat(s, d_, h, di, n_points=n, l_max=l_max)
    for got, want in zip(_res_np(again), _res_np(res)):
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Full-build equivalence + bounded-memory acceptance
# ---------------------------------------------------------------------------

def _smoke_params(metric, **kw):
    base = dict(
        rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
        leaf=LeafParams(k=2, leaf_chunk=8),
        l_max=32, max_deg=16, metric=metric, seed=1,
    )
    base.update(kw)
    return PiPNNParams(**base)


@pytest.mark.parametrize("metric", ["l2", "mips"])
def test_streaming_build_bit_identical_to_flat(metric):
    rng = np.random.default_rng(0)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    p = _smoke_params(metric)
    i_s = pipnn.build(x, p, streaming=True)
    i_f = pipnn.build(x, p, streaming=False)
    np.testing.assert_array_equal(i_s.graph, i_f.graph)
    np.testing.assert_array_equal(i_s.dists, i_f.dists)
    assert i_s.start == i_f.start
    assert i_s.stats["n_candidate_edges"] == i_f.stats["n_candidate_edges"]
    assert i_s.stats["streaming"] and not i_f.stats["streaming"]


def test_streaming_peak_memory_bounded_by_chunk():
    """Acceptance: streaming peak candidate-edge bytes are a function of the
    chunk size only — NOT of the total edge count the flat path pays for."""
    rng = np.random.default_rng(1)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    p = _smoke_params("l2", leaf=LeafParams(k=2, leaf_chunk=8, stream_chunk=8))
    i_s = pipnn.build(x, p, streaming=True)
    i_f = pipnn.build(x, p, streaming=False)
    np.testing.assert_array_equal(i_s.graph, i_f.graph)
    chunk, c_max, k = 8, p.rbc.c_max, p.leaf.k
    bound = 2 * chunk * c_max * k * 16  # bidirected, 16 B/edge
    assert i_s.stats["stream_chunk_leaves"] == chunk
    assert i_s.stats["peak_edge_bytes"] == bound
    assert i_s.stats["peak_edge_bytes"] < i_f.stats["peak_edge_bytes"]
    # flat peak scales with E (every candidate edge materialized at once)
    assert i_f.stats["peak_edge_bytes"] >= i_f.stats["n_candidate_edges"] * 16
    # per-path actual-allocation stats: the host EdgeList has no hash field
    # (12 B/edge); the streaming chunk buffers carry all four fields
    e_alloc = i_f.stats["peak_edge_bytes"] // 16
    assert i_f.stats["edge_bytes_build_leaves"] == e_alloc * 12
    assert i_f.stats["merge_workspace_bytes"] == e_alloc * 16
    assert i_s.stats["edge_bytes_build_leaves"] == bound
    # segmented merge: chunk-only sort + [n, 2*l_max] per-row rows
    n = x.shape[0]
    assert i_s.stats["merge_workspace_bytes"] == bound + 2 * n * p.l_max * 12
    # flat-merge fold pays the reservoir-as-edges re-sort instead
    i_m = pipnn.build(x, p.with_(merge="flat"), streaming=True)
    assert i_m.stats["merge_workspace_bytes"] == bound + n * p.l_max * 16


def test_streaming_auto_chunk_is_reservoir_bounded():
    """Auto stream_chunk: one chunk's edge buffer is O(n * l_max) entries
    (+ one leaf_chunk of rounding slack), independent of total E."""
    rng = np.random.default_rng(6)
    x = rng.standard_normal((2000, 32)).astype(np.float32)
    p = _smoke_params("l2")
    i_s = pipnn.build(x, p, streaming=True)
    n, lc, c_max, k = x.shape[0], p.leaf.leaf_chunk, p.rbc.c_max, p.leaf.k
    slack = lc * c_max * k * 2
    assert i_s.stats["peak_edge_bytes"] <= 16 * (n * p.l_max + slack)


@pytest.mark.parametrize("metric", ["l2", "mips"])
def test_streaming_flat_merge_variant_bit_identical(metric):
    """merge="flat" (the global-re-sort oracle fold) and the default
    segmented fold produce the same graph as the flat build."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal((1200, 24)).astype(np.float32)
    p = _smoke_params(metric)
    i_f = pipnn.build(x, p, streaming=False)
    for merge in ("segmented", "flat"):
        i_s = pipnn.build(x, p.with_(merge=merge), streaming=True)
        np.testing.assert_array_equal(i_s.graph, i_f.graph)
        np.testing.assert_array_equal(i_s.dists, i_f.dists)


@pytest.mark.parametrize("metric", ["l2", "mips"])
def test_streaming_robust_prune_leaf_bit_identical_to_flat(metric):
    """The robust_prune leaf method now streams; only ``mst`` falls back."""
    rng = np.random.default_rng(8)
    x = rng.standard_normal((900, 16)).astype(np.float32)
    p = _smoke_params(metric, rbc=RBCParams(c_max=64, c_min=8, fanout=(3,)),
                      leaf=LeafParams(method="robust_prune", leaf_chunk=4,
                                      alpha=1.2, max_deg=8))
    i_s = pipnn.build(x, p, streaming=True)
    i_f = pipnn.build(x, p, streaming=False)
    assert i_s.stats["streaming"] and not i_f.stats["streaming"]
    np.testing.assert_array_equal(i_s.graph, i_f.graph)
    np.testing.assert_array_equal(i_s.dists, i_f.dists)
    assert i_s.stats["n_candidate_edges"] == i_f.stats["n_candidate_edges"]


def test_streaming_falls_back_for_mst_leaf_method():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((600, 16)).astype(np.float32)
    p = _smoke_params("l2")
    p = p.with_(leaf=LeafParams(method="mst", leaf_chunk=4))
    idx = pipnn.build(x, p, streaming=True)
    assert not idx.stats["streaming"]
    assert (idx.graph >= 0).any(axis=1).all()


# ---------------------------------------------------------------------------
# Streaming final prune (Stage 4)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["l2", "mips"])
@pytest.mark.parametrize("l_max,max_deg", [(8, 16), (16, 8), (8, 8)])
def test_final_prune_streaming_matches_host(metric, l_max, max_deg):
    """Device-resident final_prune == host-looped oracle, bit for bit —
    including l_max < max_deg, l_max > max_deg, and the tie/duplicate-heavy
    reservoirs _random_edges produces (quantized distances)."""
    for seed in range(3):
        rng = np.random.default_rng(seed)
        n, e = 50, 900
        src, dst, hashes, dist = _random_edges(rng, n, e, metric)
        res = hashprune_flat(
            jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
            jnp.asarray(dist), n_points=n, l_max=l_max)
        x = rng.standard_normal((n, 12)).astype(np.float32)
        # chunk=7 does not divide n: exercises the idempotent tail overlap
        g_s, d_s = final_prune(x, res, alpha=1.3, max_deg=max_deg,
                               metric=metric, chunk=7)
        g_h, d_h = final_prune_host(x, res, alpha=1.3, max_deg=max_deg,
                                    metric=metric, chunk=7)
        np.testing.assert_array_equal(g_s, g_h)
        np.testing.assert_array_equal(d_s, d_h)


def test_final_prune_chunk_larger_than_n():
    rng = np.random.default_rng(11)
    n = 20
    src, dst, hashes, dist = _random_edges(rng, n, 200, "l2")
    res = hashprune_flat(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(hashes),
        jnp.asarray(dist), n_points=n, l_max=8)
    x = rng.standard_normal((n, 6)).astype(np.float32)
    g_s, d_s = final_prune(x, res, max_deg=4, chunk=4096)
    g_h, d_h = final_prune_host(x, res, max_deg=4, chunk=4096)
    np.testing.assert_array_equal(g_s, g_h)
    np.testing.assert_array_equal(d_s, d_h)


# ---------------------------------------------------------------------------
# search shape contract
# ---------------------------------------------------------------------------

def test_search_beam_smaller_than_k_pads_to_k():
    """Regression: beam < k used to silently return [Q, beam]."""
    rng = np.random.default_rng(9)
    x = rng.standard_normal((800, 16)).astype(np.float32)
    idx = pipnn.build(x, _smoke_params("l2"))
    q = x[:7]
    for beam in (4, 10, 32):
        for batch in (True, False):
            found = pipnn.search(idx, x, q, k=10, beam=beam, batch=batch)
            assert found.shape == (7, 10), (beam, batch, found.shape)
            if beam < 10:
                assert (found[:, beam:] == -1).all()
    # real neighbors fill the non-padded prefix
    found = pipnn.search(idx, x, q, k=10, beam=4)
    assert (found[:, :4] >= 0).all()


def test_emit_knn_edges_jax_matches_numpy():
    from repro.core.leaf import _emit_knn_edges

    rng = np.random.default_rng(4)
    b, c, k = 3, 16, 2
    leaf_ids = rng.integers(-1, 40, (b, c)).astype(np.int32)
    nbr_idx = rng.integers(-1, c, (b, c, k)).astype(np.int32)
    nbr_dist = rng.uniform(0, 5, (b, c, k)).astype(np.float32)
    for direction in ("bidirected", "directed", "inverted"):
        want = _emit_knn_edges(leaf_ids, nbr_idx, nbr_dist, direction)
        src, dst, dist = emit_knn_edges_jax(
            jnp.asarray(leaf_ids), jnp.asarray(nbr_idx),
            jnp.asarray(nbr_dist), direction=direction)
        # numpy path masks only src on invalid; compare the valid set plus
        # array shapes (the streaming consumer keys validity off src alone)
        np.testing.assert_array_equal(np.asarray(src), want.src)
        ok = want.src >= 0
        np.testing.assert_array_equal(np.asarray(dst)[ok], want.dst[ok])
        np.testing.assert_array_equal(np.asarray(dist)[ok], want.dist[ok])


def test_pallas_edge_hash_path_matches_fallback():
    """use_pallas_hash=True (interpret mode on CPU) must not change the
    graph."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((500, 16)).astype(np.float32)
    p = _smoke_params("l2", rbc=RBCParams(c_max=64, c_min=8, fanout=(2,)),
                      leaf=LeafParams(k=2, leaf_chunk=4))
    base = pipnn.build(x, p, streaming=True)
    for streaming in (True, False):
        got = pipnn.build(x, p.with_(use_pallas_hash=True),
                          streaming=streaming)
        np.testing.assert_array_equal(got.graph, base.graph)
        np.testing.assert_array_equal(got.dists, base.dists)
