"""End-to-end behaviour tests for the PiPNN system (build -> query -> recall),
determinism (Appendix A.8), and the downstream k-NN-graph task."""
import numpy as np
import pytest

from repro.core import pipnn
from repro.core.beam_search import beam_search_np, brute_force_knn, recall_at_k
from repro.core.knn_graph import knn_graph_pipnn, knn_graph_recall
from repro.core.leaf import LeafParams
from repro.core.pipnn import PiPNNParams
from repro.core.rbc import RBCParams


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(11)
    return rng.standard_normal((4000, 24)).astype(np.float32)


@pytest.fixture(scope="module")
def params():
    return PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, p_samp=0.02, fanout=(4, 2)),
        leaf=LeafParams(k=2, leaf_chunk=8),
        hash_bits=12,
        l_max=64,
        max_deg=32,
        seed=3,
    )


@pytest.fixture(scope="module")
def index(dataset, params):
    return pipnn.build(dataset, params)


def test_build_shapes_and_sanity(index, dataset, params):
    n = dataset.shape[0]
    assert index.graph.shape == (n, params.max_deg)
    assert index.dists.shape == (n, params.max_deg)
    v = index.graph >= 0
    assert v.any(axis=1).all(), "every point needs at least one neighbor"
    assert np.isfinite(index.dists[v]).all()
    # no self loops
    rows = np.broadcast_to(np.arange(n)[:, None], index.graph.shape)
    assert (index.graph[v] != rows[v]).all()
    assert 0 <= index.start < n


def test_recall_meets_bar(index, dataset):
    """10@10 recall (the paper's metric) on held-in queries, modest beam."""
    q = dataset[:200]
    truth = brute_force_knn(dataset, q, 11)
    t = np.array([row[row != i][:10] for i, row in enumerate(truth)])
    found = pipnn.search(index, dataset, q, k=11, beam=64)
    f = np.array([row[row != i][:10] for i, row in enumerate(found)])
    r = recall_at_k(f, t, 10)
    assert r > 0.9, f"recall {r}"


def test_deterministic_rebuild(dataset, params, index):
    """Appendix A.8: fixed seed => bit-identical index."""
    again = pipnn.build(dataset, params)
    np.testing.assert_array_equal(index.graph, again.graph)
    np.testing.assert_array_equal(index.dists, again.dists)
    assert index.start == again.start


def test_replicas_add_quality(dataset, params):
    """Extra replica (Sec. 5.2) must not hurt candidate coverage."""
    p1 = params.with_(rbc=params.rbc)
    import dataclasses
    p2 = params.with_(rbc=dataclasses.replace(params.rbc, replicas=2))
    i1 = pipnn.build(dataset, p1)
    i2 = pipnn.build(dataset, p2)
    assert i2.stats["n_candidate_edges"] > i1.stats["n_candidate_edges"]
    assert i2.average_degree() >= i1.average_degree() * 0.8


def test_no_final_prune_variant(dataset, params):
    idx = pipnn.build(dataset, params.with_(final_prune=False))
    assert (idx.graph >= 0).any(axis=1).all()


def test_mips_metric_build(dataset):
    p = PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(3,)),
        leaf=LeafParams(k=2),
        metric="mips", l_max=32, max_deg=16, seed=0,
    )
    idx = pipnn.build(dataset, p)
    q = dataset[:50]
    truth = brute_force_knn(dataset, q, 10, metric="mips")
    found = pipnn.search(idx, dataset, q, k=10, beam=48)
    r = recall_at_k(found, truth, 10)
    assert r > 0.6, f"MIPS recall {r}"


def test_knn_graph_task(dataset):
    p = PiPNNParams(
        rbc=RBCParams(c_max=256, c_min=32, fanout=(4, 2)),
        leaf=LeafParams(k=3), l_max=64, max_deg=32, seed=0,
    )
    knn, timings = knn_graph_pipnn(dataset, k=10, beam=48, params=p)
    assert knn.shape == (dataset.shape[0], 10)
    r = knn_graph_recall(dataset, knn, k=10, sample=400)
    assert r > 0.85, f"knn-graph recall {r}"
    assert timings["total"] > 0


def test_sequential_and_batch_search_agree(index, dataset):
    q = dataset[:20]
    f_batch = pipnn.search(index, dataset, q, k=10, beam=32, batch=True)
    f_np = pipnn.search(index, dataset, q, k=10, beam=32, batch=False)
    agree = np.mean([
        len(set(a.tolist()) & set(b.tolist())) / 10
        for a, b in zip(f_batch, f_np)
    ])
    assert agree > 0.8, f"batch/np agreement {agree}"
