"""EP (all_to_all) MoE dispatch vs the gather-based reference.

The multi-shard check runs in a subprocess (forced host device count must
not leak into the main test process).
"""
import os
import subprocess
import sys
import textwrap

MOE_EP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import moe

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    E, top_k, d, ff = 8, 2, 32, 64
    key = jax.random.PRNGKey(0)
    p = moe.moe_init(key, d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, d))
    act = NamedSharding(mesh, P(("data",), "model", None))
    x = jax.device_put(x, act)

    # generous capacity so neither path drops tokens -> outputs must match
    y_ref, aux_ref = jax.jit(lambda x: moe.moe_apply(
        p, x, top_k=top_k, n_experts=E, capacity_factor=8.0))(x)
    with mesh:
        y_ep, aux_ep = jax.jit(lambda x: moe.moe_apply_ep(
            p, x, top_k=top_k, n_experts=E, act_sharding=act,
            capacity_factor=8.0))(x)
    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    aerr = abs(float(aux_ref) - float(aux_ep))
    assert err < 1e-4, ("y mismatch", err)
    assert aerr < 1e-4, ("aux mismatch", aerr)
    print("MOE_EP_OK", err, aerr)
""")


def test_moe_ep_matches_reference_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", MOE_EP_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert "MOE_EP_OK" in out.stdout, out.stdout + out.stderr


def test_moe_ep_falls_back_on_single_model_axis():
    """model axis of size 1 (or indivisible experts) -> gather path."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models import moe

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    p = moe.moe_init(jax.random.PRNGKey(0), 16, 32, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    act = NamedSharding(mesh, P(None, None, None))
    y_ref, _ = moe.moe_apply(p, x, top_k=2, n_experts=4,
                             capacity_factor=8.0)
    y_ep, _ = moe.moe_apply_ep(p, x, top_k=2, n_experts=4,
                               act_sharding=act, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               atol=1e-5)
