"""SPMD sharding auditor tests — every PIPS rule gets a positive fixture
(a deliberately broken program/contract the rule MUST flag) and a
negative (the real registry must stay clean).

Synthetic specs reuse the auditor's own registry types
(``SPMDSpec``/``SPMDProgram``), so the positives exercise the exact code
path the lint pass runs — not a parallel re-implementation.  Multi-mesh
positives are gated on the forced-device host (the CI job runs this file
under ``--xla_force_host_platform_device_count=8``)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.analysis import spmd_audit as sa
from repro.analysis.spmd_audit import SPMDProgram, SPMDSpec
from repro.distributed.compat import shard_map_norep

NDEV = len(jax.devices())

multidevice = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _spec(name, build, *, collectives=frozenset(), replicated_ok=frozenset()):
    return SPMDSpec(name=name, path=f"tests/{name}.py", symbol=name,
                    build=build, collectives=frozenset(collectives),
                    replicated_ok=frozenset(replicated_ok))


def _mesh(s, axis="ax"):
    return Mesh(np.array(jax.devices()[:s]), (axis,))


# ------------------------------------------------------------- PIPS001 ---

def _psum_program(s):
    """A 'per-shard' body that sneaks in a psum — works even on a
    1-device mesh, so the positive runs everywhere."""
    mesh = _mesh(s)

    def body(x):
        return jax.lax.psum(x, "ax")

    fn = jax.jit(shard_map_norep(body, mesh=mesh, in_specs=(P("ax"),),
                                 out_specs=P("ax")))
    return SPMDProgram(fn=fn, args=(jax.ShapeDtypeStruct((s, 4), jnp.float32),),
                       arg_names=("x",), sharded=frozenset({"x"}))


def test_pips001_flags_undeclared_collective():
    spec = _spec("sneaky_psum", _psum_program)
    findings = sa.audit_collectives(specs=(spec,))
    assert len(findings) == 1
    f = findings[0]
    assert f.rule == "PIPS001"
    assert "psum" in f.message and "'ax'" in f.message


def test_pips001_quiet_when_contract_declares_it():
    spec = _spec("declared_psum", _psum_program,
                 collectives={("psum", "ax")})
    assert sa.audit_collectives(specs=(spec,)) == []


def test_collectives_in_sees_through_nesting():
    mesh = _mesh(1)

    def body(x):
        # collective buried under scan -> cond nesting
        def step(c, _):
            c = jax.lax.cond(c.sum() > 0,
                             lambda v: jax.lax.psum(v, "ax"),
                             lambda v: v, c)
            return c, None
        c, _ = jax.lax.scan(step, x, None, length=2)
        return c

    fn = jax.jit(shard_map_norep(body, mesh=mesh, in_specs=(P("ax"),),
                                 out_specs=P("ax")))
    got = sa.collectives_in(fn, (jax.ShapeDtypeStruct((1, 4), jnp.float32),))
    assert ("psum", "ax") in got


# ------------------------------------------------------------- PIPS002 ---

def _mislabeled_program(s):
    """in_specs says replicated (P()) for an operand the registry claims
    is sharded — the exact drift PIPS002 exists to catch."""
    mesh = _mesh(s)

    def body(x, y):
        return x + y.sum()

    fn = jax.jit(shard_map_norep(body, mesh=mesh,
                                 in_specs=(P("ax"), P()),
                                 out_specs=P("ax")))
    args = (jax.ShapeDtypeStruct((s * 4, 8), jnp.float32),
            jax.ShapeDtypeStruct((4, 8), jnp.float32))
    return SPMDProgram(fn=fn, args=args, arg_names=("x", "y"),
                       sharded=frozenset({"x", "y"}))


@multidevice
def test_pips002_flags_declared_sharded_but_replicated():
    spec = _spec("mislabeled", _mislabeled_program)
    findings = sa.audit_replication(specs=(spec,))
    assert [f.rule for f in findings] == ["PIPS002"]
    assert "'y'" in findings[0].message


@multidevice
def test_pips002_flags_unwhitelisted_replication():
    def build(s):
        prog = _mislabeled_program(s)
        # correctly declared replicated, but NOT whitelisted
        return SPMDProgram(fn=prog.fn, args=prog.args,
                           arg_names=prog.arg_names,
                           sharded=frozenset({"x"}))

    assert [f.rule for f in sa.audit_replication(specs=(_spec("norep", build),))
            ] == ["PIPS002"]
    # whitelisting it is the fix
    ok = _spec("norep_ok", build, replicated_ok={"y"})
    assert sa.audit_replication(specs=(ok,)) == []


# ------------------------------------------------------------- PIPS003 ---

def test_pips003_envelope_fires_under_tiny_budget():
    findings = sa.audit_footprint(budget=1024)
    assert findings, "a 1KiB HBM budget must trip the envelope pricing"
    assert all(f.rule == "PIPS003" for f in findings)


def test_pips003_quiet_at_default_budget():
    assert sa.audit_footprint() == []


def test_price_shard_packing_monotone_in_halo():
    lo = sa.price_shard_packing(1 << 20, 64, 32, 16, halo_fraction=0.0)
    hi = sa.price_shard_packing(1 << 20, 64, 32, 16, halo_fraction=0.5)
    assert hi["total"] > lo["total"]
    assert hi["rows"] > lo["rows"]
    # int8 points shrink the footprint vs f32
    q = sa.price_shard_packing(1 << 20, 64, 32, 16, int8=True)
    f = sa.price_shard_packing(1 << 20, 64, 32, 16, int8=False)
    assert q["points"] < f["points"]


# ------------------------------------------------------------- PIPS004 ---

def test_pips004_flags_implicit_transfer():
    # a serving path that feeds raw numpy straight into a jit dispatch:
    # an unrouted h2d the guard must catch
    def bad_call(sv, q):
        sv.search(q, k=4, beam=8)
        jax.jit(jnp.sum)(np.asarray(q)).block_until_ready()

    findings = sa.audit_transfers(search_call=bad_call)
    assert [f.rule for f in findings] == ["PIPS004"]
    assert "implicit host transfer" in findings[0].message


def test_pips004_flags_over_budget():
    findings = sa.audit_transfers(budget={"h2d": 0, "d2h": 0})
    assert [f.rule for f in findings] == ["PIPS004"]
    assert "more than" in findings[0].message


def test_pips004_quiet_at_declared_budget():
    assert sa.audit_transfers() == []


# ------------------------------------------------------------- PIPS005 ---

def _unrolled_program(s):
    """Shard count leaked into Python control flow: the traced program
    grows one sin() per shard."""
    def fn(x):
        for _ in range(s):
            x = jnp.sin(x)
        return x

    return SPMDProgram(fn=fn, args=(jax.ShapeDtypeStruct((4,), jnp.float32),),
                       arg_names=("x",), sharded=frozenset())


def _scanned_program(s):
    """The same computation folded into lax control flow: structurally
    identical for every s."""
    def fn(x):
        def step(c, _):
            return jnp.sin(c), None
        c, _ = jax.lax.scan(step, x, None, length=s)
        return c

    return SPMDProgram(fn=fn, args=(jax.ShapeDtypeStruct((4,), jnp.float32),),
                       arg_names=("x",), sharded=frozenset())


def test_fingerprint_distinguishes_unrolled_from_scanned():
    u1, u2 = (_unrolled_program(s) for s in (1, 2))
    assert (sa.structural_fingerprint(u1.fn, u1.args)
            != sa.structural_fingerprint(u2.fn, u2.args))
    s1, s2 = (_scanned_program(s) for s in (1, 2))
    assert (sa.structural_fingerprint(s1.fn, s1.args)
            == sa.structural_fingerprint(s2.fn, s2.args))


@multidevice
def test_pips005_flags_unrolled_program():
    findings = sa.audit_mesh_stability(specs=(_spec("unrolled",
                                                    _unrolled_program),))
    assert [f.rule for f in findings] == ["PIPS005"]


@multidevice
def test_pips005_quiet_for_scanned_program():
    assert sa.audit_mesh_stability(specs=(_spec("scanned",
                                                _scanned_program),)) == []


# ----------------------------------------------------------- acceptance ---

def test_registry_collectives_clean():
    """PIPS001 over the real registry: the per-shard search body is
    proven collective-free, the build supersteps match their declared
    contracts — at every shard count this host can mesh."""
    assert sa.audit_collectives() == []


@multidevice
def test_registry_mesh_stable():
    assert sa.audit_mesh_stability() == []


@multidevice
def test_registry_replication_clean():
    assert sa.audit_replication() == []


def test_every_pips_rule_documented():
    from repro.analysis.lint import RULES

    for rule in ("PIPS001", "PIPS002", "PIPS003", "PIPS004", "PIPS005"):
        assert rule in RULES
