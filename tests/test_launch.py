"""Launcher integration: train loop (with checkpoint/restart), server, and
the cell-program assembly for every family on a 1-device mesh."""
import os
import tempfile

import numpy as np
import pytest

import jax

from repro.configs.base import ShapeCell
from repro.configs.registry import get_config
from repro.launch import steps, train
from repro.launch.serve import Retriever, Server


def test_train_cli_loss_falls(tmp_path):
    rc = train.main(["--arch", "qwen3-14b", "--smoke", "--steps", "12",
                     "--batch", "8", "--seq", "32", "--micro", "2",
                     "--log-every", "100"])
    assert rc == 0


def test_train_checkpoint_restart(tmp_path, capsys):
    common = ["--arch", "mamba2-130m", "--smoke", "--batch", "4",
              "--seq", "32", "--ckpt-dir", str(tmp_path),
              "--ckpt-every", "4", "--log-every", "100"]
    train.main(common + ["--steps", "6"])
    out1 = capsys.readouterr().out
    train.main(common + ["--steps", "10", "--resume"])
    out2 = capsys.readouterr().out
    assert "resumed from step 6" in out2, out2
    # loss keeps falling across the restart ("done: loss A -> B")
    import re
    first = float(re.search(r"done: loss ([\d.]+) ->", out1).group(1))
    last = float(re.search(r"done: loss [\d.]+ -> ([\d.]+)", out2).group(1))
    assert last < first


def test_retriever_dtypes_agree(tmp_path):
    """The ANN Retriever serves at every points precision; the int8
    scalar-quantized copy is the smallest and stays at retrieval parity
    with f32 on an easy clustered corpus."""
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((16, 24)) * 3.0
    corpus = (centers[rng.integers(0, 16, 800)]
              + 0.2 * rng.standard_normal((800, 24))).astype(np.float32)
    f32 = Retriever(corpus, points_dtype="f32", metric="mips")
    i8 = Retriever(corpus, index=f32.index, points_dtype="int8",
                   metric="mips")
    assert i8.device_bytes() < f32.device_bytes()
    q = corpus[:16] + 0.05 * rng.standard_normal((16, 24)).astype(np.float32)
    h32 = f32.retrieve(q, k=4, beam=32)
    h8 = i8.retrieve(q, k=4, beam=32)
    overlap = np.mean([len(set(a) & set(b)) / 4 for a, b in zip(h32, h8)])
    assert overlap >= 0.9, overlap
    with pytest.raises(ValueError):
        Retriever(corpus, index=f32.index, points_dtype="fp4")
    # a metric disagreeing with the prebuilt index is a loud error, not a
    # silent reinterpretation (serving always uses the index's metric)
    with pytest.raises(ValueError):
        Retriever(corpus, index=f32.index, metric="l2")


def test_server_generates(tmp_path):
    server = Server("whisper-tiny", smoke=True, max_len=24)
    prompts = np.random.default_rng(0).integers(
        0, server.vocab, (2, 8)).astype(np.int32)
    toks, stats = server.generate(prompts, 8)
    assert toks.shape == (2, 8)
    assert stats["decode_tok_per_s"] > 0


@pytest.mark.parametrize("arch_id", ["qwen2-7b", "granite-moe-1b-a400m",
                                     "whisper-tiny", "mamba2-130m",
                                     "zamba2-2.7b", "qwen2-vl-7b"])
def test_cell_program_lowers_smoke(arch_id):
    """cell_program (the dry-run unit) lowers for each family on 1 device;
    full-size lowering for the production meshes is the dry-run's job."""
    arch = get_config(arch_id)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    cell = ShapeCell("t", "train", 32, 4)
    with mesh:
        prog = steps.cell_program(arch, cell, mesh, smoke=True)
        prog.lower()
    cell = ShapeCell("d", "decode", 32, 4)
    with mesh:
        prog = steps.cell_program(arch, cell, mesh, smoke=True)
        prog.lower()
