"""Sharded SPMD serving tests: the cross-shard top-k merge vs a numpy
lexsort oracle (plus hypothesis property sweeps), 1-device-mesh parity
with the single-device ServingIndex, packing invariants, and (when the
host exposes >= 4 simulated devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) end-to-end
recall parity of the sharded search."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st
from jax.sharding import Mesh

from repro.core import pipnn
from repro.core.beam_search import brute_force_knn, recall_at_k
from repro.core.serving import ServingIndex
from repro.distributed.serving import ShardedServingIndex, cross_shard_topk

NDEV = len(jax.devices())

multidevice = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _mesh(s):
    return Mesh(np.array(jax.devices()[:s]), ("shards",))


# ------------------------------------------------------ cross-shard merge ---

def _topk_oracle(ids_s, ds_s, k):
    """numpy lexsort reference: per query, unique valid (dist, id) pairs
    across all shards, ascending by (dist, id), -1/inf padded to k."""
    s, nq, b = ids_s.shape
    out_i = np.full((nq, k), -1, np.int64)
    out_d = np.full((nq, k), np.inf, np.float32)
    for qi in range(nq):
        pairs = {}
        for si in range(s):
            for bi in range(b):
                i, dd = int(ids_s[si, qi, bi]), float(ds_s[si, qi, bi])
                if i >= 0 and np.isfinite(dd) and i not in pairs:
                    pairs[i] = dd
        items = sorted(pairs.items(), key=lambda t: (t[1], t[0]))[:k]
        for j, (i, dd) in enumerate(items):
            out_i[qi, j] = i
            out_d[qi, j] = dd
    return out_i, out_d


def _random_blocks(rng, s, nq, b, n_ids, *, tie_prob=0.0, drop_prob=0.2):
    """Disjoint per-shard id pools (the partition contract) with random
    -1 pads; optional exact-duplicate distances WITHIN a query to force
    (dist, id) tie-breaks."""
    ids = np.full((s, nq, b), -1, np.int64)
    ds = np.full((s, nq, b), np.inf, np.float32)
    pool = rng.permutation(n_ids)
    bounds = np.linspace(0, n_ids, s + 1).astype(int)
    for si in range(s):
        shard_pool = pool[bounds[si]: bounds[si + 1]]
        for qi in range(nq):
            take = min(b, len(shard_pool))
            chosen = rng.choice(shard_pool, size=take, replace=False)
            dd = rng.standard_normal(take).astype(np.float32)
            if tie_prob and take > 1:
                dup = rng.random(take) < tie_prob
                dd[dup] = dd[0]
            keep = rng.random(take) >= drop_prob
            ids[si, qi, :take][keep] = chosen[keep]
            ds[si, qi, :take][keep] = dd[keep]
    return ids, ds


@pytest.mark.parametrize("s,nq,b,k", [(2, 3, 4, 4), (4, 5, 8, 6),
                                      (8, 2, 4, 16), (3, 4, 6, 1)])
def test_cross_shard_topk_matches_lexsort_oracle(s, nq, b, k):
    rng = np.random.default_rng(hash((s, nq, b, k)) % 2**31)
    ids, ds = _random_blocks(rng, s, nq, b, n_ids=s * b * 2)
    gi, gd = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=k)
    wi, wd = _topk_oracle(ids, ds, k)
    np.testing.assert_array_equal(np.asarray(gi), wi)
    np.testing.assert_allclose(np.asarray(gd), wd, rtol=0, atol=0)


def test_cross_shard_topk_tie_breaks_toward_smaller_id():
    """Exactly equal distances across shards must order by id — the same
    (dist, id) lex key the beam itself uses, so merges are deterministic
    regardless of shard order."""
    ids = np.array([[[7, 3]], [[5, 1]]], np.int64)        # [2, 1, 2]
    ds = np.zeros((2, 1, 2), np.float32)                  # all tied
    gi, gd = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=4)
    np.testing.assert_array_equal(np.asarray(gi), [[1, 3, 5, 7]])
    assert (np.asarray(gd) == 0).all()


def test_cross_shard_topk_k_exceeds_union():
    """k past the union of valid entries pads with (-1, inf)."""
    ids = np.array([[[4, -1]], [[9, -1]]], np.int64)
    ds = np.array([[[0.5, np.inf]], [[0.25, np.inf]]], np.float32)
    gi, gd = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=5)
    np.testing.assert_array_equal(np.asarray(gi), [[9, 4, -1, -1, -1]])
    assert np.isinf(np.asarray(gd)[0, 2:]).all()


def test_cross_shard_topk_k_exceeds_per_shard_beam():
    """k > B draws from MULTIPLE shards' beams — the merged depth is the
    union's, not one shard's."""
    rng = np.random.default_rng(9)
    s, nq, b, k = 4, 3, 4, 12
    ids, ds = _random_blocks(rng, s, nq, b, n_ids=64, drop_prob=0.0)
    gi, _ = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=k)
    wi, _ = _topk_oracle(ids, ds, k)
    np.testing.assert_array_equal(np.asarray(gi), wi)
    assert (np.asarray(gi)[:, b:] >= 0).any(), "merge must reach past B"


def test_cross_shard_topk_halo_duplicates_identical_dists():
    """The halo contract: the SAME global id may appear in two shards'
    beams with bit-identical distances — the merge keeps one copy."""
    ids = np.array([[[2, 8]], [[2, 5]]], np.int64)        # id 2 replicated
    ds = np.array([[[0.125, 0.5]], [[0.125, 0.25]]], np.float32)
    gi, gd = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=4)
    np.testing.assert_array_equal(np.asarray(gi), [[2, 5, 8, -1]])
    np.testing.assert_array_equal(np.asarray(gd)[0, :3],
                                  [0.125, 0.25, 0.5])


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    s=st.integers(1, 6),
    nq=st.integers(1, 4),
    b=st.integers(1, 8),
    k=st.integers(1, 20),
    tie_prob=st.sampled_from([0.0, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cross_shard_topk_property(s, nq, b, k, tie_prob, seed):
    """Ragged per-shard counts, in-query ties, k above/below B/union —
    the merge must equal the lexsort oracle everywhere."""
    rng = np.random.default_rng(seed)
    ids, ds = _random_blocks(rng, s, nq, b, n_ids=max(s * b, 4),
                             tie_prob=tie_prob, drop_prob=0.35)
    gi, gd = cross_shard_topk(jnp.asarray(ids), jnp.asarray(ds), k=k)
    wi, wd = _topk_oracle(ids, ds, k)
    np.testing.assert_array_equal(np.asarray(gi), wi)
    np.testing.assert_allclose(np.asarray(gd), wd, rtol=0, atol=0)


# ----------------------------------------------------- packing invariants ---

@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((900, 16)).astype(np.float32)
    return pipnn.build(x), x


def test_sharded_packing_partition_and_halo(built):
    """Owned rows partition the dataset exactly once; ghost rows replicate
    member-edge endpoints; every member edge survives the renumbering."""
    idx, x = built
    ssv = ShardedServingIndex.from_index(idx, x, mesh=_mesh(1))
    gids = np.asarray(ssv.gids)
    live = gids[gids >= 0]
    assert ssv.n == x.shape[0]
    # an S=1 mesh has no cross-shard edges, hence no halo
    assert len(live) == x.shape[0]
    assert sorted(live.tolist()) == list(range(x.shape[0]))
    # local graph ids resolve through gids to the original edges
    g = np.asarray(ssv.graph)[0]
    orig = np.asarray(idx.graph)
    for row in range(0, x.shape[0], 97):
        gid = gids[0, row]
        local = g[row][g[row] >= 0]
        np.testing.assert_array_equal(
            np.sort(gids[0, local]), np.sort(orig[gid][orig[gid] >= 0]))


def test_sharded_packing_rejects_bad_args(built):
    idx, x = built
    with pytest.raises(ValueError):
        ShardedServingIndex.from_index(idx, x, mesh=_mesh(1), router="rr")
    with pytest.raises(ValueError):        # fewer points than devices
        ShardedServingIndex.from_graph(idx.graph[:0], x[:0], 0,
                                       mesh=_mesh(1))
    # single-device ServingIndex rejects mesh-only kwargs without a mesh
    with pytest.raises(TypeError):
        ServingIndex.from_index(idx, x, router="all")


def test_sharded_one_device_mesh_matches_single(built):
    """An S=1 mesh is the single-device search wearing the shard_map
    plumbing: identical ids (one shard holds the whole graph, the merge
    is a no-op)."""
    idx, x = built
    q = x[:32]
    sv = ServingIndex.from_index(idx, x)
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    assert isinstance(ssv, ShardedServingIndex)
    a = sv.search(q, k=10, beam=24)
    b, stats = ssv.search(q, k=10, beam=24, with_stats=True)
    np.testing.assert_array_equal(a, b)
    assert stats["n_shards"] == 1 and stats["router"] == "all"
    assert stats["kernel_path"] == "xla"      # CPU auto-selection
    assert stats["hops"].shape == (32,)


def test_sharded_device_bytes_and_empty_batch(built):
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    assert ssv.device_bytes() > 0
    assert ssv.device_bytes(per_shard=True) == ssv.device_bytes()
    out = ssv.search(np.zeros((0, x.shape[1]), np.float32), k=7)
    assert out.shape == (0, 7) and out.dtype == np.int64


# --------------------------------------------- multi-device recall parity ---

@multidevice
def test_sharded_search_recall_parity(built):
    """>= 4 shards, replicate-to-all router: the halo packing keeps the
    full 1-hop neighborhood of every owned point, so merged recall stays
    within 0.01 of the single-device search."""
    idx, x = built
    rng = np.random.default_rng(7)
    q = rng.standard_normal((96, x.shape[1])).astype(np.float32)
    gt = brute_force_knn(x, q, k=10)
    r1 = recall_at_k(ServingIndex.from_index(idx, x).search(
        q, k=10, beam=32), gt)
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4))
    rs = recall_at_k(ssv.search(q, k=10, beam=32), gt)
    assert rs >= r1 - 0.01, (r1, rs)


@multidevice
def test_sharded_int8_recall_parity(built):
    idx, x = built
    rng = np.random.default_rng(8)
    q = rng.standard_normal((64, x.shape[1])).astype(np.float32)
    gt = brute_force_knn(x, q, k=10)
    r1 = recall_at_k(ServingIndex.from_index(idx, x, dtype="int8").search(
        q, k=10, beam=32), gt)
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4), dtype="int8")
    rs = recall_at_k(ssv.search(q, k=10, beam=32), gt)
    assert rs >= r1 - 0.01, (r1, rs)


@multidevice
def test_sharded_leaders_router_masks_shards(built):
    """The probing router serves each query from n_probes shards only:
    summed hops drop vs replicate-to-all, recall stays reasonable."""
    idx, x = built
    rng = np.random.default_rng(9)
    q = rng.standard_normal((48, x.shape[1])).astype(np.float32)
    gt = brute_force_knn(x, q, k=10)
    sall = ServingIndex.from_index(idx, x, mesh=_mesh(4))
    slead = ServingIndex.from_index(idx, x, mesh=_mesh(4),
                                    router="leaders", n_probes=2)
    a, st_all = sall.search(q, k=10, beam=32, with_stats=True)
    b, st_lead = slead.search(q, k=10, beam=32, with_stats=True)
    assert st_lead["router"] == "leaders"
    assert st_lead["hops"].sum() < st_all["hops"].sum()
    assert recall_at_k(b, gt) >= recall_at_k(a, gt) - 0.1


@multidevice
def test_pipnn_search_mesh_end_to_end(built):
    """mesh= threads through pipnn.search's serving cache; mesh and
    non-mesh packings coexist only one at a time (single cache slot)."""
    idx, x = built
    q = x[:16]
    mesh = _mesh(4)
    ids, stats = pipnn.search(idx, x, q, k=5, mesh=mesh, with_stats=True)
    assert stats["n_shards"] == 4
    assert isinstance(idx._serving, ShardedServingIndex)
    sv1 = idx._serving
    pipnn.search(idx, x, q, k=5, mesh=mesh)
    assert idx._serving is sv1                # cache hit on the same mesh
    with pytest.raises(ValueError):
        pipnn.search(idx, x, q, k=5, batch=False, mesh=mesh)


# ------------------------------------------- halo stats / query chunking ---

def test_halo_stats_one_device_mesh(built):
    """S=1: no cross-shard edges, so zero ghosts and zero halo fraction;
    members account for every point."""
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    hs = ssv.halo_stats()
    assert int(hs["members"].sum()) == x.shape[0]
    assert int(hs["ghosts"].sum()) == 0
    assert hs["halo_fraction"] == 0.0
    bd = ssv.device_bytes(breakdown=True)
    assert bd["ghost_bytes"] == 0
    assert bd["total"] == ssv.device_bytes()
    _, stats = ssv.search(x[:4], k=5, with_stats=True)
    assert stats["halo_fraction"] == 0.0


@multidevice
def test_halo_stats_accounting(built):
    """S=4: members still partition the dataset exactly; ghosts are the
    replicated neighborhood rows; member+ghost+pad == capacity per shard;
    the byte breakdown sums to device_bytes."""
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4))
    hs = ssv.halo_stats()
    assert int(hs["members"].sum()) == x.shape[0]
    assert int(hs["ghosts"].sum()) > 0        # a real graph has halo
    cap = ssv.shard_capacity
    np.testing.assert_array_equal(
        hs["members"] + hs["ghosts"] + hs["pads"], np.full(4, cap))
    assert 0.0 < hs["halo_fraction"] < 1.0
    bd = ssv.device_bytes(breakdown=True)
    rows = bd["member_bytes"] + bd["ghost_bytes"] + bd["pad_bytes"]
    # the breakdown covers the row-indexed arrays; starts/leaders ride
    # on top of it in the total
    assert 0 < rows <= bd["total"] == ssv.device_bytes()
    assert bd["halo_fraction"] == hs["halo_fraction"]
    _, stats = ssv.search(x[:4], k=5, with_stats=True)
    assert stats["halo_fraction"] == hs["halo_fraction"]


def test_sharded_query_chunk_parity(built):
    """Chunked dispatch pads every batch to one shape: identical results,
    and the jit cache stops growing with batch size."""
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    q = x[:13]
    a = ssv.search(q, k=7, beam=16)
    b = ssv.search(q, k=7, beam=16, query_chunk=4)
    np.testing.assert_array_equal(a, b)
    # stats survive chunking (concatenated per chunk, trimmed to nq)
    c, stats = ssv.search(q, k=7, beam=16, query_chunk=5, with_stats=True)
    np.testing.assert_array_equal(a, c)
    assert stats["hops"].shape == (13,)
    with pytest.raises(ValueError):
        ssv.search(q, k=7, query_chunk=0)


@multidevice
def test_sharded_query_chunk_bounds_jit_cache(built):
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4))
    for nq in (1, 3, 7, 12):
        ssv.search(x[:nq], k=5, beam=16, query_chunk=4)
    sizes = [fn._cache_size() for fn in ssv._search_cache.values()]
    assert sum(sizes) == 1, sizes


# ------------------------------------------------- n_probes validation ---

def test_leaders_router_rejects_nonpositive_probes(built):
    idx, x = built
    with pytest.raises(ValueError, match="n_probes"):
        ServingIndex.from_index(idx, x, mesh=_mesh(1), router="leaders",
                                n_probes=0)
    with pytest.raises(ValueError, match="n_probes"):
        ServingIndex.from_index(idx, x, mesh=_mesh(1), router="leaders",
                                n_probes=-3)


@multidevice
@pytest.mark.parametrize("n_probes", [1, 4, 9])
def test_leaders_router_probe_sweep(built, n_probes):
    """n_probes in {1, S, >S}: every query is served by at least one
    shard (no all-masked rows — the pre-PR-8 n_probes<=0 regression),
    and >S clamps to S (== replicate-to-all results)."""
    idx, x = built
    rng = np.random.default_rng(11)
    q = rng.standard_normal((24, x.shape[1])).astype(np.float32)
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4), router="leaders",
                                  n_probes=n_probes)
    ids, stats = ssv.search(q, k=5, beam=24, with_stats=True)
    assert stats["n_probes"] == min(n_probes, 4)
    assert (ids[:, 0] >= 0).all(), "a query was masked from every shard"
    if n_probes >= 4:
        sall = ServingIndex.from_index(idx, x, mesh=_mesh(4))
        np.testing.assert_array_equal(ids, sall.search(q, k=5, beam=24))


# ------------------------------------------------- transfer discipline ---

def test_sharded_search_no_implicit_transfers(built, no_implicit_transfers):
    """The serving call under transfer_guard('disallow'): every host
    crossing must be routed through the declared to_device/to_host
    boundaries (the PIPS004 contract, enforced live)."""
    from repro.core import transfers

    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    q = x[:6]
    expect = ssv.search(q, k=5, beam=16)          # compile outside guard
    ssv.search(q, k=5, beam=16, with_stats=True)
    with transfers.ledger() as counts, no_implicit_transfers():
        got = ssv.search(q, k=5, beam=16)
        np.testing.assert_array_equal(got, expect)
        assert counts == ShardedServingIndex.TRANSFER_BUDGET
        ssv.search(q, k=5, beam=16, with_stats=True)


@multidevice
def test_sharded_search_no_implicit_transfers_multidevice(
        built, no_implicit_transfers):
    """Same discipline on a real 4-shard mesh, int8 packing and chunked
    batches included (chunking pays one h2d/d2h per chunk)."""
    from repro.core import transfers

    idx, x = built
    for dtype in (None, "int8"):
        ssv = ServingIndex.from_index(idx, x, mesh=_mesh(4), dtype=dtype)
        q = x[:9]
        ssv.search(q, k=5, beam=16, query_chunk=4)    # compile first
        with transfers.ledger() as counts, no_implicit_transfers():
            ssv.search(q, k=5, beam=16, query_chunk=4)
        assert counts == {"h2d": 3, "d2h": 3}         # ceil(9/4) chunks


# ----------------------------------------------------- boundary hardening ---

def test_sharded_search_guards_k_beam_and_nan(built):
    """The sharded entry shares the single-device boundary validation:
    non-positive k/beam and NaN/Inf rows fail fast and structured, before
    anything is dispatched to the mesh."""
    from repro.core.validation import InvalidQueryError

    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    with pytest.raises(ValueError, match="k must be >= 1"):
        ssv.search(x[:2], k=0)
    with pytest.raises(ValueError, match="beam must be >= 1"):
        ssv.search(x[:2], k=5, beam=-2)
    q = np.array(x[:3])
    q[2, 1] = np.inf
    with pytest.raises(InvalidQueryError) as ei:
        ssv.search(q, k=5)
    assert ei.value.reason == "nan_inf" and ei.value.rows == (2,)


def test_all_shards_down_raises(built):
    from repro.distributed.serving import AllShardsDown

    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    ssv.mark_shard_down(0)
    with pytest.raises(AllShardsDown):
        ssv.search(x[:2], k=5)
    # probing re-admits immediately: no fault harness, so the default
    # probe (serve the shard's own leader) succeeds on the first try
    assert ssv.probe_shard(0)
    assert not ssv.down_shards
    assert (np.asarray(ssv.search(x[:2], k=5))[:, 0] >= 0).all()


def test_probe_shard_failure_keeps_tombstone(built):
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    ssv.mark_shard_down(0)
    assert not ssv.probe_shard(0, probe=lambda s: False)
    assert ssv.down_shards == (0,)
    calls = []

    def raising_probe(s):
        calls.append(s)
        raise RuntimeError("still dead")

    assert not ssv.probe_shard(0, probe=raising_probe)
    assert calls == [0] and ssv.down_shards == (0,)
    assert ssv.probe_shard(0, probe=lambda s: True)
    assert ssv.healthy_shards == 1


def test_sharded_converged_telemetry(built):
    idx, x = built
    ssv = ServingIndex.from_index(idx, x, mesh=_mesh(1))
    _, stats = ssv.search(x[:5], k=5, beam=16, with_stats=True)
    conv = stats["converged"]
    assert conv.shape == (5,) and conv.dtype == bool
    assert conv.all()
    _, stats1 = ssv.search(x[:5], k=5, beam=16, iters=1, with_stats=True)
    assert not stats1["converged"].any()
    assert stats["healthy_shards"] == 1
