"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracles,
swept over shapes and dtypes, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.kernels import ops, ref

INTERP = True  # CPU container: kernels execute in interpret mode


# ------------------------------------------------------------- distance ---

SHAPES = [
    (1, 8, 8, 4),       # tiny, heavy padding
    (2, 128, 128, 32),  # exact tiles
    (3, 100, 200, 17),  # ragged everything
    (1, 257, 129, 128), # off-by-one over tiles
]


@pytest.mark.parametrize("b,m,n,d", SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_distance_matches_ref(b, m, n, d, metric, dtype):
    rng = np.random.default_rng(hash((b, m, n, d, metric)) % 2**31)
    a = jnp.asarray(rng.standard_normal((b, m, d)), dtype=dtype)
    bb = jnp.asarray(rng.standard_normal((b, n, d)), dtype=dtype)
    got = ops.pairwise_distance(a, bb, metric=metric, interpret=INTERP)
    want = ref.pairwise_distance_ref(a, bb, metric=metric)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("b,m,n,d", [(1, 16, 16, 8), (2, 130, 70, 100)])
def test_pairwise_distance_int8_exact(b, m, n, d):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, (b, m, d)), dtype=jnp.int8)
    bb = jnp.asarray(rng.integers(-128, 128, (b, n, d)), dtype=jnp.int8)
    got = ops.pairwise_distance_int8(a, bb, interpret=INTERP)
    want = ref.pairwise_distance_int8_ref(a, bb)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pairwise_distance_int8_rejects_float():
    a = jnp.zeros((1, 8, 8), jnp.float32)
    with pytest.raises(TypeError):
        ops.pairwise_distance_int8(a, a, interpret=INTERP)


# -------------------------------------------------------------- FlashKNN ---

@pytest.mark.parametrize("c,d,k", [(32, 8, 2), (128, 32, 4), (200, 64, 3),
                                   (260, 16, 8)])
@pytest.mark.parametrize("metric", ["l2", "mips"])
def test_leaf_topk_matches_ref(c, d, k, metric):
    rng = np.random.default_rng(hash((c, d, k)) % 2**31)
    pts = jnp.asarray(rng.standard_normal((2, c, d)), dtype=jnp.float32)
    valid = np.ones((2, c), dtype=bool)
    valid[0, c // 2 :] = False  # one heavily padded leaf
    valid[1, ::7] = False       # scattered invalids
    vj = jnp.asarray(valid)
    gi, gv = ops.leaf_topk(pts, vj, k=k, metric=metric, interpret=INTERP)
    wi, wv = ref.leaf_topk_ref(pts, vj, k=k, metric=metric)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_leaf_topk_duplicate_points_tiebreak():
    """Duplicate points => zero distances; ties must break identically."""
    pts = jnp.zeros((1, 64, 8), dtype=jnp.float32)
    valid = jnp.ones((1, 64), dtype=bool)
    gi, gv = ops.leaf_topk(pts, valid, k=3, interpret=INTERP)
    wi, wv = ref.leaf_topk_ref(pts, valid, k=3)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    assert (np.asarray(gv) == 0).all()


@hypothesis.settings(max_examples=15, deadline=None)
@hypothesis.given(
    c=st.integers(4, 80),
    d=st.integers(2, 40),
    k=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_leaf_topk_property(c, d, k, seed):
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.standard_normal((1, c, d)), dtype=jnp.float32)
    valid = jnp.asarray(rng.random((1, c)) > 0.2)
    gi, gv = ops.leaf_topk(pts, valid, k=k, interpret=INTERP)
    wi, wv = ref.leaf_topk_ref(pts, valid, k=k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


# ----------------------------------------------------------- rowwise topk ---

@pytest.mark.parametrize("b,m,n,k", [(1, 8, 8, 2), (2, 128, 300, 4),
                                     (1, 100, 1000, 8)])
def test_rowwise_topk_matches_ref(b, m, n, k):
    rng = np.random.default_rng(hash((b, m, n, k)) % 2**31)
    d = rng.standard_normal((b, m, n)).astype(np.float32)
    d[rng.random((b, m, n)) < 0.1] = np.inf  # masked entries
    dj = jnp.asarray(d)
    gi, gv = ops.rowwise_topk(dj, k=k, interpret=INTERP)
    wi, wv = ref.rowwise_topk_ref(dj, k=k)
    np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_rowwise_topk_all_masked_row():
    d = jnp.full((1, 4, 16), jnp.inf)
    gi, gv = ops.rowwise_topk(d, k=3, interpret=INTERP)
    assert (np.asarray(gi) == -1).all()
    assert np.isinf(np.asarray(gv)).all()


# -------------------------------------------------------------- edge hash ---

@pytest.mark.parametrize("e,m", [(1, 12), (128, 12), (1000, 16), (257, 8)])
def test_edge_hashes_match_sketch_module(e, m):
    from repro.core import sketch as _sketch

    rng = np.random.default_rng(e * 31 + m)
    s = jnp.asarray(rng.standard_normal((e, m)), dtype=jnp.float32)
    t = jnp.asarray(rng.standard_normal((e, m)), dtype=jnp.float32)
    got = ops.edge_hashes(s, t, interpret=INTERP)
    want = _sketch.hash_from_sketches(t, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_edge_hash_range():
    rng = np.random.default_rng(0)
    s = jnp.asarray(rng.standard_normal((500, 12)), dtype=jnp.float32)
    t = jnp.asarray(rng.standard_normal((500, 12)), dtype=jnp.float32)
    h = np.asarray(ops.edge_hashes(s, t, interpret=INTERP))
    assert (h >= 0).all() and (h < 2**12).all()


# ------------------------------------------------------- gather-distance ---

GD_SHAPES = [
    (60, 4, 3, 5),        # tiny, heavy padding everywhere
    (300, 32, 16, 128),   # serving-shaped: E*R = 128 lane-exact
    (257, 17, 9, 65),     # ragged everything
    (128, 128, 8, 256),   # exact tiles, wide candidate block
]


@pytest.mark.parametrize("n,d,q,c", GD_SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_matches_ref(n, d, q, c, metric):
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance

    rng = np.random.default_rng(hash((n, d, q, c, metric)) % 2**31)
    x = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    qs = jnp.asarray(rng.standard_normal((q, d)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, n, (q, c)), dtype=jnp.int32)
    norms = point_norms(x, metric)
    got = gather_distance(x, norms, qs, ids, metric=metric, interpret=INTERP)
    want = ref.gather_distance_ref(x, norms, qs, ids, metric=metric)
    g, w = np.asarray(got), np.asarray(want)
    mask = np.asarray(ids) >= 0
    assert (np.isinf(g) == ~mask).all(), "-1 ids must map to +inf"
    np.testing.assert_allclose(g[mask], w[mask], rtol=1e-5, atol=1e-5)


def test_gather_distance_downcast_points():
    """bf16 points: the norm half stays exact (precomputed f32), only the
    inner product is rounded — kernel and oracle agree within bf16 tol."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance

    rng = np.random.default_rng(0)
    x32 = jnp.asarray(rng.standard_normal((200, 24)), dtype=jnp.float32)
    norms = point_norms(x32, "l2")       # BEFORE the downcast
    x16 = x32.astype(jnp.bfloat16)
    qs = jnp.asarray(rng.standard_normal((7, 24)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(0, 200, (7, 33)), dtype=jnp.int32)
    got = gather_distance(x16, norms, qs, ids, metric="l2", interpret=INTERP)
    want = ref.gather_distance_ref(x16, norms, qs, ids, metric="l2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-2, atol=3e-2)
    exact = ref.gather_distance_ref(x32, norms, qs, ids, metric="l2")
    np.testing.assert_allclose(np.asarray(got), np.asarray(exact),
                               rtol=0.15, atol=0.3)


def test_gather_distance_fits_vmem_budget():
    from repro.kernels.gather_distance import fits_vmem

    assert fits_vmem(jnp.zeros((1000, 32), jnp.float32))
    assert not fits_vmem(jnp.zeros((1 << 20, 128), jnp.float32))


def test_fits_vmem_int8_headroom():
    """The budget check is itemsize-aware: an int8 packing (plus its f32
    scales) fits where the same-shape f32 block does not."""
    from repro.kernels.gather_distance import fits_vmem

    n, d = 40960, 128           # f32: 20 MB > budget; int8 + scales: ~5.2 MB
    assert not fits_vmem(jnp.zeros((n, d), jnp.float32))
    assert fits_vmem(jnp.zeros((n, d), jnp.int8), jnp.zeros((n,), jnp.float32))
    # the extras count against the budget too
    assert not fits_vmem(jnp.zeros((n, d), jnp.int8),
                         jnp.zeros((n, d), jnp.float32))


# ------------------------------------------- int8 gather-distance (serving) ---

def _quantized(rng, n, d):
    x32 = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    x8, scl = ref.quantize_symmetric(x32)
    return x32, x8, scl


def test_quantize_symmetric_basics():
    rng = np.random.default_rng(3)
    x32, x8, scl = _quantized(rng, 50, 19)
    assert x8.dtype == jnp.int8 and scl.dtype == jnp.float32
    assert (np.abs(np.asarray(x8)) <= 127).all()
    assert (np.asarray(scl) > 0).all()
    # every row's max-|value| element hits +-127 exactly
    assert (np.max(np.abs(np.asarray(x8)), axis=-1) == 127).all()
    # dequantization error bounded by half a step per component
    err = np.abs(np.asarray(x8) * np.asarray(scl)[:, None] - np.asarray(x32))
    assert (err <= 0.5 * np.asarray(scl)[:, None] + 1e-7).all()


def test_quantize_symmetric_zero_rows():
    """Zero rows quantize to zeros with a tiny positive scale (no NaN/inf
    from the 0/0)."""
    x = jnp.zeros((4, 8), jnp.float32)
    q, s = ref.quantize_symmetric(x)
    assert (np.asarray(q) == 0).all()
    assert (np.asarray(s) > 0).all() and np.isfinite(np.asarray(s)).all()


@pytest.mark.parametrize("n,d,q,c", GD_SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_int8_matches_ref_bitexact(n, d, q, c, metric):
    """The quantized Pallas kernel (interpret mode) must agree with the
    jnp oracle BIT-FOR-BIT: the int8 x int8 -> int32 inner product is
    exact, the quantization is the shared order-independent scheme, and
    every f32 op is written in matching order on both sides."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_int8

    rng = np.random.default_rng(hash((n, d, q, c, metric, 8)) % 2**31)
    x32, x8, scl = _quantized(rng, n, d)
    qs = jnp.asarray(rng.standard_normal((q, d)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, n, (q, c)), dtype=jnp.int32)
    norms = point_norms(x32, metric)          # EXACT, pre-quantization
    qn = point_norms(qs, metric)   # query norm terms: same mapping
    got = gather_distance_int8(x8, scl, norms, qs, qn, ids, metric=metric,
                               interpret=INTERP)
    want = ref.gather_distance_int8_ref(x8, scl, norms, qs, qn, ids,
                                        metric=metric)
    g = np.asarray(got)
    assert (np.isinf(g) == (np.asarray(ids) < 0)).all()
    np.testing.assert_array_equal(g, np.asarray(want))


@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_int8_close_to_f32(metric):
    """Quantized distances approximate the exact f32 block: the norm
    halves are exact, so the error is the rescaled int8 inner-product
    rounding only."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_int8

    rng = np.random.default_rng(11)
    x32, x8, scl = _quantized(rng, 300, 24)
    qs = jnp.asarray(rng.standard_normal((9, 24)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(0, 300, (9, 40)), dtype=jnp.int32)
    norms = point_norms(x32, metric)
    qn = point_norms(qs, metric)   # query norm terms: same mapping
    got = np.asarray(gather_distance_int8(x8, scl, norms, qs, qn, ids,
                                          metric=metric, interpret=INTERP))
    exact = np.asarray(ref.gather_distance_ref(x32, norms, qs, ids,
                                               metric=metric))
    # the quantization error is ABSOLUTE in the inner product (half a step
    # per component), so near-zero mips values need the atol term
    np.testing.assert_allclose(got, exact, rtol=0.05, atol=0.2)


def test_gather_distance_int8_degenerate_scales():
    """Zero vectors and constant datasets: tiny clamped scales must not
    produce NaN/inf in valid entries, and kernel == oracle still."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_int8

    rng = np.random.default_rng(0)
    for x32 in (jnp.zeros((40, 8), jnp.float32),               # all zero
                jnp.full((40, 8), 2.25, jnp.float32),          # constant
                jnp.zeros((40, 8), jnp.float32).at[7:].set(-1.5)):
        x8, scl = ref.quantize_symmetric(x32)
        qs = jnp.asarray(rng.standard_normal((5, 8)), dtype=jnp.float32)
        ids = jnp.asarray(rng.integers(-1, 40, (5, 11)), dtype=jnp.int32)
        for metric in ("l2", "mips", "cosine"):
            norms = point_norms(x32, metric)
            qn = point_norms(qs, metric)   # query norm terms: same mapping
            got = np.asarray(gather_distance_int8(
                x8, scl, norms, qs, qn, ids, metric=metric, interpret=INTERP))
            want = np.asarray(ref.gather_distance_int8_ref(
                x8, scl, norms, qs, qn, ids, metric=metric))
            np.testing.assert_array_equal(got, want)
            valid = np.asarray(ids) >= 0
            assert np.isfinite(got[valid]).all()


def test_gather_distance_int8_rejects_float_points():
    from repro.kernels.gather_distance import gather_distance_int8

    x = jnp.zeros((16, 8), jnp.float32)
    aux = jnp.zeros((16,), jnp.float32)
    qs = jnp.zeros((2, 8), jnp.float32)
    ids = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(TypeError):
        gather_distance_int8(x, aux, aux, qs, jnp.zeros((2,), jnp.float32),
                             ids, interpret=INTERP)


# ------------------------------------- HBM-streaming gather-distance ---

@pytest.mark.parametrize("n,d,q,c", GD_SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_hbm_matches_ref_bitexact(n, d, q, c, metric):
    """The HBM-streaming kernel (points stay in HBM, neighbor rows DMA'd
    into VMEM scratch) must agree with its shape-mirrored oracle
    BIT-FOR-BIT: both sides reduce the same lane-padded extent in the
    same elementwise order, and the norm halves are shared f32 data."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_hbm

    rng = np.random.default_rng(hash((n, d, q, c, metric, 77)) % 2**31)
    x = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    qs = jnp.asarray(rng.standard_normal((q, d)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, n, (q, c)), dtype=jnp.int32)
    norms = point_norms(x, metric)
    got = gather_distance_hbm(x, norms, qs, ids, metric=metric,
                              interpret=INTERP)
    want = ref.gather_distance_hbm_ref(x, norms, qs, ids, metric=metric)
    g = np.asarray(got)
    assert (np.isinf(g) == (np.asarray(ids) < 0)).all()
    np.testing.assert_array_equal(g, np.asarray(want))


@pytest.mark.parametrize("n,d,q,c", GD_SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_hbm_close_to_vmem_kernel(n, d, q, c, metric):
    """Streaming vs VMEM-resident kernel on the same inputs: different
    reduction strategies, same distances to f32 tolerance — an oversized
    shard can switch paths without a recall cliff."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import (gather_distance,
                                               gather_distance_hbm)

    rng = np.random.default_rng(hash((n, d, q, c, metric, 78)) % 2**31)
    x = jnp.asarray(rng.standard_normal((n, d)), dtype=jnp.float32)
    qs = jnp.asarray(rng.standard_normal((q, d)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, n, (q, c)), dtype=jnp.int32)
    norms = point_norms(x, metric)
    a = np.asarray(gather_distance_hbm(x, norms, qs, ids, metric=metric,
                                       interpret=INTERP))
    b = np.asarray(gather_distance(x, norms, qs, ids, metric=metric,
                                   interpret=INTERP))
    mask = np.asarray(ids) >= 0
    np.testing.assert_allclose(a[mask], b[mask], rtol=1e-5, atol=1e-5)


def test_gather_distance_hbm_downcast_points():
    """bf16 points stream bit-identically too: the scratch buffer keeps
    the points dtype and both sides upcast row-wise in the same order."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_hbm

    rng = np.random.default_rng(21)
    x32 = jnp.asarray(rng.standard_normal((150, 24)), dtype=jnp.float32)
    norms = point_norms(x32, "l2")       # BEFORE the downcast
    x16 = x32.astype(jnp.bfloat16)
    qs = jnp.asarray(rng.standard_normal((6, 24)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, 150, (6, 18)), dtype=jnp.int32)
    got = gather_distance_hbm(x16, norms, qs, ids, metric="l2",
                              interpret=INTERP)
    want = ref.gather_distance_hbm_ref(x16, norms, qs, ids, metric="l2")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,d,q,c", GD_SHAPES)
@pytest.mark.parametrize("metric", ["l2", "mips", "cosine"])
def test_gather_distance_int8_hbm_matches_ref_bitexact(n, d, q, c, metric):
    """The int8 streaming kernel shares the VMEM kernel's oracle: the
    int32 accumulation is order-free and every f32 op is elementwise in
    matching order, so ``gather_distance_int8_ref`` is bit-exact for
    BOTH kernels."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import gather_distance_int8_hbm

    rng = np.random.default_rng(hash((n, d, q, c, metric, 79)) % 2**31)
    x32, x8, scl = _quantized(rng, n, d)
    qs = jnp.asarray(rng.standard_normal((q, d)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, n, (q, c)), dtype=jnp.int32)
    norms = point_norms(x32, metric)          # EXACT, pre-quantization
    qn = point_norms(qs, metric)
    got = gather_distance_int8_hbm(x8, scl, norms, qs, qn, ids,
                                   metric=metric, interpret=INTERP)
    want = ref.gather_distance_int8_ref(x8, scl, norms, qs, qn, ids,
                                        metric=metric)
    g = np.asarray(got)
    assert (np.isinf(g) == (np.asarray(ids) < 0)).all()
    np.testing.assert_array_equal(g, np.asarray(want))


def test_gather_distance_int8_hbm_rejects_float_points():
    from repro.kernels.gather_distance import gather_distance_int8_hbm

    x = jnp.zeros((16, 8), jnp.float32)
    aux = jnp.zeros((16,), jnp.float32)
    qs = jnp.zeros((2, 8), jnp.float32)
    ids = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(TypeError):
        gather_distance_int8_hbm(x, aux, aux, qs,
                                 jnp.zeros((2,), jnp.float32), ids,
                                 interpret=INTERP)


def test_gather_distance_hbm_beyond_vmem_budget():
    """The whole point of the streaming path: a points block the VMEM
    budget rejects still serves bit-exactly through the HBM kernel."""
    from repro.core.metrics import point_norms
    from repro.kernels.gather_distance import fits_vmem, gather_distance_hbm

    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((2048, 32)), dtype=jnp.float32)
    budget = 64 * 1024                        # 256 KB block >> 64 KB budget
    assert not fits_vmem(x, budget=budget)
    qs = jnp.asarray(rng.standard_normal((4, 32)), dtype=jnp.float32)
    ids = jnp.asarray(rng.integers(-1, 2048, (4, 24)), dtype=jnp.int32)
    norms = point_norms(x, "l2")
    got = gather_distance_hbm(x, norms, qs, ids, metric="l2",
                              interpret=INTERP)
    want = ref.gather_distance_hbm_ref(x, norms, qs, ids, metric="l2")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vmem_points_budget_env_override(monkeypatch):
    """`PIPNN_VMEM_POINTS_BUDGET` reconfigures the budget every
    ``fits_vmem`` call reads; an explicit ``budget=`` beats the env."""
    from repro.kernels.gather_distance import fits_vmem, vmem_points_budget

    x = jnp.zeros((1000, 32), jnp.float32)    # 128 KB
    assert fits_vmem(x)                       # default 8 MiB
    monkeypatch.setenv("PIPNN_VMEM_POINTS_BUDGET", str(64 * 1024))
    assert vmem_points_budget() == 64 * 1024
    assert not fits_vmem(x)
    assert fits_vmem(x, budget=1 << 23)       # explicit beats env
    monkeypatch.delenv("PIPNN_VMEM_POINTS_BUDGET")
    assert fits_vmem(x)


# ----------------------------------------------- kernel-powered PiPNN build ---

def test_full_build_with_flashknn_matches_jax_path():
    """The fused kernel must produce the same index as the pure-JAX path."""
    from repro.core import pipnn
    from repro.core.leaf import LeafParams
    from repro.core.pipnn import PiPNNParams
    from repro.core.rbc import RBCParams

    rng = np.random.default_rng(5)
    x = rng.standard_normal((1500, 16)).astype(np.float32)
    params = PiPNNParams(
        rbc=RBCParams(c_max=128, c_min=16, fanout=(3,)),
        leaf=LeafParams(k=2, leaf_chunk=4),
        l_max=32, max_deg=16, seed=1,
    )
    i_jax = pipnn.build(x, params)
    i_krn = pipnn.build(x, params, knn_fn=ops.make_knn_fn(2, "l2", INTERP))
    np.testing.assert_array_equal(i_jax.graph, i_krn.graph)
